//! "Vendor" RNG backends (DESIGN.md S5).
//!
//! Each backend reproduces a native library's three observable properties
//! (see the substitution table in DESIGN.md §1):
//!
//! 1. **API shape** — cuRAND/hipRAND expose create/seed/generate/destroy
//!    with fixed output types, no output range, no seed initializer lists,
//!    and ICDF methods only for quasirandom engines. The oneMKL-native
//!    backends expose the full 36-entry surface.
//! 2. **Numerics** — bit-exact engines ([`crate::rng::engines`]).
//! 3. **Runtime cost structure** — via the platform perf models and the
//!    [`NativeTimeline`] used by the native (non-SYCL) application paths.
//!
//! [`PjrtBackend`] is the real-compute path: it executes the AOT-compiled
//! Pallas Philox kernel through PJRT.

mod curand_sim;
mod hiprand_sim;
mod mkl_cpu;
mod native_app;
mod onemkl_intel;
mod pjrt;
mod vendor;

pub use curand_sim::{
    curand_create_generator, curand_destroy_generator, curand_generate_normal,
    curand_generate_uniform, curand_set_generator_offset,
    curand_set_pseudo_random_generator_seed, CurandBackend, CurandGenerator, CurandStatus,
};
pub use hiprand_sim::{HiprandBackend, HiprandStatus};
pub use mkl_cpu::MklCpuBackend;
pub use native_app::NativeTimeline;
pub use onemkl_intel::OneMklIntelGpuBackend;
pub use pjrt::PjrtBackend;
pub use vendor::VendorGeneratorImpl;

use crate::error::Result;
use crate::platform::PlatformId;
use crate::rng::engines::{Engine, EngineKind};
use crate::rng::Distribution;

/// A live generator handle, mirroring `curandGenerator_t` lifecycle.
///
/// NOTE: not `Send` — the PJRT client underneath the real-compute backend
/// is `Rc`-based, so generator handles stay on the thread that created
/// them (the coordinator gives each worker thread its own backend set).
pub trait VendorGenerator {
    /// Owning backend's name.
    fn backend_name(&self) -> &'static str;

    /// Engine family behind the handle.
    fn engine_kind(&self) -> EngineKind;

    /// `curandSetPseudoRandomGeneratorSeed` — resets the stream.
    fn set_seed(&mut self, seed: u64) -> Result<()>;

    /// `curandSetGeneratorOffset` — skip-ahead in raw draws.
    fn set_offset(&mut self, offset: u64) -> Result<()>;

    /// Whether ICDF generation methods are available on this handle.
    fn supports_icdf(&self) -> bool;

    /// Generate the *canonical* sequence for the distribution family:
    /// `[0,1)` for uniform, `N(0,1)` for gaussian/lognormal (pre-exp),
    /// raw bits for `Bits`. Range/mean/std application is the oneMKL
    /// layer's transform kernel, NOT the vendor's job (paper §4.1).
    fn generate_canonical(&mut self, distr: &Distribution, out: &mut [f32]) -> Result<()>;

    /// Fork an independent copy of the underlying engine positioned at
    /// absolute raw-draw offset `offset` — the tiled executor's source of
    /// per-tile sub-streams ([`crate::rng::generate_batch_usm_tiled`]).
    /// `None` when the engine cannot seek absolutely in place (the caller
    /// falls back to the serial flush path) or the handle is destroyed.
    fn fork_engine_at(&self, _offset: u64) -> Option<Box<dyn Engine>> {
        None
    }

    /// `curandDestroyGenerator`. Further use errors.
    fn destroy(&mut self) -> Result<()>;

    /// Whether the handle has been destroyed.
    fn is_destroyed(&self) -> bool;
}

/// A vendor RNG library bound to a platform. Not `Send`/`Sync` — see
/// [`VendorGenerator`]; per-thread instances are cheap to construct.
pub trait RngBackend {
    /// Library name ("cuRAND", "hipRAND", "oneMKL-x86", ...).
    fn name(&self) -> &'static str;

    /// The platform this backend's kernels run on.
    fn platform(&self) -> PlatformId;

    /// Whether generation happens on a device (vs host).
    fn is_device(&self) -> bool;

    /// Feature matrix: does (engine, distribution) work here?
    fn supports(&self, engine: EngineKind, distr: &Distribution) -> bool;

    /// `curandCreateGenerator` + seed.
    fn create_generator(&self, engine: EngineKind, seed: u64)
        -> Result<Box<dyn VendorGenerator>>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::engines::PhiloxEngine;
    use crate::rng::Engine;

    /// All vendor backends must agree bit-exactly with the raw engine on
    /// the canonical uniform stream (the interop promise: the *native*
    /// library does the work, the wrapper adds nothing numerically).
    #[test]
    fn canonical_uniform_parity_across_backends() {
        let backends: Vec<Box<dyn RngBackend>> = vec![
            Box::new(CurandBackend::new()),
            Box::new(HiprandBackend::new()),
            Box::new(MklCpuBackend::new(PlatformId::Rome7742)),
            Box::new(OneMklIntelGpuBackend::new()),
        ];
        let mut reference = vec![0f32; 1000];
        PhiloxEngine::new(42).fill_uniform_f32(&mut reference);

        for b in &backends {
            let mut gen = b.create_generator(EngineKind::Philox4x32x10, 42).unwrap();
            let mut out = vec![0f32; 1000];
            gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out)
                .unwrap();
            assert_eq!(out, reference, "backend {}", b.name());
        }
    }

    #[test]
    fn icdf_support_matrix_matches_paper() {
        // cuRAND/hipRAND: ICDF only for quasirandom; oneMKL natives: all.
        let cur = CurandBackend::new();
        let icdf = Distribution::Gaussian {
            mean: 0.0,
            stddev: 1.0,
            method: crate::rng::GaussianMethod::Icdf,
        };
        assert!(!cur.supports(EngineKind::Philox4x32x10, &icdf));
        assert!(cur.supports(EngineKind::Sobol32, &icdf));
        let mkl = MklCpuBackend::new(PlatformId::CoreI7_10875H);
        assert!(mkl.supports(EngineKind::Philox4x32x10, &icdf));
    }

    #[test]
    fn destroyed_generator_errors() {
        let b = CurandBackend::new();
        let mut gen = b.create_generator(EngineKind::Philox4x32x10, 1).unwrap();
        gen.destroy().unwrap();
        assert!(gen.is_destroyed());
        let mut out = vec![0f32; 4];
        assert!(gen
            .generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out)
            .is_err());
        assert!(gen.destroy().is_err());
        assert!(gen.set_seed(2).is_err());
    }

    #[test]
    fn set_offset_equals_engine_skip() {
        let b = HiprandBackend::new();
        let mut gen = b.create_generator(EngineKind::Philox4x32x10, 7).unwrap();
        gen.set_offset(12_345).unwrap();
        let mut out = vec![0f32; 64];
        gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out).unwrap();

        let mut e = PhiloxEngine::new(7);
        e.skip_ahead(12_345);
        let mut want = vec![0f32; 64];
        e.fill_uniform_f32(&mut want);
        assert_eq!(out, want);
    }
}
