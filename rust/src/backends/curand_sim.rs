//! cuRAND-shaped backend (NVIDIA A100).
//!
//! Exposes the exact host-API surface the paper wraps (§4.2 workflow):
//! `curandCreateGenerator` -> `curandSetPseudoRandomGeneratorSeed` ->
//! `curandGenerateUniform`/`curandGenerateNormal` ->
//! `curandDestroyGenerator`, with `curandStatus_t`-style return codes. The
//! oneMKL interop kernel (Listing 1.1) calls these from inside a SYCL host
//! task; the native burner calls them directly.

use crate::error::{Error, Result};
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;

use super::vendor::{vendor_supports, VendorGeneratorImpl};
use super::{RngBackend, VendorGenerator};

/// `curandStatus_t` analogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CurandStatus {
    /// CURAND_STATUS_SUCCESS
    Success,
    /// CURAND_STATUS_NOT_INITIALIZED (destroyed / invalid handle)
    NotInitialized,
    /// CURAND_STATUS_TYPE_ERROR (unsupported generation request)
    TypeError,
}

/// `curandGenerator_t` analogue.
pub struct CurandGenerator(VendorGeneratorImpl);

/// `curandCreateGenerator`.
pub fn curand_create_generator(kind: EngineKind) -> CurandGenerator {
    CurandGenerator(VendorGeneratorImpl::new("cuRAND", kind, 0, false))
}

/// `curandSetPseudoRandomGeneratorSeed`.
pub fn curand_set_pseudo_random_generator_seed(
    gen: &mut CurandGenerator,
    seed: u64,
) -> CurandStatus {
    match gen.0.set_seed(seed) {
        Ok(()) => CurandStatus::Success,
        Err(_) => CurandStatus::NotInitialized,
    }
}

/// `curandSetGeneratorOffset`.
pub fn curand_set_generator_offset(gen: &mut CurandGenerator, offset: u64) -> CurandStatus {
    match gen.0.set_offset(offset) {
        Ok(()) => CurandStatus::Success,
        Err(_) => CurandStatus::NotInitialized,
    }
}

/// `curandGenerateUniform`: fixed type, fixed [0,1) range — "there is no
/// concept of a 'range'; it is left to the user to post-process" (§4.1).
pub fn curand_generate_uniform(gen: &mut CurandGenerator, out: &mut [f32]) -> CurandStatus {
    match gen.0.generate_canonical(&Distribution::uniform(0.0, 1.0), out) {
        Ok(()) => CurandStatus::Success,
        Err(Error::Unsupported { .. }) => CurandStatus::TypeError,
        Err(_) => CurandStatus::NotInitialized,
    }
}

/// `curandGenerateNormal` (mean/std applied in-library, as cuRAND does for
/// normals — unlike uniforms).
pub fn curand_generate_normal(
    gen: &mut CurandGenerator,
    out: &mut [f32],
    mean: f32,
    stddev: f32,
) -> CurandStatus {
    match gen.0.generate_canonical(&Distribution::gaussian(0.0, 1.0), out) {
        Ok(()) => {
            crate::rng::range_transform::scale_gaussian_inplace(out, mean, stddev);
            CurandStatus::Success
        }
        Err(Error::Unsupported { .. }) => CurandStatus::TypeError,
        Err(_) => CurandStatus::NotInitialized,
    }
}

/// `curandDestroyGenerator`.
pub fn curand_destroy_generator(gen: &mut CurandGenerator) -> CurandStatus {
    match gen.0.destroy() {
        Ok(()) => CurandStatus::Success,
        Err(_) => CurandStatus::NotInitialized,
    }
}

/// The cuRAND library as an [`RngBackend`].
pub struct CurandBackend;

impl CurandBackend {
    /// cuRAND on the A100.
    pub fn new() -> Self {
        CurandBackend
    }
}

impl Default for CurandBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl RngBackend for CurandBackend {
    fn name(&self) -> &'static str {
        "cuRAND"
    }

    fn platform(&self) -> PlatformId {
        PlatformId::A100
    }

    fn is_device(&self) -> bool {
        true
    }

    fn supports(&self, engine: EngineKind, distr: &Distribution) -> bool {
        vendor_supports(engine, distr)
    }

    fn create_generator(
        &self,
        engine: EngineKind,
        seed: u64,
    ) -> Result<Box<dyn VendorGenerator>> {
        let mut g = VendorGeneratorImpl::new("cuRAND", engine, seed, false);
        g.set_seed(seed)?;
        Ok(Box::new(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::engines::PhiloxEngine;
    use crate::rng::Engine;

    #[test]
    fn curand_flow_matches_paper_workflow() {
        // §4.2: create -> set options -> generate -> destroy.
        let mut gen = curand_create_generator(EngineKind::Philox4x32x10);
        assert_eq!(curand_set_pseudo_random_generator_seed(&mut gen, 99), CurandStatus::Success);
        let mut out = vec![0f32; 128];
        assert_eq!(curand_generate_uniform(&mut gen, &mut out), CurandStatus::Success);
        let mut want = vec![0f32; 128];
        PhiloxEngine::new(99).fill_uniform_f32(&mut want);
        assert_eq!(out, want);
        assert_eq!(curand_destroy_generator(&mut gen), CurandStatus::Success);
        assert_eq!(curand_generate_uniform(&mut gen, &mut out), CurandStatus::NotInitialized);
    }

    #[test]
    fn normal_applies_mean_std() {
        let mut gen = curand_create_generator(EngineKind::Philox4x32x10);
        curand_set_pseudo_random_generator_seed(&mut gen, 5);
        let mut out = vec![0f32; 100_000];
        assert_eq!(curand_generate_normal(&mut gen, &mut out, 10.0, 2.0), CurandStatus::Success);
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
    }
}
