//! Virtual timeline for the *native* (non-SYCL) benchmark applications.
//!
//! The paper's baselines are plain CUDA / HIP / C++ programs: no runtime
//! DAG, no accessors — just sequential API calls, each paying the
//! platform's launch latency and the native runtime's completion-callback
//! cost. This struct is their clock; it records the same
//! [`CommandClass`]-tagged spans as the SYCL queue so Fig. 4 can compare
//! per-kernel durations across both.

use crate::platform::{jitter_from, CommandCost, PerfModel, PlatformId, TransferDir};
use crate::sycl::{CommandClass, CommandRecord};

/// Sequential virtual clock of a native application.
pub struct NativeTimeline {
    model: PerfModel,
    now_ns: u64,
    records: Vec<CommandRecord>,
    salt: u64,
    next_id: u64,
}

impl NativeTimeline {
    /// New timeline on `platform`.
    pub fn new(platform: PlatformId) -> Self {
        NativeTimeline {
            model: PerfModel::new(platform.spec()),
            now_ns: 0,
            records: Vec::new(),
            salt: 0,
            next_id: 0,
        }
    }

    /// Deterministic-noise salt (one per measurement iteration).
    pub fn set_noise_salt(&mut self, salt: u64) {
        self.salt = salt;
    }

    /// The platform's performance model.
    pub fn model(&self) -> &PerfModel {
        &self.model
    }

    fn push(
        &mut self,
        name: &str,
        class: CommandClass,
        exec_ns: u64,
        tpb: Option<u32>,
        items: u64,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let exec_ns =
            (exec_ns as f64 * jitter_from("native-cmd", self.salt, id, exec_ns)) as u64;
        let start = self.now_ns;
        let end = start + exec_ns;
        self.now_ns = end;
        self.records.push(CommandRecord {
            id,
            name: name.to_string(),
            class,
            dep_ids: if id == 0 { vec![] } else { vec![id - 1] },
            virt_start_ns: start,
            virt_end_ns: end,
            wall_ns: 0,
            tpb,
            occupancy: tpb.map(|t| {
                crate::platform::occupancy(items, t, self.model.spec()).achieved
            }),
        });
        exec_ns
    }

    /// `curandCreateGenerator` + seed call.
    pub fn create_generator(&mut self) {
        let ns = self.model.execution_ns(&CommandCost::GeneratorSetup);
        self.push("create_generator", CommandClass::Setup, ns, None, 0);
    }

    /// `{cuda,hip}Malloc`.
    pub fn malloc(&mut self) {
        let ns = self.model.execution_ns(&CommandCost::Malloc);
        self.push("malloc", CommandClass::Malloc, ns, None, 0);
    }

    /// A device kernel at the native app's hardcoded thread-block size,
    /// followed by the native runtime's completion callback.
    pub fn kernel(&mut self, name: &str, class: CommandClass, cost: CommandCost) {
        let tpb = match cost {
            CommandCost::Kernel { tpb, .. } if tpb != 0 => tpb,
            _ => self.model.spec().native_tpb,
        };
        let (cost, items) = match cost {
            CommandCost::Kernel { bytes_read, bytes_written, items, .. } => {
                (CommandCost::Kernel { bytes_read, bytes_written, items, tpb }, items)
            }
            c => (c, 0),
        };
        let ns = self.model.execution_ns(&cost);
        self.push(name, class, ns, Some(tpb), items);
        // Stream-callback / synchronize cost the native app pays per kernel
        // (cudaDeviceSynchronize in Listing 1.1's native counterpart).
        let cb = self.model.native_callback_ns();
        self.push("callback", CommandClass::Other, cb, None, 0);
    }

    /// A device kernel launched asynchronously (no per-kernel callback) —
    /// pipelined applications like the CUDA FastCaloSim port launch many
    /// kernels per event and synchronize once at event end via
    /// [`Self::sync`].
    pub fn kernel_async(&mut self, name: &str, class: CommandClass, cost: CommandCost) {
        let tpb = match cost {
            CommandCost::Kernel { tpb, .. } if tpb != 0 => tpb,
            _ => self.model.spec().native_tpb,
        };
        let (cost, items) = match cost {
            CommandCost::Kernel { bytes_read, bytes_written, items, .. } => {
                (CommandCost::Kernel { bytes_read, bytes_written, items, tpb }, items)
            }
            c => (c, 0),
        };
        let ns = self.model.execution_ns(&cost);
        self.push(name, class, ns, Some(tpb), items);
    }

    /// Stream synchronize (one completion callback).
    pub fn sync(&mut self) {
        let cb = self.model.native_callback_ns();
        self.push("sync", CommandClass::Other, cb, None, 0);
    }

    /// Host<->device copy.
    pub fn transfer(&mut self, bytes: u64, dir: TransferDir) {
        let ns = self.model.transfer_ns(bytes);
        let class = match dir {
            TransferDir::H2D => CommandClass::TransferH2D,
            TransferDir::D2H => CommandClass::TransferD2H,
        };
        self.push(
            if class == CommandClass::TransferH2D { "h2d" } else { "d2h" },
            class,
            ns,
            None,
            0,
        );
    }

    /// Host-side work of known duration.
    pub fn host(&mut self, name: &str, ns: u64) {
        self.push(name, CommandClass::Other, ns, None, 0);
    }

    /// Total virtual elapsed time.
    pub fn total_ns(&self) -> u64 {
        self.now_ns
    }

    /// Recorded spans.
    pub fn records(&self) -> &[CommandRecord] {
        &self.records
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strictly_sequential() {
        let mut t = NativeTimeline::new(PlatformId::A100);
        t.create_generator();
        t.malloc();
        t.kernel(
            "generate",
            CommandClass::Generate,
            CommandCost::Kernel { bytes_read: 0, bytes_written: 4 << 20, items: 1 << 20, tpb: 0 },
        );
        t.transfer(4 << 20, TransferDir::D2H);
        let r = t.records();
        for w in r.windows(2) {
            assert!(w[1].virt_start_ns >= w[0].virt_end_ns);
        }
        assert_eq!(t.total_ns(), r.last().unwrap().virt_end_ns);
    }

    #[test]
    fn kernels_pay_native_callback() {
        let mut a = NativeTimeline::new(PlatformId::A100);
        a.kernel(
            "k",
            CommandClass::Generate,
            CommandCost::Kernel { bytes_read: 0, bytes_written: 4096, items: 1024, tpb: 0 },
        );
        // generate + callback spans recorded.
        assert_eq!(a.records().len(), 2);
        assert!(a.records()[1].virt_end_ns - a.records()[1].virt_start_ns > 0);
    }

    #[test]
    fn native_tpb_is_256_on_gpus() {
        let mut t = NativeTimeline::new(PlatformId::A100);
        t.kernel(
            "k",
            CommandClass::Generate,
            CommandCost::Kernel { bytes_read: 0, bytes_written: 4096, items: 1024, tpb: 0 },
        );
        assert_eq!(t.records()[0].tpb, Some(256));
    }
}
