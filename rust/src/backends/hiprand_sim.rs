//! hipRAND-shaped backend (Radeon RX Vega 56, ROCm).
//!
//! Same API shape as cuRAND (AMD tracks it deliberately); what differs is
//! the *runtime* behaviour captured by the platform model: the ROCm
//! dispatch path is "nearly callback-free" (paper §7), which is why the
//! hipSYCL buffer path can beat the native app at small batch sizes.

use crate::error::Result;
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;

use super::vendor::{vendor_supports, VendorGeneratorImpl};
use super::{RngBackend, VendorGenerator};

/// `hiprandStatus_t` analogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HiprandStatus {
    /// HIPRAND_STATUS_SUCCESS
    Success,
    /// HIPRAND_STATUS_NOT_INITIALIZED
    NotInitialized,
    /// HIPRAND_STATUS_TYPE_ERROR
    TypeError,
}

/// The hipRAND library as an [`RngBackend`].
pub struct HiprandBackend;

impl HiprandBackend {
    /// hipRAND on the Vega 56.
    pub fn new() -> Self {
        HiprandBackend
    }
}

impl Default for HiprandBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl RngBackend for HiprandBackend {
    fn name(&self) -> &'static str {
        "hipRAND"
    }

    fn platform(&self) -> PlatformId {
        PlatformId::Vega56
    }

    fn is_device(&self) -> bool {
        true
    }

    fn supports(&self, engine: EngineKind, distr: &Distribution) -> bool {
        vendor_supports(engine, distr)
    }

    fn create_generator(
        &self,
        engine: EngineKind,
        seed: u64,
    ) -> Result<Box<dyn VendorGenerator>> {
        let mut g = VendorGeneratorImpl::new("hipRAND", engine, seed, false);
        g.set_seed(seed)?;
        Ok(Box::new(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::CurandBackend;

    #[test]
    fn hiprand_and_curand_same_numerics() {
        // The two vendor streams must agree: both are Philox4x32x10.
        let hip = HiprandBackend::new();
        let cur = CurandBackend::new();
        let mut a = hip.create_generator(EngineKind::Philox4x32x10, 7).unwrap();
        let mut b = cur.create_generator(EngineKind::Philox4x32x10, 7).unwrap();
        let (mut xa, mut xb) = (vec![0f32; 256], vec![0f32; 256]);
        let d = Distribution::uniform(0.0, 1.0);
        a.generate_canonical(&d, &mut xa).unwrap();
        b.generate_canonical(&d, &mut xb).unwrap();
        assert_eq!(xa, xb);
    }

    #[test]
    fn hiprand_platform_is_vega() {
        assert_eq!(HiprandBackend::new().platform(), PlatformId::Vega56);
        assert!(HiprandBackend::new().is_device());
    }
}
