//! oneMKL native Intel-GPU backend (UHD Graphics 630).
//!
//! The other pre-existing oneMKL backend in the paper (§2.2: "RNG
//! interfaces which wrap the optimized Intel routines targeting x86
//! architectures and Intel GPUs"). UMA zero-copy applies on this platform.

use crate::error::Result;
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;

use super::vendor::VendorGeneratorImpl;
use super::{RngBackend, VendorGenerator};

/// oneMKL's Intel-GPU RNG routines.
pub struct OneMklIntelGpuBackend;

impl OneMklIntelGpuBackend {
    /// oneMKL on the UHD 630 iGPU.
    pub fn new() -> Self {
        OneMklIntelGpuBackend
    }
}

impl Default for OneMklIntelGpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl RngBackend for OneMklIntelGpuBackend {
    fn name(&self) -> &'static str {
        "oneMKL-iGPU"
    }

    fn platform(&self) -> PlatformId {
        PlatformId::Uhd630
    }

    fn is_device(&self) -> bool {
        true
    }

    fn supports(&self, _engine: EngineKind, _distr: &Distribution) -> bool {
        true
    }

    fn create_generator(
        &self,
        engine: EngineKind,
        seed: u64,
    ) -> Result<Box<dyn VendorGenerator>> {
        Ok(Box::new(VendorGeneratorImpl::new("oneMKL-iGPU", engine, seed, true)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn igpu_is_uma_device() {
        let b = OneMklIntelGpuBackend::new();
        assert!(b.is_device());
        assert!(b.platform().spec().uma);
    }
}
