//! oneMKL native x86 backend (the paper's baseline on Rome 7742, Core
//! i7-10875H and Xeon Gold 5220). Full 36-entry API surface: ICDF methods,
//! copy-construction and seed initializer lists all work here — the
//! asymmetries the cuRAND/hipRAND backends carry do not apply.

use crate::error::Result;
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;

use super::vendor::VendorGeneratorImpl;
use super::{RngBackend, VendorGenerator};

/// oneMKL's optimized x86 RNG routines.
pub struct MklCpuBackend {
    platform: PlatformId,
}

impl MklCpuBackend {
    /// oneMKL on a specific CPU platform.
    pub fn new(platform: PlatformId) -> Self {
        debug_assert!(matches!(
            platform,
            PlatformId::Rome7742 | PlatformId::CoreI7_10875H | PlatformId::XeonGold5220
        ));
        MklCpuBackend { platform }
    }
}

impl RngBackend for MklCpuBackend {
    fn name(&self) -> &'static str {
        "oneMKL-x86"
    }

    fn platform(&self) -> PlatformId {
        self.platform
    }

    fn is_device(&self) -> bool {
        false
    }

    fn supports(&self, _engine: EngineKind, _distr: &Distribution) -> bool {
        true // full API surface
    }

    fn create_generator(
        &self,
        engine: EngineKind,
        seed: u64,
    ) -> Result<Box<dyn VendorGenerator>> {
        Ok(Box::new(VendorGeneratorImpl::new("oneMKL-x86", engine, seed, true)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianMethod;

    #[test]
    fn full_surface_includes_icdf_and_exponential() {
        let b = MklCpuBackend::new(PlatformId::Rome7742);
        let mut gen = b.create_generator(EngineKind::Mrg32k3a, 3).unwrap();
        let mut out = vec![0f32; 1000];
        gen.generate_canonical(
            &Distribution::Gaussian { mean: 0.0, stddev: 1.0, method: GaussianMethod::Icdf },
            &mut out,
        )
        .unwrap();
        gen.generate_canonical(&Distribution::Exponential { lambda: 1.0 }, &mut out)
            .unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
