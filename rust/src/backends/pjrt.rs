//! PJRT backend: the real-compute device path.
//!
//! Executes the AOT-compiled Pallas Philox kernel (fused generate +
//! convert + range-transform) through the XLA PJRT CPU client. Arbitrary
//! batch sizes are served by the artifact ladder (smallest compiled size
//! >= n, truncated), with the counter offset advanced so successive calls
//! remain stream-exact with the Rust/Python Philox implementations.

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;
use crate::runtime::PjrtRuntime;

use super::{RngBackend, VendorGenerator};

/// Backend executing the Pallas-kernel artifacts.
pub struct PjrtBackend {
    runtime: Arc<PjrtRuntime>,
    /// (size, artifact-name) ladder, ascending.
    ladder: Vec<(usize, String)>,
}

impl PjrtBackend {
    /// Wrap a PJRT runtime.
    pub fn new(runtime: Arc<PjrtRuntime>) -> Result<Self> {
        let ladder = runtime.manifest().burner_sizes();
        if ladder.is_empty() {
            return Err(Error::Artifact("no burner_uniform_* artifacts in manifest".into()));
        }
        Ok(PjrtBackend { runtime, ladder })
    }

    /// The artifact (name, size) used for a batch of `n`.
    pub fn artifact_for(&self, n: usize) -> Result<(usize, &str)> {
        self.ladder
            .iter()
            .find(|(size, _)| *size >= n)
            .map(|(size, name)| (*size, name.as_str()))
            .ok_or_else(|| {
                Error::InvalidArgument(format!(
                    "batch {n} exceeds the largest compiled artifact ({}); \
                     add a size to python/compile/model.ARTIFACTS",
                    self.ladder.last().map(|(s, _)| *s).unwrap_or(0)
                ))
            })
    }

    /// The shared runtime.
    pub fn runtime(&self) -> &Arc<PjrtRuntime> {
        &self.runtime
    }
}

impl RngBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pallas-pjrt"
    }

    fn platform(&self) -> PlatformId {
        // The real-compute path models the device the artifacts were tuned
        // for; the A100 is the paper's flagship comparison.
        PlatformId::A100
    }

    fn is_device(&self) -> bool {
        true
    }

    fn supports(&self, engine: EngineKind, distr: &Distribution) -> bool {
        engine == EngineKind::Philox4x32x10
            && matches!(distr, Distribution::Uniform { .. } | Distribution::Gaussian { .. })
    }

    fn create_generator(
        &self,
        engine: EngineKind,
        seed: u64,
    ) -> Result<Box<dyn VendorGenerator>> {
        if engine != EngineKind::Philox4x32x10 {
            return Err(Error::unsupported(
                "pallas-pjrt",
                format!("{} (only philox4x32x10 is compiled)", engine.name()),
            ));
        }
        Ok(Box::new(PjrtGenerator {
            backend: PjrtBackend {
                runtime: self.runtime.clone(),
                ladder: self.ladder.clone(),
            },
            state: Mutex::new(GenState { seed, block_offset: 0, destroyed: false }),
        }))
    }
}

struct GenState {
    seed: u64,
    /// 64-bit Philox counter-block offset for the next call.
    block_offset: u64,
    destroyed: bool,
}

/// Generator handle over the PJRT artifacts.
pub struct PjrtGenerator {
    backend: PjrtBackend,
    state: Mutex<GenState>,
}

impl PjrtGenerator {
    fn key_off(state: &GenState) -> ([u32; 2], [u32; 2]) {
        (
            [state.seed as u32, (state.seed >> 32) as u32],
            [state.block_offset as u32, (state.block_offset >> 32) as u32],
        )
    }
}

impl VendorGenerator for PjrtGenerator {
    fn backend_name(&self) -> &'static str {
        "pallas-pjrt"
    }

    fn engine_kind(&self) -> EngineKind {
        EngineKind::Philox4x32x10
    }

    fn set_seed(&mut self, seed: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.destroyed {
            return Err(Error::Sycl("pallas-pjrt: destroyed handle".into()));
        }
        st.seed = seed;
        st.block_offset = 0;
        Ok(())
    }

    fn set_offset(&mut self, offset: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.destroyed {
            return Err(Error::Sycl("pallas-pjrt: destroyed handle".into()));
        }
        if offset % 4 != 0 {
            return Err(Error::InvalidArgument(
                "pjrt offset must be a multiple of 4 (counter-block granularity)".into(),
            ));
        }
        st.block_offset = offset / 4;
        Ok(())
    }

    fn supports_icdf(&self) -> bool {
        false
    }

    fn generate_canonical(&mut self, distr: &Distribution, out: &mut [f32]) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.destroyed {
            return Err(Error::Sycl("pallas-pjrt: destroyed handle".into()));
        }
        let n = out.len();
        let (padded, artifact) = self.backend.artifact_for(n)?;
        let (key, off) = Self::key_off(&st);
        let full = match distr {
            Distribution::Uniform { .. } => {
                // Fused kernel emits the canonical [0,1): the range is
                // applied by the oneMKL transform stage (or fused in the
                // `burner` fast path which passes (a,b) directly).
                self.backend.runtime.run_burner(artifact, key, off, 0.0, 1.0)?
            }
            Distribution::Gaussian { .. } => {
                let gname = format!("burner_gaussian_{padded}");
                let gname = if self.backend.runtime.manifest().artifacts.contains_key(&gname) {
                    gname
                } else {
                    "burner_gaussian_65536".to_string()
                };
                let gspec = self.backend.runtime.spec(&gname)?;
                if gspec.outputs[0].elements() < n {
                    return Err(Error::InvalidArgument(format!(
                        "gaussian batch {n} exceeds compiled artifact {gname}"
                    )));
                }
                self.backend.runtime.run_burner(&gname, key, off, 0.0, 1.0)?
            }
            other => {
                return Err(Error::unsupported(
                    "pallas-pjrt",
                    format!("{} (not compiled)", other.name()),
                ))
            }
        };
        out.copy_from_slice(&full[..n]);
        // Advance by the padded counter consumption to stay block-aligned.
        st.block_offset += (padded as u64) / 4;
        Ok(())
    }

    fn destroy(&mut self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.destroyed {
            return Err(Error::Sycl("pallas-pjrt: double destroy".into()));
        }
        st.destroyed = true;
        Ok(())
    }

    fn is_destroyed(&self) -> bool {
        self.state.lock().unwrap().destroyed
    }
}
