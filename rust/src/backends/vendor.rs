//! Shared machinery for the cuRAND/hipRAND-shaped vendor libraries.

use crate::error::{Error, Result};
use crate::rng::distributions::box_muller_pair;
use crate::rng::engines::{Engine, EngineKind};
use crate::rng::{Distribution, GaussianMethod};

use super::VendorGenerator;

/// Concrete generator used by both cuRAND-sim and hipRAND-sim (and, with
/// `full_api = true`, by the oneMKL-native backends).
pub struct VendorGeneratorImpl {
    backend: &'static str,
    engine: Box<dyn Engine>,
    seed: u64,
    /// Full oneMKL feature surface (ICDF for pseudorandom engines,
    /// exponential/poisson natively).
    full_api: bool,
    destroyed: bool,
    /// Reusable uniform scratch for the gaussian/lognormal paths: sized on
    /// first use, amortized to zero allocations on the steady-state
    /// serving path (a flush used to heap-allocate per member here).
    scratch: Vec<f32>,
}

impl VendorGeneratorImpl {
    /// Create a live handle.
    pub fn new(backend: &'static str, kind: EngineKind, seed: u64, full_api: bool) -> Self {
        VendorGeneratorImpl {
            backend,
            engine: kind.create(seed),
            seed,
            full_api,
            destroyed: false,
            scratch: Vec::new(),
        }
    }

    fn check_live(&self) -> Result<()> {
        if self.destroyed {
            Err(Error::Sycl(format!(
                "{}: use of destroyed generator handle",
                self.backend
            )))
        } else {
            Ok(())
        }
    }
}

impl VendorGenerator for VendorGeneratorImpl {
    fn backend_name(&self) -> &'static str {
        self.backend
    }

    fn engine_kind(&self) -> EngineKind {
        self.engine.kind()
    }

    fn set_seed(&mut self, seed: u64) -> Result<()> {
        self.check_live()?;
        self.seed = seed;
        self.engine = self.engine.kind().create(seed);
        Ok(())
    }

    fn set_offset(&mut self, offset: u64) -> Result<()> {
        self.check_live()?;
        // Offset is absolute. Seek-capable engines reposition in place
        // (O(1) Philox, O(log n) MRG32k3a) — the batched serving path
        // calls this once per member per flush, and recreating the engine
        // box every time was a measurable per-member allocation. Engines
        // without an absolute seek fall back to recreate + skip.
        if !self.engine.try_seek(offset) {
            self.engine = self.engine.kind().create(self.seed);
            self.engine.skip_ahead(offset);
        }
        Ok(())
    }

    fn supports_icdf(&self) -> bool {
        self.full_api || self.engine.kind().is_quasi()
    }

    fn generate_canonical(&mut self, distr: &Distribution, out: &mut [f32]) -> Result<()> {
        self.check_live()?;
        // The resilience layer's vendor-call fault seam: a thread-level
        // chaos plan can refuse this generation op (modelling e.g. a
        // curandGenerate* status error). Inert without a plan.
        crate::fault::trip(crate::fault::FaultSite::Generate)?;
        match distr {
            Distribution::Uniform { .. } => {
                self.engine.fill_uniform_f32(out);
                Ok(())
            }
            Distribution::Gaussian { method, .. } | Distribution::Lognormal { method, .. } => {
                if *method == GaussianMethod::Icdf && !self.supports_icdf() {
                    return Err(Error::unsupported(
                        self.backend,
                        "ICDF gaussian methods (pseudorandom engines)",
                    ));
                }
                // Canonical N(0,1): mean/std/exp applied by the oneMKL
                // transform kernel. The uniform draws land in the
                // handle-owned scratch (grown monotonically, reused across
                // calls) instead of a fresh per-call allocation.
                let n = out.len();
                let n_u = n + (n & 1);
                if self.scratch.len() < n_u {
                    self.scratch.resize(n_u, 0.0);
                }
                let u = &mut self.scratch[..n_u];
                self.engine.fill_uniform_f32(u);
                match method {
                    GaussianMethod::BoxMuller => {
                        for i in (0..n).step_by(2) {
                            let (z0, z1) = box_muller_pair(u[i], u[i + 1]);
                            out[i] = z0;
                            if i + 1 < n {
                                out[i + 1] = z1;
                            }
                        }
                    }
                    GaussianMethod::Icdf => {
                        for i in 0..n {
                            out[i] = crate::rng::distributions::gaussian_icdf(u[i] as f64) as f32;
                        }
                    }
                }
                Ok(())
            }
            Distribution::Bits => {
                // Each f32 lane is just 32 bits of storage: draw through a
                // cache-resident stack chunk and round-trip the bits with
                // `from_bits` — no heap scratch sized to the request.
                const CHUNK: usize = 4096;
                let mut raw = [0u32; CHUNK];
                for block in out.chunks_mut(CHUNK) {
                    let r = &mut raw[..block.len()];
                    self.engine.fill_u32(r);
                    for (dst, &src) in block.iter_mut().zip(r.iter()) {
                        *dst = f32::from_bits(src);
                    }
                }
                Ok(())
            }
            Distribution::Exponential { lambda } if self.full_api => {
                let d = Distribution::Exponential { lambda: *lambda };
                d.sample_f32(self.engine.as_mut(), out);
                Ok(())
            }
            Distribution::Poisson { lambda } if self.full_api => {
                let d = Distribution::Poisson { lambda: *lambda };
                d.sample_f32(self.engine.as_mut(), out);
                Ok(())
            }
            other => Err(Error::unsupported(
                self.backend,
                format!("{} generation (vendor API has no such entry point)", other.name()),
            )),
        }
    }

    fn fork_engine_at(&self, offset: u64) -> Option<Box<dyn Engine>> {
        if self.destroyed {
            return None;
        }
        let mut e = self.engine.clone_box();
        e.try_seek(offset).then_some(e)
    }

    fn destroy(&mut self) -> Result<()> {
        self.check_live()?;
        self.destroyed = true;
        Ok(())
    }

    fn is_destroyed(&self) -> bool {
        self.destroyed
    }
}

/// Feature matrix shared by the cuRAND/hipRAND-shaped libraries.
pub fn vendor_supports(engine: EngineKind, distr: &Distribution) -> bool {
    match distr {
        Distribution::Uniform { .. } => true,
        Distribution::Gaussian { method, .. } | Distribution::Lognormal { method, .. } => {
            *method != GaussianMethod::Icdf || engine.is_quasi()
        }
        Distribution::Bits => true,
        // No native exponential/poisson entry points in cuRAND/hipRAND's
        // host API; oneMKL synthesizes them from uniforms + a transform.
        Distribution::Exponential { .. } | Distribution::Poisson { .. } => false,
    }
}
