//! Performance-portability metrics (paper §6.1) and measurement statistics.

mod stats;
mod vavs;

pub use stats::{ci95, mean, median, stddev, Summary};
pub use vavs::{pennycook, vavs_efficiency};
