//! VAVS efficiency and the Pennycook performance-portability metric.
//!
//! Paper eq. (1): P(a, p; H) = |H| / sum_i 1/e_i if a is supported on all
//! i in H, else 0. The paper's e_i is the *vendor-agnostic to
//! vendor-specific* (VAVS) efficiency: achieved performance of the
//! portability solution relative to the native solution on the same
//! platform.

/// VAVS efficiency: native time / portable time (in time domain, higher is
/// better; > 1 means the portable path beat the native app).
pub fn vavs_efficiency(t_native_ns: f64, t_portable_ns: f64) -> f64 {
    assert!(t_native_ns > 0.0 && t_portable_ns > 0.0, "times must be positive");
    t_native_ns / t_portable_ns
}

/// Pennycook P̄: harmonic mean of per-platform efficiencies; `None` in the
/// efficiency list means "unsupported on that platform" -> P = 0.
pub fn pennycook(efficiencies: &[Option<f64>]) -> f64 {
    if efficiencies.is_empty() || efficiencies.iter().any(Option::is_none) {
        return 0.0;
    }
    let inv_sum: f64 = efficiencies.iter().map(|e| 1.0 / e.unwrap()).sum();
    efficiencies.len() as f64 / inv_sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_direction() {
        assert_eq!(vavs_efficiency(100.0, 100.0), 1.0);
        assert!(vavs_efficiency(100.0, 200.0) < 1.0); // portable slower
        assert!(vavs_efficiency(200.0, 100.0) > 1.0); // portable faster
    }

    #[test]
    fn pennycook_harmonic_mean() {
        // Paper Table 2 row {Vega56, A100} buffer: e = {0.974.., 1.186..}
        // combine to ~1.07.
        let p = pennycook(&[Some(0.974), Some(1.186)]);
        assert!((p - 1.0695).abs() < 0.01, "p={p}");
    }

    #[test]
    fn unsupported_platform_zeroes_p() {
        assert_eq!(pennycook(&[Some(1.0), None]), 0.0);
        assert_eq!(pennycook(&[]), 0.0);
    }

    #[test]
    fn harmonic_mean_penalises_outliers() {
        let p = pennycook(&[Some(1.0), Some(0.1)]);
        assert!(p < 0.2, "p={p}"); // far below the arithmetic mean 0.55
    }
}
