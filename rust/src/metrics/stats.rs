//! Measurement statistics over benchmark iterations.

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

/// 95% confidence half-interval on the mean (normal approximation).
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Five-number summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Median.
    pub median: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// 95% CI half-width on the mean.
    pub ci95: f64,
}

impl Summary {
    /// Summarise a sample.
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            median: median(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            ci95: ci95(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert!((stddev(&xs) - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn even_median() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[5.0]), 0.0);
        assert_eq!(ci95(&[5.0]), 0.0);
    }

    #[test]
    fn summary_consistency() {
        let xs = [2.0, 4.0, 6.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 6.0);
        assert_eq!(s.mean, 4.0);
    }
}
