//! Dependency-DAG introspection over executed command records.
//!
//! The queue executes eagerly but records the full dependency structure;
//! this module reconstructs the DAG for validation (the invariants the
//! SYCL runtime guarantees — §3: "the correct ordering of kernel execution
//! ... is guaranteed by SYCL runtime via a set of rules defined for
//! dependency checking") and for timeline analytics (critical path,
//! makespan, overlap).

use std::collections::HashMap;

use super::event::CommandRecord;
use super::hazard::{analyze_hazards, HazardReport};

/// Reconstructed DAG over a queue's command records.
#[derive(Debug)]
pub struct Dag<'a> {
    records: &'a [CommandRecord],
    by_id: HashMap<u64, &'a CommandRecord>,
    /// Command ids appearing more than once in the record stream — a
    /// corrupt stream, surfaced by [`Dag::validate`].
    duplicates: Vec<u64>,
}

/// Aggregate DAG statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DagStats {
    /// Number of commands.
    pub nodes: usize,
    /// Number of dependency edges.
    pub edges: usize,
    /// Virtual makespan: max end - min start.
    pub makespan_ns: u64,
    /// Length of the longest dependency chain in virtual ns.
    pub critical_path_ns: u64,
    /// Sum of all command durations (serial time).
    pub total_work_ns: u64,
}

impl<'a> Dag<'a> {
    /// Build from records (as returned by `Queue::records`). Duplicate
    /// command ids are retained (first occurrence wins for lookups) and
    /// reported by [`Dag::validate`] — they must never be silently
    /// collapsed, since a collision means two distinct commands would
    /// alias in every id-keyed analysis.
    pub fn new(records: &'a [CommandRecord]) -> Self {
        let mut by_id: HashMap<u64, &'a CommandRecord> = HashMap::with_capacity(records.len());
        let mut duplicates = Vec::new();
        for r in records {
            if by_id.contains_key(&r.id) {
                duplicates.push(r.id);
            } else {
                by_id.insert(r.id, r);
            }
        }
        Dag { records, by_id, duplicates }
    }

    /// Every command id must be unique, every dependency must point to an
    /// earlier-submitted command (the runtime can only depend on
    /// already-known nodes) and must be temporally respected:
    /// dep.end <= node.start.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(id) = self.duplicates.first() {
            return Err(format!(
                "duplicate command id {} ({} collision(s) total)",
                id,
                self.duplicates.len()
            ));
        }
        for r in self.records {
            for d in &r.dep_ids {
                let dep = self
                    .by_id
                    .get(d)
                    .ok_or_else(|| format!("cmd {} depends on unknown {}", r.id, d))?;
                if dep.id >= r.id {
                    return Err(format!("cmd {} depends on later cmd {}", r.id, dep.id));
                }
                if dep.virt_end_ns > r.virt_start_ns {
                    return Err(format!(
                        "cmd {} starts at {} before dep {} ends at {}",
                        r.id, r.virt_start_ns, dep.id, dep.virt_end_ns
                    ));
                }
            }
        }
        Ok(())
    }

    /// Run the memory-hazard analyzer over this DAG's records: prove
    /// every pair of conflicting accesses is connected by an ordering
    /// path (see [`crate::sycl::hazard`] for the diagnostic taxonomy and
    /// the windowed-analysis contract).
    pub fn analyze_hazards(&self) -> HazardReport {
        analyze_hazards(self.records)
    }

    /// True if any two commands overlap on the virtual timeline.
    pub fn has_overlap(&self) -> bool {
        for (i, a) in self.records.iter().enumerate() {
            for b in &self.records[i + 1..] {
                if a.virt_start_ns < b.virt_end_ns && b.virt_start_ns < a.virt_end_ns {
                    return true;
                }
            }
        }
        false
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> DagStats {
        let edges = self.records.iter().map(|r| r.dep_ids.len()).sum();
        let min_start = self.records.iter().map(|r| r.virt_start_ns).min().unwrap_or(0);
        let max_end = self.records.iter().map(|r| r.virt_end_ns).max().unwrap_or(0);
        let total_work_ns =
            self.records.iter().map(|r| r.virt_end_ns - r.virt_start_ns).sum();

        // Longest path by DP over ids (deps always point backwards).
        let mut longest: HashMap<u64, u64> = HashMap::new();
        let mut critical = 0u64;
        for r in self.records {
            let dur = r.virt_end_ns - r.virt_start_ns;
            let base = r
                .dep_ids
                .iter()
                .filter_map(|d| longest.get(d).copied())
                .max()
                .unwrap_or(0);
            let path = base + dur;
            longest.insert(r.id, path);
            critical = critical.max(path);
        }

        DagStats {
            nodes: self.records.len(),
            edges,
            makespan_ns: max_end - min_start,
            critical_path_ns: critical,
            total_work_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{CommandCost, PlatformId};
    use crate::sycl::{AccessMode, Buffer, CommandClass, Queue, SyclRuntimeProfile};

    fn kernel(items: u64) -> CommandCost {
        CommandCost::Kernel { bytes_read: 0, bytes_written: items * 4, items, tpb: 0 }
    }

    fn chain_queue(n: usize) -> Queue {
        let q = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let buf = Buffer::<f32>::new(1 << 16);
        for i in 0..n {
            q.submit(|cgh| {
                let acc = cgh.require(&buf, AccessMode::ReadWrite);
                cgh.host_task(format!("k{i}"), CommandClass::Generate, kernel(1 << 16), move |_| {
                    let _ = acc;
                });
            });
        }
        q
    }

    #[test]
    fn chain_validates_and_has_no_overlap() {
        let q = chain_queue(5);
        let records = q.records();
        let dag = Dag::new(&records);
        dag.validate().unwrap();
        assert!(!dag.has_overlap());
        let stats = dag.stats();
        // 5 kernels + the implicit first-use H2D upload.
        assert_eq!(stats.nodes, 6);
        assert_eq!(stats.edges, 5);
        // A pure chain: critical path == total work.
        assert_eq!(stats.critical_path_ns, stats.total_work_ns);
        assert!(dag.analyze_hazards().is_clean());
    }

    #[test]
    fn duplicate_ids_fail_validation() {
        let q = chain_queue(2);
        let mut records = q.records();
        let forged = records[0].clone();
        records.push(forged);
        let dag = Dag::new(&records);
        let err = dag.validate().unwrap_err();
        assert!(err.contains("duplicate command id"), "unexpected error: {err}");
        let report = dag.analyze_hazards();
        assert_eq!(report.count_of(crate::sycl::HazardKind::DuplicateId), 1);
    }

    #[test]
    fn fan_out_overlaps_and_critical_path_shorter_than_work() {
        // Independent commands on different channels (compute vs copy)
        // overlap on an out-of-order queue.
        let q = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        for i in 0..4 {
            let buf = Buffer::<f32>::new(1 << 20);
            let class = if i % 2 == 0 { CommandClass::Generate } else { CommandClass::TransferD2H };
            let cost = if i % 2 == 0 {
                kernel(1 << 20)
            } else {
                crate::platform::CommandCost::Transfer {
                    bytes: 4 << 20,
                    dir: crate::platform::TransferDir::D2H,
                }
            };
            q.submit(|cgh| {
                let acc = cgh.require(&buf, AccessMode::Write);
                cgh.host_task(format!("k{i}"), class, cost, move |_| {
                    let _ = acc;
                });
            });
        }
        let records = q.records();
        let dag = Dag::new(&records);
        dag.validate().unwrap();
        assert!(dag.has_overlap());
        let stats = dag.stats();
        assert!(stats.critical_path_ns < stats.total_work_ns);
        assert!(stats.makespan_ns < stats.total_work_ns);
        assert!(dag.analyze_hazards().is_clean());
    }
}
