//! Interoperability handles (SYCL 2020 `interop_handle`).
//!
//! Inside a host task, the paper's code does
//! `ih.get_native_mem<backend::cuda>(acc)` to reinterpret a SYCL accessor
//! as a raw device pointer for cuRAND. Our equivalent hands back the locked
//! backing store of the accessor's buffer plus the native-backend identity
//! of the queue's device.

use crate::platform::{PlatformKind, PlatformSpec};

use super::queue::Accessor;

/// Handle passed to host-task closures.
#[derive(Debug, Clone)]
pub struct InteropHandle {
    spec: PlatformSpec,
}

impl InteropHandle {
    pub(crate) fn new(spec: PlatformSpec) -> Self {
        InteropHandle { spec }
    }

    /// The native backend this device maps to (`backend::cuda`,
    /// `backend::hip`, ...).
    pub fn native_backend(&self) -> &'static str {
        match (self.spec.kind, self.spec.rng_library) {
            (_, lib) if lib.starts_with("cuRAND") => "cuda",
            (_, lib) if lib.starts_with("hipRAND") => "hip",
            (PlatformKind::Cpu, _) => "host",
            _ => "level_zero",
        }
    }

    /// `interop_handle::get_native_mem`: raw access to an accessor's
    /// storage for native API calls.
    pub fn get_native_mem<'a, T: Clone + Default + Send + 'static>(
        &self,
        acc: &'a Accessor<T>,
    ) -> std::sync::MutexGuard<'a, Vec<T>> {
        acc.lock()
    }

    /// Device spec (native device queries).
    pub fn device_spec(&self) -> &PlatformSpec {
        &self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    #[test]
    fn backend_mapping() {
        let ih = InteropHandle::new(PlatformId::A100.spec());
        assert_eq!(ih.native_backend(), "cuda");
        let ih = InteropHandle::new(PlatformId::Vega56.spec());
        assert_eq!(ih.native_backend(), "hip");
        let ih = InteropHandle::new(PlatformId::Rome7742.spec());
        assert_eq!(ih.native_backend(), "host");
        let ih = InteropHandle::new(PlatformId::Uhd630.spec());
        assert_eq!(ih.native_backend(), "level_zero");
    }
}
