//! Unified shared memory allocations (paper §4.1 USM API).
//!
//! Pointer-style allocations: the runtime cannot derive dependencies from
//! them, so USM command submissions carry explicit event lists
//! ([`crate::sycl::Queue::submit_usm`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

static NEXT_USM_ID: AtomicU64 = AtomicU64::new(1);

/// A `malloc_device`/`malloc_shared`-style allocation of `T`.
#[derive(Debug, Clone)]
pub struct UsmBuffer<T> {
    id: u64,
    data: Arc<Mutex<Vec<T>>>,
}

impl<T: Clone + Default + Send + 'static> UsmBuffer<T> {
    /// Allocate `n` default-initialised elements (the queue models the
    /// malloc latency — see [`crate::sycl::Queue::malloc_device`]).
    pub(crate) fn new(n: usize) -> Self {
        UsmBuffer {
            id: NEXT_USM_ID.fetch_add(1, Ordering::Relaxed),
            data: Arc::new(Mutex::new(vec![T::default(); n])),
        }
    }

    /// Allocation id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Length in elements.
    pub fn len(&self) -> usize {
        self.data.lock().unwrap().len()
    }

    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw pointer-style access (what the interop kernel hands to
    /// `curandGenerate`).
    pub fn lock(&self) -> MutexGuard<'_, Vec<T>> {
        self.data.lock().unwrap()
    }

    /// Host copy without timeline accounting (tests / assertions).
    pub fn snapshot(&self) -> Vec<T> {
        self.data.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unique_ids_and_storage() {
        let a: UsmBuffer<f32> = UsmBuffer::new(8);
        let b: UsmBuffer<f32> = UsmBuffer::new(8);
        assert_ne!(a.id(), b.id());
        a.lock()[0] = 3.5;
        assert_eq!(a.snapshot()[0], 3.5);
        assert_eq!(b.snapshot()[0], 0.0);
    }
}
