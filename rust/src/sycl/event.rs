//! Events and command records: the profiling layer of the runtime.
//!
//! Every executed command additionally carries its *access set*
//! ([`Access`]): which allocations it touched and how. Buffer-path
//! submissions derive the set from their accessor declarations, USM-path
//! submissions declare it explicitly, and D2H readbacks record it
//! automatically — the raw material the hazard analyzer
//! ([`crate::sycl::analyze_hazards`]) proves race-freedom from.

use std::sync::Arc;

use super::buffer::AccessMode;

/// Classification of commands for the Fig. 4 per-kernel breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommandClass {
    /// Generator construction + seeding.
    Setup,
    /// The vendor-native generation kernel.
    Generate,
    /// The oneMKL-side range-transformation kernel.
    Transform,
    /// Implicit or explicit host-to-device copy.
    TransferH2D,
    /// Device-to-host copy.
    TransferD2H,
    /// Device memory allocation.
    Malloc,
    /// Anything else (host tasks, app logic).
    Other,
}

impl CommandClass {
    /// Stable token for CSV output.
    pub fn token(self) -> &'static str {
        match self {
            CommandClass::Setup => "setup",
            CommandClass::Generate => "generate",
            CommandClass::Transform => "transform",
            CommandClass::TransferH2D => "h2d",
            CommandClass::TransferD2H => "d2h",
            CommandClass::Malloc => "malloc",
            CommandClass::Other => "other",
        }
    }
}

/// Which kind of allocation an [`Access`] refers to. The three namespaces
/// are disjoint: a buffer id and a USM id never collide semantically even
/// when the integers coincide.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessKind {
    /// A `Buffer` (accessor/DAG path) — id is `Buffer::id()`.
    Buffer,
    /// A USM allocation (pointer/event path) — id is `UsmBuffer::id()`.
    Usm,
    /// A host-side reply slice written by a D2H copy. Each copy writes a
    /// distinct slice, so these ids are unique per command and never
    /// alias.
    HostSlice,
}

impl AccessKind {
    /// Stable token for reports.
    pub fn token(self) -> &'static str {
        match self {
            AccessKind::Buffer => "buffer",
            AccessKind::Usm => "usm",
            AccessKind::HostSlice => "host-slice",
        }
    }
}

/// One entry of a command's access set: `(allocation, mode)` plus — for
/// arena-leased USM — the lease generation the command believed it held,
/// letting the analyzer tell reuse-after-recycle (generations differ,
/// ordering required) from use-after-recycle (generation went backwards:
/// someone kept a stale handle).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Access {
    /// Allocation namespace.
    pub kind: AccessKind,
    /// Allocation id within the namespace.
    pub id: u64,
    /// How the command touched it.
    pub mode: AccessMode,
    /// Arena-lease generation, when the allocation was checked out of a
    /// [`crate::sycl::UsmArena`]; `None` for untracked allocations.
    pub generation: Option<u64>,
    /// Element sub-range `(start, len)` the command touched, when known;
    /// `None` means the whole allocation (the conservative default).
    /// Per-tile work items declare their tile's range so the hazard
    /// analyzer can prove tile disjointness instead of flagging every
    /// unordered tile pair as a race.
    pub range: Option<(usize, usize)>,
}

impl Access {
    /// Buffer-path access (generation-free).
    pub fn buffer(id: u64, mode: AccessMode) -> Access {
        Access { kind: AccessKind::Buffer, id, mode, generation: None, range: None }
    }

    /// USM access outside any arena lease.
    pub fn usm(id: u64, mode: AccessMode) -> Access {
        Access { kind: AccessKind::Usm, id, mode, generation: None, range: None }
    }

    /// USM access under an arena lease of known generation (pass the
    /// lease's [`crate::sycl::UsmLease::generation`]); `None` degrades to
    /// [`Access::usm`].
    pub fn usm_leased(id: u64, mode: AccessMode, generation: Option<u64>) -> Access {
        Access { kind: AccessKind::Usm, id, mode, generation, range: None }
    }

    /// Host reply-slice write of a D2H copy.
    pub fn host_slice(id: u64) -> Access {
        Access {
            kind: AccessKind::HostSlice,
            id,
            mode: AccessMode::Write,
            generation: None,
            range: None,
        }
    }

    /// Narrow this access to the element sub-range `[start, start + len)`.
    /// Two accesses to the same allocation with disjoint declared ranges
    /// never conflict; an access without a range conflicts with every
    /// range (whole-allocation semantics are the safe default).
    pub fn with_range(mut self, start: usize, len: usize) -> Access {
        self.range = Some((start, len));
        self
    }

    /// Whether this access may overlap `other` element-wise: true unless
    /// both declare ranges and the ranges are disjoint. Zero-length
    /// ranges touch nothing and overlap nothing.
    pub fn ranges_may_overlap(&self, other: &Access) -> bool {
        match (self.range, other.range) {
            (Some((a, alen)), Some((b, blen))) => {
                a < b.saturating_add(blen) && b < a.saturating_add(alen) && alen > 0 && blen > 0
            }
            _ => true,
        }
    }
}

#[derive(Debug)]
pub(crate) struct EventInner {
    pub id: u64,
    pub name: String,
    pub class: CommandClass,
    /// Virtual-timeline start (ns since queue creation).
    pub virt_start_ns: u64,
    /// Virtual-timeline end.
    pub virt_end_ns: u64,
    /// Real wall time the host spent executing the command's closure.
    pub wall_ns: u64,
}

/// A completed command's handle — the SYCL `event` with
/// `info::event_profiling` semantics (command_start / command_end on the
/// virtual timeline).
#[derive(Debug, Clone)]
pub struct Event(pub(crate) Arc<EventInner>);

impl Event {
    /// Unique command id (submission order).
    pub fn id(&self) -> u64 {
        self.0.id
    }

    /// Command label.
    pub fn name(&self) -> &str {
        &self.0.name
    }

    /// Command classification.
    pub fn class(&self) -> CommandClass {
        self.0.class
    }

    /// Virtual `command_start` (ns).
    pub fn profiling_command_start(&self) -> u64 {
        self.0.virt_start_ns
    }

    /// Virtual `command_end` (ns).
    pub fn profiling_command_end(&self) -> u64 {
        self.0.virt_end_ns
    }

    /// Virtual duration (ns).
    pub fn virtual_duration_ns(&self) -> u64 {
        self.0.virt_end_ns - self.0.virt_start_ns
    }

    /// Real host wall time spent in the command closure (ns).
    pub fn wall_ns(&self) -> u64 {
        self.0.wall_ns
    }
}

/// Immutable record of an executed command, kept by the queue for DAG
/// introspection and the experiment drivers.
#[derive(Debug, Clone)]
pub struct CommandRecord {
    /// Command id (== submission index).
    pub id: u64,
    /// Label.
    pub name: String,
    /// Classification.
    pub class: CommandClass,
    /// Ids of commands this one waited on (derived + explicit).
    pub dep_ids: Vec<u64>,
    /// Virtual start ns.
    pub virt_start_ns: u64,
    /// Virtual end ns.
    pub virt_end_ns: u64,
    /// Host wall ns for the closure.
    pub wall_ns: u64,
    /// Threads-per-block in effect (kernels only).
    pub tpb: Option<u32>,
    /// Achieved occupancy (kernels only).
    pub occupancy: Option<f64>,
    /// Allocations this command touched and how (the hazard analyzer's
    /// input; empty for commands with no tracked memory effects).
    pub accesses: Vec<Access>,
}
