//! Events and command records: the profiling layer of the runtime.

use std::sync::Arc;

/// Classification of commands for the Fig. 4 per-kernel breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommandClass {
    /// Generator construction + seeding.
    Setup,
    /// The vendor-native generation kernel.
    Generate,
    /// The oneMKL-side range-transformation kernel.
    Transform,
    /// Implicit or explicit host-to-device copy.
    TransferH2D,
    /// Device-to-host copy.
    TransferD2H,
    /// Device memory allocation.
    Malloc,
    /// Anything else (host tasks, app logic).
    Other,
}

impl CommandClass {
    /// Stable token for CSV output.
    pub fn token(self) -> &'static str {
        match self {
            CommandClass::Setup => "setup",
            CommandClass::Generate => "generate",
            CommandClass::Transform => "transform",
            CommandClass::TransferH2D => "h2d",
            CommandClass::TransferD2H => "d2h",
            CommandClass::Malloc => "malloc",
            CommandClass::Other => "other",
        }
    }
}

#[derive(Debug)]
pub(crate) struct EventInner {
    pub id: u64,
    pub name: String,
    pub class: CommandClass,
    /// Virtual-timeline start (ns since queue creation).
    pub virt_start_ns: u64,
    /// Virtual-timeline end.
    pub virt_end_ns: u64,
    /// Real wall time the host spent executing the command's closure.
    pub wall_ns: u64,
}

/// A completed command's handle — the SYCL `event` with
/// `info::event_profiling` semantics (command_start / command_end on the
/// virtual timeline).
#[derive(Debug, Clone)]
pub struct Event(pub(crate) Arc<EventInner>);

impl Event {
    /// Unique command id (submission order).
    pub fn id(&self) -> u64 {
        self.0.id
    }

    /// Command label.
    pub fn name(&self) -> &str {
        &self.0.name
    }

    /// Command classification.
    pub fn class(&self) -> CommandClass {
        self.0.class
    }

    /// Virtual `command_start` (ns).
    pub fn profiling_command_start(&self) -> u64 {
        self.0.virt_start_ns
    }

    /// Virtual `command_end` (ns).
    pub fn profiling_command_end(&self) -> u64 {
        self.0.virt_end_ns
    }

    /// Virtual duration (ns).
    pub fn virtual_duration_ns(&self) -> u64 {
        self.0.virt_end_ns - self.0.virt_start_ns
    }

    /// Real host wall time spent in the command closure (ns).
    pub fn wall_ns(&self) -> u64 {
        self.0.wall_ns
    }
}

/// Immutable record of an executed command, kept by the queue for DAG
/// introspection and the experiment drivers.
#[derive(Debug, Clone)]
pub struct CommandRecord {
    /// Command id (== submission index).
    pub id: u64,
    /// Label.
    pub name: String,
    /// Classification.
    pub class: CommandClass,
    /// Ids of commands this one waited on (derived + explicit).
    pub dep_ids: Vec<u64>,
    /// Virtual start ns.
    pub virt_start_ns: u64,
    /// Virtual end ns.
    pub virt_end_ns: u64,
    /// Host wall ns for the closure.
    pub wall_ns: u64,
    /// Threads-per-block in effect (kernels only).
    pub tpb: Option<u32>,
    /// Achieved occupancy (kernels only).
    pub occupancy: Option<f64>,
}
