//! Memory-hazard analyzer over executed command records (DESIGN.md S14).
//!
//! The queue's buffer path derives its dependency edges automatically, but
//! the interop fast paths — USM submissions, arena-recycled launch
//! buffers, event-chained D2H slices — build those edges *by hand* (paper
//! §4.1: "it is the user's responsibility to ensure dependencies are
//! met"), and a missing edge is a silent data race, not an error. This
//! module turns that class of bug into a typed diagnostic: every command
//! carries its access set ([`super::event::Access`]), and
//! [`analyze_hazards`] walks the recorded DAG proving that every pair of
//! conflicting accesses is connected by an ordering path.
//!
//! The analysis is *windowed*: long-lived worker queues drain their record
//! log after every flush ([`super::Queue::drain_records`]), so a window's
//! records may depend on commands drained before it. Command ids are
//! monotonic and execution is eager, therefore any dependency on an id
//! below the window's smallest retained id is already satisfied — those
//! edges are counted as `external_deps`, not dangling. Missing ids at or
//! above the window floor are real [`HazardKind::DanglingDep`]s.
//!
//! Enforcement: under `cfg(debug_assertions)` or `PORTARNG_HAZARD_CHECK=1`
//! the queue runs this analyzer in `wait()`/`drain_records()` and panics
//! on any diagnostic, making the whole test + bench corpus a
//! race-detection suite. `portarng lint-dag` runs it across every
//! platform spec, and the pool counts reports into the telemetry `hazards`
//! block.

use std::collections::{BTreeMap, HashMap};

use crate::jsonlite::Value;

use super::event::{Access, AccessKind, CommandClass, CommandRecord};

/// Taxonomy of diagnostics the analyzer emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HazardKind {
    /// Read-after-write with no ordering path from the writer.
    Raw,
    /// Write-after-read with no ordering path from the reader.
    War,
    /// Write-after-write with no ordering path between the writers.
    Waw,
    /// A D2H readback not ordered after the kernel producing its data —
    /// the RAW special case the serving path's reply buffers ride on.
    UnorderedD2h,
    /// Two commands touched the same arena allocation under *different*
    /// lease generations with no ordering path: a recycled lease whose
    /// pending events the next checkout did not inherit.
    LeaseReuse,
    /// A later command used an *older* lease generation than an earlier
    /// one — someone kept a stale handle across a recycle (flagged even
    /// when an ordering path exists; the handle itself is invalid).
    StaleLease,
    /// A dependency edge pointing at a command id that is neither in the
    /// window nor below its floor (a forged or corrupted edge).
    DanglingDep,
    /// Two records share a command id (ids are submission-unique; a
    /// collision means the record stream itself is corrupt).
    DuplicateId,
}

impl HazardKind {
    /// All kinds, report order.
    pub const ALL: [HazardKind; 8] = [
        HazardKind::Raw,
        HazardKind::War,
        HazardKind::Waw,
        HazardKind::UnorderedD2h,
        HazardKind::LeaseReuse,
        HazardKind::StaleLease,
        HazardKind::DanglingDep,
        HazardKind::DuplicateId,
    ];

    /// Stable token for reports and telemetry.
    pub fn token(self) -> &'static str {
        match self {
            HazardKind::Raw => "raw",
            HazardKind::War => "war",
            HazardKind::Waw => "waw",
            HazardKind::UnorderedD2h => "unordered-d2h",
            HazardKind::LeaseReuse => "lease-reuse",
            HazardKind::StaleLease => "stale-lease",
            HazardKind::DanglingDep => "dangling-dep",
            HazardKind::DuplicateId => "duplicate-id",
        }
    }

    fn index(self) -> usize {
        HazardKind::ALL.iter().position(|&k| k == self).unwrap()
    }
}

/// One diagnostic: a pair of commands (or one command and a bad edge)
/// violating the race-freedom proof.
#[derive(Debug, Clone)]
pub struct Hazard {
    /// Diagnostic type.
    pub kind: HazardKind,
    /// Earlier command id of the pair (the record owning the bad edge for
    /// [`HazardKind::DanglingDep`] / the colliding record for
    /// [`HazardKind::DuplicateId`]).
    pub first: u64,
    /// Later command id of the pair (the missing dependency id for
    /// [`HazardKind::DanglingDep`]).
    pub second: u64,
    /// Allocation the conflict is on, when the diagnostic concerns one.
    pub access: Option<(AccessKind, u64)>,
    /// Human-readable explanation.
    pub detail: String,
}

/// Structured result of one [`analyze_hazards`] pass.
#[derive(Debug, Clone, Default)]
pub struct HazardReport {
    /// Commands analyzed.
    pub commands: usize,
    /// Dependency edges satisfied by commands drained before this window
    /// (ids below the window floor — sound because ids are monotonic and
    /// execution is eager).
    pub external_deps: usize,
    /// Diagnostics, submission order.
    pub hazards: Vec<Hazard>,
}

impl HazardReport {
    /// True when the window proved race-free.
    pub fn is_clean(&self) -> bool {
        self.hazards.is_empty()
    }

    /// Diagnostics of one kind.
    pub fn count_of(&self, kind: HazardKind) -> u64 {
        self.hazards.iter().filter(|h| h.kind == kind).count() as u64
    }

    /// Per-kind counts in [`HazardKind::ALL`] order.
    pub fn counts(&self) -> [u64; 8] {
        let mut counts = [0u64; 8];
        for h in &self.hazards {
            counts[h.kind.index()] += 1;
        }
        counts
    }

    /// Serialize for `lint-dag --json` style consumers.
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("commands".into(), Value::Number(self.commands as f64));
        m.insert("external_deps".into(), Value::Number(self.external_deps as f64));
        m.insert("clean".into(), Value::Bool(self.is_clean()));
        let mut counts = BTreeMap::new();
        for (kind, n) in HazardKind::ALL.iter().zip(self.counts()) {
            counts.insert(kind.token().into(), Value::Number(n as f64));
        }
        m.insert("counts".into(), Value::Object(counts));
        m.insert(
            "hazards".into(),
            Value::Array(
                self.hazards
                    .iter()
                    .map(|h| {
                        let mut hm = BTreeMap::new();
                        hm.insert("kind".into(), Value::String(h.kind.token().into()));
                        hm.insert("first".into(), Value::Number(h.first as f64));
                        hm.insert("second".into(), Value::Number(h.second as f64));
                        if let Some((kind, id)) = h.access {
                            hm.insert("alloc_kind".into(), Value::String(kind.token().into()));
                            hm.insert("alloc_id".into(), Value::Number(id as f64));
                        }
                        hm.insert("detail".into(), Value::String(h.detail.clone()));
                        Value::Object(hm)
                    })
                    .collect(),
            ),
        );
        Value::Object(m)
    }

    /// Multi-line human-readable rendering (lint-dag, panic messages).
    pub fn pretty(&self) -> String {
        let mut out = format!(
            "{} command(s), {} external dep(s), {} diagnostic(s)",
            self.commands,
            self.external_deps,
            self.hazards.len()
        );
        for h in &self.hazards {
            out.push_str(&format!("\n  [{}] {}", h.kind.token(), h.detail));
        }
        out
    }
}

/// Per-allocation occurrence of an access: which window index touched it.
struct Touch {
    idx: usize,
    access: Access,
}

/// Prove every conflicting access pair in `records` is connected by an
/// ordering path; see the module docs for the windowed-analysis contract.
/// Records need not be sorted (the analyzer orders them by id), but ids
/// must be unique — collisions are reported, with later duplicates
/// excluded from the pair analysis.
pub fn analyze_hazards(records: &[CommandRecord]) -> HazardReport {
    let mut report = HazardReport { commands: records.len(), ..Default::default() };

    // Deduplicate ids (first occurrence wins) and order by id, so "earlier"
    // below always means "submitted earlier".
    let mut recs: Vec<&CommandRecord> = Vec::with_capacity(records.len());
    let mut seen: HashMap<u64, ()> = HashMap::with_capacity(records.len());
    for r in records {
        if seen.insert(r.id, ()).is_some() {
            report.hazards.push(Hazard {
                kind: HazardKind::DuplicateId,
                first: r.id,
                second: r.id,
                access: None,
                detail: format!("command id {} (`{}`) recorded more than once", r.id, r.name),
            });
        } else {
            recs.push(r);
        }
    }
    recs.sort_by_key(|r| r.id);
    let Some(floor) = recs.first().map(|r| r.id) else {
        return report;
    };
    let pos: HashMap<u64, usize> = recs.iter().enumerate().map(|(i, r)| (r.id, i)).collect();

    // Resolve dependency edges: in-window predecessors, window-external
    // (drained, already satisfied), or dangling.
    let n = recs.len();
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, r) in recs.iter().enumerate() {
        for &d in &r.dep_ids {
            match pos.get(&d) {
                Some(&j) if recs[j].id < r.id => preds[i].push(j),
                Some(_) => report.hazards.push(Hazard {
                    kind: HazardKind::DanglingDep,
                    first: r.id,
                    second: d,
                    access: None,
                    detail: format!(
                        "command {} (`{}`) has a non-causal edge to command {}",
                        r.id, r.name, d
                    ),
                }),
                None if d < floor => report.external_deps += 1,
                None => report.hazards.push(Hazard {
                    kind: HazardKind::DanglingDep,
                    first: r.id,
                    second: d,
                    access: None,
                    detail: format!(
                        "command {} (`{}`) depends on unknown command {}",
                        r.id, r.name, d
                    ),
                }),
            }
        }
    }

    // Group accesses by allocation.
    let mut groups: BTreeMap<(u8, u64), Vec<Touch>> = BTreeMap::new();
    let kind_key = |k: AccessKind| match k {
        AccessKind::Buffer => 0u8,
        AccessKind::Usm => 1,
        AccessKind::HostSlice => 2,
    };
    for (i, r) in recs.iter().enumerate() {
        for &a in &r.accesses {
            groups
                .entry((kind_key(a.kind), a.id))
                .or_default()
                .push(Touch { idx: i, access: a });
        }
    }

    // Reachability (ancestor bitsets) is only paid for when some
    // allocation actually has a potentially conflicting pair — windows of
    // access-free commands (host tasks without accessors) stay O(n).
    let needs_reachability = groups.values().any(|g| {
        g.len() >= 2
            && (g.iter().any(|t| t.access.mode.writes())
                || g
                    .iter()
                    .filter_map(|t| t.access.generation)
                    .collect::<std::collections::HashSet<_>>()
                    .len()
                    > 1)
    });
    let words = n.div_ceil(64);
    let mut anc: Vec<u64> = Vec::new();
    if needs_reachability {
        anc = vec![0u64; n * words];
        for i in 0..n {
            let (lo, hi) = anc.split_at_mut(i * words);
            let row = &mut hi[..words];
            for &j in &preds[i] {
                let prow = &lo[j * words..(j + 1) * words];
                for (w, p) in row.iter_mut().zip(prow) {
                    *w |= p;
                }
                row[j / 64] |= 1u64 << (j % 64);
            }
        }
    }
    let ordered =
        |i: usize, j: usize| anc[j * words + i / 64] >> (i % 64) & 1 == 1;

    // Pairwise conflict check per allocation. Touches are in id order
    // (records were walked sorted), so `a` is always the earlier command.
    for ((_, alloc_id), touches) in &groups {
        for (x, ta) in touches.iter().enumerate() {
            for tb in &touches[x + 1..] {
                let (i, a) = (ta.idx, ta.access);
                let (j, b) = (tb.idx, tb.access);
                if i == j {
                    continue; // two accessors of one command never race
                }
                let cross_gen =
                    matches!((a.generation, b.generation), (Some(ga), Some(gb)) if ga != gb);
                let stale =
                    matches!((a.generation, b.generation), (Some(ga), Some(gb)) if gb < ga);
                if !cross_gen && !a.mode.writes() && !b.mode.writes() {
                    continue; // concurrent same-generation reads are fine
                }
                if !cross_gen && !a.ranges_may_overlap(&b) {
                    // Both accesses declared element ranges and they are
                    // disjoint: independent tiles of one nd-range never
                    // conflict. Generation semantics stay whole-allocation
                    // (a recycle invalidates every range), so the skip
                    // only applies within one generation.
                    continue;
                }
                let (ra, rb) = (recs[i], recs[j]);
                let where_ = format!(
                    "command {} (`{}`) vs command {} (`{}`) on {} {}",
                    ra.id,
                    ra.name,
                    rb.id,
                    rb.name,
                    a.kind.token(),
                    alloc_id
                );
                if stale {
                    // Invalid regardless of ordering: the later command
                    // held a handle from before the recycle.
                    report.hazards.push(Hazard {
                        kind: HazardKind::StaleLease,
                        first: ra.id,
                        second: rb.id,
                        access: Some((a.kind, *alloc_id)),
                        detail: format!(
                            "{where_}: later command used stale lease generation {} (current {})",
                            b.generation.unwrap(),
                            a.generation.unwrap()
                        ),
                    });
                    continue;
                }
                if ordered(i, j) {
                    continue;
                }
                let kind = if cross_gen {
                    HazardKind::LeaseReuse
                } else if rb.class == CommandClass::TransferD2H
                    && b.mode.reads()
                    && a.mode.writes()
                {
                    HazardKind::UnorderedD2h
                } else if a.mode.writes() && b.mode.writes() {
                    HazardKind::Waw
                } else if a.mode.writes() {
                    HazardKind::Raw
                } else {
                    HazardKind::War
                };
                let why = match kind {
                    HazardKind::LeaseReuse => format!(
                        "lease generation {} reused after generation {} \
                         without inheriting its pending events",
                        b.generation.unwrap(),
                        a.generation.unwrap()
                    ),
                    HazardKind::UnorderedD2h => {
                        "D2H readback is not ordered after the producing command".into()
                    }
                    _ => "no ordering path between conflicting accesses".into(),
                };
                report.hazards.push(Hazard {
                    kind,
                    first: ra.id,
                    second: rb.id,
                    access: Some((a.kind, *alloc_id)),
                    detail: format!("{where_}: {why}"),
                });
            }
        }
    }

    // Deterministic output order: by earlier command id, then kind.
    report
        .hazards
        .sort_by_key(|h| (h.first, h.second, h.kind.index()));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sycl::AccessMode;

    fn rec(id: u64, deps: &[u64], accesses: Vec<Access>) -> CommandRecord {
        CommandRecord {
            id,
            name: format!("c{id}"),
            class: CommandClass::Other,
            dep_ids: deps.to_vec(),
            virt_start_ns: id * 10,
            virt_end_ns: id * 10 + 5,
            wall_ns: 0,
            tpb: None,
            occupancy: None,
            accesses,
        }
    }

    #[test]
    fn empty_window_is_clean() {
        let report = analyze_hazards(&[]);
        assert!(report.is_clean());
        assert_eq!(report.commands, 0);
    }

    #[test]
    fn ordered_chain_is_clean_and_transitive() {
        // w(0) -> rw(1) -> r(2): the 0->2 RAW is covered transitively.
        let records = [
            rec(0, &[], vec![Access::usm(7, AccessMode::Write)]),
            rec(1, &[0], vec![Access::usm(7, AccessMode::ReadWrite)]),
            rec(2, &[1], vec![Access::usm(7, AccessMode::Read)]),
        ];
        assert!(analyze_hazards(&records).is_clean());
    }

    #[test]
    fn unordered_conflicts_classify_raw_war_waw() {
        let records = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Write)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Read)]),
            rec(2, &[], vec![Access::usm(2, AccessMode::Read)]),
            rec(3, &[], vec![Access::usm(2, AccessMode::Write)]),
            rec(4, &[], vec![Access::usm(3, AccessMode::Write)]),
            rec(5, &[], vec![Access::usm(3, AccessMode::Write)]),
        ];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 3);
        assert_eq!(report.count_of(HazardKind::Raw), 1);
        assert_eq!(report.count_of(HazardKind::War), 1);
        assert_eq!(report.count_of(HazardKind::Waw), 1);
    }

    #[test]
    fn concurrent_reads_do_not_conflict() {
        let records = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Read)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Read)]),
        ];
        assert!(analyze_hazards(&records).is_clean());
    }

    #[test]
    fn d2h_read_gets_the_specific_diagnostic() {
        let mut d2h = rec(1, &[], vec![Access::usm(9, AccessMode::Read)]);
        d2h.class = CommandClass::TransferD2H;
        let records = [rec(0, &[], vec![Access::usm(9, AccessMode::Write)]), d2h];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::UnorderedD2h);
        assert_eq!(report.hazards[0].access, Some((AccessKind::Usm, 9)));
    }

    #[test]
    fn cross_generation_unordered_is_lease_reuse() {
        let records = [
            rec(0, &[], vec![Access::usm_leased(5, AccessMode::Write, Some(0))]),
            rec(1, &[], vec![Access::usm_leased(5, AccessMode::Write, Some(1))]),
        ];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::LeaseReuse);
        // The same pair *with* the edge is clean: reuse is fine when the
        // next checkout chains behind the previous lease's events.
        let chained = [
            rec(0, &[], vec![Access::usm_leased(5, AccessMode::Write, Some(0))]),
            rec(1, &[0], vec![Access::usm_leased(5, AccessMode::Write, Some(1))]),
        ];
        assert!(analyze_hazards(&chained).is_clean());
    }

    #[test]
    fn generation_going_backwards_is_stale_even_when_ordered() {
        let records = [
            rec(0, &[], vec![Access::usm_leased(5, AccessMode::Write, Some(3))]),
            rec(1, &[0], vec![Access::usm_leased(5, AccessMode::Write, Some(2))]),
        ];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::StaleLease);
    }

    #[test]
    fn window_floor_externalizes_drained_deps() {
        // Window starts at id 10; deps on 3 are drained predecessors, a
        // dep on 11 from id 12 is fine, a dep on 999 is dangling.
        let records = [
            rec(10, &[3], vec![]),
            rec(11, &[10], vec![]),
            rec(12, &[11, 999], vec![]),
        ];
        let report = analyze_hazards(&records);
        assert_eq!(report.external_deps, 1);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::DanglingDep);
        assert_eq!(report.hazards[0].second, 999);
    }

    #[test]
    fn disjoint_ranges_do_not_conflict() {
        // Two unordered writers of the same allocation, but each declares
        // its own tile range: [0, 64) vs [64, 64) — provably disjoint.
        let records = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Write).with_range(0, 64)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Write).with_range(64, 64)]),
        ];
        assert!(analyze_hazards(&records).is_clean());
        // Overlapping ranges still conflict ([0, 64) vs [63, 64)).
        let overlapping = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Write).with_range(0, 64)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Write).with_range(63, 64)]),
        ];
        let report = analyze_hazards(&overlapping);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::Waw);
        // A rangeless access means "whole allocation": conflicts with any
        // ranged access (the conservative default).
        let mixed = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Write).with_range(0, 64)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Write)]),
        ];
        assert_eq!(analyze_hazards(&mixed).hazards.len(), 1);
    }

    #[test]
    fn tiled_window_with_ranged_d2h_readers_is_clean() {
        // The executor's flush shape: per-tile generate writes with
        // disjoint ranges, per-tile transforms chained tile-to-tile, a
        // D2H read spanning two tiles that depends on both transforms.
        let w = |start: usize| {
            Access::usm_leased(9, AccessMode::Write, Some(2)).with_range(start, 100)
        };
        let t = |start: usize| {
            Access::usm_leased(9, AccessMode::ReadWrite, Some(2)).with_range(start, 100)
        };
        let mut d2h = rec(
            4,
            &[2, 3],
            vec![
                Access::usm_leased(9, AccessMode::Read, Some(2)).with_range(50, 150),
                Access::host_slice(77),
            ],
        );
        d2h.class = CommandClass::TransferD2H;
        let records = [
            rec(0, &[], vec![w(0)]),
            rec(1, &[], vec![w(100)]),
            rec(2, &[0], vec![t(0)]),
            rec(3, &[1], vec![t(100)]),
            d2h,
        ];
        assert!(analyze_hazards(&records).is_clean());
        // Severing one transform edge exposes the cross-tile D2H race.
        let mut broken = rec(
            4,
            &[2],
            vec![
                Access::usm_leased(9, AccessMode::Read, Some(2)).with_range(50, 150),
                Access::host_slice(77),
            ],
        );
        broken.class = CommandClass::TransferD2H;
        let records = [
            rec(0, &[], vec![w(0)]),
            rec(1, &[], vec![w(100)]),
            rec(2, &[0], vec![t(0)]),
            rec(3, &[1], vec![t(100)]),
            broken,
        ];
        let report = analyze_hazards(&records);
        assert!(!report.is_clean());
        assert!(report.count_of(HazardKind::UnorderedD2h) >= 1);
    }

    #[test]
    fn cross_generation_ranges_never_prove_disjointness() {
        // Disjoint ranges under *different* lease generations still
        // require ordering: the recycle invalidated the whole allocation.
        let records = [
            rec(0, &[], vec![
                Access::usm_leased(5, AccessMode::Write, Some(0)).with_range(0, 64),
            ]),
            rec(1, &[], vec![
                Access::usm_leased(5, AccessMode::Write, Some(1)).with_range(64, 64),
            ]),
        ];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::LeaseReuse);
    }

    #[test]
    fn duplicate_ids_are_reported_and_excluded() {
        let records = [rec(0, &[], vec![]), rec(0, &[], vec![]), rec(1, &[0], vec![])];
        let report = analyze_hazards(&records);
        assert_eq!(report.hazards.len(), 1);
        assert_eq!(report.hazards[0].kind, HazardKind::DuplicateId);
    }

    #[test]
    fn report_json_has_counts_and_hazard_entries() {
        let records = [
            rec(0, &[], vec![Access::usm(1, AccessMode::Write)]),
            rec(1, &[], vec![Access::usm(1, AccessMode::Write)]),
        ];
        let report = analyze_hazards(&records);
        let v = report.to_json();
        assert_eq!(v.get("clean"), Some(&Value::Bool(false)));
        assert_eq!(v.get("counts").and_then(|c| c.get("waw")).and_then(Value::as_f64), Some(1.0));
        assert_eq!(v.get("hazards").and_then(Value::as_array).map(<[Value]>::len), Some(1));
        // Round-trips through the serializer.
        assert!(Value::parse(&v.to_json()).is_ok());
        assert!(report.pretty().contains("waw"));
    }
}
