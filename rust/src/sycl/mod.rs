//! Mini-SYCL runtime substrate (DESIGN.md S1).
//!
//! A faithful reduction of the SYCL execution model the paper's
//! measurements depend on:
//!
//! * **Queues** own a device + a runtime profile (DPC++ or hipSYCL) and
//!   execute *command groups*.
//! * **Buffers** are encapsulating objects; command groups declare
//!   [`AccessMode`] accessors and the runtime derives the dependency DAG
//!   (RAW/WAR/WAW) automatically, including implicit H2D/D2H transfer
//!   commands on non-UMA devices.
//! * **USM** allocations take the pointer-based path: no accessors, the
//!   *user* supplies explicit event dependency lists (paper §4.1).
//!   [`UsmArena`] recycles them in size classes for serving workloads,
//!   carrying each allocation's pending events across reuse (S13).
//! * **Host tasks** are the interoperability mechanism (the paper's
//!   `codeplay_host_task`): closures that run on the host, receive an
//!   [`InteropHandle`], and produce side effects attributed to the device
//!   timeline — exactly how the cuRAND/hipRAND calls are wired in.
//!
//! Execution is eager (commands run at submit), but *virtual time* is
//! computed from the dependency structure: an out-of-order queue lets
//! independent commands overlap on the virtual timeline, an in-order queue
//! serialises them. Profiling info on [`Event`]s mirrors
//! `info::event_profiling`.
//!
//! Every command additionally records its *access set* ([`Access`]), and
//! the [`hazard`] analyzer proves each recorded DAG race-free — see
//! [`analyze_hazards`], [`Dag::analyze_hazards`], and the enforcement
//! hooks in [`Queue::wait`]/[`Queue::drain_records`] (S14).

mod arena;
mod buffer;
mod dag;
mod event;
mod executor;
pub mod hazard;
mod interop;
mod profile;
mod queue;
mod usm;

pub use arena::{ArenaStats, UsmArena, UsmLease};
pub use buffer::{AccessMode, Buffer};
pub use dag::{Dag, DagStats};
pub use event::{Access, AccessKind, CommandClass, CommandRecord, Event};
pub use executor::{TileExecutor, TileTiming, TilingSpec};
pub use hazard::{analyze_hazards, Hazard, HazardKind, HazardReport};
pub use interop::InteropHandle;
pub use profile::SyclRuntimeProfile;
pub use queue::{CommandGroupHandler, Queue};
pub use usm::UsmBuffer;
