//! Worker-local kernel executor: nd-range tiled execution (DESIGN.md S16).
//!
//! The paper's near-native numbers come from the device running generation
//! as a wide data-parallel kernel; the serving path, by contrast, executed
//! each flush as one serial host task, capping a shard at a single core.
//! [`TileExecutor`] closes that gap on the host side: a submitted command
//! is executed as an *nd-range of independent tiles* — disjoint
//! `&mut` sub-slices of the launch buffer, distributed over a team of
//! worker threads — exactly the shape a `parallel_for` gives the device.
//!
//! Tile independence is what Philox buys us: `seek`/`skip_ahead` are O(1)
//! counter arithmetic, so a tile starting at global stream position `p`
//! generates exactly the numbers the serial pass would have written there
//! — tiled output is bit-identical to serial for every tile size and team
//! width (pinned by property tests in `rng::generate` and
//! `tests/coordinator.rs`).
//!
//! The team is scoped, not pooled: tiles borrow the caller's buffer, so
//! workers are spawned per nd-range via `std::thread::scope` (the only
//! borrow-safe structure without external thread-pool dependencies) and
//! tiles are dealt round-robin — a deterministic static partition; tiles
//! are near-uniform by construction, so work stealing would buy noise, not
//! throughput. Each tile's real wall time is measured and returned so the
//! queue can record one command per tile (with a per-tile [`super::Access`]
//! range — the hazard analyzer proves tile disjointness instead of going
//! blind) and telemetry can expose the per-tile distribution.

use std::time::Instant;

/// Tiling knobs for one nd-range execution: how large each tile is and how
/// many team threads execute them. Both are live-retunable through the
/// pool's `TuningHandle` (`tile_size` / `team_width`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilingSpec {
    /// Elements per tile; `0` disables tiling (serial execution).
    pub tile_size: usize,
    /// Worker threads executing tiles; `<= 1` disables tiling.
    pub team_width: usize,
}

impl TilingSpec {
    /// The serial configuration: one tile, one thread — the default shape
    /// every existing single-submission invariant is pinned against.
    pub fn serial() -> TilingSpec {
        TilingSpec { tile_size: 0, team_width: 1 }
    }

    /// Tiling with `tile_size`-element tiles on a `team_width`-thread team
    /// (clamped to at least one thread).
    pub fn new(tile_size: usize, team_width: usize) -> TilingSpec {
        TilingSpec { tile_size, team_width: team_width.max(1) }
    }

    /// Whether this spec degenerates to the serial path.
    pub fn is_serial(&self) -> bool {
        self.tile_size == 0 || self.team_width <= 1
    }

    /// Tile ranges `(start, len)` covering `[0, n)` in order. Serial specs
    /// (and launches that fit one tile) yield a single tile; `n == 0`
    /// yields none.
    pub fn tiles(&self, n: usize) -> Vec<(usize, usize)> {
        if n == 0 {
            return Vec::new();
        }
        if self.is_serial() || n <= self.tile_size {
            return vec![(0, n)];
        }
        let mut out = Vec::with_capacity(n.div_ceil(self.tile_size));
        let mut start = 0;
        while start < n {
            let len = self.tile_size.min(n - start);
            out.push((start, len));
            start += len;
        }
        out
    }
}

/// Real wall time of one executed tile, in nd-range order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileTiming {
    /// Tile index within the nd-range.
    pub tile: usize,
    /// First element of the tile in the launch buffer.
    pub start: usize,
    /// Tile length in elements.
    pub len: usize,
    /// Real wall time the tile's closure took on its team thread.
    pub wall_ns: u64,
}

/// The worker-local executor: runs tile closures over disjoint sub-slices
/// of a launch buffer on a team of scoped threads (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct TileExecutor {
    team_width: usize,
}

impl TileExecutor {
    /// Executor with a team of `team_width` threads (clamped to >= 1).
    pub fn new(team_width: usize) -> TileExecutor {
        TileExecutor { team_width: team_width.max(1) }
    }

    /// Configured team width.
    pub fn team_width(&self) -> usize {
        self.team_width
    }

    /// Execute `work` once per tile over disjoint sub-slices of `data`, as
    /// an nd-range: tile `i` receives `(i, start_i, &mut data[start_i ..
    /// start_i + len_i])`. Tiles must be ascending and non-overlapping
    /// (the shape [`TilingSpec::tiles`] produces); elements not covered by
    /// any tile are left untouched. Returns per-tile wall timings in tile
    /// order. With one tile or a one-thread team the calling thread runs
    /// everything inline — no spawn cost on the serial path.
    pub fn run<T, W>(&self, data: &mut [T], tiles: &[(usize, usize)], work: W) -> Vec<TileTiming>
    where
        T: Send,
        W: Fn(usize, usize, &mut [T]) + Sync,
    {
        // Carve the buffer into per-tile disjoint `&mut` slices up front —
        // the borrow-checker-visible proof that tiles cannot race, the
        // same fact the per-tile `Access` ranges hand the hazard analyzer.
        let mut slices: Vec<(usize, usize, &mut [T])> = Vec::with_capacity(tiles.len());
        let mut rest = data;
        let mut consumed = 0usize;
        for (i, &(start, len)) in tiles.iter().enumerate() {
            assert!(start >= consumed, "tiles must be ascending and non-overlapping");
            let (_, tail) = rest.split_at_mut(start - consumed);
            let (tile, tail) = tail.split_at_mut(len);
            slices.push((i, start, tile));
            rest = tail;
            consumed = start + len;
        }

        let timed = |(i, start, slice): (usize, usize, &mut [T]), work: &W| {
            let len = slice.len();
            let t0 = Instant::now();
            work(i, start, slice);
            TileTiming {
                tile: i,
                start,
                len,
                wall_ns: t0.elapsed().as_nanos() as u64,
            }
        };

        if self.team_width <= 1 || slices.len() <= 1 {
            return slices.into_iter().map(|s| timed(s, &work)).collect();
        }

        // Deterministic static partition: tile i goes to team member
        // i % width. Tiles are near-uniform (one partial tail at most),
        // so dynamic stealing would add nondeterminism for no throughput.
        let width = self.team_width.min(slices.len());
        let mut per_member: Vec<Vec<(usize, usize, &mut [T])>> =
            (0..width).map(|_| Vec::new()).collect();
        for slice in slices {
            let member = slice.0 % width;
            per_member[member].push(slice);
        }

        let work = &work;
        let mut timings: Vec<TileTiming> = std::thread::scope(|scope| {
            let handles: Vec<_> = per_member
                .into_iter()
                .map(|chunk| {
                    scope.spawn(move || {
                        chunk.into_iter().map(|s| timed(s, work)).collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("tile team thread panicked"))
                .collect()
        });
        timings.sort_by_key(|t| t.tile);
        timings
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_spec_yields_one_tile() {
        let spec = TilingSpec::serial();
        assert!(spec.is_serial());
        assert_eq!(spec.tiles(1000), vec![(0, 1000)]);
        assert_eq!(spec.tiles(0), Vec::<(usize, usize)>::new());
        // team_width <= 1 is serial regardless of tile size.
        assert!(TilingSpec::new(64, 1).is_serial());
        // tile_size == 0 is serial regardless of team width.
        assert!(TilingSpec::new(0, 8).is_serial());
    }

    #[test]
    fn tiles_partition_the_range_exactly() {
        let spec = TilingSpec::new(100, 4);
        for n in [1usize, 99, 100, 101, 250, 400, 1001] {
            let tiles = spec.tiles(n);
            let mut expect_start = 0usize;
            for &(start, len) in &tiles {
                assert_eq!(start, expect_start);
                assert!(len > 0 && len <= 100);
                expect_start += len;
            }
            assert_eq!(expect_start, n, "tiles must cover [0, {n}) exactly");
        }
        // A launch that fits one tile is a single tile.
        assert_eq!(spec.tiles(100), vec![(0, 100)]);
        assert_eq!(spec.tiles(101).len(), 2);
    }

    #[test]
    fn run_writes_every_tile_through_its_own_slice() {
        let spec = TilingSpec::new(7, 3);
        let mut data = vec![0u64; 100];
        let tiles = spec.tiles(data.len());
        let exec = TileExecutor::new(3);
        let timings = exec.run(&mut data, &tiles, |tile, start, slice| {
            for (k, v) in slice.iter_mut().enumerate() {
                *v = (tile as u64) << 32 | (start + k) as u64;
            }
        });
        assert_eq!(timings.len(), tiles.len());
        for (i, t) in timings.iter().enumerate() {
            assert_eq!(t.tile, i);
            assert_eq!((t.start, t.len), tiles[i]);
        }
        for (k, &v) in data.iter().enumerate() {
            assert_eq!((v & 0xFFFF_FFFF) as usize, k, "element {k} written by wrong index");
            assert_eq!((v >> 32) as usize, k / 7, "element {k} written by wrong tile");
        }
    }

    #[test]
    fn parallel_run_matches_serial_run_exactly() {
        // The executor-level bit-identity statement: any team width
        // produces the same buffer contents as the serial pass.
        use crate::rng::Engine;
        let n = 10_000usize;
        let fill = |_tile: usize, start: usize, slice: &mut [u32]| {
            let mut e = crate::rng::PhiloxEngine::new(42);
            e.seek(start as u64);
            e.fill_u32(slice);
        };
        let spec = TilingSpec::new(257, 4);
        let tiles = spec.tiles(n);
        let mut serial = vec![0u32; n];
        TileExecutor::new(1).run(&mut serial, &[(0, n)], fill);
        for width in [2usize, 3, 4, 8] {
            let mut tiled = vec![0u32; n];
            let timings = TileExecutor::new(width).run(&mut tiled, &tiles, fill);
            assert_eq!(tiled, serial, "width {width} diverged");
            assert_eq!(timings.len(), tiles.len());
        }
    }

    #[test]
    fn gap_elements_are_left_untouched() {
        let mut data = vec![7u8; 10];
        let exec = TileExecutor::new(2);
        exec.run(&mut data, &[(2, 3), (7, 2)], |_, _, slice| slice.fill(0));
        assert_eq!(data, [7, 7, 0, 0, 0, 7, 7, 0, 0, 7]);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn overlapping_tiles_are_rejected() {
        let mut data = vec![0u8; 10];
        TileExecutor::new(2).run(&mut data, &[(0, 5), (3, 5)], |_, _, _| {});
    }
}
