//! Queues and command groups: eager execution, virtual-time scheduling.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::platform::{jitter_from, CommandCost, PerfModel, PlatformId, PlatformSpec};

use super::buffer::{AccessMode, Buffer, BufferDeps};
use super::event::{Access, CommandClass, CommandRecord, Event, EventInner};
use super::hazard::analyze_hazards;
use super::interop::InteropHandle;
use super::profile::SyclRuntimeProfile;
use super::usm::UsmBuffer;

/// Typed accessor handed back by [`CommandGroupHandler::require`]; moved
/// into the command closure to reach the buffer storage (the SYCL
/// `accessor` whose pointer `interop_handle::get_native_mem` reinterprets).
#[derive(Debug, Clone)]
pub struct Accessor<T> {
    buffer: Buffer<T>,
    mode: AccessMode,
}

impl<T: Clone + Default + Send + 'static> Accessor<T> {
    /// Lock the underlying storage (read or write as per mode; the type
    /// system cannot see SYCL access modes, so misuse is checked at the
    /// runtime level in debug builds).
    pub fn lock(&self) -> std::sync::MutexGuard<'_, Vec<T>> {
        self.buffer.lock()
    }

    /// Access mode this accessor was declared with.
    pub fn mode(&self) -> AccessMode {
        self.mode
    }

    /// The underlying buffer id.
    pub fn buffer_id(&self) -> u64 {
        self.buffer.id()
    }
}

struct AccessorDecl {
    buffer_id: u64,
    mode: AccessMode,
    bytes: u64,
    deps: Arc<Mutex<BufferDeps>>,
}

type Task<'scope> = Box<dyn FnOnce(&InteropHandle) + 'scope>;

/// Builder passed to the `queue.submit(|cgh| ...)` closure — the SYCL
/// command-group handler.
///
/// `'scope` is the lifetime of borrows the command closure may capture:
/// because this runtime executes command groups eagerly (the closure runs
/// inside [`Queue::submit`], before it returns), the closure does not need
/// to be `'static` — it may borrow the caller's generator handle and write
/// vendor output directly into accessor memory, with no staging copy.
pub struct CommandGroupHandler<'q, 'scope> {
    queue: &'q Queue,
    accessors: Vec<AccessorDecl>,
    explicit_deps: Vec<Event>,
    task: Option<(String, CommandClass, CommandCost, Task<'scope>)>,
}

impl<'q, 'scope> CommandGroupHandler<'q, 'scope> {
    /// Declare a buffer accessor (`buffer.get_access<mode>(cgh)`).
    pub fn require<T: Clone + Default + Send + 'static>(
        &mut self,
        buf: &Buffer<T>,
        mode: AccessMode,
    ) -> Accessor<T> {
        self.accessors.push(AccessorDecl {
            buffer_id: buf.id(),
            mode,
            bytes: (buf.len() * std::mem::size_of::<T>()) as u64,
            deps: buf.inner.deps.clone(),
        });
        Accessor { buffer: buf.clone(), mode }
    }

    /// Add an explicit event dependency (`cgh.depends_on(ev)`).
    pub fn depends_on(&mut self, ev: &Event) {
        self.explicit_deps.push(ev.clone());
    }

    /// Register the command body as a host task with device side effects —
    /// the interoperability mechanism (`cgh.codeplay_host_task` /
    /// SYCL 2020 `host_task` with interop handle).
    pub fn host_task(
        &mut self,
        name: impl Into<String>,
        class: CommandClass,
        cost: CommandCost,
        f: impl FnOnce(&InteropHandle) + 'scope,
    ) {
        debug_assert!(self.task.is_none(), "one command per group");
        self.task = Some((name.into(), class, cost, Box::new(f)));
    }

    /// Register a device kernel (`cgh.parallel_for`). Identical execution
    /// semantics here — the distinction is which runtime-overhead constants
    /// apply and how the record is classified.
    pub fn parallel_for(
        &mut self,
        name: impl Into<String>,
        class: CommandClass,
        cost: CommandCost,
        f: impl FnOnce(&InteropHandle) + 'scope,
    ) {
        self.host_task(name, class, cost, f);
    }

    /// The queue this group is being submitted to.
    pub fn queue(&self) -> &'q Queue {
        self.queue
    }
}

/// Hardware resource a command occupies. Commands on the same channel
/// serialise even on an out-of-order queue (one PCIe link, one compute
/// engine); different channels overlap — the copy/compute overlap real
/// SYCL runtimes get from separate streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Channel {
    Copy,
    Compute,
    Host,
}

fn channel_of(class: CommandClass) -> Channel {
    match class {
        CommandClass::TransferH2D | CommandClass::TransferD2H => Channel::Copy,
        CommandClass::Setup | CommandClass::Malloc | CommandClass::Other => Channel::Host,
        CommandClass::Generate | CommandClass::Transform => Channel::Compute,
    }
}

#[derive(Debug, Default)]
struct QueueState {
    next_id: u64,
    /// Host-thread virtual time (advances with submissions + blocking ops).
    host_now_ns: u64,
    /// Latest command end on the device timeline.
    last_end_ns: u64,
    /// Per-resource-channel availability (serialisation within a channel).
    channel_end_ns: std::collections::HashMap<Channel, u64>,
    records: Vec<CommandRecord>,
    noise_salt: u64,
    /// Record-log length already proven hazard-free (enforcement memo:
    /// records are append-only between drains, so a clean prefix stays
    /// clean and `wait()` only re-analyzes when the log has grown).
    hazard_verified_len: usize,
}

/// A SYCL queue bound to one device and one runtime profile.
pub struct Queue {
    spec: PlatformSpec,
    model: PerfModel,
    profile: SyclRuntimeProfile,
    in_order: bool,
    state: Mutex<QueueState>,
}

impl Queue {
    /// Out-of-order queue (default in SYCL) on `platform`.
    pub fn new(platform: PlatformId, profile: SyclRuntimeProfile) -> Self {
        Queue::with_order(platform, profile, false)
    }

    /// In-order queue.
    pub fn in_order(platform: PlatformId, profile: SyclRuntimeProfile) -> Self {
        Queue::with_order(platform, profile, true)
    }

    fn with_order(platform: PlatformId, profile: SyclRuntimeProfile, in_order: bool) -> Self {
        let spec = platform.spec();
        Queue {
            model: PerfModel::new(spec.clone()),
            spec,
            profile,
            in_order,
            state: Mutex::new(QueueState::default()),
        }
    }

    /// Platform spec of the queue's device.
    pub fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    /// Runtime profile (DPC++ / hipSYCL).
    pub fn runtime_profile(&self) -> SyclRuntimeProfile {
        self.profile
    }

    /// Performance model for this device.
    pub fn perf_model(&self) -> &PerfModel {
        &self.model
    }

    /// Set the deterministic-noise salt (one per measurement iteration).
    pub fn set_noise_salt(&self, salt: u64) {
        self.state.lock().unwrap().noise_salt = salt;
    }

    /// Submit a command group; returns its completion event. The command
    /// closure may borrow from the caller (`'scope`): execution is eager,
    /// so the closure runs — and its borrows end — before `submit` returns.
    pub fn submit<'scope, F>(&self, f: F) -> Event
    where
        F: for<'q> FnOnce(&mut CommandGroupHandler<'q, 'scope>),
    {
        let mut cgh = CommandGroupHandler {
            queue: self,
            accessors: Vec::new(),
            explicit_deps: Vec::new(),
            task: None,
        };
        f(&mut cgh);
        let (name, class, cost, task) = cgh
            .task
            .expect("command group submitted without a command");

        let mut st = self.state.lock().unwrap();
        // Host-side submission cost: group + per-accessor DAG bookkeeping.
        st.host_now_ns += self.profile.submit_overhead_ns()
            + self.profile.accessor_overhead_ns() * cgh.accessors.len() as u64;

        // Implicit H2D transfers for buffers not yet device-resident.
        for decl in &cgh.accessors {
            let needs_upload = {
                let d = decl.deps.lock().unwrap();
                !d.device_resident && !self.spec.uma && decl.mode.reads()
            };
            if needs_upload {
                let ev = self.record_command(
                    &mut st,
                    format!("h2d:buf{}", decl.buffer_id),
                    CommandClass::TransferH2D,
                    CommandCost::Transfer {
                        bytes: decl.bytes,
                        dir: crate::platform::TransferDir::H2D,
                    },
                    &self.buffer_deps(decl, /*transfer*/ true),
                    vec![Access::buffer(decl.buffer_id, AccessMode::Write)],
                    0,
                );
                let mut d = decl.deps.lock().unwrap();
                d.last_write = Some(ev);
                d.readers_since_write.clear();
            }
            // Writes (or reads on UMA) make the device copy authoritative.
            let mut d = decl.deps.lock().unwrap();
            d.device_resident = true;
        }

        // Dependency set for the main command.
        let mut deps: Vec<Event> = cgh.explicit_deps.clone();
        for decl in &cgh.accessors {
            deps.extend(self.buffer_deps(decl, false));
        }

        // Execute the closure for real, on the host.
        let ih = InteropHandle::new(self.spec.clone());
        let wall_start = Instant::now();
        task(&ih);
        let wall_ns = wall_start.elapsed().as_nanos() as u64;

        let accesses = cgh
            .accessors
            .iter()
            .map(|decl| Access::buffer(decl.buffer_id, decl.mode))
            .collect();
        let ev = self.record_command(&mut st, name, class, cost, &deps, accesses, wall_ns);

        // Update buffer hazard state.
        for decl in &cgh.accessors {
            let mut d = decl.deps.lock().unwrap();
            if decl.mode.writes() {
                d.last_write = Some(ev.clone());
                d.readers_since_write.clear();
            } else {
                d.readers_since_write.push(ev.clone());
            }
        }
        ev
    }

    /// USM-path submission: no accessors, explicit event dependencies only
    /// (paper §4.1: "it is the user's responsibility to ensure dependencies
    /// are met"). `accesses` declares which allocations the command touches
    /// — the runtime cannot derive it without accessors, and the hazard
    /// analyzer uses it to prove the explicit `deps` are sufficient.
    pub fn submit_usm(
        &self,
        name: impl Into<String>,
        class: CommandClass,
        cost: CommandCost,
        deps: &[Event],
        accesses: Vec<Access>,
        f: impl FnOnce(&InteropHandle),
    ) -> Event {
        let mut st = self.state.lock().unwrap();
        st.host_now_ns += self.profile.submit_overhead_ns()
            + self.profile.usm_submit_overhead_ns(&self.spec)
            + self.profile.usm_dep_wait_ns() * deps.len() as u64;

        let ih = InteropHandle::new(self.spec.clone());
        let wall_start = Instant::now();
        f(&ih);
        let wall_ns = wall_start.elapsed().as_nanos() as u64;

        self.record_command(&mut st, name.into(), class, cost, deps, accesses, wall_ns)
    }

    /// Record a USM-path command whose body was already executed by the
    /// tile executor ([`super::TileExecutor`]): the nd-range runs the tile
    /// closures on its thread team (measuring real wall time per tile),
    /// then each tile is recorded as its own command — with its own
    /// dependency list, its own [`Access`] range, and the measured
    /// `wall_ns` — so the DAG, the hazard analyzer, and telemetry see the
    /// per-tile structure. Identical submission accounting to
    /// [`Queue::submit_usm`]; only the closure execution has moved off the
    /// submitting thread.
    pub fn submit_executed(
        &self,
        name: impl Into<String>,
        class: CommandClass,
        cost: CommandCost,
        deps: &[Event],
        accesses: Vec<Access>,
        wall_ns: u64,
    ) -> Event {
        let mut st = self.state.lock().unwrap();
        st.host_now_ns += self.profile.submit_overhead_ns()
            + self.profile.usm_submit_overhead_ns(&self.spec)
            + self.profile.usm_dep_wait_ns() * deps.len() as u64;
        self.record_command(&mut st, name.into(), class, cost, deps, accesses, wall_ns)
    }

    /// Allocate device USM (`malloc_device`) — a blocking host call.
    pub fn malloc_device<T: Clone + Default + Send + 'static>(&self, n: usize) -> UsmBuffer<T> {
        let mut st = self.state.lock().unwrap();
        st.host_now_ns += self.spec.malloc_ns;
        drop(st);
        UsmBuffer::new(n)
    }

    /// Copy a buffer's contents back to the host, modelling the D2H
    /// transfer (blocking, like a host accessor).
    pub fn host_read<T: Clone + Default + Send + 'static>(&self, buf: &Buffer<T>) -> Vec<T> {
        let bytes = (buf.len() * std::mem::size_of::<T>()) as u64;
        let deps: Vec<Event> = {
            let d = buf.inner.deps.lock().unwrap();
            d.last_write.iter().cloned().collect()
        };
        let mut st = self.state.lock().unwrap();
        let ev = self.record_command(
            &mut st,
            format!("d2h:buf{}", buf.id()),
            CommandClass::TransferD2H,
            CommandCost::Transfer { bytes, dir: crate::platform::TransferDir::D2H },
            &deps,
            vec![Access::buffer(buf.id(), AccessMode::Read)],
            0,
        );
        // Blocking: the host waits for the copy.
        st.host_now_ns = st.host_now_ns.max(ev.profiling_command_end());
        drop(st);
        buf.inner.deps.lock().unwrap().readers_since_write.push(ev);
        buf.snapshot()
    }

    /// USM D2H copy (`queue.memcpy` to host) — blocking.
    pub fn usm_to_host<T: Clone + Default + Send + 'static>(
        &self,
        usm: &UsmBuffer<T>,
        deps: &[Event],
    ) -> Vec<T> {
        let bytes = (usm.len() * std::mem::size_of::<T>()) as u64;
        let mut st = self.state.lock().unwrap();
        st.host_now_ns += self.profile.usm_dep_wait_ns() * deps.len() as u64;
        let ev = self.record_command(
            &mut st,
            format!("d2h:usm{}", usm.id()),
            CommandClass::TransferD2H,
            CommandCost::Transfer { bytes, dir: crate::platform::TransferDir::D2H },
            deps,
            vec![Access::usm(usm.id(), AccessMode::Read)],
            0,
        );
        st.host_now_ns = st.host_now_ns.max(ev.profiling_command_end());
        drop(st);
        usm.snapshot()
    }

    /// Asynchronous USM D2H copy of `usm[offset..offset + len]`
    /// (`queue.memcpy` from a pointer interior). Unlike
    /// [`Queue::usm_to_host`] the *host* does not block: ordering is
    /// carried by the returned [`Event`] (chain it into later submissions
    /// or wait on the queue). The batched serving path issues one of these
    /// per batch member, all depending on the flush's transform event.
    pub fn usm_slice_to_host<T: Clone + Default + Send + 'static>(
        &self,
        usm: &UsmBuffer<T>,
        offset: usize,
        len: usize,
        deps: &[Event],
    ) -> (Vec<T>, Event) {
        let bytes = (len * std::mem::size_of::<T>()) as u64;
        let mut st = self.state.lock().unwrap();
        st.host_now_ns += self.profile.usm_dep_wait_ns() * deps.len() as u64;
        // The copy reads exactly the requested element range of the USM
        // source (declared, so tiled flushes can prove it disjoint from
        // non-overlapping tiles) and writes a per-command host reply
        // slice (the next command id doubles as a unique slice id).
        let accesses = vec![
            Access::usm(usm.id(), AccessMode::Read).with_range(offset, len),
            Access::host_slice(st.next_id),
        ];
        let ev = self.record_command(
            &mut st,
            format!("d2h:usm{}+{offset}", usm.id()),
            CommandClass::TransferD2H,
            CommandCost::Transfer { bytes, dir: crate::platform::TransferDir::D2H },
            deps,
            accesses,
            0,
        );
        drop(st);
        let data = usm.lock()[offset..offset + len].to_vec();
        (data, ev)
    }

    /// [`Queue::submit_usm`] behind the submission fault seam: when the
    /// calling thread runs under a [`crate::fault`] plan and the plan
    /// trips, the submission is refused with
    /// [`Error::Injected`](crate::error::Error::Injected) *before*
    /// anything is recorded — modelling a queue that rejects the command
    /// group. Costs one thread-local null check when no plan is armed.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_usm_checked(
        &self,
        name: impl Into<String>,
        class: CommandClass,
        cost: CommandCost,
        deps: &[Event],
        accesses: Vec<Access>,
        f: impl FnOnce(&InteropHandle),
    ) -> crate::error::Result<Event> {
        crate::fault::trip(crate::fault::FaultSite::Submit)?;
        Ok(self.submit_usm(name, class, cost, deps, accesses, f))
    }

    /// [`Queue::usm_slice_to_host`] behind the D2H fault seam: a tripped
    /// plan fails the copy with
    /// [`Error::Injected`](crate::error::Error::Injected) before any
    /// transfer is recorded.
    pub fn usm_slice_to_host_checked<T: Clone + Default + Send + 'static>(
        &self,
        usm: &UsmBuffer<T>,
        offset: usize,
        len: usize,
        deps: &[Event],
    ) -> crate::error::Result<(Vec<T>, Event)> {
        crate::fault::trip(crate::fault::FaultSite::D2h)?;
        Ok(self.usm_slice_to_host(usm, offset, len, deps))
    }

    /// Model host-side work of known duration between submissions.
    pub fn advance_host(&self, ns: u64) {
        self.state.lock().unwrap().host_now_ns += ns;
    }

    /// Whether hazard enforcement is on for this process: `wait()` and
    /// [`Queue::drain_records`] run the analyzer and panic on any
    /// diagnostic. Controlled by `PORTARNG_HAZARD_CHECK` (`"0"` or empty
    /// disables, any other value enables); when unset, enforcement follows
    /// `cfg(debug_assertions)` — debug test runs get race detection for
    /// free, release benchmarks stay unperturbed.
    pub fn hazard_check_enabled() -> bool {
        static ENABLED: OnceLock<bool> = OnceLock::new();
        *ENABLED.get_or_init(|| match std::env::var("PORTARNG_HAZARD_CHECK") {
            Ok(v) => !(v.is_empty() || v == "0"),
            Err(_) => cfg!(debug_assertions),
        })
    }

    /// Enforcement helper: analyze the retained records if enabled and the
    /// log grew since the last clean pass. Returns the failure message
    /// instead of panicking so callers can release the state lock first
    /// (panicking under the lock would poison the queue for unwinding
    /// observers). In-order queues are skipped: same-queue commands
    /// serialise by construction, so unordered record pairs are not races.
    fn hazard_violation(&self, st: &mut QueueState) -> Option<String> {
        if self.in_order
            || !Queue::hazard_check_enabled()
            || st.records.len() == st.hazard_verified_len
        {
            return None;
        }
        let report = analyze_hazards(&st.records);
        if report.is_clean() {
            st.hazard_verified_len = st.records.len();
            None
        } else {
            Some(format!("hazard check failed (PORTARNG_HAZARD_CHECK):\n{}", report.pretty()))
        }
    }

    /// Block until all submitted commands complete; returns total virtual
    /// elapsed ns (the paper's "total execution time" clock).
    ///
    /// Under hazard enforcement ([`Queue::hazard_check_enabled`]) the
    /// retained records are analyzed first — a sync point is exactly where
    /// a race would be observed — and any diagnostic panics.
    pub fn wait(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        st.host_now_ns = st.host_now_ns.max(st.last_end_ns) + self.profile.sync_ns();
        let now = st.host_now_ns;
        let violation = self.hazard_violation(&mut st);
        drop(st);
        if let Some(msg) = violation {
            panic!("{msg}");
        }
        now
    }

    /// Current virtual host time (ns) without synchronising.
    pub fn virtual_now_ns(&self) -> u64 {
        self.state.lock().unwrap().host_now_ns
    }

    /// Executed-command records (DAG introspection, Fig. 4 breakdown).
    ///
    /// Clones the full record vec — fine for tests and one-shot analysis,
    /// wrong for hot loops. Aggregation paths should use
    /// [`Queue::visit_records`] (no copy) and long-lived queues should
    /// bound their memory with [`Queue::drain_records`].
    pub fn records(&self) -> Vec<CommandRecord> {
        self.state.lock().unwrap().records.clone()
    }

    /// Number of executed-command records currently retained.
    pub fn records_len(&self) -> usize {
        self.state.lock().unwrap().records.len()
    }

    /// Visit every retained record in submission order without cloning —
    /// the accounting path for benches and the burner breakdown.
    ///
    /// The queue's internal lock is held while iterating: `f` must not
    /// call back into the same queue (submit/read/drain would deadlock on
    /// the non-reentrant mutex). Pure aggregation only.
    pub fn visit_records<F: FnMut(&CommandRecord)>(&self, mut f: F) {
        for r in &self.state.lock().unwrap().records {
            f(r);
        }
    }

    /// Take ownership of the retained records, leaving the queue's record
    /// log empty (timeline state — virtual clocks, channel availability,
    /// command ids — is unaffected). Long-lived worker queues drain after
    /// every flush so the log never grows with uptime.
    ///
    /// Under hazard enforcement ([`Queue::hazard_check_enabled`]) the
    /// drained window is analyzed and any diagnostic panics — for a
    /// flush-per-drain worker this checks exactly one flush's DAG.
    pub fn drain_records(&self) -> Vec<CommandRecord> {
        let (records, in_order) = {
            let mut st = self.state.lock().unwrap();
            st.hazard_verified_len = 0;
            (std::mem::take(&mut st.records), self.in_order)
        };
        if !in_order && Queue::hazard_check_enabled() {
            let report = analyze_hazards(&records);
            assert!(
                report.is_clean(),
                "hazard check failed (PORTARNG_HAZARD_CHECK):\n{}",
                report.pretty()
            );
        }
        records
    }

    fn buffer_deps(&self, decl: &AccessorDecl, for_transfer: bool) -> Vec<Event> {
        let d = decl.deps.lock().unwrap();
        let mut deps = Vec::new();
        if decl.mode.reads() || for_transfer {
            deps.extend(d.last_write.iter().cloned());
        }
        if decl.mode.writes() && !for_transfer {
            deps.extend(d.last_write.iter().cloned());
            deps.extend(d.readers_since_write.iter().cloned());
        }
        deps.sort_by_key(Event::id);
        deps.dedup_by_key(|e| e.id());
        deps
    }

    #[allow(clippy::too_many_arguments)]
    fn record_command(
        &self,
        st: &mut QueueState,
        name: String,
        class: CommandClass,
        cost: CommandCost,
        deps: &[Event],
        accesses: Vec<Access>,
        wall_ns: u64,
    ) -> Event {
        let id = st.next_id;
        st.next_id += 1;

        // Fill in the runtime-chosen thread-block size where applicable.
        let (cost, tpb, occ) = match cost {
            CommandCost::Kernel { bytes_read, bytes_written, items, tpb } => {
                let tpb = if tpb == 0 { self.profile.pick_tpb(&self.spec) } else { tpb };
                let occ = crate::platform::occupancy(items, tpb, &self.spec).achieved;
                (
                    CommandCost::Kernel { bytes_read, bytes_written, items, tpb },
                    Some(tpb),
                    Some(occ),
                )
            }
            c => (c, None, None),
        };

        let mut start = st.host_now_ns + self.spec.launch_latency_ns;
        if !deps.is_empty() {
            start += self.profile.dag_callback_ns();
            for d in deps {
                start = start.max(d.profiling_command_end());
            }
        }
        if self.in_order {
            start = start.max(st.last_end_ns);
        }
        // Same-channel commands occupy the same hardware resource.
        let channel = channel_of(class);
        start = start.max(st.channel_end_ns.get(&channel).copied().unwrap_or(0));

        let exec = self.model.execution_ns(&cost);
        let exec = (exec as f64 * jitter_from("sycl-cmd", st.noise_salt, id, exec)) as u64;
        let end = start + exec;
        st.last_end_ns = st.last_end_ns.max(end);
        st.channel_end_ns.insert(channel, end);

        let ev = Event(Arc::new(EventInner {
            id,
            name: name.clone(),
            class,
            virt_start_ns: start,
            virt_end_ns: end,
            wall_ns,
        }));
        st.records.push(CommandRecord {
            id,
            name,
            class,
            dep_ids: deps.iter().map(Event::id).collect(),
            virt_start_ns: start,
            virt_end_ns: end,
            wall_ns,
            tpb,
            occupancy: occ,
            accesses,
        });
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::TransferDir;

    fn q() -> Queue {
        Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp)
    }

    fn kernel_cost(items: u64) -> CommandCost {
        CommandCost::Kernel { bytes_read: 0, bytes_written: items * 4, items, tpb: 0 }
    }

    #[test]
    fn raw_dependency_orders_commands() {
        let queue = q();
        let buf = Buffer::<f32>::new(1024);
        let e1 = queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::ReadWrite);
            cgh.host_task("gen", CommandClass::Generate, kernel_cost(1024), move |_| {
                acc.lock().iter_mut().for_each(|x| *x = 0.5);
            });
        });
        let e2 = queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::ReadWrite);
            cgh.parallel_for("xform", CommandClass::Transform, kernel_cost(1024), move |_| {
                acc.lock().iter_mut().for_each(|x| *x = *x * 2.0);
            });
        });
        // Transform must start at/after generate's end (RAW via buffer).
        assert!(e2.profiling_command_start() >= e1.profiling_command_end());
        assert_eq!(queue.host_read(&buf)[0], 1.0);
    }

    #[test]
    fn independent_channels_overlap_out_of_order() {
        // Copy/compute overlap: a transfer on another buffer may start
        // while a kernel runs (separate hardware channels).
        let queue = q();
        let (a, b) = (Buffer::<f32>::new(1 << 20), Buffer::<f32>::new(1 << 24));
        let e1 = queue.submit(|cgh| {
            let acc = cgh.require(&a, AccessMode::Write);
            cgh.host_task("k1", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        let e2 = queue.submit(|cgh| {
            let acc = cgh.require(&b, AccessMode::Write);
            cgh.host_task(
                "d2h",
                CommandClass::TransferD2H,
                CommandCost::Transfer { bytes: 4 << 24, dir: TransferDir::D2H },
                move |_| {
                    let _ = acc;
                },
            );
        });
        assert!(e2.profiling_command_start() < e1.profiling_command_end());
    }

    #[test]
    fn same_channel_kernels_serialise() {
        // One compute engine: independent kernels still queue up.
        let queue = q();
        let (a, b) = (Buffer::<f32>::new(1 << 20), Buffer::<f32>::new(1 << 20));
        let e1 = queue.submit(|cgh| {
            let acc = cgh.require(&a, AccessMode::Write);
            cgh.host_task("k1", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        let e2 = queue.submit(|cgh| {
            let acc = cgh.require(&b, AccessMode::Write);
            cgh.host_task("k2", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        assert!(e2.profiling_command_start() >= e1.profiling_command_end());
    }

    #[test]
    fn in_order_queue_serialises() {
        let queue = Queue::in_order(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let (a, b) = (Buffer::<f32>::new(1 << 20), Buffer::<f32>::new(1 << 20));
        let e1 = queue.submit(|cgh| {
            let acc = cgh.require(&a, AccessMode::Write);
            cgh.host_task("k1", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        let e2 = queue.submit(|cgh| {
            let acc = cgh.require(&b, AccessMode::Write);
            cgh.host_task("k2", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        assert!(e2.profiling_command_start() >= e1.profiling_command_end());
    }

    #[test]
    fn first_read_inserts_h2d_on_discrete_gpu() {
        let queue = q();
        let buf = Buffer::from_vec(vec![1f32; 4096]);
        queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Read);
            cgh.host_task("consume", CommandClass::Other, kernel_cost(4096), move |_| {
                let _ = acc;
            });
        });
        let records = queue.records();
        assert_eq!(records[0].class, CommandClass::TransferH2D);
        // Second use: no new transfer.
        queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Read);
            cgh.host_task("again", CommandClass::Other, kernel_cost(4096), move |_| {
                let _ = acc;
            });
        });
        let h2d = queue
            .records()
            .iter()
            .filter(|r| r.class == CommandClass::TransferH2D)
            .count();
        assert_eq!(h2d, 1);
    }

    #[test]
    fn uma_platform_has_free_transfers() {
        let queue = Queue::new(PlatformId::Uhd630, SyclRuntimeProfile::Dpcpp);
        let buf = Buffer::from_vec(vec![1f32; 1 << 20]);
        let out = queue.host_read(&buf);
        assert_eq!(out.len(), 1 << 20);
        let rec = &queue.records()[0];
        assert_eq!(rec.class, CommandClass::TransferD2H);
        assert!(rec.virt_end_ns - rec.virt_start_ns < 2_000); // ~free
    }

    #[test]
    fn usm_explicit_deps_enforced() {
        let queue = q();
        let e1 = queue.submit_usm(
            "gen",
            CommandClass::Generate,
            kernel_cost(1 << 16),
            &[],
            vec![],
            |_| {},
        );
        let e2 = queue.submit_usm(
            "xform",
            CommandClass::Transform,
            kernel_cost(1 << 16),
            std::slice::from_ref(&e1),
            vec![],
            |_| {},
        );
        assert!(e2.profiling_command_start() >= e1.profiling_command_end());
    }

    #[test]
    fn usm_without_deps_may_race() {
        // The footgun the paper warns about: USM + forgotten deps -> a
        // readback may start while the producing kernel still runs.
        // (hipSYCL profile: cheap USM submits, so the overlap is visible.)
        let queue = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let e1 = queue.submit_usm(
            "gen",
            CommandClass::Generate,
            kernel_cost(1 << 22),
            &[],
            vec![],
            |_| {},
        );
        let e2 = queue.submit_usm(
            "d2h",
            CommandClass::TransferD2H,
            CommandCost::Transfer { bytes: 4 << 22, dir: TransferDir::D2H },
            &[],
            vec![],
            |_| {},
        );
        assert!(e2.profiling_command_start() < e1.profiling_command_end());
    }

    #[test]
    fn wait_covers_all_commands() {
        let queue = q();
        let buf = Buffer::<f32>::new(1 << 22);
        queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Write);
            cgh.host_task("k", CommandClass::Generate, kernel_cost(1 << 22), move |_| {
                let _ = acc;
            });
        });
        let total = queue.wait();
        let max_end = queue.records().iter().map(|r| r.virt_end_ns).max().unwrap();
        assert!(total >= max_end);
    }

    #[test]
    fn transfer_cost_realistic() {
        let queue = q();
        let ns = queue.perf_model().transfer_ns(400_000_000);
        assert!(ns > 20_000_000);
        let _ = TransferDir::D2H;
    }

    #[test]
    fn war_dependency_write_waits_for_readers() {
        let queue = q();
        let buf = Buffer::<f32>::new(1 << 20);
        let _w = queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Write);
            cgh.host_task("w1", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        let r = queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Read);
            cgh.host_task("r", CommandClass::Other, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        let w2 = queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::Write);
            cgh.host_task("w2", CommandClass::Generate, kernel_cost(1 << 20), move |_| {
                let _ = acc;
            });
        });
        assert!(w2.profiling_command_start() >= r.profiling_command_end());
    }

    #[test]
    fn command_closures_may_borrow_the_caller() {
        // The zero-staging contract: a host task may capture &mut state
        // from the submitting scope because execution is eager.
        let queue = q();
        let buf = Buffer::<f32>::new(16);
        let mut calls = 0usize;
        queue.submit(|cgh| {
            let acc = cgh.require(&buf, AccessMode::ReadWrite);
            cgh.host_task("gen", CommandClass::Generate, kernel_cost(16), |ih| {
                let mut mem = ih.get_native_mem(&acc);
                mem[0] = 7.0;
                calls += 1;
            });
        });
        assert_eq!(calls, 1);
        assert_eq!(buf.snapshot()[0], 7.0);
    }

    #[test]
    fn record_visiting_and_draining_match_the_cloning_path() {
        let queue = q();
        let buf = Buffer::<f32>::new(1 << 12);
        for _ in 0..3 {
            queue.submit(|cgh| {
                let acc = cgh.require(&buf, AccessMode::ReadWrite);
                cgh.host_task("k", CommandClass::Generate, kernel_cost(1 << 12), move |_| {
                    let _ = acc;
                });
            });
        }
        let cloned = queue.records();
        assert_eq!(queue.records_len(), cloned.len());
        let mut visited = 0usize;
        queue.visit_records(|r| {
            assert_eq!(r.id, cloned[visited].id);
            visited += 1;
        });
        assert_eq!(visited, cloned.len());

        let drained = queue.drain_records();
        assert_eq!(drained.len(), cloned.len());
        assert_eq!(queue.records_len(), 0);
        // Draining does not reset the timeline: new commands keep fresh
        // ids and start no earlier than the drained ones ended.
        let ev =
            queue.submit_usm("k2", CommandClass::Generate, kernel_cost(16), &[], vec![], |_| {});
        assert!(ev.id() > drained.last().unwrap().id);
        assert_eq!(queue.records_len(), 1);
    }

    #[test]
    fn usm_slice_readback_is_event_chained_not_host_blocking() {
        let queue = q();
        let usm = queue.malloc_device::<f32>(64);
        usm.lock()[10] = 5.0;
        let gen = queue.submit_usm(
            "gen",
            CommandClass::Generate,
            kernel_cost(64),
            &[],
            vec![Access::usm(usm.id(), AccessMode::Write)],
            |_| {},
        );
        let host_before = queue.virtual_now_ns();
        let (data, ev) = queue.usm_slice_to_host(&usm, 10, 4, std::slice::from_ref(&gen));
        assert_eq!(data, vec![5.0, 0.0, 0.0, 0.0]);
        // Chained: the copy starts after the producer ends ...
        assert!(ev.profiling_command_start() >= gen.profiling_command_end());
        assert_eq!(ev.class(), CommandClass::TransferD2H);
        // ... but the host does not sit out the transfer (unlike
        // `usm_to_host`, which advances host time to the copy's end).
        assert!(queue.virtual_now_ns() < ev.profiling_command_end());
        let _ = host_before;
    }
}
