//! USM allocation arena: size-class recycling for serving workloads
//! (DESIGN.md S13).
//!
//! `malloc_device` is a blocking host call (tens of microseconds on the
//! discrete GPUs — [`crate::platform::PlatformSpec::malloc_ns`]), which is
//! fine for a one-shot benchmark and fatal on a serving hot path issuing a
//! launch per flush. [`UsmArena`] sits between a worker and its
//! [`Queue`]: allocations are rounded up to power-of-two size classes,
//! checked out as [`UsmLease`]s and parked back in per-class free lists on
//! recycle, so a steady-state worker performs **zero** device mallocs —
//! every flush reuses a warm allocation.
//!
//! USM dependencies are the user's responsibility (paper §4.1), and a
//! recycled allocation is the classic place to forget them: the next
//! writer must wait for the previous user's reads. The arena carries that
//! bookkeeping for free — each lease stores the events of the last
//! commands touching its buffer ([`UsmLease::set_pending`]), and a
//! checkout hands them back ([`UsmLease::deps`]) so the next flush chains
//! its generate submission behind them.
//!
//! Returning a buffer is **explicit**: [`UsmLease::recycle`] parks it with
//! its pending events and bumps the allocation's *generation* counter (the
//! hazard analyzer's handle for telling reuse-after-recycle from
//! use-after-recycle — see [`crate::sycl::hazard`]). Merely dropping a
//! lease does *not* recycle: the allocation is freed, its pending events
//! are discarded, and the loss is counted in [`ArenaStats::leaked`] — a
//! dropped lease on a serving path is a bug (the warm allocation is gone),
//! so it is observable rather than silently papered over.

use std::sync::Mutex;

use super::event::Event;
use super::queue::Queue;
use super::usm::UsmBuffer;

/// Occupancy and traffic counters for a [`UsmArena`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ArenaStats {
    /// Leases handed out.
    pub checkouts: u64,
    /// Checkouts served from a parked allocation (no device malloc).
    pub hits: u64,
    /// Checkouts that had to `malloc_device` (cold class).
    pub misses: u64,
    /// Leases returned to the free lists.
    pub recycles: u64,
    /// Leases dropped without [`UsmLease::recycle`]: the allocation was
    /// freed instead of parked and its pending events were discarded.
    pub leaked: u64,
    /// Leases currently checked out.
    pub live: u64,
    /// Allocations parked in the free lists.
    pub pooled: u64,
    /// Bytes parked in the free lists.
    pub pooled_bytes: u64,
}

impl ArenaStats {
    /// Fraction of checkouts served without a device malloc (0 when the
    /// arena is untouched).
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            0.0
        } else {
            self.hits as f64 / self.checkouts as f64
        }
    }
}

struct Parked<T> {
    buf: UsmBuffer<T>,
    /// Last commands that touched the buffer — the dependency set the
    /// next checkout must chain behind.
    pending: Vec<Event>,
    /// Recycle count of this allocation; the next checkout's lease is
    /// stamped with it so commands can tag their accesses.
    generation: u64,
}

struct ArenaState<T> {
    /// Free lists indexed by size class (class `c` holds `1 << c`-element
    /// allocations).
    free: Vec<Vec<Parked<T>>>,
    stats: ArenaStats,
}

/// A worker-owned pool of recycled [`UsmBuffer`] allocations in
/// power-of-two size classes.
pub struct UsmArena<T> {
    state: Mutex<ArenaState<T>>,
}

/// Size class for an `n`-element request: smallest power of two >= n.
fn class_of(n: usize) -> usize {
    (usize::BITS - n.max(1).next_power_of_two().leading_zeros() - 1) as usize
}

impl<T: Clone + Default + Send + 'static> UsmArena<T> {
    /// Empty arena (allocations happen lazily on checkout misses).
    pub fn new() -> UsmArena<T> {
        UsmArena {
            state: Mutex::new(ArenaState {
                free: (0..usize::BITS as usize).map(|_| Vec::new()).collect(),
                stats: ArenaStats::default(),
            }),
        }
    }

    /// Check out an allocation of at least `n` elements. A parked
    /// allocation of the matching size class is reused (hit); otherwise
    /// `queue.malloc_device` pays the real allocation cost (miss). Return
    /// the lease with [`UsmLease::recycle`] — dropping it leaks (see
    /// module docs).
    pub fn checkout(&self, queue: &Queue, n: usize) -> UsmLease<'_, T> {
        let class = class_of(n);
        let parked = {
            let mut st = self.state.lock().unwrap();
            st.stats.checkouts += 1;
            st.stats.live += 1;
            match st.free[class].pop() {
                Some(p) => {
                    st.stats.hits += 1;
                    st.stats.pooled -= 1;
                    st.stats.pooled_bytes -=
                        ((1usize << class) * std::mem::size_of::<T>()) as u64;
                    Some(p)
                }
                None => {
                    st.stats.misses += 1;
                    None
                }
            }
        };
        // The miss path mallocs outside the state lock: the queue models
        // the blocking host call and must not serialise other checkouts.
        let parked = parked.unwrap_or_else(|| Parked {
            buf: queue.malloc_device::<T>(1usize << class),
            pending: Vec::new(),
            generation: 0,
        });
        UsmLease {
            arena: self,
            class,
            buf: Some(parked.buf),
            pending: parked.pending,
            generation: parked.generation,
        }
    }

    /// Current counters.
    pub fn stats(&self) -> ArenaStats {
        self.state.lock().unwrap().stats
    }

    fn park(&self, class: usize, buf: UsmBuffer<T>, pending: Vec<Event>, generation: u64) {
        let mut st = self.state.lock().unwrap();
        st.stats.recycles += 1;
        st.stats.live -= 1;
        st.stats.pooled += 1;
        st.stats.pooled_bytes += ((1usize << class) * std::mem::size_of::<T>()) as u64;
        st.free[class].push(Parked { buf, pending, generation });
    }

    fn leak(&self) {
        let mut st = self.state.lock().unwrap();
        st.stats.leaked += 1;
        st.stats.live -= 1;
    }
}

impl<T: Clone + Default + Send + 'static> Default for UsmArena<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A checked-out arena allocation. [`UsmLease::recycle`] parks the buffer
/// back in the arena's free list together with the pending events recorded
/// through [`UsmLease::set_pending`], bumping its generation; dropping the
/// lease instead frees the allocation and counts a leak (see module docs).
pub struct UsmLease<'a, T: Clone + Default + Send + 'static> {
    arena: &'a UsmArena<T>,
    class: usize,
    buf: Option<UsmBuffer<T>>,
    pending: Vec<Event>,
    generation: u64,
}

impl<T: Clone + Default + Send + 'static> UsmLease<'_, T> {
    /// The leased allocation (capacity `>=` the requested element count).
    pub fn buffer(&self) -> &UsmBuffer<T> {
        self.buf.as_ref().expect("lease already recycled")
    }

    /// Capacity in elements (the size class, not the requested count).
    pub fn capacity(&self) -> usize {
        1usize << self.class
    }

    /// Events of the last commands that touched this allocation before it
    /// was recycled — the dependency set a new user must chain behind
    /// (USM hazards are explicit; see module docs).
    pub fn deps(&self) -> &[Event] {
        &self.pending
    }

    /// Record the events of the commands this lease submitted, replacing
    /// the inherited set; they travel with the buffer into the free list.
    pub fn set_pending(&mut self, events: Vec<Event>) {
        self.pending = events;
    }

    /// How many times this allocation has been recycled before this
    /// checkout (0 for a cold allocation). Stamp it on the lease's USM
    /// accesses ([`crate::sycl::Access::usm_leased`]) so the hazard
    /// analyzer can reason about reuse across recycles.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Return the allocation to the arena's free list together with its
    /// pending events, bumping the generation the next checkout will see.
    /// This is the only way back into the pool — a lease that is merely
    /// dropped leaks instead.
    pub fn recycle(mut self) {
        let buf = self.buf.take().expect("lease buffer already taken");
        let pending = std::mem::take(&mut self.pending);
        self.arena.park(self.class, buf, pending, self.generation + 1);
    }
}

impl<T: Clone + Default + Send + 'static> Drop for UsmLease<'_, T> {
    fn drop(&mut self) {
        // Not recycled: free the allocation (dropping `buf` releases it),
        // discard pending events, and make the loss observable.
        if self.buf.take().is_some() {
            self.arena.leak();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;
    use crate::sycl::SyclRuntimeProfile;

    fn q() -> Queue {
        Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp)
    }

    #[test]
    fn size_classes_are_power_of_two_ceilings() {
        assert_eq!(class_of(0), 0);
        assert_eq!(class_of(1), 0);
        assert_eq!(class_of(2), 1);
        assert_eq!(class_of(3), 2);
        assert_eq!(class_of(4), 2);
        assert_eq!(class_of(5), 3);
        assert_eq!(class_of(1 << 20), 20);
        assert_eq!(class_of((1 << 20) + 1), 21);
    }

    #[test]
    fn checkout_recycle_checkout_hits_the_same_allocation() {
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        let lease = arena.checkout(&queue, 1000);
        assert!(lease.capacity() >= 1000);
        assert_eq!(lease.generation(), 0);
        let first_id = lease.buffer().id();
        lease.recycle();
        let lease = arena.checkout(&queue, 900); // same class (1024)
        assert_eq!(lease.buffer().id(), first_id);
        assert_eq!(lease.generation(), 1);
        let s = arena.stats();
        assert_eq!(s.checkouts, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.recycles, 1);
        assert_eq!(s.live, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_classes_do_not_share_allocations() {
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        let small = arena.checkout(&queue, 100);
        let large = arena.checkout(&queue, 100_000);
        assert_ne!(small.buffer().id(), large.buffer().id());
        assert_ne!(small.capacity(), large.capacity());
        small.recycle();
        large.recycle();
        let s = arena.stats();
        assert_eq!(s.misses, 2);
        assert_eq!(s.pooled, 2);
        assert_eq!(
            s.pooled_bytes,
            ((128 + 131_072) * std::mem::size_of::<f32>()) as u64
        );
    }

    #[test]
    fn pending_events_travel_with_the_recycled_buffer() {
        use crate::platform::CommandCost;
        use crate::sycl::CommandClass;
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        let mut lease = arena.checkout(&queue, 64);
        let ev = queue.submit_usm(
            "touch",
            CommandClass::Generate,
            CommandCost::Kernel { bytes_read: 0, bytes_written: 256, items: 64, tpb: 0 },
            &[],
            vec![crate::sycl::Access::usm_leased(
                lease.buffer().id(),
                crate::sycl::AccessMode::Write,
                Some(lease.generation()),
            )],
            |_| {},
        );
        lease.set_pending(vec![ev.clone()]);
        lease.recycle();
        let next = arena.checkout(&queue, 64);
        assert_eq!(next.deps().len(), 1);
        assert_eq!(next.deps()[0].id(), ev.id());
        // A cold checkout carries no inherited hazards.
        let cold = arena.checkout(&queue, 64);
        assert!(cold.deps().is_empty());
    }

    #[test]
    fn steady_state_serves_without_mallocs() {
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        for _ in 0..100 {
            let lease = arena.checkout(&queue, 4096);
            lease.recycle();
        }
        let s = arena.stats();
        assert_eq!(s.checkouts, 100);
        assert_eq!(s.misses, 1);
        assert!(s.hit_rate() > 0.98);
        assert_eq!(s.live, 0);
        assert_eq!(s.pooled, 1);
        assert_eq!(s.leaked, 0);
    }

    #[test]
    fn dropping_without_recycle_is_an_observable_leak() {
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        let first_id = {
            let lease = arena.checkout(&queue, 256);
            lease.buffer().id()
        }; // dropped, not recycled
        let s = arena.stats();
        assert_eq!(s.leaked, 1);
        assert_eq!(s.recycles, 0);
        assert_eq!(s.live, 0);
        assert_eq!(s.pooled, 0);
        // The allocation did not survive: the next checkout is a fresh
        // malloc with a new id and a reset generation.
        let lease = arena.checkout(&queue, 256);
        assert_ne!(lease.buffer().id(), first_id);
        assert_eq!(lease.generation(), 0);
        assert_eq!(arena.stats().misses, 2);
        lease.recycle();
    }

    #[test]
    fn generations_count_recycles_per_allocation() {
        let queue = q();
        let arena: UsmArena<f32> = UsmArena::new();
        for expect in 0..5 {
            let lease = arena.checkout(&queue, 512);
            assert_eq!(lease.generation(), expect);
            lease.recycle();
        }
    }
}
