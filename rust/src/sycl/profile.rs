//! SYCL runtime profiles: the two compilers' runtime cost structures.
//!
//! The paper attributes every native-vs-SYCL delta to a small set of
//! runtime behaviours; each is a constant here (values calibrated so the
//! computed Table 2 lands near the paper's — see EXPERIMENTS.md):
//!
//! * DPC++ issues completion callbacks between dependent commands and its
//!   USM event-wait path is expensive (the Fig. 3b / Table 2 USM penalty).
//! * hipSYCL is "nearly callback-free" (§7) and its buffer DAG scheduling
//!   is cheap enough to *beat* the native HIP application at small batches.
//! * DPC++ lets the runtime choose the thread-block size — 1024 on the
//!   A100 vs the native app's 256 (the Fig. 4b occupancy divergence).

use crate::platform::{PlatformKind, PlatformSpec};

/// Which SYCL compiler/runtime stack a queue models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SyclRuntimeProfile {
    /// Intel LLVM DPC++ (sycl-nightly-20210330).
    Dpcpp,
    /// hipSYCL 0.9.0.
    HipSycl,
}

impl SyclRuntimeProfile {
    /// The profile the paper uses for a given platform (Table 1):
    /// DPC++ everywhere except the Radeon, which uses hipSYCL.
    pub fn for_platform(spec: &PlatformSpec) -> Self {
        if spec.compiler.contains("hipSYCL") && spec.kind == PlatformKind::DiscreteGpu {
            SyclRuntimeProfile::HipSycl
        } else {
            SyclRuntimeProfile::Dpcpp
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            SyclRuntimeProfile::Dpcpp => "DPC++",
            SyclRuntimeProfile::HipSycl => "hipSYCL",
        }
    }

    /// Host cost of submitting one command group.
    pub fn submit_overhead_ns(self) -> u64 {
        match self {
            SyclRuntimeProfile::Dpcpp => 3_500,
            SyclRuntimeProfile::HipSycl => 2_500,
        }
    }

    /// Host cost per declared accessor (DAG bookkeeping on the scheduler
    /// thread).
    pub fn accessor_overhead_ns(self) -> u64 {
        match self {
            SyclRuntimeProfile::Dpcpp => 700,
            SyclRuntimeProfile::HipSycl => 500,
        }
    }

    /// Scheduling gap inserted before a command with buffer-DAG
    /// dependencies (runtime callback signalling task completion).
    pub fn dag_callback_ns(self) -> u64 {
        match self {
            SyclRuntimeProfile::Dpcpp => 6_000,
            SyclRuntimeProfile::HipSycl => 600, // nearly callback-free
        }
    }

    /// Extra wait cost per *explicit* event dependency on the USM path.
    pub fn usm_dep_wait_ns(self) -> u64 {
        match self {
            SyclRuntimeProfile::Dpcpp => 2_000,
            SyclRuntimeProfile::HipSycl => 500,
        }
    }

    /// Per-submission overhead of the USM path on top of
    /// [`Self::submit_overhead_ns`]. "The DPC++ runtime scheduler does not
    /// perform the same for the USM version as that of for the buffer one"
    /// (§7): on CUDA devices DPC++'s USM command chain goes through an
    /// expensive stream-event wait per command — the Fig. 3b / Table 2
    /// {A100} USM ≈ 0.24 collapse. Host and UMA devices don't pay it
    /// (Fig. 2 shows buffer ≈ USM on CPUs/iGPU).
    pub fn usm_submit_overhead_ns(self, spec: &PlatformSpec) -> u64 {
        match (self, spec.kind) {
            (SyclRuntimeProfile::Dpcpp, PlatformKind::DiscreteGpu) => 330_000,
            (SyclRuntimeProfile::Dpcpp, _) => 1_200,
            (SyclRuntimeProfile::HipSycl, _) => 800,
        }
    }

    /// One-time oneMKL wrapper overhead on generator construction for a
    /// given memory API: engine-class setup, internal state buffers and
    /// (USM on CUDA) the event-pool initialisation. These four constants
    /// are the calibration levers for the paper's Table 2 (see
    /// EXPERIMENTS.md §Calibration).
    pub fn onemkl_setup_overhead_ns(self, usm: bool, spec: &PlatformSpec) -> u64 {
        match (self, usm, spec.kind) {
            (SyclRuntimeProfile::HipSycl, false, _) => 55_000,
            (SyclRuntimeProfile::HipSycl, true, _) => 36_000,
            (SyclRuntimeProfile::Dpcpp, true, PlatformKind::DiscreteGpu) => 1_300_000,
            (SyclRuntimeProfile::Dpcpp, false, PlatformKind::DiscreteGpu) => 12_000,
            (SyclRuntimeProfile::Dpcpp, _, _) => 4_000,
        }
    }

    /// Final queue-synchronisation cost (queue::wait).
    pub fn sync_ns(self) -> u64 {
        match self {
            SyclRuntimeProfile::Dpcpp => 5_000,
            SyclRuntimeProfile::HipSycl => 2_000,
        }
    }

    /// Thread-block size the runtime selects when the kernel does not
    /// specify one. DPC++ picks the device maximum (1024 observed on the
    /// A100); hipSYCL follows the native default.
    pub fn pick_tpb(self, spec: &PlatformSpec) -> u32 {
        match spec.kind {
            PlatformKind::Cpu => 1,
            _ => match self {
                SyclRuntimeProfile::Dpcpp => 1_024,
                SyclRuntimeProfile::HipSycl => spec.native_tpb,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    #[test]
    fn platform_profile_assignment_matches_table1() {
        assert_eq!(
            SyclRuntimeProfile::for_platform(&PlatformId::Vega56.spec()),
            SyclRuntimeProfile::HipSycl
        );
        for p in [PlatformId::A100, PlatformId::Uhd630, PlatformId::CoreI7_10875H] {
            assert_eq!(
                SyclRuntimeProfile::for_platform(&p.spec()),
                SyclRuntimeProfile::Dpcpp,
                "{p:?}"
            );
        }
    }

    #[test]
    fn dpcpp_picks_1024_on_a100() {
        let spec = PlatformId::A100.spec();
        assert_eq!(SyclRuntimeProfile::Dpcpp.pick_tpb(&spec), 1024);
        assert_eq!(SyclRuntimeProfile::HipSycl.pick_tpb(&spec), 256);
    }

    #[test]
    fn hipsycl_is_nearly_callback_free() {
        assert!(
            SyclRuntimeProfile::HipSycl.dag_callback_ns() * 5
                < SyclRuntimeProfile::Dpcpp.dag_callback_ns()
        );
    }
}
