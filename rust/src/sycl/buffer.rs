//! Buffer API: encapsulating objects with runtime-tracked dependencies.
//!
//! "Buffers ... provide a simple yet powerful way for the SYCL runtime to
//! handle data dependencies between kernels" (paper §4.1). A [`Buffer`]
//! owns its storage; command groups declare accessors with an
//! [`AccessMode`] and the queue derives RAW/WAR/WAW edges automatically.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::event::Event;

static NEXT_BUFFER_ID: AtomicU64 = AtomicU64::new(1);

/// SYCL access modes (the subset the paper's listings use).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessMode {
    /// `access::mode::read`
    Read,
    /// `access::mode::write`
    Write,
    /// `access::mode::read_write`
    ReadWrite,
}

impl AccessMode {
    /// Does this access observe prior writes?
    pub fn reads(self) -> bool {
        matches!(self, AccessMode::Read | AccessMode::ReadWrite)
    }

    /// Does this access mutate the buffer?
    pub fn writes(self) -> bool {
        matches!(self, AccessMode::Write | AccessMode::ReadWrite)
    }
}

#[derive(Debug, Default)]
pub(crate) struct BufferDeps {
    /// Last command that wrote the buffer.
    pub last_write: Option<Event>,
    /// Readers since the last write (WAR hazards).
    pub readers_since_write: Vec<Event>,
    /// Whether a device-resident copy exists (non-UMA devices insert an
    /// implicit H2D transfer on first device use).
    pub device_resident: bool,
}

#[derive(Debug)]
pub(crate) struct BufferInner<T> {
    pub id: u64,
    pub data: Mutex<Vec<T>>,
    /// Shared separately from the typed payload so the queue can track
    /// dependencies for heterogeneous buffers uniformly.
    pub deps: Arc<Mutex<BufferDeps>>,
}

/// A 1-D SYCL buffer of `T`.
#[derive(Debug, Clone)]
pub struct Buffer<T> {
    pub(crate) inner: Arc<BufferInner<T>>,
}

impl<T: Clone + Default + Send + 'static> Buffer<T> {
    /// Uninitialised (default-filled) buffer of length `n`.
    pub fn new(n: usize) -> Self {
        Buffer::from_vec(vec![T::default(); n])
    }

    /// Buffer taking ownership of host data.
    pub fn from_vec(data: Vec<T>) -> Self {
        Buffer {
            inner: Arc::new(BufferInner {
                id: NEXT_BUFFER_ID.fetch_add(1, Ordering::Relaxed),
                data: Mutex::new(data),
                deps: Arc::new(Mutex::new(BufferDeps::default())),
            }),
        }
    }

    /// Unique buffer id.
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// Length in elements.
    pub fn len(&self) -> usize {
        self.inner.data.lock().unwrap().len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Direct host snapshot WITHOUT timeline accounting — for tests and
    /// assertions only. Production reads go through
    /// [`crate::sycl::Queue::host_read`], which models the D2H transfer.
    pub fn snapshot(&self) -> Vec<T> {
        self.inner.data.lock().unwrap().clone()
    }

    /// Lock the backing store (used by accessors inside command closures).
    pub fn lock(&self) -> MutexGuard<'_, Vec<T>> {
        self.inner.data.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes() {
        assert!(AccessMode::Read.reads() && !AccessMode::Read.writes());
        assert!(!AccessMode::Write.reads() && AccessMode::Write.writes());
        assert!(AccessMode::ReadWrite.reads() && AccessMode::ReadWrite.writes());
    }

    #[test]
    fn unique_ids() {
        let a = Buffer::<f32>::new(4);
        let b = Buffer::<f32>::new(4);
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn snapshot_reflects_mutation() {
        let buf = Buffer::from_vec(vec![1u32, 2, 3]);
        buf.lock()[1] = 99;
        assert_eq!(buf.snapshot(), vec![1, 99, 3]);
    }
}
