//! Mini property-testing kit (substrate — proptest is unavailable offline).
//!
//! Deterministic randomized testing driven by our own Philox engine: a
//! [`Gen`] produces structured random inputs from a seed; [`forall`] runs a
//! property over many cases and reports the failing seed + case for exact
//! reproduction (`PORTARNG_PROPTEST_SEED=<n>` to re-run a failure).

use crate::rng::engines::{Engine, PhiloxEngine};

/// Deterministic input generator for property tests.
pub struct Gen {
    engine: PhiloxEngine,
}

impl Gen {
    /// New generator from a case seed.
    pub fn new(seed: u64) -> Self {
        Gen { engine: PhiloxEngine::new(seed) }
    }

    /// Uniform u32.
    pub fn u32(&mut self) -> u32 {
        self.engine.next_u32()
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        (self.engine.next_u32() as u64) << 32 | self.engine.next_u32() as u64
    }

    /// Uniform in [lo, hi] (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.u64() % (hi - lo + 1)
    }

    /// Uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// f32 in [0, 1).
    pub fn unit_f32(&mut self) -> f32 {
        crate::rng::u32_to_uniform_f32(self.u32())
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.unit_f32() * (hi - lo)
    }

    /// Bool with probability `p`.
    pub fn bool_with(&mut self, p: f32) -> bool {
        self.unit_f32() < p
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vector of n draws.
    pub fn vec_u32(&mut self, n: usize) -> Vec<u32> {
        let mut v = vec![0u32; n];
        self.engine.fill_u32(&mut v);
        v
    }
}

/// Run `cases` random property checks. The property returns `Err(msg)` to
/// fail; the panic message includes the exact case seed.
pub fn forall(name: &str, cases: usize, property: impl Fn(&mut Gen) -> Result<(), String>) {
    let base = std::env::var("PORTARNG_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    let case_seeds: Vec<u64> = match base {
        Some(s) => vec![s],
        None => (0..cases as u64).map(|i| 0x9E37_79B9u64.wrapping_mul(i + 1)).collect(),
    };
    for seed in case_seeds {
        let mut gen = Gen::new(seed);
        if let Err(msg) = property(&mut gen) {
            panic!(
                "property `{name}` failed for seed {seed}: {msg}\n\
                 reproduce with PORTARNG_PROPTEST_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u32-in-range", 50, |g| {
            let x = g.range(10, 20);
            if (10..=20).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn forall_reports_failures() {
        forall("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.vec_u32(16), b.vec_u32(16));
        assert_eq!(a.f32_in(-1.0, 1.0), b.f32_in(-1.0, 1.0));
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut g = Gen::new(1);
        let xs = [0usize, 1, 2, 3];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[*g.choose(&xs)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
