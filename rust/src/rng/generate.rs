//! The oneMKL generate entry points (paper §4.1: "each engine class
//! comprises 36 high-level generate function templates — 18 per buffer and
//! USM API").
//!
//! [`generate_buffer`] is the paper's Listing 1.1 + 1.2 pair: an interop
//! host task calls the vendor's generation routine into the buffer, then a
//! SYCL kernel applies the range transformation; the dependency between the
//! two is derived automatically from the `read_write` accessors.
//! [`generate_usm`] is the same flow on the pointer path with an explicit
//! event chain, and [`generate_batch_usm`] coalesces a whole serving
//! flush — many requests at distinct global stream offsets — into that
//! same two-kernel shape (one interop host task, one transform kernel)
//! plus per-member D2H slices. All three write vendor output directly
//! into accessor/USM memory inside the command closure; there is no
//! staging copy anywhere on the path. [`catalog`] enumerates the 36-entry
//! API surface and which entries each backend class supports (20/36 on
//! cuRAND/hipRAND).

use crate::backends::VendorGenerator;
use crate::error::Result;
use crate::platform::CommandCost;
use crate::sycl::{
    Access, AccessMode, Buffer, CommandClass, Event, Queue, TileExecutor, TileTiming, TilingSpec,
    UsmBuffer,
};

use super::distributions::{Distribution, GaussianMethod, UniformMethod};
use super::engines::Engine;
use super::range_transform;

/// Which memory API a generate call uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GenerateApi {
    /// Accessor/DAG path.
    Buffer,
    /// Pointer/event path.
    Usm,
}

fn generate_kernel_cost(n: usize) -> CommandCost {
    CommandCost::Kernel {
        bytes_read: 0,
        bytes_written: (n as u64) * 4,
        items: n as u64,
        tpb: 0, // runtime chooses (profile.pick_tpb)
    }
}

fn transform_kernel_cost(n: usize) -> CommandCost {
    CommandCost::Kernel {
        bytes_read: (n as u64) * 4,
        bytes_written: (n as u64) * 4,
        items: n as u64,
        tpb: 0,
    }
}

/// Post-generation transform parameters for a distribution.
fn transform_params(distr: &Distribution) -> Option<(f32, f32, bool)> {
    match *distr {
        Distribution::Uniform { a, b, .. } if distr.requires_range_transform() => {
            Some((a, b, false))
        }
        Distribution::Gaussian { mean, stddev, .. } if distr.requires_range_transform() => {
            Some((mean, stddev, true))
        }
        Distribution::Lognormal { .. } => None, // exp applied below
        _ => None,
    }
}

/// Buffer-API generate: Listing 1.1 (interop kernel) + Listing 1.2
/// (transform kernel). Returns the last event.
///
/// Error semantics mirror a real runtime: the command group is submitted
/// and the vendor call fails *inside* the host task, so a rejected
/// combination (e.g. ICDF on cuRAND) still leaves a recorded — but
/// data-less — Generate command on the queue (and, on this path, a write
/// registered against the buffer). Callers must treat the buffer contents
/// as undefined after an `Err`.
pub fn generate_buffer(
    queue: &Queue,
    generator: &mut Box<dyn VendorGenerator>,
    distr: Distribution,
    n: usize,
    buf: &Buffer<f32>,
) -> Result<Event> {
    assert!(buf.len() >= n, "output buffer too small");

    // Kernel 1: SYCL interop host task wrapping the vendor call
    // (cgh.codeplay_host_task in the paper's listing). The vendor call
    // happens inside the command closure, writing directly into the
    // accessor's native memory — no staging allocation, exactly the
    // paper's `ih.get_native_mem` flow. The closure may borrow
    // `generator` because command groups execute eagerly.
    let mut vendor: Result<()> = Ok(());
    let name = format!("{}::generate", generator.backend_name());
    // Reborrows moved into the task closure (the task outlives the
    // command-group closure's body, so it cannot borrow its locals).
    let vendor_slot = &mut vendor;
    let gen_ref = &mut *generator;
    let distr_ref = &distr;
    let gen_ev = queue.submit(|cgh| {
        let acc = cgh.require(buf, AccessMode::ReadWrite);
        cgh.host_task(name, CommandClass::Generate, generate_kernel_cost(n), move |ih| {
            let mut mem = ih.get_native_mem(&acc);
            *vendor_slot = gen_ref.generate_canonical(distr_ref, &mut mem[..n]);
        });
    });
    vendor?;

    // Kernel 2: the range-transformation kernel (pure SYCL, Listing 1.2).
    // The RAW dependency on kernel 1 is derived from the accessors.
    if let Some((p0, p1, gaussian)) = transform_params(&distr) {
        let ev = queue.submit(move |cgh| {
            let acc = cgh.require(buf, AccessMode::ReadWrite);
            cgh.parallel_for(
                "range_transform_fp",
                CommandClass::Transform,
                transform_kernel_cost(n),
                move |ih| {
                    let mut mem = ih.get_native_mem(&acc);
                    if gaussian {
                        range_transform::scale_gaussian_inplace(&mut mem[..n], p0, p1);
                    } else {
                        range_transform::range_transform_inplace(&mut mem[..n], p0, p1);
                    }
                },
            );
        });
        return Ok(ev);
    }
    if let Distribution::Lognormal { m, s, .. } = distr {
        let ev = queue.submit(move |cgh| {
            let acc = cgh.require(buf, AccessMode::ReadWrite);
            cgh.parallel_for(
                "lognormal_transform",
                CommandClass::Transform,
                transform_kernel_cost(n),
                move |ih| {
                    let mut mem = ih.get_native_mem(&acc);
                    for x in mem[..n].iter_mut() {
                        *x = (m + s * *x).exp();
                    }
                },
            );
        });
        return Ok(ev);
    }
    Ok(gen_ev)
}

/// USM-API generate: same two kernels, dependencies threaded explicitly
/// through the returned events (paper §4.3: "a direct injection of the
/// event object returned by the command group handler"). As with
/// [`generate_buffer`], a failing vendor call errors *inside* the
/// submitted host task: the Generate command stays recorded and the USM
/// contents are undefined after an `Err`.
pub fn generate_usm(
    queue: &Queue,
    generator: &mut Box<dyn VendorGenerator>,
    distr: Distribution,
    n: usize,
    usm: &UsmBuffer<f32>,
    deps: &[Event],
) -> Result<Event> {
    assert!(usm.len() >= n, "output allocation too small");

    // The vendor call writes directly into USM memory inside the command
    // closure — no staging vec (USM submissions were never `'static`, the
    // staging here was pure legacy).
    let mut vendor: Result<()> = Ok(());
    let name = format!("{}::generate", generator.backend_name());
    let gen_ev = queue.submit_usm(
        name,
        CommandClass::Generate,
        generate_kernel_cost(n),
        deps,
        vec![Access::usm(usm.id(), AccessMode::Write)],
        |_ih| {
            vendor = generator.generate_canonical(&distr, &mut usm.lock()[..n]);
        },
    );
    vendor?;

    if let Some((p0, p1, gaussian)) = transform_params(&distr) {
        let ev = queue.submit_usm(
            "range_transform_fp",
            CommandClass::Transform,
            transform_kernel_cost(n),
            std::slice::from_ref(&gen_ev),
            vec![Access::usm(usm.id(), AccessMode::ReadWrite)],
            |_ih| {
                let mut mem = usm.lock();
                if gaussian {
                    range_transform::scale_gaussian_inplace(&mut mem[..n], p0, p1);
                } else {
                    range_transform::range_transform_inplace(&mut mem[..n], p0, p1);
                }
            },
        );
        return Ok(ev);
    }
    if let Distribution::Lognormal { m, s, .. } = distr {
        let ev = queue.submit_usm(
            "lognormal_transform",
            CommandClass::Transform,
            transform_kernel_cost(n),
            std::slice::from_ref(&gen_ev),
            vec![Access::usm(usm.id(), AccessMode::ReadWrite)],
            |_ih| {
                for x in usm.lock()[..n].iter_mut() {
                    *x = (m + s * *x).exp();
                }
            },
        );
        return Ok(ev);
    }
    Ok(gen_ev)
}

/// One member of a coalesced USM generate: a slice of the launch buffer
/// bound to an absolute offset in the global engine stream and its own
/// output range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchSlice {
    /// Start of the member's slice inside the launch buffer.
    pub buffer_offset: usize,
    /// Absolute offset in the global engine stream (O(1) skip-ahead).
    pub stream_offset: u64,
    /// Numbers wanted.
    pub n: usize,
    /// Output range `[a, b)`; `(0.0, 1.0)` needs no transform.
    pub range: (f32, f32),
}

/// Result of one [`generate_batch_usm`] / [`generate_batch_usm_tiled`]
/// flush.
#[derive(Debug)]
pub struct UsmBatch {
    /// Per-member readbacks (member order); a member fails alone when its
    /// vendor call errors, without poisoning the rest of the flush.
    pub payloads: Vec<Result<Vec<f32>>>,
    /// The interop generate host task (serial flush), or the *last* per-
    /// tile generate work item (tiled flush — compute commands serialize
    /// on the virtual timeline, so the last recorded one ends last).
    pub generate: Event,
    /// The range-transform kernel — single on a serial flush, the last
    /// per-tile one on a tiled flush; absent when every member asked for
    /// the canonical `[0, 1)` range.
    pub transform: Option<Event>,
    /// Per-member D2H slice copies, chained behind the last kernel
    /// covering the member's range.
    pub d2h: Vec<Event>,
    /// Real per-tile wall timings when the flush executed tiled (generate
    /// pass then transform pass, tile order within each); empty on the
    /// serial path.
    pub tiles: Vec<TileTiming>,
}

impl UsmBatch {
    /// The event completing the whole flush (for chaining into the next
    /// user of the allocation — e.g. [`crate::sycl::UsmLease::set_pending`]).
    pub fn last_events(&self) -> Vec<Event> {
        if self.d2h.is_empty() {
            let last = self.transform.clone().unwrap_or_else(|| self.generate.clone());
            vec![last]
        } else {
            self.d2h.clone()
        }
    }
}

/// Batched USM generate — the serving path's flush primitive. Renders one
/// closed batch of `members` (each at its own global stream offset, each
/// with its own output range) as exactly **one** interop generate host
/// task + at most **one** range-transform kernel over the whole launch
/// buffer + one D2H slice copy per member, all chained by events:
///
/// ```text
///   deps ─▶ vendor::generate_batch ─▶ range_transform_fp ─▶ d2h slice 0
///           (one host task; per-member                  ├▶ d2h slice 1
///            O(1) skip-ahead inside)                    └▶ ...
/// ```
///
/// Every member observes the bit-exact sub-stream a dedicated engine at
/// `stream_offset` would produce: the host task skips the shared engine to
/// each member's offset before generating its slice (counter-based, O(1)),
/// and the transform kernel applies each member's own affine range.
///
/// `generation` is the arena-lease generation when `usm` is a recycled
/// launch buffer ([`crate::sycl::UsmLease::generation`]) — stamped on the
/// kernels' access sets so the hazard analyzer can distinguish
/// reuse-after-recycle from use-after-recycle; pass `None` for a
/// non-arena allocation.
#[allow(clippy::too_many_arguments)]
pub fn generate_batch_usm(
    queue: &Queue,
    generator: &mut dyn VendorGenerator,
    members: &[BatchSlice],
    launch_n: usize,
    usm: &UsmBuffer<f32>,
    generation: Option<u64>,
    deps: &[Event],
) -> Result<UsmBatch> {
    if members.is_empty() {
        return Err(crate::error::Error::InvalidArgument(
            "generate_batch_usm: empty batch".into(),
        ));
    }
    assert!(usm.len() >= launch_n, "launch allocation too small");
    for m in members {
        assert!(
            m.buffer_offset + m.n <= launch_n,
            "batch member overruns the launch buffer"
        );
    }

    let canonical = Distribution::uniform(0.0, 1.0);
    let mut member_res: Vec<Result<()>> = Vec::with_capacity(members.len());
    let name = format!("{}::generate_batch", generator.backend_name());
    // Submission runs through the queue's fault seam: under a chaos plan
    // the whole flush can be refused before anything is recorded (the
    // caller fails every member with the — transient — injected error).
    // The per-member vendor seam lives inside `generate_canonical`.
    let gen_ev = queue.submit_usm_checked(
        name,
        CommandClass::Generate,
        generate_kernel_cost(launch_n),
        deps,
        vec![Access::usm_leased(usm.id(), AccessMode::Write, generation)],
        |_ih| {
            let mut mem = usm.lock();
            for m in members {
                let out = &mut mem[m.buffer_offset..m.buffer_offset + m.n];
                let r = generator
                    .set_offset(m.stream_offset)
                    .and_then(|()| generator.generate_canonical(&canonical, out));
                member_res.push(r);
            }
        },
    )?;

    // One transform kernel for the whole flush: each member's own affine
    // range applied to its slice (skipped entirely when every member is
    // canonical — matching the single-request path's record shape). The
    // kernel is costed by the items it actually transforms, so a mixed
    // canonical/ranged batch does not overstate the transform share in
    // the per-command-class telemetry.
    let transform_items: usize = members
        .iter()
        .zip(&member_res)
        .filter(|(m, r)| r.is_ok() && m.range != (0.0, 1.0))
        .map(|(m, _)| m.n)
        .sum();
    let transform_ev = (transform_items > 0).then(|| {
        queue.submit_usm(
            "range_transform_fp",
            CommandClass::Transform,
            transform_kernel_cost(transform_items),
            std::slice::from_ref(&gen_ev),
            vec![Access::usm_leased(usm.id(), AccessMode::ReadWrite, generation)],
            |_ih| {
                let mut mem = usm.lock();
                for (m, r) in members.iter().zip(&member_res) {
                    if r.is_ok() && m.range != (0.0, 1.0) {
                        range_transform::range_transform_inplace(
                            &mut mem[m.buffer_offset..m.buffer_offset + m.n],
                            m.range.0,
                            m.range.1,
                        );
                    }
                }
            },
        )
    });

    let last = transform_ev.as_ref().unwrap_or(&gen_ev).clone();
    let mut payloads = Vec::with_capacity(members.len());
    let mut d2h = Vec::with_capacity(members.len());
    for (m, r) in members.iter().zip(member_res) {
        match r {
            // The readback runs through the D2H fault seam: a tripped
            // member fails alone (no copy recorded, no event chained)
            // while the rest of the flush delivers normally.
            Ok(()) => match queue.usm_slice_to_host_checked(
                usm,
                m.buffer_offset,
                m.n,
                std::slice::from_ref(&last),
            ) {
                Ok((data, ev)) => {
                    payloads.push(Ok(data));
                    d2h.push(ev);
                }
                Err(e) => payloads.push(Err(e)),
            },
            Err(e) => payloads.push(Err(e)),
        }
    }
    Ok(UsmBatch { payloads, generate: gen_ev, transform: transform_ev, d2h, tiles: Vec::new() })
}

/// Tiled variant of [`generate_batch_usm`]: the flush executes as an
/// nd-range of independent tiles on a worker-local [`TileExecutor`] team
/// instead of one serial host task (DESIGN.md S16).
///
/// ```text
///   deps ─▶ generate[tile 0] ─▶ transform[tile 0] ─┐
///   deps ─▶ generate[tile 1] ─▶ transform[tile 1] ─┼▶ d2h per member
///   deps ─▶ ...                                    ┘  (deps = tiles the
///                                                      member overlaps)
/// ```
///
/// **Bit-identity:** a tile covering launch elements `[s, s+l)` of member
/// `m` generates from absolute stream position `m.stream_offset + (s -
/// m.buffer_offset)` — for a counter-based engine (`Engine::try_seek`)
/// that is *exactly* the sub-stream the serial pass writes there, so
/// tiled output equals serial output for every tile size and team width
/// (pinned by the parity tests below and `tests/coordinator.rs`).
///
/// Every tile records its own command: its own dependency list, measured
/// wall time, and an [`Access`] narrowed to the tile's element range — the
/// hazard analyzer *proves* tile disjointness rather than going blind.
/// Falls back to the serial path when `spec` is serial, the launch fits
/// one tile, or the engine cannot seek absolutely in place (XORWOW /
/// MT19937 / Sobol).
#[allow(clippy::too_many_arguments)]
pub fn generate_batch_usm_tiled(
    queue: &Queue,
    generator: &mut dyn VendorGenerator,
    members: &[BatchSlice],
    launch_n: usize,
    usm: &UsmBuffer<f32>,
    generation: Option<u64>,
    deps: &[Event],
    spec: TilingSpec,
    executor: &TileExecutor,
) -> Result<UsmBatch> {
    if members.is_empty() {
        return Err(crate::error::Error::InvalidArgument(
            "generate_batch_usm: empty batch".into(),
        ));
    }
    let tiles = spec.tiles(launch_n);
    if spec.is_serial() || tiles.len() <= 1 {
        return generate_batch_usm(queue, generator, members, launch_n, usm, generation, deps);
    }
    let Some(template) = generator.fork_engine_at(0) else {
        return generate_batch_usm(queue, generator, members, launch_n, usm, generation, deps);
    };
    assert!(usm.len() >= launch_n, "launch allocation too small");
    for m in members {
        assert!(
            m.buffer_offset + m.n <= launch_n,
            "batch member overruns the launch buffer"
        );
    }

    // Same whole-flush submission seam as the serial path's
    // `submit_usm_checked`, tripped before anything is recorded...
    crate::fault::trip(crate::fault::FaultSite::Submit)?;
    // ...and the same per-member vendor seam, tripped in member order on
    // the submitting thread (op-index parity with the serial flush, where
    // `generate_canonical` trips once per member inside the host task).
    let member_res: Vec<Result<()>> = members
        .iter()
        .map(|_| crate::fault::trip(crate::fault::FaultSite::Generate))
        .collect();

    // Segment each tile by the live members overlapping it. A tile's
    // generate segment is (offset within the tile, length, absolute
    // stream position); its transform segment additionally carries the
    // member's output range.
    let mut gen_segs: Vec<Vec<(usize, usize, u64)>> = vec![Vec::new(); tiles.len()];
    let mut tf_segs: Vec<Vec<(usize, usize, f32, f32)>> = vec![Vec::new(); tiles.len()];
    for (m, r) in members.iter().zip(&member_res) {
        if r.is_err() {
            continue;
        }
        let (m_lo, m_hi) = (m.buffer_offset, m.buffer_offset + m.n);
        for (t, &(t_start, t_len)) in tiles.iter().enumerate() {
            let lo = m_lo.max(t_start);
            let hi = m_hi.min(t_start + t_len);
            if lo >= hi {
                continue;
            }
            let stream = m.stream_offset + (lo - m_lo) as u64;
            gen_segs[t].push((lo - t_start, hi - lo, stream));
            if m.range != (0.0, 1.0) {
                tf_segs[t].push((lo - t_start, hi - lo, m.range.0, m.range.1));
            }
        }
    }

    // One forked engine per tile: independent sub-streams by counter
    // arithmetic. The mutex only hands each team thread `&mut` access to
    // its own tile's engine — one tile, one uncontended lock.
    let engines: Vec<std::sync::Mutex<Box<dyn Engine>>> =
        tiles.iter().map(|_| std::sync::Mutex::new(template.clone_box())).collect();

    // Nd-range pass 1: generate. The launch buffer is locked once on the
    // submitting thread and carved into disjoint per-tile `&mut` slices
    // by the executor; each tile seeks to its segments' stream positions
    // and fills the canonical uniforms the serial pass would have.
    let gen_timings = {
        let mut mem = usm.lock();
        executor.run(&mut mem[..launch_n], &tiles, |tile, _start, slice| {
            let mut e = engines[tile].lock().unwrap();
            for &(local, len, stream) in &gen_segs[tile] {
                let sought = e.try_seek(stream);
                debug_assert!(sought, "forked engine lost its seek capability");
                e.fill_uniform_f32(&mut slice[local..local + len]);
            }
        })
    };
    let name = format!("{}::generate_batch", generator.backend_name());
    let mut gen_events: Vec<Event> = Vec::with_capacity(tiles.len());
    for t in &gen_timings {
        gen_events.push(queue.submit_executed(
            format!("{name}[tile {}]", t.tile),
            CommandClass::Generate,
            generate_kernel_cost(t.len),
            deps,
            vec![Access::usm_leased(usm.id(), AccessMode::Write, generation)
                .with_range(t.start, t.len)],
            t.wall_ns,
        ));
    }

    // Nd-range pass 2: transform, only over tiles holding ranged
    // segments. Each tile's transform depends on *its own* generate only
    // — the declared ranges prove disjointness from every other tile.
    let mut tf_map: Vec<usize> = Vec::new();
    let mut tf_tiles: Vec<(usize, usize)> = Vec::new();
    for (t, &range) in tiles.iter().enumerate() {
        if !tf_segs[t].is_empty() {
            tf_map.push(t);
            tf_tiles.push(range);
        }
    }
    let mut transform_events: Vec<Option<Event>> = vec![None; tiles.len()];
    let mut all_timings = gen_timings;
    if !tf_tiles.is_empty() {
        let tf_timings = {
            let mut mem = usm.lock();
            executor.run(&mut mem[..launch_n], &tf_tiles, |i, _start, slice| {
                for &(local, len, a, b) in &tf_segs[tf_map[i]] {
                    range_transform::range_transform_inplace(&mut slice[local..local + len], a, b);
                }
            })
        };
        for timing in &tf_timings {
            let t = tf_map[timing.tile];
            let items: usize = tf_segs[t].iter().map(|s| s.1).sum();
            transform_events[t] = Some(queue.submit_executed(
                format!("range_transform_fp[tile {t}]"),
                CommandClass::Transform,
                transform_kernel_cost(items),
                std::slice::from_ref(&gen_events[t]),
                vec![Access::usm_leased(usm.id(), AccessMode::ReadWrite, generation)
                    .with_range(tiles[t].0, tiles[t].1)],
                timing.wall_ns,
            ));
            all_timings.push(TileTiming {
                tile: t,
                start: timing.start,
                len: timing.len,
                wall_ns: timing.wall_ns,
            });
        }
    }

    // Per-member D2H: chained behind the last kernel of every tile the
    // member overlaps — nothing else (the copy's declared read range is
    // disjoint from all other tiles, so the DAG stays provably race-free
    // with this minimal dependency set).
    let tile_last: Vec<Event> = (0..tiles.len())
        .map(|t| transform_events[t].clone().unwrap_or_else(|| gen_events[t].clone()))
        .collect();
    let mut payloads = Vec::with_capacity(members.len());
    let mut d2h = Vec::with_capacity(members.len());
    for (m, r) in members.iter().zip(member_res) {
        match r {
            Ok(()) => {
                let mdeps: Vec<Event> = tiles
                    .iter()
                    .enumerate()
                    .filter(|&(_, &(s, l))| s < m.buffer_offset + m.n && m.buffer_offset < s + l)
                    .map(|(t, _)| tile_last[t].clone())
                    .collect();
                match queue.usm_slice_to_host_checked(usm, m.buffer_offset, m.n, &mdeps) {
                    Ok((data, ev)) => {
                        payloads.push(Ok(data));
                        d2h.push(ev);
                    }
                    Err(e) => payloads.push(Err(e)),
                }
            }
            Err(e) => payloads.push(Err(e)),
        }
    }

    let generate = gen_events.last().expect("tiled flush has at least one tile").clone();
    let transform = tf_map.last().and_then(|&t| transform_events[t].clone());
    Ok(UsmBatch { payloads, generate, transform, d2h, tiles: all_timings })
}

/// Output type of a generate entry point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OutputType {
    /// f32 outputs.
    F32,
    /// f64 outputs.
    F64,
    /// raw u32 / u64 bit outputs.
    U32,
    /// u64 bits.
    U64,
}

/// One of the 36 generate function templates.
#[derive(Debug, Clone)]
pub struct GenerateEntry {
    /// Memory API.
    pub api: GenerateApi,
    /// Distribution family + method.
    pub distr: &'static str,
    /// Output type.
    pub ty: OutputType,
    /// Uses an ICDF-based method (unsupported on cuRAND/hipRAND backends
    /// for pseudorandom engines — paper §4.1/§4.3).
    pub icdf_based: bool,
}

/// The 36-entry API catalog (18 per memory API). The ICDF-based 16 are the
/// ones the paper's cuRAND/hipRAND backends cannot implement: "Of the total
/// 36 generate functions available in oneMKL, 20 are supported".
pub fn catalog() -> Vec<GenerateEntry> {
    let mut entries = Vec::new();
    for api in [GenerateApi::Buffer, GenerateApi::Usm] {
        let mut push = |distr: &'static str, ty: OutputType, icdf_based: bool| {
            entries.push(GenerateEntry { api, distr, ty, icdf_based });
        };
        // Uniform: standard (scale/offset) and accurate (ICDF-corrected).
        push("uniform/standard", OutputType::F32, false);
        push("uniform/standard", OutputType::F64, false);
        push("uniform/accurate", OutputType::F32, true);
        push("uniform/accurate", OutputType::F64, true);
        // Integer-range uniforms.
        push("uniform/int", OutputType::U32, false);
        push("uniform/int", OutputType::U64, false);
        // Gaussian: Box-Muller + ICDF.
        push("gaussian/box_muller", OutputType::F32, false);
        push("gaussian/box_muller", OutputType::F64, false);
        push("gaussian/icdf", OutputType::F32, true);
        push("gaussian/icdf", OutputType::F64, true);
        // Lognormal: Box-Muller + ICDF.
        push("lognormal/box_muller", OutputType::F32, false);
        push("lognormal/box_muller", OutputType::F64, false);
        push("lognormal/icdf", OutputType::F32, true);
        push("lognormal/icdf", OutputType::F64, true);
        // Exponential (ICDF by construction in oneMKL).
        push("exponential/icdf", OutputType::F32, true);
        push("exponential/icdf", OutputType::F64, true);
        // Poisson + raw bits.
        push("poisson/ptpe", OutputType::U32, false);
        push("bits", OutputType::U32, false);
    }
    entries
}

/// Parse CLI tokens for the memory API.
impl GenerateApi {
    /// "buffer" | "usm"
    pub fn parse(s: &str) -> Option<GenerateApi> {
        match s {
            "buffer" => Some(GenerateApi::Buffer),
            "usm" => Some(GenerateApi::Usm),
            _ => None,
        }
    }

    /// Token for reports.
    pub fn token(self) -> &'static str {
        match self {
            GenerateApi::Buffer => "buffer",
            GenerateApi::Usm => "usm",
        }
    }
}

/// Construct the benchmark distribution from CLI tokens, with explicit
/// per-family parameter arity:
///
/// * `uniform a b` — range `[a, b)`
/// * `gaussian mean stddev`
/// * `lognormal m s`
/// * `exponential lambda` — lambda is the FIRST (and only) parameter;
///   extra parameters are rejected rather than silently ignored
/// * `poisson lambda`
/// * `bits` — no parameters
pub fn parse_distribution(name: &str, params: &[f32]) -> crate::error::Result<Distribution> {
    use crate::error::Error;
    let arity = |want: usize| -> crate::error::Result<()> {
        if params.len() == want {
            Ok(())
        } else {
            Err(Error::InvalidArgument(format!(
                "distribution `{name}` takes {want} parameter(s), got {}",
                params.len()
            )))
        }
    };
    match name {
        "uniform" => {
            arity(2)?;
            Ok(Distribution::Uniform {
                a: params[0],
                b: params[1],
                method: UniformMethod::Standard,
            })
        }
        "gaussian" => {
            arity(2)?;
            Ok(Distribution::Gaussian {
                mean: params[0],
                stddev: params[1],
                method: GaussianMethod::BoxMuller,
            })
        }
        "lognormal" => {
            arity(2)?;
            Ok(Distribution::Lognormal {
                m: params[0],
                s: params[1],
                method: GaussianMethod::BoxMuller,
            })
        }
        "exponential" => {
            arity(1)?;
            Ok(Distribution::Exponential { lambda: params[0] })
        }
        "poisson" => {
            arity(1)?;
            Ok(Distribution::Poisson { lambda: params[0] as f64 })
        }
        "bits" => {
            arity(0)?;
            Ok(Distribution::Bits)
        }
        other => Err(Error::InvalidArgument(format!("unknown distribution `{other}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{CurandBackend, RngBackend};
    use crate::platform::PlatformId;
    use crate::rng::engines::{Engine, EngineKind, PhiloxEngine};
    use crate::sycl::SyclRuntimeProfile;

    #[test]
    fn catalog_is_36_with_16_icdf() {
        let cat = catalog();
        assert_eq!(cat.len(), 36);
        let icdf = cat.iter().filter(|e| e.icdf_based).count();
        assert_eq!(icdf, 16);
        // 20 supported on cuRAND/hipRAND (paper §4.3).
        assert_eq!(cat.len() - icdf, 20);
        let buffer = cat.iter().filter(|e| e.api == GenerateApi::Buffer).count();
        assert_eq!(buffer, 18);
    }

    #[test]
    fn buffer_generate_produces_vendor_stream_with_range() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 11).unwrap();
        let buf = Buffer::<f32>::new(1000);
        let distr = Distribution::uniform(-1.0, 1.0);
        generate_buffer(&queue, &mut gen, distr, 1000, &buf).unwrap();
        let out = queue.host_read(&buf);

        let mut want = vec![0f32; 1000];
        PhiloxEngine::new(11).fill_uniform_f32(&mut want);
        range_transform::range_transform_inplace(&mut want, -1.0, 1.0);
        assert_eq!(out, want);

        // Two kernels recorded: generate + transform (+ d2h).
        let classes: Vec<_> = queue.records().iter().map(|r| r.class).collect();
        assert!(classes.contains(&CommandClass::Generate));
        assert!(classes.contains(&CommandClass::Transform));
    }

    #[test]
    fn usm_generate_matches_buffer_generate() {
        let distr = Distribution::uniform(5.0, 9.0);
        let n = 4096;

        let qb = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let backend = crate::backends::HiprandBackend::new();
        let mut g1 = backend.create_generator(EngineKind::Philox4x32x10, 3).unwrap();
        let buf = Buffer::<f32>::new(n);
        generate_buffer(&qb, &mut g1, distr, n, &buf).unwrap();

        let qu = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let mut g2 = backend.create_generator(EngineKind::Philox4x32x10, 3).unwrap();
        let usm = qu.malloc_device::<f32>(n);
        let ev = generate_usm(&qu, &mut g2, distr, n, &usm, &[]).unwrap();
        let out_usm = qu.usm_to_host(&usm, std::slice::from_ref(&ev));

        assert_eq!(qb.host_read(&buf), out_usm);
    }

    #[test]
    fn no_transform_kernel_for_unit_range() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 1).unwrap();
        let buf = Buffer::<f32>::new(64);
        generate_buffer(&queue, &mut gen, Distribution::uniform(0.0, 1.0), 64, &buf).unwrap();
        let transforms = queue
            .records()
            .iter()
            .filter(|r| r.class == CommandClass::Transform)
            .count();
        assert_eq!(transforms, 0);
    }

    #[test]
    fn parse_distribution_maps_exponential_lambda_from_first_param() {
        // Regression: the old signature read lambda from the SECOND slot
        // and silently ignored the first.
        let d = parse_distribution("exponential", &[2.5]).unwrap();
        assert_eq!(d, Distribution::Exponential { lambda: 2.5 });
        // Extra parameter is an error, not silently dropped.
        assert!(parse_distribution("exponential", &[2.5, 9.0]).is_err());
        assert!(parse_distribution("exponential", &[]).is_err());
    }

    #[test]
    fn parse_distribution_arity_checks() {
        assert_eq!(
            parse_distribution("uniform", &[-1.0, 1.0]).unwrap(),
            Distribution::uniform(-1.0, 1.0)
        );
        assert!(parse_distribution("uniform", &[0.0]).is_err());
        assert_eq!(parse_distribution("bits", &[]).unwrap(), Distribution::Bits);
        assert!(parse_distribution("bits", &[1.0]).is_err());
        assert!(parse_distribution("nope", &[]).is_err());
        let g = parse_distribution("gaussian", &[3.0, 0.5]).unwrap();
        assert_eq!(g, Distribution::gaussian(3.0, 0.5));
    }

    #[test]
    fn batch_usm_matches_dedicated_engines_with_one_kernel_pair() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 77).unwrap();
        // Mixed sizes/offsets/ranges, deliberately not multiples of 4.
        let members = [
            BatchSlice { buffer_offset: 0, stream_offset: 500, n: 33, range: (0.0, 1.0) },
            BatchSlice { buffer_offset: 33, stream_offset: 0, n: 101, range: (-2.0, 2.0) },
            BatchSlice { buffer_offset: 134, stream_offset: 7_777, n: 66, range: (5.0, 9.0) },
        ];
        let usm = queue.malloc_device::<f32>(256);
        let batch =
            generate_batch_usm(&queue, gen.as_mut(), &members, 200, &usm, None, &[]).unwrap();

        for (m, payload) in members.iter().zip(&batch.payloads) {
            let got = payload.as_ref().unwrap();
            let mut want = vec![0f32; m.n];
            let mut e = PhiloxEngine::with_offset(77, m.stream_offset);
            e.fill_uniform_f32(&mut want);
            if m.range != (0.0, 1.0) {
                range_transform::range_transform_inplace(&mut want, m.range.0, m.range.1);
            }
            assert_eq!(got, &want, "member at stream offset {}", m.stream_offset);
        }

        // Exactly ONE generate host task + ONE transform kernel for the
        // whole flush, one D2H per member, all correctly chained.
        let records = queue.records();
        let count = |c: CommandClass| records.iter().filter(|r| r.class == c).count();
        assert_eq!(count(CommandClass::Generate), 1);
        assert_eq!(count(CommandClass::Transform), 1);
        assert_eq!(count(CommandClass::TransferD2H), members.len());
        let transform = batch.transform.as_ref().unwrap();
        assert!(transform.profiling_command_start() >= batch.generate.profiling_command_end());
        for ev in &batch.d2h {
            assert!(ev.profiling_command_start() >= transform.profiling_command_end());
        }
    }

    #[test]
    fn batch_usm_single_member_parity_with_unbatched_paths() {
        let distr = Distribution::uniform(-1.0, 3.0);
        let n = 999;

        let qb = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let backend = crate::backends::HiprandBackend::new();
        let mut g1 = backend.create_generator(EngineKind::Philox4x32x10, 5).unwrap();
        let buf = Buffer::<f32>::new(n);
        generate_buffer(&qb, &mut g1, distr, n, &buf).unwrap();

        let qx = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let mut g2 = backend.create_generator(EngineKind::Philox4x32x10, 5).unwrap();
        let usm = qx.malloc_device::<f32>(1024);
        let member =
            BatchSlice { buffer_offset: 0, stream_offset: 0, n, range: (-1.0, 3.0) };
        let batch = generate_batch_usm(&qx, g2.as_mut(), &[member], n, &usm, None, &[]).unwrap();
        assert_eq!(batch.payloads[0].as_ref().unwrap(), &qb.host_read(&buf));
    }

    #[test]
    fn batch_usm_all_canonical_skips_the_transform_kernel() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 1).unwrap();
        let members = [
            BatchSlice { buffer_offset: 0, stream_offset: 0, n: 64, range: (0.0, 1.0) },
            BatchSlice { buffer_offset: 64, stream_offset: 64, n: 64, range: (0.0, 1.0) },
        ];
        let usm = queue.malloc_device::<f32>(128);
        let batch =
            generate_batch_usm(&queue, gen.as_mut(), &members, 128, &usm, None, &[]).unwrap();
        assert!(batch.transform.is_none());
        // The flush's last events are the D2H copies, chained on generate.
        assert_eq!(batch.last_events().len(), 2);
        for ev in &batch.d2h {
            assert!(ev.profiling_command_start() >= batch.generate.profiling_command_end());
        }
        assert!(generate_batch_usm(&queue, gen.as_mut(), &[], 0, &usm, None, &[]).is_err());
    }

    #[test]
    fn tiled_batch_matches_serial_and_dedicated_engines_across_tile_shapes() {
        // The bit-identity statement of DESIGN.md S16: any (tile size,
        // team width) — including phase-unaligned tile boundaries —
        // produces exactly the serial flush's bytes.
        let members = [
            BatchSlice { buffer_offset: 0, stream_offset: 500, n: 33, range: (0.0, 1.0) },
            BatchSlice { buffer_offset: 33, stream_offset: 0, n: 101, range: (-2.0, 2.0) },
            BatchSlice { buffer_offset: 134, stream_offset: 7_777, n: 66, range: (5.0, 9.0) },
        ];
        let backend = CurandBackend::new();

        let qs = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let mut gs = backend.create_generator(EngineKind::Philox4x32x10, 77).unwrap();
        let usm_s = qs.malloc_device::<f32>(256);
        let serial =
            generate_batch_usm(&qs, gs.as_mut(), &members, 200, &usm_s, None, &[]).unwrap();

        for (tile_size, width) in [(37usize, 2usize), (64, 3), (50, 4), (7, 8), (1000, 4)] {
            let qt = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
            let mut gt = backend.create_generator(EngineKind::Philox4x32x10, 77).unwrap();
            let usm_t = qt.malloc_device::<f32>(256);
            let exec = TileExecutor::new(width);
            let tiled = generate_batch_usm_tiled(
                &qt,
                gt.as_mut(),
                &members,
                200,
                &usm_t,
                None,
                &[],
                TilingSpec::new(tile_size, width),
                &exec,
            )
            .unwrap();
            for (i, (m, payload)) in members.iter().zip(&tiled.payloads).enumerate() {
                let got = payload.as_ref().unwrap();
                assert_eq!(
                    got,
                    serial.payloads[i].as_ref().unwrap(),
                    "tile {tile_size} width {width} member {i} diverged from serial"
                );
                let mut want = vec![0f32; m.n];
                let mut e = PhiloxEngine::with_offset(77, m.stream_offset);
                e.fill_uniform_f32(&mut want);
                if m.range != (0.0, 1.0) {
                    range_transform::range_transform_inplace(&mut want, m.range.0, m.range.1);
                }
                assert_eq!(got, &want, "tile {tile_size} width {width} member {i} vs dedicated");
            }
        }
    }

    #[test]
    fn tiled_batch_records_one_command_per_tile_with_disjoint_ranges() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 77).unwrap();
        let members = [
            BatchSlice { buffer_offset: 0, stream_offset: 500, n: 33, range: (0.0, 1.0) },
            BatchSlice { buffer_offset: 33, stream_offset: 0, n: 101, range: (-2.0, 2.0) },
            BatchSlice { buffer_offset: 134, stream_offset: 7_777, n: 66, range: (5.0, 9.0) },
        ];
        let usm = queue.malloc_device::<f32>(256);
        let spec = TilingSpec::new(64, 4); // tiles (0,64) (64,64) (128,64) (192,8)
        let exec = TileExecutor::new(4);
        let batch = generate_batch_usm_tiled(
            &queue, gen.as_mut(), &members, 200, &usm, None, &[], spec, &exec,
        )
        .unwrap();

        let records = queue.records();
        let count = |c: CommandClass| records.iter().filter(|r| r.class == c).count();
        // One generate per tile; every tile holds a ranged segment here,
        // so one transform per tile too; one D2H per member.
        assert_eq!(count(CommandClass::Generate), 4);
        assert_eq!(count(CommandClass::Transform), 4);
        assert_eq!(count(CommandClass::TransferD2H), members.len());
        assert_eq!(batch.tiles.len(), 8); // 4 generate + 4 transform timings

        // Every kernel declares its tile's element range.
        let tiles = spec.tiles(200);
        let gens: Vec<_> =
            records.iter().filter(|r| r.class == CommandClass::Generate).collect();
        for (r, &(start, len)) in gens.iter().zip(&tiles) {
            assert_eq!(r.accesses[0].range, Some((start, len)), "generate {}", r.name);
        }
        // Each transform depends on exactly its own tile's generate.
        let by_id: std::collections::HashMap<u64, &crate::sycl::CommandRecord> =
            records.iter().map(|r| (r.id, r)).collect();
        for r in records.iter().filter(|r| r.class == CommandClass::Transform) {
            assert_eq!(r.dep_ids.len(), 1, "transform {}", r.name);
            let dep = by_id[&r.dep_ids[0]];
            assert_eq!(dep.class, CommandClass::Generate);
            assert_eq!(dep.accesses[0].range, r.accesses[0].range);
            assert!(r.virt_start_ns >= dep.virt_end_ns);
        }
        // Each member's D2H depends on exactly the tiles it overlaps:
        // member 0 spans tile 0; member 1 tiles 0-2; member 2 tiles 2-3.
        let d2h: Vec<_> =
            records.iter().filter(|r| r.class == CommandClass::TransferD2H).collect();
        assert_eq!(
            d2h.iter().map(|r| r.dep_ids.len()).collect::<Vec<_>>(),
            vec![1, 3, 2]
        );

        // The per-tile ranges PROVE the nd-range race-free.
        let report = crate::sycl::analyze_hazards(&records);
        assert!(report.is_clean(), "tiled flush not proven race-free: {:?}", report.hazards);
    }

    #[test]
    fn tiled_batch_falls_back_to_serial_when_it_must() {
        let members =
            [BatchSlice { buffer_offset: 0, stream_offset: 9, n: 150, range: (0.0, 1.0) }];
        let backend = CurandBackend::new();
        let exec = TileExecutor::new(4);

        // Serial spec → the one-host-task shape.
        let q1 = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let mut g1 = backend.create_generator(EngineKind::Philox4x32x10, 3).unwrap();
        let usm1 = q1.malloc_device::<f32>(150);
        let b1 = generate_batch_usm_tiled(
            &q1, g1.as_mut(), &members, 150, &usm1, None, &[], TilingSpec::serial(), &exec,
        )
        .unwrap();
        let gens = |q: &Queue| {
            q.records().iter().filter(|r| r.class == CommandClass::Generate).count()
        };
        assert_eq!(gens(&q1), 1);
        assert!(b1.tiles.is_empty());

        // Launch fits one tile → serial.
        let q2 = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let mut g2 = backend.create_generator(EngineKind::Philox4x32x10, 3).unwrap();
        let usm2 = q2.malloc_device::<f32>(150);
        generate_batch_usm_tiled(
            &q2, g2.as_mut(), &members, 150, &usm2, None, &[], TilingSpec::new(4096, 4), &exec,
        )
        .unwrap();
        assert_eq!(gens(&q2), 1);

        // Engine without an absolute in-place seek (MT19937) → serial,
        // same payload as the untiled call.
        let q3 = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let mut g3 = backend.create_generator(EngineKind::Mt19937, 3).unwrap();
        let usm3 = q3.malloc_device::<f32>(150);
        let b3 = generate_batch_usm_tiled(
            &q3, g3.as_mut(), &members, 150, &usm3, None, &[], TilingSpec::new(32, 4), &exec,
        )
        .unwrap();
        assert_eq!(gens(&q3), 1);
        let q4 = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let mut g4 = backend.create_generator(EngineKind::Mt19937, 3).unwrap();
        let usm4 = q4.malloc_device::<f32>(150);
        let b4 =
            generate_batch_usm(&q4, g4.as_mut(), &members, 150, &usm4, None, &[]).unwrap();
        assert_eq!(
            b3.payloads[0].as_ref().unwrap(),
            b4.payloads[0].as_ref().unwrap()
        );
    }

    #[test]
    fn icdf_on_curand_is_rejected() {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 1).unwrap();
        let buf = Buffer::<f32>::new(64);
        let distr =
            Distribution::Gaussian { mean: 0.0, stddev: 1.0, method: GaussianMethod::Icdf };
        assert!(generate_buffer(&queue, &mut gen, distr, 64, &buf).is_err());
    }
}
