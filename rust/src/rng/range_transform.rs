//! The range-transformation kernel (paper §4.3, Listing 1.2).
//!
//! cuRAND and hipRAND emit only the canonical [0,1) / N(0,1) sequences;
//! oneMKL's API promises arbitrary ranges, so the paper adds a second
//! kernel that post-processes the generated buffer. This module is the
//! host-side implementation used by the simulated vendor backends and CPU
//! paths; the device path uses the standalone Pallas kernel
//! (`python/compile/kernels/range_transform.py`) or the fused variant.

/// In-place `[0,1) -> [a,b)` (or `N(0,1) -> N(a, b)` with `a`=mean,
/// `b`=stddev when `scale_stddev` semantics are applied by the caller).
#[inline]
pub fn range_transform_inplace(out: &mut [f32], a: f32, b: f32) {
    let w = b - a;
    for x in out.iter_mut() {
        *x = a + *x * w;
    }
}

/// Gaussian variant: `z -> mean + stddev * z`.
#[inline]
pub fn scale_gaussian_inplace(out: &mut [f32], mean: f32, stddev: f32) {
    for x in out.iter_mut() {
        *x = mean + stddev * *x;
    }
}

/// Bytes touched by the standalone transform kernel (read + write), used by
/// the platform performance model for the Fig. 4 per-kernel breakdown.
pub fn transform_kernel_bytes(n: usize) -> u64 {
    (n as u64) * 4 * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_transform_is_noop() {
        let mut v = vec![0.25f32, 0.5, 0.75];
        let orig = v.clone();
        range_transform_inplace(&mut v, 0.0, 1.0);
        assert_eq!(v, orig);
    }

    #[test]
    fn affine_map_endpoints() {
        let mut v = vec![0.0f32, 0.5, 0.999999];
        range_transform_inplace(&mut v, -4.0, 4.0);
        assert_eq!(v[0], -4.0);
        assert_eq!(v[1], 0.0);
        assert!(v[2] < 4.0);
    }

    #[test]
    fn gaussian_scale() {
        let mut v = vec![-1.0f32, 0.0, 2.0];
        scale_gaussian_inplace(&mut v, 10.0, 0.5);
        assert_eq!(v, vec![9.5, 10.0, 11.0]);
    }

    #[test]
    fn kernel_bytes_model() {
        assert_eq!(transform_kernel_bytes(1000), 8000);
    }
}
