//! The oneMKL-like RNG front-end: engines, distributions, generate API.
//!
//! This is the portable interface of the paper's contribution: a single
//! vendor-agnostic API whose entry points dispatch to vendor-native
//! backends ([`crate::backends`]), plus the range-transformation kernel the
//! native libraries lack (paper §4.3, Listing 1.2).

pub mod distributions;
pub mod engines;
pub mod generate;
pub mod range_transform;

pub use distributions::{Distribution, GaussianMethod, UniformMethod};
pub use engines::{Engine, EngineKind, PhiloxEngine};
pub use generate::{
    generate_batch_usm, generate_batch_usm_tiled, generate_buffer, generate_usm,
    parse_distribution, BatchSlice, GenerateApi, UsmBatch,
};
pub use range_transform::range_transform_inplace;

/// Canonical u32 -> f32 `[0, 1)` conversion (DESIGN.md §4): keep the top 24
/// bits so the result is exactly representable and strictly below 1.
#[inline(always)]
pub fn u32_to_uniform_f32(x: u32) -> f32 {
    const SCALE: f32 = 1.0 / (1u32 << 24) as f32;
    (x >> 8) as f32 * SCALE
}

/// Canonical u32-pair -> f64 `[0, 1)` conversion (top 53 bits).
#[inline(always)]
pub fn u32x2_to_uniform_f64(hi: u32, lo: u32) -> f64 {
    const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
    let bits = ((hi as u64) << 32 | lo as u64) >> 11;
    bits as f64 * SCALE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u01_range_and_resolution() {
        assert_eq!(u32_to_uniform_f32(0), 0.0);
        let max = u32_to_uniform_f32(u32::MAX);
        assert!(max < 1.0);
        assert_eq!(max, (0xFF_FFFF as f32) / (1 << 24) as f32);
        // Exactly representable: consecutive 24-bit payloads differ.
        assert_ne!(u32_to_uniform_f32(0x100), u32_to_uniform_f32(0x200));
        // Bottom 8 bits are discarded.
        assert_eq!(u32_to_uniform_f32(0x1FF), u32_to_uniform_f32(0x100));
    }

    #[test]
    fn u01_f64_range() {
        assert_eq!(u32x2_to_uniform_f64(0, 0), 0.0);
        assert!(u32x2_to_uniform_f64(u32::MAX, u32::MAX) < 1.0);
    }
}
