//! MRG32k3a — L'Ecuyer's combined multiple-recursive generator
//! (oneMKL `mrg32k3a`, cuRAND `CURAND_RNG_PSEUDO_MRG32K3A`).
//!
//! Two order-3 recurrences modulo `m1 = 2^32 - 209` and `m2 = 2^32 - 22853`
//! combined as `z = (p1 - p2) mod m1`. Skip-ahead uses 3x3 matrix powers
//! modulo m1/m2, giving O(log n) stream jumps for parallel substreams.

use super::{Engine, EngineKind};

const M1: u64 = 4_294_967_087; // 2^32 - 209
const M2: u64 = 4_294_944_443; // 2^32 - 22853
const A12: u64 = 1_403_580;
const A13N: u64 = 810_728;
const A21: u64 = 527_612;
const A23N: u64 = 1_370_589;

/// Recurrence matrices (mod m1 / mod m2) for one step.
const A1: [[u64; 3]; 3] = [[0, 1, 0], [0, 0, 1], [M1 - A13N, A12, 0]];
const A2: [[u64; 3]; 3] = [[0, 1, 0], [0, 0, 1], [M2 - A23N, 0, A21]];

fn mat_mul(a: &[[u64; 3]; 3], b: &[[u64; 3]; 3], m: u64) -> [[u64; 3]; 3] {
    let mut c = [[0u64; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            let mut acc: u128 = 0;
            for (k, row) in b.iter().enumerate() {
                acc += a[i][k] as u128 * row[j] as u128;
            }
            c[i][j] = (acc % m as u128) as u64;
        }
    }
    c
}

fn mat_vec(a: &[[u64; 3]; 3], v: &[u64; 3], m: u64) -> [u64; 3] {
    let mut r = [0u64; 3];
    for i in 0..3 {
        let mut acc: u128 = 0;
        for (k, &vk) in v.iter().enumerate() {
            acc += a[i][k] as u128 * vk as u128;
        }
        r[i] = (acc % m as u128) as u64;
    }
    r
}

fn mat_pow(mut a: [[u64; 3]; 3], mut n: u64, m: u64) -> [[u64; 3]; 3] {
    let mut r = [[1, 0, 0], [0, 1, 0], [0, 0, 1]];
    while n > 0 {
        if n & 1 == 1 {
            r = mat_mul(&a, &r, m);
        }
        a = mat_mul(&a.clone(), &a, m);
        n >>= 1;
    }
    r
}

/// L'Ecuyer MRG32k3a engine.
#[derive(Debug, Clone)]
pub struct Mrg32k3aEngine {
    s1: [u64; 3],
    s2: [u64; 3],
    /// Seed-derived initial state, kept so [`Engine::try_seek`] can
    /// reposition absolutely (restore + O(log pos) jump) without the
    /// caller reconstructing the engine.
    init1: [u64; 3],
    init2: [u64; 3],
}

impl Mrg32k3aEngine {
    /// Seed the six state words from a 64-bit seed via splitmix64,
    /// guaranteeing the all-zero (resp. all-zero mod m) states are avoided.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let mut s1 = [0u64; 3];
        let mut s2 = [0u64; 3];
        for v in s1.iter_mut() {
            *v = next() % (M1 - 1) + 1; // in [1, m1-1]: never the zero state
        }
        for v in s2.iter_mut() {
            *v = next() % (M2 - 1) + 1;
        }
        Mrg32k3aEngine { s1, s2, init1: s1, init2: s2 }
    }

    #[inline]
    fn step(&mut self) -> u64 {
        // p1 = (a12*s1[1] - a13n*s1[0]) mod m1
        let p1 = (A12 as u128 * self.s1[1] as u128 + (M1 - A13N) as u128 * self.s1[0] as u128)
            % M1 as u128;
        self.s1 = [self.s1[1], self.s1[2], p1 as u64];
        // p2 = (a21*s2[2] - a23n*s2[0]) mod m2
        let p2 = (A21 as u128 * self.s2[2] as u128 + (M2 - A23N) as u128 * self.s2[0] as u128)
            % M2 as u128;
        self.s2 = [self.s2[1], self.s2[2], p2 as u64];
        let (z1, z2) = (self.s1[2], self.s2[2]);
        if z1 > z2 {
            z1 - z2
        } else {
            z1 + M1 - z2
        }
    }
}

impl Engine for Mrg32k3aEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Mrg32k3a
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        for dst in out.iter_mut() {
            // Map [0, m1) onto the full u32 range.
            *dst = (((self.step() as u128) << 32) / M1 as u128) as u32;
        }
    }

    fn skip_ahead(&mut self, n: u64) {
        // O(log n) jump via matrix powers.
        let p1 = mat_pow(A1, n, M1);
        let p2 = mat_pow(A2, n, M2);
        self.s1 = mat_vec(&p1, &self.s1, M1);
        self.s2 = mat_vec(&p2, &self.s2, M2);
    }

    fn try_seek(&mut self, pos: u64) -> bool {
        // Absolute seek = restore the seed-derived initial state, then
        // one O(log pos) matrix jump — no reconstruction needed.
        self.s1 = self.init1;
        self.s2 = self.init2;
        self.skip_ahead(pos);
        true
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// L'Ecuyer's canonical check: with all state words = 12345, the sum of
    /// the first 10^4 u01 doubles is a published constant ~5001.8; we check
    /// the tighter per-draw property that outputs stay in [0, m1).
    #[test]
    fn canonical_state_stream() {
        let mut e = Mrg32k3aEngine {
            s1: [12345; 3],
            s2: [12345; 3],
            init1: [12345; 3],
            init2: [12345; 3],
        };
        let mut sum = 0f64;
        for _ in 0..10_000 {
            let z = e.step();
            assert!(z < M1);
            // L'Ecuyer's u01 convention for the reference sum.
            sum += (z as f64 + 1.0) / (M1 as f64 + 1.0);
        }
        // Published reference behaviour: mean ~0.5 within 1%.
        assert!((sum / 10_000.0 - 0.5).abs() < 0.01, "mean={}", sum / 10_000.0);
    }

    #[test]
    fn matrix_skip_matches_stepping() {
        for n in [1u64, 2, 3, 17, 1000, 65_537] {
            let mut a = Mrg32k3aEngine::new(5);
            let mut b = a.clone();
            for _ in 0..n {
                a.step();
            }
            b.skip_ahead(n);
            assert_eq!(a.s1, b.s1, "s1 after {n}");
            assert_eq!(a.s2, b.s2, "s2 after {n}");
        }
    }

    #[test]
    fn try_seek_matches_fresh_engine_at_offset() {
        for pos in [0u64, 1, 2, 1000, 65_537, 1_000_000] {
            let mut a = Mrg32k3aEngine::new(7);
            let mut burn = vec![0u32; 123]; // move off the initial state
            a.fill_u32(&mut burn);
            assert!(a.try_seek(pos));

            let mut b = Mrg32k3aEngine::new(7);
            b.skip_ahead(pos);
            let (mut xa, mut xb) = ([0u32; 16], [0u32; 16]);
            a.fill_u32(&mut xa);
            b.fill_u32(&mut xb);
            assert_eq!(xa, xb, "pos {pos}");
        }
    }

    #[test]
    fn different_seeds_different_streams() {
        let mut a = Mrg32k3aEngine::new(1);
        let mut b = Mrg32k3aEngine::new(2);
        let (mut xa, mut xb) = ([0u32; 16], [0u32; 16]);
        a.fill_u32(&mut xa);
        b.fill_u32(&mut xb);
        assert_ne!(xa, xb);
    }

    #[test]
    fn state_never_zero() {
        for seed in 0..50u64 {
            let e = Mrg32k3aEngine::new(seed);
            assert!(e.s1.iter().any(|&x| x != 0));
            assert!(e.s2.iter().any(|&x| x != 0));
            assert!(e.s1.iter().all(|&x| x < M1));
            assert!(e.s2.iter().all(|&x| x < M2));
        }
    }
}
