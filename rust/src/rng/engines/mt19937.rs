//! MT19937 — Mersenne Twister (oneMKL `mt19937`,
//! cuRAND `CURAND_RNG_PSEUDO_MT19937`). Matsumoto–Nishimura reference
//! initialization and tempering; known-answer tested against the canonical
//! first outputs for the default seed 5489.

use super::{Engine, EngineKind};

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_B0DF;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7FFF_FFFF;

/// Mersenne Twister engine (period 2^19937 - 1).
#[derive(Clone)]
pub struct Mt19937Engine {
    mt: [u32; N],
    mti: usize,
}

impl std::fmt::Debug for Mt19937Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mt19937Engine").field("mti", &self.mti).finish()
    }
}

impl Mt19937Engine {
    /// Reference `init_genrand` seeding.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1_812_433_253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937Engine { mt, mti: N }
    }

    fn twist(&mut self) {
        for i in 0..N {
            let y = (self.mt[i] & UPPER_MASK) | (self.mt[(i + 1) % N] & LOWER_MASK);
            let mut next = self.mt[(i + M) % N] ^ (y >> 1);
            if y & 1 == 1 {
                next ^= MATRIX_A;
            }
            self.mt[i] = next;
        }
        self.mti = 0;
    }

    #[inline]
    fn step(&mut self) -> u32 {
        if self.mti >= N {
            self.twist();
        }
        let mut y = self.mt[self.mti];
        self.mti += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9D2C_5680;
        y ^= (y << 15) & 0xEFC6_0000;
        y ^ (y >> 18)
    }
}

impl Engine for Mt19937Engine {
    fn kind(&self) -> EngineKind {
        EngineKind::Mt19937
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        for dst in out.iter_mut() {
            *dst = self.step();
        }
    }

    fn skip_ahead(&mut self, n: u64) {
        for _ in 0..n {
            self.step();
        }
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Canonical first outputs for the reference default seed 5489.
    #[test]
    fn known_answer_seed_5489() {
        let mut e = Mt19937Engine::new(5489);
        let mut out = [0u32; 5];
        e.fill_u32(&mut out);
        assert_eq!(out, [3_499_211_612, 581_869_302, 3_890_346_734, 3_586_334_585, 545_404_204]);
    }

    #[test]
    fn twist_boundary_continuity() {
        // Crossing the 624-word reload boundary must not disturb the stream.
        let mut a = Mt19937Engine::new(1);
        let mut whole = vec![0u32; 2 * N + 10];
        a.fill_u32(&mut whole);
        let mut b = Mt19937Engine::new(1);
        let mut parts = Vec::new();
        while parts.len() < whole.len() {
            let take = (whole.len() - parts.len()).min(100);
            let mut chunk = vec![0u32; take];
            b.fill_u32(&mut chunk);
            parts.extend(chunk);
        }
        assert_eq!(whole, parts);
    }
}
