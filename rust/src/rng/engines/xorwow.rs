//! XORWOW — Marsaglia's xorshift variant with a Weyl sequence
//! (cuRAND's default engine, `CURAND_RNG_PSEUDO_XORWOW`).

use super::{Engine, EngineKind};

const WEYL: u32 = 362_437;

/// Marsaglia XORWOW engine (period ~2^192 - 2^32).
#[derive(Debug, Clone)]
pub struct XorwowEngine {
    x: [u32; 5],
    d: u32,
}

impl XorwowEngine {
    /// Seed the five xorshift words + Weyl counter via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let mut x = [0u32; 5];
        for v in x.iter_mut() {
            *v = next() as u32;
        }
        if x.iter().all(|&v| v == 0) {
            x[0] = 1; // the all-zero xorshift state is absorbing
        }
        XorwowEngine { x, d: next() as u32 }
    }

    #[inline(always)]
    fn step(&mut self) -> u32 {
        let t = self.x[0] ^ (self.x[0] >> 2);
        self.x[0] = self.x[1];
        self.x[1] = self.x[2];
        self.x[2] = self.x[3];
        self.x[3] = self.x[4];
        self.x[4] = (self.x[4] ^ (self.x[4] << 4)) ^ (t ^ (t << 1));
        self.d = self.d.wrapping_add(WEYL);
        self.d.wrapping_add(self.x[4])
    }
}

impl Engine for XorwowEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Xorwow
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        for dst in out.iter_mut() {
            *dst = self.step();
        }
    }

    fn skip_ahead(&mut self, n: u64) {
        // xorshift jump polynomials exist but the paper only ever uses
        // Philox for skip-ahead streams; sequential skip is adequate here.
        for _ in 0..n {
            self.step();
        }
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Marsaglia's paper (Xorshift RNGs, JSS 2003) example trace for the
    /// xorwow state update: verify the recurrence directly.
    #[test]
    fn recurrence_matches_definition() {
        let mut e = XorwowEngine { x: [1, 2, 3, 4, 5], d: 6 };
        let x0 = e.x;
        let d0 = e.d;
        let out = e.step();
        let t = x0[0] ^ (x0[0] >> 2);
        let v = (x0[4] ^ (x0[4] << 4)) ^ (t ^ (t << 1));
        assert_eq!(e.x, [x0[1], x0[2], x0[3], x0[4], v]);
        assert_eq!(e.d, d0.wrapping_add(WEYL));
        assert_eq!(out, d0.wrapping_add(WEYL).wrapping_add(v));
    }

    #[test]
    fn no_short_cycle() {
        let mut e = XorwowEngine::new(1);
        let first = e.step();
        for _ in 0..100_000 {
            assert_ne!(e.x, [0, 0, 0, 0, 0]);
        }
        let _ = first;
    }

    #[test]
    fn equidistribution_rough() {
        let mut e = XorwowEngine::new(123);
        let mut buckets = [0usize; 16];
        for _ in 0..160_000 {
            buckets[(e.step() >> 28) as usize] += 1;
        }
        for &b in &buckets {
            assert!((b as f64 - 10_000.0).abs() < 600.0, "bucket {b}");
        }
    }
}
