//! Sobol32 — quasirandom low-discrepancy sequence (cuRAND
//! `CURAND_RNG_QUASI_SOBOL32`, oneMKL `sobol`).
//!
//! Gray-code construction with Joe–Kuo direction numbers for the first few
//! dimensions. In cuRAND/hipRAND these engines are the only ones with ICDF
//! generation methods (paper §4.1) — the distribution layer enforces that
//! asymmetry. Skip-ahead is O(32) via the Gray-code closed form.

use super::{Engine, EngineKind};

const BITS: usize = 32;
/// Primitive-polynomial parameters (dimension, degree s, coefficient a,
/// initial direction numbers m_i) — Joe–Kuo table, dimensions 2..=4.
/// Dimension 1 is the van der Corput sequence (m_i = 1).
const JOE_KUO: [(u32, u32, &[u32]); 3] =
    [(1, 0, &[1]), (2, 1, &[1, 3]), (3, 1, &[1, 3, 1])];

fn direction_numbers(dim: u32) -> [u32; BITS] {
    let mut v = [0u32; BITS];
    if dim == 0 {
        // van der Corput: v_j = 2^(31-j)
        for (j, vj) in v.iter_mut().enumerate() {
            *vj = 1 << (31 - j);
        }
        return v;
    }
    let (s, a, m) = JOE_KUO[(dim as usize - 1) % JOE_KUO.len()];
    let s = s as usize;
    for j in 0..s.min(BITS) {
        v[j] = m[j] << (31 - j);
    }
    for j in s..BITS {
        let mut vj = v[j - s] ^ (v[j - s] >> s);
        for k in 1..s {
            if (a >> (s - 1 - k)) & 1 == 1 {
                vj ^= v[j - k];
            }
        }
        v[j] = vj;
    }
    v
}

/// 32-bit Sobol sequence engine for a single dimension.
#[derive(Debug, Clone)]
pub struct Sobol32Engine {
    v: [u32; BITS],
    /// Current point value (x_index).
    x: u32,
    /// Zero-based index of the *next* point to emit.
    index: u64,
}

impl Sobol32Engine {
    /// New Sobol stream for `dimension` (1-based, wraps over the table).
    pub fn new(dimension: u32) -> Self {
        Sobol32Engine {
            v: direction_numbers(dimension.saturating_sub(1)),
            x: 0,
            index: 0,
        }
    }

    /// Closed-form value of point `n`: XOR of v_j over set bits of gray(n).
    fn point(&self, n: u64) -> u32 {
        let gray = n ^ (n >> 1);
        let mut x = 0u32;
        for (j, &vj) in self.v.iter().enumerate() {
            if (gray >> j) & 1 == 1 {
                x ^= vj;
            }
        }
        x
    }
}

impl Engine for Sobol32Engine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sobol32
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        for dst in out.iter_mut() {
            *dst = self.x;
            // Gray-code increment: flip direction number of ctz(index+1).
            let c = (self.index + 1).trailing_zeros() as usize;
            self.x ^= self.v[c % BITS];
            self.index += 1;
        }
    }

    fn skip_ahead(&mut self, n: u64) {
        self.index += n;
        self.x = self.point(self.index);
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim1_is_van_der_corput() {
        let mut e = Sobol32Engine::new(1);
        let mut out = [0u32; 8];
        e.fill_u32(&mut out);
        // Bit-reversed integers: 0, 1/2, 1/4, 3/4, ... scaled to 2^32.
        assert_eq!(out[0], 0);
        assert_eq!(out[1], 0x8000_0000);
        assert_eq!(out[2], 0xC000_0000);
        assert_eq!(out[3], 0x4000_0000);
        assert_eq!(out[4], 0x6000_0000);
    }

    #[test]
    fn closed_form_matches_iteration() {
        let mut e = Sobol32Engine::new(2);
        let mut out = vec![0u32; 100];
        e.fill_u32(&mut out);
        let fresh = Sobol32Engine::new(2);
        for (n, &x) in out.iter().enumerate() {
            assert_eq!(fresh.point(n as u64), x, "point {n}");
        }
    }

    #[test]
    fn low_discrepancy_beats_random_spacing() {
        // First 2^k points of dim 1 hit every length-2^-k dyadic interval
        // exactly once.
        let mut e = Sobol32Engine::new(1);
        let mut out = vec![0u32; 256];
        e.fill_u32(&mut out);
        let mut buckets = [0u32; 256];
        for &x in &out {
            buckets[(x >> 24) as usize] += 1;
        }
        assert!(buckets.iter().all(|&b| b == 1));
    }
}
