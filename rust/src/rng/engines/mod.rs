//! Pseudo- and quasirandom engines, bit-exact with their reference
//! implementations (Random123 for Philox, L'Ecuyer for MRG32k3a, Marsaglia
//! for XORWOW, Matsumoto–Nishimura for MT19937, Joe–Kuo for Sobol32).
//!
//! All engines expose the same [`Engine`] trait used by backends; Philox is
//! the paper's benchmark generator and the only one with O(1) skip-ahead
//! (counter-based), which the PJRT device path relies on.

mod mrg32k3a;
mod mt19937;
mod philox;
mod sobol32;
mod xorwow;

pub use mrg32k3a::Mrg32k3aEngine;
pub use mt19937::Mt19937Engine;
pub use philox::{philox4x32_10, PhiloxEngine, PHILOX_M0, PHILOX_M1, PHILOX_W0, PHILOX_W1};
pub use sobol32::Sobol32Engine;
pub use xorwow::XorwowEngine;

/// Engine families, matching oneMKL / cuRAND / hipRAND generator types.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Philox4x32x10 counter-based generator (paper's benchmark engine).
    Philox4x32x10,
    /// L'Ecuyer combined multiple-recursive generator.
    Mrg32k3a,
    /// Marsaglia XORWOW (cuRAND's default pseudorandom engine).
    Xorwow,
    /// Mersenne Twister 19937.
    Mt19937,
    /// Sobol 32-bit quasirandom sequence.
    Sobol32,
}

impl EngineKind {
    /// All engine kinds (for sweeps and property tests).
    pub const ALL: [EngineKind; 5] = [
        EngineKind::Philox4x32x10,
        EngineKind::Mrg32k3a,
        EngineKind::Xorwow,
        EngineKind::Mt19937,
        EngineKind::Sobol32,
    ];

    /// Human-readable name as used in reports.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Philox4x32x10 => "philox4x32x10",
            EngineKind::Mrg32k3a => "mrg32k3a",
            EngineKind::Xorwow => "xorwow",
            EngineKind::Mt19937 => "mt19937",
            EngineKind::Sobol32 => "sobol32",
        }
    }

    /// Whether the engine is quasirandom (ICDF-only in cuRAND/hipRAND —
    /// paper §4.1: "such methods are available only for quasirandom number
    /// generators in the curand and hiprand API").
    pub fn is_quasi(self) -> bool {
        matches!(self, EngineKind::Sobol32)
    }

    /// Construct a boxed engine of this kind.
    pub fn create(self, seed: u64) -> Box<dyn Engine> {
        match self {
            EngineKind::Philox4x32x10 => Box::new(PhiloxEngine::new(seed)),
            EngineKind::Mrg32k3a => Box::new(Mrg32k3aEngine::new(seed)),
            EngineKind::Xorwow => Box::new(XorwowEngine::new(seed)),
            EngineKind::Mt19937 => Box::new(Mt19937Engine::new(seed as u32)),
            EngineKind::Sobol32 => Box::new(Sobol32Engine::new(1)),
        }
    }
}

/// A raw u32 stream generator.
///
/// The distribution layer sits on top of this; backends may bypass it when
/// they have a fused path (e.g. the PJRT Pallas kernel generates, converts
/// and transforms in one device pass).
pub trait Engine: Send {
    /// Engine family.
    fn kind(&self) -> EngineKind;

    /// Fill `out` with the next raw u32 draws.
    fn fill_u32(&mut self, out: &mut [u32]);

    /// Skip `n` raw u32 draws ahead, *relative* to the current position.
    ///
    /// Cost varies wildly by family: O(1) for Philox (counter
    /// arithmetic), O(log n) for MRG32k3a (matrix powers), O(n) for
    /// everything else (the engine literally draws and discards). Callers
    /// repositioning absolutely on a hot path should use
    /// [`Engine::try_seek`] and only fall back to recreate + `skip_ahead`
    /// when it returns `false`.
    fn skip_ahead(&mut self, n: u64);

    /// Seek to *absolute* raw-draw position `pos`, when the engine can do
    /// so without being reconstructed: Philox seeks in O(1), MRG32k3a
    /// restores its seed-derived initial state and jumps in O(log pos).
    /// Returns `false` — leaving the state untouched — for engines that
    /// only know how to move forward; callers then recreate from the seed
    /// and [`Engine::skip_ahead`].
    fn try_seek(&mut self, _pos: u64) -> bool {
        false
    }

    /// Clone into a boxed engine (engines are deterministic state machines).
    fn clone_box(&self) -> Box<dyn Engine>;

    /// Next single u32 (convenience; engines may override).
    fn next_u32(&mut self) -> u32 {
        let mut one = [0u32; 1];
        self.fill_u32(&mut one);
        one[0]
    }

    /// Fill with f32 uniforms in [0,1) via the canonical conversion.
    fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        // Chunked to keep the scratch buffer cache-resident.
        const CHUNK: usize = 4096;
        let mut scratch = [0u32; CHUNK];
        for block in out.chunks_mut(CHUNK) {
            let s = &mut scratch[..block.len()];
            self.fill_u32(s);
            for (dst, &src) in block.iter_mut().zip(s.iter()) {
                *dst = super::u32_to_uniform_f32(src);
            }
        }
    }
}

impl Clone for Box<dyn Engine> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_create_and_generate() {
        for kind in EngineKind::ALL {
            let mut e = kind.create(12345);
            let mut out = vec![0u32; 64];
            e.fill_u32(&mut out);
            assert!(out.iter().any(|&x| x != 0), "{:?} all zero", kind);
            assert_eq!(e.kind(), kind);
        }
    }

    #[test]
    fn clone_preserves_stream() {
        for kind in EngineKind::ALL {
            let mut a = kind.create(7);
            let mut warm = vec![0u32; 17];
            a.fill_u32(&mut warm);
            let mut b = a.clone_box();
            let (mut xa, mut xb) = (vec![0u32; 32], vec![0u32; 32]);
            a.fill_u32(&mut xa);
            b.fill_u32(&mut xb);
            assert_eq!(xa, xb, "{:?} clone diverged", kind);
        }
    }

    #[test]
    fn skip_ahead_matches_sequential_draw() {
        for kind in EngineKind::ALL {
            let mut a = kind.create(99);
            let mut b = kind.create(99);
            let mut burn = vec![0u32; 1000];
            a.fill_u32(&mut burn);
            b.skip_ahead(1000);
            let (mut xa, mut xb) = (vec![0u32; 16], vec![0u32; 16]);
            a.fill_u32(&mut xa);
            b.fill_u32(&mut xb);
            assert_eq!(xa, xb, "{:?} skip_ahead != sequential", kind);
        }
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        for kind in EngineKind::ALL {
            let mut e = kind.create(3);
            let mut out = vec![0f32; 10_000];
            e.fill_uniform_f32(&mut out);
            assert!(out.iter().all(|&x| (0.0..1.0).contains(&x)), "{:?}", kind);
            let mean = out.iter().sum::<f32>() / out.len() as f32;
            assert!((mean - 0.5).abs() < 0.02, "{:?} mean={mean}", kind);
        }
    }
}
