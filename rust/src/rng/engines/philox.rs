//! Philox4x32x10 — the paper's benchmark generator (cuRAND
//! `CURAND_RNG_PSEUDO_PHILOX4_32_10`, oneMKL `philox4x32x10`).
//!
//! Random123 convention, bit-exact with the Pallas kernel and the jnp
//! oracle (`python/compile/kernels/ref.py`) — see DESIGN.md §4 and the
//! `cross_layer` integration test.

use super::{Engine, EngineKind};

/// Round multiplier for lanes 0/1.
pub const PHILOX_M0: u32 = 0xD251_1F53;
/// Round multiplier for lanes 2/3.
pub const PHILOX_M1: u32 = 0xCD9E_8D57;
/// Weyl increment for key word 0.
pub const PHILOX_W0: u32 = 0x9E37_79B9;
/// Weyl increment for key word 1.
pub const PHILOX_W1: u32 = 0xBB67_AE85;

const ROUNDS: u32 = 10;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

#[inline(always)]
fn round(c: [u32; 4], k: [u32; 2]) -> [u32; 4] {
    let (hi0, lo0) = mulhilo(PHILOX_M0, c[0]);
    let (hi1, lo1) = mulhilo(PHILOX_M1, c[2]);
    [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0]
}

/// The full 10-round Philox4x32 keyed permutation.
#[inline(always)]
pub fn philox4x32_10(mut ctr: [u32; 4], mut key: [u32; 2]) -> [u32; 4] {
    for r in 0..ROUNDS {
        if r > 0 {
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
        ctr = round(ctr, key);
    }
    ctr
}

/// Counter-based Philox engine with O(1) skip-ahead.
///
/// Counter layout (DESIGN.md §4): block `j` uses `(lo(off+j), hi(off+j),
/// 0, 0)` with the 64-bit block offset split into u32 words; the seed
/// occupies the 64-bit key. One block yields 4 u32 draws; `phase` tracks
/// the intra-block position so arbitrary-length fills stay stream-exact.
#[derive(Debug, Clone)]
pub struct PhiloxEngine {
    key: [u32; 2],
    /// 128-bit counter; low 64 bits used as the block index.
    block: u64,
    /// Draws already consumed from the current block (0..=3).
    phase: u8,
    /// Cached current block output.
    cache: [u32; 4],
}

impl PhiloxEngine {
    /// New engine from a 64-bit seed (cuRAND-style
    /// `curandSetPseudoRandomGeneratorSeed`).
    pub fn new(seed: u64) -> Self {
        Self::with_offset(seed, 0)
    }

    /// New engine skipped ahead to raw-draw offset `offset`
    /// (`curandSetGeneratorOffset` analogue; offset counts u32 draws).
    pub fn with_offset(seed: u64, offset: u64) -> Self {
        let mut e = PhiloxEngine {
            key: [seed as u32, (seed >> 32) as u32],
            block: 0,
            phase: 0,
            cache: [0; 4],
        };
        e.seek(offset);
        e
    }

    /// Absolute seek to raw-draw position `pos` in the stream.
    pub fn seek(&mut self, pos: u64) {
        self.block = pos / 4;
        self.phase = (pos % 4) as u8;
        if self.phase != 0 {
            self.cache = self.block_output(self.block);
        }
    }

    /// Current absolute raw-draw position.
    pub fn position(&self) -> u64 {
        self.block * 4 + self.phase as u64
    }

    #[inline]
    fn block_output(&self, block: u64) -> [u32; 4] {
        philox4x32_10([block as u32, (block >> 32) as u32, 0, 0], self.key)
    }

    /// `W` independent counter blocks evaluated in lockstep. The Philox
    /// round is a multiply-latency chain; interleaving independent chains
    /// gives the out-of-order core the ILP to hide it (§Perf L3
    /// optimization iterations: 176 -> 272 -> 320+ M u32/s, see
    /// EXPERIMENTS.md §Perf).
    #[inline(always)]
    fn block_output_wide<const W: usize>(&self, block: u64) -> [[u32; 4]; W] {
        let mut c = [[0u32; 4]; W];
        for (i, ci) in c.iter_mut().enumerate() {
            let b = block.wrapping_add(i as u64);
            *ci = [b as u32, (b >> 32) as u32, 0, 0];
        }
        let mut k = self.key;
        for r in 0..ROUNDS {
            if r > 0 {
                k[0] = k[0].wrapping_add(PHILOX_W0);
                k[1] = k[1].wrapping_add(PHILOX_W1);
            }
            // W independent S-box rounds; the compiler interleaves.
            for ci in c.iter_mut() {
                *ci = round(*ci, k);
            }
        }
        c
    }

    #[inline(always)]
    fn block_output_x4(&self, block: u64) -> [[u32; 4]; 4] {
        self.block_output_wide::<4>(block)
    }

    /// Fill `out` with uniforms in [0,1) fused with generation (hot path:
    /// avoids the intermediate u32 buffer of the default trait method).
    pub fn fill_uniform_f32_fused(&mut self, out: &mut [f32]) {
        let mut i = 0;
        // Drain a partially consumed block first.
        while self.phase != 0 && i < out.len() {
            out[i] = crate::rng::u32_to_uniform_f32(self.cache[self.phase as usize]);
            self.advance_phase();
            i += 1;
        }
        // 4-blocks-at-a-time main loop (16 outputs per iteration);
        // 8-wide was tried and regressed (register pressure) — §Perf log.
        let mut wide = out[i..].chunks_exact_mut(16);
        for chunk in &mut wide {
            let blocks = self.block_output_wide::<4>(self.block);
            self.block = self.block.wrapping_add(4);
            for (j, v) in blocks.iter().enumerate() {
                chunk[4 * j] = crate::rng::u32_to_uniform_f32(v[0]);
                chunk[4 * j + 1] = crate::rng::u32_to_uniform_f32(v[1]);
                chunk[4 * j + 2] = crate::rng::u32_to_uniform_f32(v[2]);
                chunk[4 * j + 3] = crate::rng::u32_to_uniform_f32(v[3]);
            }
        }
        let rem16 = wide.into_remainder();
        let mut chunks = rem16.chunks_exact_mut(4);
        for chunk in &mut chunks {
            let v = self.block_output(self.block);
            self.block = self.block.wrapping_add(1);
            chunk[0] = crate::rng::u32_to_uniform_f32(v[0]);
            chunk[1] = crate::rng::u32_to_uniform_f32(v[1]);
            chunk[2] = crate::rng::u32_to_uniform_f32(v[2]);
            chunk[3] = crate::rng::u32_to_uniform_f32(v[3]);
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            self.cache = self.block_output(self.block);
            for (j, dst) in rem.iter_mut().enumerate() {
                *dst = crate::rng::u32_to_uniform_f32(self.cache[j]);
            }
            self.phase = rem.len() as u8;
        }
    }

    #[inline]
    fn advance_phase(&mut self) {
        self.phase += 1;
        if self.phase == 4 {
            self.phase = 0;
            self.block = self.block.wrapping_add(1);
        }
    }
}

impl Engine for PhiloxEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Philox4x32x10
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        let mut i = 0;
        while self.phase != 0 && i < out.len() {
            out[i] = self.cache[self.phase as usize];
            self.advance_phase();
            i += 1;
        }
        // 4-blocks-at-a-time main loop (16 outputs per iteration);
        // 8-wide was tried and regressed (register pressure) — §Perf log.
        let mut wide = out[i..].chunks_exact_mut(16);
        for chunk in &mut wide {
            let blocks = self.block_output_wide::<4>(self.block);
            self.block = self.block.wrapping_add(4);
            for (j, v) in blocks.iter().enumerate() {
                chunk[4 * j..4 * j + 4].copy_from_slice(v);
            }
        }
        let rem16 = wide.into_remainder();
        let mut chunks = rem16.chunks_exact_mut(4);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.block_output(self.block));
            self.block = self.block.wrapping_add(1);
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            self.cache = self.block_output(self.block);
            rem.copy_from_slice(&self.cache[..rem.len()]);
            self.phase = rem.len() as u8;
        }
    }

    fn skip_ahead(&mut self, n: u64) {
        let pos = self.position().wrapping_add(n);
        self.seek(pos);
    }

    fn try_seek(&mut self, pos: u64) -> bool {
        self.seek(pos);
        true
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }

    fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        self.fill_uniform_f32_fused(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random123 kat_vectors, philox4x32x10.
    #[test]
    fn known_answer_vectors() {
        assert_eq!(
            philox4x32_10([0, 0, 0, 0], [0, 0]),
            [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]
        );
        assert_eq!(
            philox4x32_10([u32::MAX; 4], [u32::MAX; 2]),
            [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]
        );
        assert_eq!(
            philox4x32_10(
                [0x243F_6A88, 0x85A3_08D3, 0x1319_8A2E, 0x0370_7344],
                [0xA409_3822, 0x299F_31D0]
            ),
            [0xD16C_FE09, 0x94FD_CCEB, 0x5001_E420, 0x2412_6EA1]
        );
    }

    #[test]
    fn counter_layout_matches_contract() {
        // Draws 0..4 come from counter (0,0,0,0), 4..8 from (1,0,0,0).
        let mut e = PhiloxEngine::new(0);
        let mut out = [0u32; 8];
        e.fill_u32(&mut out);
        assert_eq!(&out[..4], &philox4x32_10([0, 0, 0, 0], [0, 0]));
        assert_eq!(&out[4..], &philox4x32_10([1, 0, 0, 0], [0, 0]));
    }

    #[test]
    fn seed_maps_to_key_words() {
        let seed = 0x1234_5678_9ABC_DEF0u64;
        let mut e = PhiloxEngine::new(seed);
        let mut out = [0u32; 4];
        e.fill_u32(&mut out);
        assert_eq!(
            out,
            philox4x32_10([0, 0, 0, 0], [0x9ABC_DEF0, 0x1234_5678])
        );
    }

    #[test]
    fn unaligned_fills_are_stream_exact() {
        let mut a = PhiloxEngine::new(42);
        let mut whole = vec![0u32; 64];
        a.fill_u32(&mut whole);

        let mut b = PhiloxEngine::new(42);
        let mut parts = Vec::new();
        for len in [1usize, 3, 5, 7, 11, 13, 24] {
            let mut chunk = vec![0u32; len];
            b.fill_u32(&mut chunk);
            parts.extend_from_slice(&chunk);
        }
        assert_eq!(&whole[..parts.len()], &parts[..]);
    }

    #[test]
    fn o1_skip_ahead_arbitrary_offsets() {
        for off in [1u64, 2, 3, 4, 5, 1000, 123_456_789] {
            let mut a = PhiloxEngine::new(9);
            let mut burn = vec![0u32; off as usize % 10_000];
            // seek via skip from a partially drawn state
            a.fill_u32(&mut burn);
            a.skip_ahead(off);
            let mut b = PhiloxEngine::with_offset(9, burn.len() as u64 + off);
            let (mut xa, mut xb) = ([0u32; 8], [0u32; 8]);
            a.fill_u32(&mut xa);
            b.fill_u32(&mut xb);
            assert_eq!(xa, xb, "offset {off}");
        }
    }

    #[test]
    fn block_counter_crosses_u32_boundary() {
        // Block index > u32::MAX exercises the (lo, hi) counter split.
        let mut e = PhiloxEngine::with_offset(1, (u32::MAX as u64 + 2) * 4);
        let mut out = [0u32; 4];
        e.fill_u32(&mut out);
        assert_eq!(out, philox4x32_10([1, 1, 0, 0], [1, 0]));
    }

    #[test]
    fn fused_uniform_matches_unfused() {
        let mut a = PhiloxEngine::new(77);
        let mut fused = vec![0f32; 1001];
        a.fill_uniform_f32_fused(&mut fused);

        let mut b = PhiloxEngine::new(77);
        let mut raw = vec![0u32; 1001];
        b.fill_u32(&mut raw);
        let unfused: Vec<f32> =
            raw.iter().map(|&x| crate::rng::u32_to_uniform_f32(x)).collect();
        assert_eq!(fused, unfused);
        // And the streams remain aligned afterwards.
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn fused_uniform_matches_unfused_for_every_phase_and_length() {
        // The fused path has four distinct regimes (phase drain, 16-wide,
        // 4-wide, partial-block tail); every (starting phase, length)
        // combination must agree bit-exactly with fill_u32 + conversion
        // and leave the stream at the same position.
        for phase in 0u64..4 {
            for len in 0usize..=33 {
                let mut a = PhiloxEngine::new(123);
                a.seek(phase);
                let mut fused = vec![0f32; len];
                a.fill_uniform_f32_fused(&mut fused);

                let mut b = PhiloxEngine::new(123);
                b.seek(phase);
                let mut raw = vec![0u32; len];
                b.fill_u32(&mut raw);
                let unfused: Vec<f32> =
                    raw.iter().map(|&x| crate::rng::u32_to_uniform_f32(x)).collect();

                assert_eq!(fused, unfused, "phase {phase} len {len}");
                assert_eq!(a.position(), b.position(), "phase {phase} len {len}");
            }
        }
    }

    #[test]
    fn fused_uniform_is_stream_exact_across_a_seek_boundary() {
        // Fused fills on either side of an arbitrary-phase seek must
        // reproduce the contiguous serial stream — the exact shape the
        // tiled executor leans on (each tile seeks, then fills).
        for boundary in [1u64, 2, 3, 5, 17, 1000, 123_457] {
            let mut whole = vec![0f32; 48];
            PhiloxEngine::with_offset(9, boundary).fill_uniform_f32_fused(&mut whole);

            let mut e = PhiloxEngine::new(9);
            e.seek(boundary);
            let mut first = vec![0f32; 19];
            e.fill_uniform_f32_fused(&mut first);
            e.seek(boundary + 19);
            let mut second = vec![0f32; 29];
            e.fill_uniform_f32_fused(&mut second);

            assert_eq!(&whole[..19], &first[..], "boundary {boundary}");
            assert_eq!(&whole[19..], &second[..], "boundary {boundary}");
        }
    }

    #[test]
    fn try_seek_is_an_absolute_o1_reposition() {
        let mut a = PhiloxEngine::new(5);
        let mut burn = [0u32; 7]; // leave a partially consumed block
        a.fill_u32(&mut burn);
        assert!(a.try_seek(1_000_003));
        let mut b = PhiloxEngine::with_offset(5, 1_000_003);
        let (mut xa, mut xb) = ([0u32; 8], [0u32; 8]);
        a.fill_u32(&mut xa);
        b.fill_u32(&mut xb);
        assert_eq!(xa, xb);
    }
}
