//! Distribution layer on top of raw u32 engines.
//!
//! Mirrors the oneMKL RNG interface: distribution objects carry their
//! parameters *and* a generation-method tag. The method asymmetry is the
//! paper's §4.1 point: oneMKL supports both Box-Muller and ICDF methods,
//! while cuRAND/hipRAND expose ICDF only for quasirandom engines — so of
//! the 36 oneMKL generate entry points only 20 are implementable on the
//! cuRAND/hipRAND backends.

mod gaussian;
mod poisson;

pub use gaussian::{box_muller_pair, gaussian_icdf};
pub use poisson::poisson_knuth;

use crate::rng::engines::Engine;
use crate::rng::{u32_to_uniform_f32, u32x2_to_uniform_f64};

/// Generation method for uniform outputs (oneMKL `uniform_method`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum UniformMethod {
    /// Plain scale/offset of the canonical [0,1) draw.
    #[default]
    Standard,
    /// Extra-accurate endpoint handling (maps to the same arithmetic here).
    Accurate,
}

/// Generation method for gaussian-family outputs (oneMKL `gaussian_method`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum GaussianMethod {
    /// Box-Muller pairs — supported by every backend.
    #[default]
    BoxMuller,
    /// Inverse-CDF — oneMKL-native backends only (paper §4.1): the
    /// cuRAND/hipRAND backends reject this with `Error::Unsupported`.
    Icdf,
}

/// A distribution request, oneMKL-style.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Distribution {
    /// Uniform in `[a, b)` — the paper's benchmark distribution.
    Uniform { a: f32, b: f32, method: UniformMethod },
    /// Gaussian N(mean, stddev).
    Gaussian { mean: f32, stddev: f32, method: GaussianMethod },
    /// Lognormal: exp of N(m, s).
    Lognormal { m: f32, s: f32, method: GaussianMethod },
    /// Exponential with rate `lambda`.
    Exponential { lambda: f32 },
    /// Poisson with mean `lambda` (integer output reinterpreted as f32).
    Poisson { lambda: f64 },
    /// Raw 32 bits.
    Bits,
}

impl Distribution {
    /// Convenience constructor for the benchmark distribution.
    pub fn uniform(a: f32, b: f32) -> Self {
        Distribution::Uniform { a, b, method: UniformMethod::Standard }
    }

    /// Convenience constructor: standard normal scaled.
    pub fn gaussian(mean: f32, stddev: f32) -> Self {
        Distribution::Gaussian { mean, stddev, method: GaussianMethod::BoxMuller }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform { .. } => "uniform",
            Distribution::Gaussian { .. } => "gaussian",
            Distribution::Lognormal { .. } => "lognormal",
            Distribution::Exponential { .. } => "exponential",
            Distribution::Poisson { .. } => "poisson",
            Distribution::Bits => "bits",
        }
    }

    /// Whether the vendor-native generation step produces only the
    /// canonical [0,1)/N(0,1) sequence, requiring the oneMKL-side range
    /// transformation kernel afterwards (paper §4.3: cuRAND/hipRAND have
    /// "no concept of a range").
    pub fn requires_range_transform(&self) -> bool {
        match self {
            Distribution::Uniform { a, b, .. } => *a != 0.0 || *b != 1.0,
            Distribution::Gaussian { mean, stddev, .. } => *mean != 0.0 || *stddev != 1.0,
            Distribution::Lognormal { .. } => false,
            Distribution::Exponential { .. } => false,
            Distribution::Poisson { .. } => false,
            Distribution::Bits => false,
        }
    }

    /// Whether the distribution uses an ICDF method.
    pub fn uses_icdf(&self) -> bool {
        matches!(
            self,
            Distribution::Gaussian { method: GaussianMethod::Icdf, .. }
                | Distribution::Lognormal { method: GaussianMethod::Icdf, .. }
        )
    }

    /// Host-side sampling: fill `out` from `engine`. This is the reference
    /// path used by CPU backends and by tests to validate device paths.
    pub fn sample_f32(&self, engine: &mut dyn Engine, out: &mut [f32]) {
        match *self {
            Distribution::Uniform { a, b, .. } => {
                engine.fill_uniform_f32(out);
                if self.requires_range_transform() {
                    crate::rng::range_transform::range_transform_inplace(out, a, b);
                }
            }
            Distribution::Gaussian { mean, stddev, method } => {
                sample_gaussian(engine, out, mean, stddev, method, false);
            }
            Distribution::Lognormal { m, s, method } => {
                sample_gaussian(engine, out, m, s, method, true);
            }
            Distribution::Exponential { lambda } => {
                engine.fill_uniform_f32(out);
                for x in out.iter_mut() {
                    // -ln(1-u)/lambda, u in [0,1) so the argument is (0,1].
                    *x = -(1.0 - *x).ln() / lambda;
                }
            }
            Distribution::Poisson { lambda } => {
                for x in out.iter_mut() {
                    *x = poisson_knuth(engine, lambda) as f32;
                }
            }
            Distribution::Bits => {
                let mut raw = vec![0u32; out.len()];
                engine.fill_u32(&mut raw);
                for (dst, &src) in out.iter_mut().zip(raw.iter()) {
                    *dst = f32::from_bits(src);
                }
            }
        }
    }

    /// Host-side f64 sampling (uniform/gaussian only — the f64 entry points
    /// of the 36-function API).
    pub fn sample_f64(&self, engine: &mut dyn Engine, out: &mut [f64]) {
        match *self {
            Distribution::Uniform { a, b, .. } => {
                let mut raw = vec![0u32; out.len() * 2];
                engine.fill_u32(&mut raw);
                for (i, dst) in out.iter_mut().enumerate() {
                    let u = u32x2_to_uniform_f64(raw[2 * i], raw[2 * i + 1]);
                    *dst = a as f64 + u * (b as f64 - a as f64);
                }
            }
            Distribution::Gaussian { mean, stddev, method } => {
                let mut raw = vec![0u32; out.len() * 2 + 2];
                engine.fill_u32(&mut raw);
                let mut i = 0;
                for pair in out.chunks_mut(2) {
                    let u1 = u32_to_uniform_f32(raw[i]) as f64;
                    let u2 = u32_to_uniform_f32(raw[i + 1]) as f64;
                    i += 2;
                    let (z0, z1) = if method == GaussianMethod::Icdf {
                        (gaussian_icdf(u1), gaussian_icdf(u2))
                    } else {
                        let r = (-2.0 * (1.0 - u1).ln()).sqrt();
                        let th = 2.0 * std::f64::consts::PI * u2;
                        (r * th.cos(), r * th.sin())
                    };
                    pair[0] = mean as f64 + stddev as f64 * z0;
                    if pair.len() > 1 {
                        pair[1] = mean as f64 + stddev as f64 * z1;
                    }
                }
            }
            _ => {
                let mut tmp = vec![0f32; out.len()];
                self.sample_f32(engine, &mut tmp);
                for (dst, &src) in out.iter_mut().zip(tmp.iter()) {
                    *dst = src as f64;
                }
            }
        }
    }
}

fn sample_gaussian(
    engine: &mut dyn Engine,
    out: &mut [f32],
    p0: f32,
    p1: f32,
    method: GaussianMethod,
    log_transform: bool,
) {
    let n = out.len();
    let n_u = n + (n & 1);
    let mut u = vec![0f32; n_u];
    engine.fill_uniform_f32(&mut u);
    match method {
        GaussianMethod::BoxMuller => {
            for i in (0..n).step_by(2) {
                let (z0, z1) = box_muller_pair(u[i], u[i + 1]);
                out[i] = p0 + p1 * z0;
                if i + 1 < n {
                    out[i + 1] = p0 + p1 * z1;
                }
            }
        }
        GaussianMethod::Icdf => {
            for i in 0..n {
                out[i] = p0 + p1 * gaussian_icdf(u[i] as f64) as f32;
            }
        }
    }
    if log_transform {
        for x in out.iter_mut() {
            *x = x.exp();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::engines::PhiloxEngine;

    fn sample(d: Distribution, n: usize) -> Vec<f32> {
        let mut e = PhiloxEngine::new(2024);
        let mut out = vec![0f32; n];
        d.sample_f32(&mut e, &mut out);
        out
    }

    #[test]
    fn uniform_range_and_moments() {
        let out = sample(Distribution::uniform(-3.0, 5.0), 100_000);
        assert!(out.iter().all(|&x| (-3.0..5.0).contains(&x)));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn gaussian_moments_both_methods() {
        for method in [GaussianMethod::BoxMuller, GaussianMethod::Icdf] {
            let out = sample(
                Distribution::Gaussian { mean: 2.0, stddev: 3.0, method },
                100_000,
            );
            let n = out.len() as f32;
            let mean = out.iter().sum::<f32>() / n;
            let var = out.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
            assert!((mean - 2.0).abs() < 0.05, "{method:?} mean={mean}");
            assert!((var.sqrt() - 3.0).abs() < 0.05, "{method:?} std={}", var.sqrt());
        }
    }

    #[test]
    fn methods_agree_in_distribution() {
        // Same distribution, different methods: compare quartiles.
        let a = sample(Distribution::Gaussian { mean: 0.0, stddev: 1.0, method: GaussianMethod::BoxMuller }, 200_000);
        let b = sample(Distribution::Gaussian { mean: 0.0, stddev: 1.0, method: GaussianMethod::Icdf }, 200_000);
        let q = |v: &[f32], p: f64| {
            let mut s = v.to_vec();
            s.sort_by(f32::total_cmp);
            s[(p * (s.len() - 1) as f64) as usize]
        };
        for p in [0.1, 0.25, 0.5, 0.75, 0.9] {
            assert!((q(&a, p) - q(&b, p)).abs() < 0.02, "quartile {p}");
        }
    }

    #[test]
    fn lognormal_is_exp_gaussian() {
        let out = sample(
            Distribution::Lognormal { m: 0.0, s: 0.5, method: GaussianMethod::BoxMuller },
            50_000,
        );
        assert!(out.iter().all(|&x| x > 0.0));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        // E[lognormal(0, 0.5)] = exp(0.125) ~ 1.133
        assert!((mean - 1.133).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let out = sample(Distribution::Exponential { lambda: 2.0 }, 100_000);
        assert!(out.iter().all(|&x| x >= 0.0));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_moments() {
        let out = sample(Distribution::Poisson { lambda: 4.0 }, 20_000);
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        let var = out.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / out.len() as f32;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn range_transform_required_detection() {
        assert!(!Distribution::uniform(0.0, 1.0).requires_range_transform());
        assert!(Distribution::uniform(0.0, 2.0).requires_range_transform());
        assert!(Distribution::gaussian(0.0, 2.0).requires_range_transform());
        assert!(!Distribution::gaussian(0.0, 1.0).requires_range_transform());
    }

    #[test]
    fn f64_uniform_uses_53_bits() {
        let mut e = PhiloxEngine::new(1);
        let mut out = vec![0f64; 4096];
        Distribution::uniform(0.0, 1.0).sample_f64(&mut e, &mut out);
        assert!(out.iter().all(|&x| (0.0..1.0).contains(&x)));
        // More resolution than f32: some values need >24 bits.
        assert!(out.iter().any(|&x| (x * (1u64 << 32) as f64).fract() != 0.0));
    }
}
