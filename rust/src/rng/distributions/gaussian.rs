//! Gaussian sampling kernels: Box-Muller (all backends) and inverse-CDF
//! (oneMKL-native backends only — paper §4.1).

/// Box-Muller: two uniforms in [0,1) -> two independent N(0,1) draws.
///
/// `u1` is reflected to (0,1] before the log, matching the Pallas kernel
/// and the jnp oracle bit-for-bit at the f32 level.
#[inline]
pub fn box_muller_pair(u1: f32, u2: f32) -> (f32, f32) {
    let r = (-2.0f32 * (1.0 - u1).ln()).sqrt();
    let theta = 2.0 * std::f32::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Acklam's rational approximation of the standard normal inverse CDF
/// (|relative error| < 1.15e-9 over (0,1)).
pub fn gaussian_icdf(p: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&p));
    // Clamp away from {0,1}: engines emit [0,1) so p=1 cannot occur, and
    // p=0 maps to the smallest representable draw's quantile.
    let p = p.clamp(1e-300, 1.0 - 1e-16);

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn icdf_known_quantiles() {
        assert!((gaussian_icdf(0.5)).abs() < 1e-9);
        assert!((gaussian_icdf(0.975) - 1.959_964).abs() < 1e-4);
        assert!((gaussian_icdf(0.025) + 1.959_964).abs() < 1e-4);
        assert!((gaussian_icdf(0.8413) - 0.9998).abs() < 1e-2); // ~ +1 sigma
    }

    #[test]
    fn icdf_symmetry() {
        for p in [0.001, 0.01, 0.1, 0.3, 0.49] {
            assert!((gaussian_icdf(p) + gaussian_icdf(1.0 - p)).abs() < 1e-8, "p={p}");
        }
    }

    #[test]
    fn box_muller_finite_and_centered() {
        // u1=0 must not produce inf: log argument is 1-u1 = 1.
        let (z0, z1) = box_muller_pair(0.0, 0.0);
        assert!(z0.is_finite() && z1.is_finite());
        let (z0, _) = box_muller_pair(0.9999999, 0.25);
        assert!(z0.is_finite());
    }
}
