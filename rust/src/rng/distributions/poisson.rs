//! Poisson sampling (Knuth's product method for small lambda, normal
//! approximation above the practical threshold).

use crate::rng::engines::Engine;
use crate::rng::u32_to_uniform_f32;

/// One Poisson(lambda) draw.
///
/// Knuth's multiplicative method consumes a geometric number of uniforms
/// (mean lambda+1); above `lambda > 30` the rounded-normal approximation is
/// used, matching what vendor libraries do for large means.
pub fn poisson_knuth(engine: &mut dyn Engine, lambda: f64) -> u64 {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda > 30.0 {
        // Normal approximation with continuity correction.
        let u1 = u32_to_uniform_f32(engine.next_u32()) as f64;
        let u2 = u32_to_uniform_f32(engine.next_u32()) as f64;
        let r = (-2.0 * (1.0 - u1).ln()).sqrt();
        let z = r * (2.0 * std::f64::consts::PI * u2).cos();
        return (lambda + lambda.sqrt() * z + 0.5).max(0.0) as u64;
    }
    let l = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0f64;
    loop {
        p *= u32_to_uniform_f32(engine.next_u32()) as f64;
        if p <= l {
            return k;
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::engines::PhiloxEngine;

    #[test]
    fn zero_lambda_is_zero() {
        let mut e = PhiloxEngine::new(1);
        assert_eq!(poisson_knuth(&mut e, 0.0), 0);
    }

    #[test]
    fn large_lambda_normal_branch_moments() {
        let mut e = PhiloxEngine::new(5);
        let n = 20_000;
        let lambda = 100.0;
        let draws: Vec<u64> = (0..n).map(|_| poisson_knuth(&mut e, lambda)).collect();
        let mean = draws.iter().sum::<u64>() as f64 / n as f64;
        assert!((mean - lambda).abs() < 1.0, "mean={mean}");
    }
}
