//! The FastCaloSim event loop over the portable RNG API.
//!
//! Per event, per particle: bin into a parameterization table (loading it
//! to the device on first use), derive the hit count, draw 3 uniforms per
//! hit through the RNG backend, and deposit hit energies into the
//! calorimeter cells. The paper's §5.2/§7 observations are reproduced
//! structurally: intra-event hit parallelism only (no inter-event
//! batching), parameterization H2D traffic dominating t t̄, and the RNG
//! contribution being small but mandatory for portability.
//!
//! Since S17 the simulator no longer owns its engine: all uniforms come
//! from a pluggable [`RngSource`] — the standalone host engine or a
//! [`PooledSource`](super::PooledSource) that routes every block through
//! the sharded [`ServicePool`](crate::coordinator::ServicePool) (see
//! [`run_fastcalosim_pooled`]). Blocks are requested per event up front
//! so shard workers generate ahead of the host-side deposition loop, and
//! the per-event RN floor is drawn for real (in
//! [`FLOOR_CHUNK`]-sized blocks) so the floor parallelises across shards
//! instead of being virtual-only accounting. The SYCL event loop records
//! every command through [`Queue::submit_usm`] with real [`Access`] sets,
//! so `PORTARNG_HAZARD_CHECK=1` proves each event's DAG race-free instead
//! of vacuously passing over empty host tasks.

use std::collections::HashMap;

use crate::backends::NativeTimeline;
use crate::coordinator::{PoolConfig, PoolStats};
use crate::error::Result;
use crate::platform::{CommandCost, PlatformId, PlatformKind, TransferDir};
use crate::sycl::{
    Access, AccessMode, CommandClass, CommandRecord, Event as SyclEvent, Queue,
    SyclRuntimeProfile,
};
use crate::telemetry::TelemetrySnapshot;

use super::event::Event;
use super::geometry::Geometry;
use super::param::{ParamStore, ParamTable, TableId};
use super::source::{HostSource, RngSource};

/// Which FastCaloSim port runs (paper §5.2: C++/CUDA native vs SYCL).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FcsApi {
    /// The original codes: C++ on CPUs, CUDA on NVIDIA.
    Native,
    /// The SYCL port with the oneMKL RNG integration.
    Sycl,
}

impl FcsApi {
    /// CLI token.
    pub fn token(self) -> &'static str {
        match self {
            FcsApi::Native => "native",
            FcsApi::Sycl => "sycl",
        }
    }

    /// Parse CLI token.
    pub fn parse(s: &str) -> Option<FcsApi> {
        match s {
            "native" => Some(FcsApi::Native),
            "sycl" => Some(FcsApi::Sycl),
            _ => None,
        }
    }
}

/// The two paper workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// 1000 (paper: 10^3) single 65 GeV electrons.
    SingleElectron {
        /// Event count.
        events: usize,
    },
    /// 500 t t̄ events.
    TTbar {
        /// Event count.
        events: usize,
    },
}

impl Workload {
    /// Paper-sized single-electron workload.
    pub fn single_electron() -> Workload {
        Workload::SingleElectron { events: 1000 }
    }

    /// Paper-sized t t̄ workload.
    pub fn ttbar() -> Workload {
        Workload::TTbar { events: 500 }
    }

    /// Label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Workload::SingleElectron { .. } => "single-e",
            Workload::TTbar { .. } => "ttbar",
        }
    }

    /// Build the events.
    pub fn events(&self, seed: u64) -> Vec<Event> {
        match *self {
            Workload::SingleElectron { events } => super::event::single_electron_events(events, seed),
            Workload::TTbar { events } => super::event::ttbar_events(events, seed),
        }
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct FcsConfig {
    /// Target platform.
    pub platform: PlatformId,
    /// Port (native vs SYCL).
    pub api: FcsApi,
    /// RNG seed.
    pub seed: u64,
    /// Real per-hit computation cap per event (virtual accounting is
    /// always exact; see DESIGN.md on tractability).
    pub real_hit_cap: usize,
    /// Retain each event's drained command window (SYCL api only) for
    /// offline DAG analysis — `lint-dag`'s fastcalosim workload. Off by
    /// default: windows are large and the inline hazard check already
    /// runs at every drain under enforcement.
    pub keep_windows: bool,
}

impl FcsConfig {
    /// Defaults for a platform/api pair.
    pub fn new(platform: PlatformId, api: FcsApi) -> FcsConfig {
        FcsConfig {
            platform,
            api,
            seed: 0xFC5,
            real_hit_cap: 20_000,
            keep_windows: false,
        }
    }
}

/// Per-event virtual-time split by command class (the Fig.-4-style
/// generate/transform/D2H breakdown, folded into telemetry v6).
#[derive(Debug, Clone, Copy, Default)]
pub struct FcsEventSplit {
    /// Virtual ns in `Generate`-class commands (rng + rng:floor).
    pub gen_ns: u64,
    /// Virtual ns in `Transform`-class commands (hit deposition kernels).
    pub transform_ns: u64,
    /// Virtual ns in D2H transfers (result readback).
    pub d2h_ns: u64,
    /// Virtual hits simulated this event.
    pub hits: u64,
}

/// Simulation outcome + virtual timing.
#[derive(Debug, Clone)]
pub struct FcsReport {
    /// Config echoed.
    pub platform: PlatformId,
    /// Port.
    pub api: FcsApi,
    /// Workload label.
    pub workload: &'static str,
    /// RNG source label (`"host"` / `"pooled"`).
    pub source: &'static str,
    /// Events simulated.
    pub events: usize,
    /// Virtual per-event times, ns.
    pub per_event_ns: Vec<f64>,
    /// Total virtual time, ns.
    pub total_ns: u64,
    /// Total hits simulated (virtual count).
    pub hits: u64,
    /// Random numbers consumed (virtual count; 3 per hit + minimum floor).
    pub rns: u64,
    /// Distinct parameterization tables loaded.
    pub tables_loaded: usize,
    /// Energy entering the calorimeter (real-computed subset).
    pub energy_in: f64,
    /// Energy deposited (real-computed subset).
    pub energy_dep: f64,
    /// Physics checksum: FNV-1a over every deposit's bit pattern plus the
    /// hit/RN totals — bit-identical across RNG sources and APIs for one
    /// seed, the standalone-vs-pooled acceptance gate.
    pub checksum: u64,
    /// Per-event command-class splits (SYCL api; empty for native, whose
    /// sequential timeline has no queue to drain).
    pub splits: Vec<FcsEventSplit>,
    /// Wall time of the run, ns.
    pub wall_ns: u64,
}

impl FcsReport {
    /// Mean virtual time per event, ms.
    pub fn mean_event_ms(&self) -> f64 {
        crate::metrics::mean(&self.per_event_ns) / 1e6
    }
}

/// Per-hit host cost for the CPU ports, ns (calibrated so 1000 single-e
/// events take O(seconds) on CPUs, matching Fig. 5's scale).
const CPU_NS_PER_HIT: f64 = 350.0;
/// Host-side per-particle bookkeeping, ns.
const HOST_NS_PER_PARTICLE: u64 = 4_000;
/// Minimum random numbers per event (paper: "the minimum set to 200,000 —
/// approximately one per calorimeter cell").
const MIN_RNS_PER_EVENT: u64 = 200_000;
/// Floor draws are requested in blocks of this many uniforms so the
/// pooled source spreads one event's ~200k-number floor across shards
/// (one monolithic request would pin the whole floor to a single
/// round-robin worker).
const FLOOR_CHUNK: usize = 65_536;

/// Device-side USM handles for the SYCL event loop. Zero-length
/// `malloc_device` ids: the cost model carries bytes through
/// [`CommandCost`], the handles exist so every command can declare real
/// [`Access`] sets for the hazard analyzer.
struct DevHandles {
    /// Uniform output buffer; rng commands write rolling disjoint ranges.
    rng_id: u64,
    /// Calorimeter deposit accumulator (read-modify-write per particle).
    dep_id: u64,
    /// Geometry tables.
    geo_id: u64,
    /// Geometry upload event (first hits command in the upload's window
    /// must order after it).
    geo_ev: Option<SyclEvent>,
    /// One device allocation per parameterization table.
    param_ids: HashMap<TableId, u64>,
    /// Serial deposit chain: last command touching `dep_id`.
    chain: Option<SyclEvent>,
    /// Next free element offset in the rng buffer's virtual range space.
    rng_cursor: usize,
}

/// The per-particle draw plan computed by the pure prepass.
struct EventPlan {
    /// Per particle: (table id, synthesized table, virtual hit count,
    /// real — capped — hit count).
    particles: Vec<(TableId, ParamTable, u64, usize)>,
    /// Virtual hits for the whole event.
    virt_hits: u64,
    /// Real floor draws (the virtual floor shortfall, drawn and
    /// discarded so pooled/standalone streams agree).
    floor: usize,
}

/// The simulator: owns geometry, parameterizations and the RNG source.
pub struct Simulator {
    cfg: FcsConfig,
    geometry: Geometry,
    params: ParamStore,
    source: Box<dyn RngSource>,
    deposits: Vec<f32>,
    windows: Vec<Vec<CommandRecord>>,
}

impl Simulator {
    /// Build a simulator over the standalone host engine (geometry upload
    /// happens on first `simulate`).
    pub fn new(cfg: FcsConfig) -> Simulator {
        let source = Box::new(HostSource::new(cfg.seed));
        Simulator::with_source(cfg, source)
    }

    /// Build a simulator over an explicit RNG source. The source's stream
    /// must start at position 0 for `cfg.seed` — for a pooled source that
    /// means the pool was spawned with the same seed and no other client.
    pub fn with_source(cfg: FcsConfig, source: Box<dyn RngSource>) -> Simulator {
        let geometry = Geometry::build();
        let params = ParamStore::new(geometry.n_layers());
        Simulator {
            source,
            geometry,
            params,
            cfg,
            deposits: Vec::new(),
            windows: Vec::new(),
        }
    }

    /// The detector geometry.
    pub fn geometry(&self) -> &Geometry {
        &self.geometry
    }

    /// The active source's label.
    pub fn source_label(&self) -> &'static str {
        self.source.label()
    }

    /// Tear down the RNG source (shuts a pooled source's pool down),
    /// returning its final stats when it had a pool behind it.
    pub fn finish_source(&mut self) -> Result<Option<PoolStats>> {
        self.source.finish()
    }

    /// Take the retained per-event command windows (empty unless
    /// `cfg.keep_windows` was set on a SYCL-api run).
    pub fn take_windows(&mut self) -> Vec<Vec<CommandRecord>> {
        std::mem::take(&mut self.windows)
    }

    /// Run the full workload.
    pub fn simulate(&mut self, events: &[Event]) -> Result<FcsReport> {
        let wall_start = std::time::Instant::now();
        let spec = self.cfg.platform.spec();
        let is_gpu = spec.kind != PlatformKind::Cpu;
        self.deposits = vec![0f32; self.geometry.n_cells()];
        self.windows.clear();

        // Timelines: the native port uses the sequential native clock; the
        // SYCL port pays queue/DAG costs. Both share the kernel cost model.
        let mut native = NativeTimeline::new(self.cfg.platform);
        let queue = Queue::new(
            self.cfg.platform,
            SyclRuntimeProfile::for_platform(&spec),
        );

        // Zero-length device handles so every SYCL command declares what
        // it touches (DESIGN.md S17: real access sets, no empty host
        // tasks).
        let mut dev = DevHandles {
            rng_id: queue.malloc_device::<f32>(0).id(),
            dep_id: queue.malloc_device::<f32>(0).id(),
            geo_id: queue.malloc_device::<f32>(0).id(),
            geo_ev: None,
            param_ids: HashMap::new(),
            chain: None,
            rng_cursor: 0,
        };

        // Geometry upload (~20 MB) once, GPU only.
        if is_gpu {
            match self.cfg.api {
                FcsApi::Native => {
                    native.transfer(self.geometry.device_bytes(), TransferDir::H2D)
                }
                FcsApi::Sycl => {
                    let bytes = self.geometry.device_bytes();
                    let ev = queue.submit_usm(
                        "geometry:h2d",
                        CommandClass::TransferH2D,
                        CommandCost::Transfer { bytes, dir: TransferDir::H2D },
                        &[],
                        vec![Access::usm(dev.geo_id, AccessMode::Write)],
                        |_| {},
                    );
                    dev.geo_ev = Some(ev);
                }
            }
        }

        let mut per_event_ns = Vec::with_capacity(events.len());
        let mut splits = Vec::new();
        let (mut hits_total, mut rns_total) = (0u64, 0u64);
        let (mut energy_in, mut energy_dep) = (0f64, 0f64);

        for (i, ev) in events.iter().enumerate() {
            let start_ns = match self.cfg.api {
                FcsApi::Native => native.total_ns(),
                FcsApi::Sycl => queue.virtual_now_ns(),
            };
            let (hits, rns, e_in, e_dep) =
                self.simulate_event(ev, i as u64, &mut native, &queue, is_gpu, &mut dev)?;
            hits_total += hits;
            rns_total += rns;
            energy_in += e_in;
            energy_dep += e_dep;
            let end_ns = match self.cfg.api {
                FcsApi::Native => native.total_ns(),
                FcsApi::Sycl => queue.wait(),
            };
            per_event_ns.push((end_ns - start_ns) as f64);

            // Drain the event's command window (SYCL only): the Fig.-4
            // split folds from it, hazard enforcement analyzes it, and
            // cross-event dependency edges become `external_deps` in the
            // next window. The geometry handle's cross-window reads need
            // no in-window writer, so later windows stay race-free.
            if self.cfg.api == FcsApi::Sycl {
                let window = queue.drain_records();
                let mut split = FcsEventSplit { hits, ..Default::default() };
                for r in &window {
                    let ns = r.virt_end_ns - r.virt_start_ns;
                    match r.class {
                        CommandClass::Generate => split.gen_ns += ns,
                        CommandClass::Transform => split.transform_ns += ns,
                        CommandClass::TransferD2H => split.d2h_ns += ns,
                        _ => {}
                    }
                }
                splits.push(split);
                if self.cfg.keep_windows {
                    self.windows.push(window);
                }
            }
        }

        let total_ns = match self.cfg.api {
            FcsApi::Native => native.total_ns(),
            FcsApi::Sycl => queue.wait(),
        };

        Ok(FcsReport {
            platform: self.cfg.platform,
            api: self.cfg.api,
            workload: if events.first().map(|e| e.particles.len() > 1).unwrap_or(false) {
                "ttbar"
            } else {
                "single-e"
            },
            source: self.source.label(),
            events: events.len(),
            per_event_ns,
            total_ns,
            hits: hits_total,
            rns: rns_total,
            tables_loaded: self.params.loaded_count(),
            energy_in,
            energy_dep,
            checksum: physics_checksum(&self.deposits, hits_total, rns_total),
            splits,
            wall_ns: wall_start.elapsed().as_nanos() as u64,
        })
    }

    /// Pure prepass: table synthesis + hit counts + the real-draw plan,
    /// with no store/device mutation — it exists so every block of the
    /// event can be requested from the source *before* deposition starts
    /// (the pooled source generates ahead while the host deposits).
    fn plan_event(&self, ev: &Event) -> EventPlan {
        let mut particles = Vec::with_capacity(ev.particles.len());
        let mut virt_hits = 0u64;
        let mut real_left = self.cfg.real_hit_cap;
        for p in &ev.particles {
            let id = TableId::for_particle(p.pdg, p.energy_gev, p.eta);
            let table = ParamTable::synthesize(id, self.geometry.n_layers());
            let n_hits = (p.energy_gev * table.hits_per_gev) as u64;
            let real = (n_hits as usize).min(real_left);
            real_left -= real;
            virt_hits += n_hits;
            particles.push((id, table, n_hits, real));
        }
        let floor = MIN_RNS_PER_EVENT.saturating_sub(3 * virt_hits) as usize;
        EventPlan { particles, virt_hits, floor }
    }

    /// One event: per-particle table fetch, RNG draw, hit deposition.
    #[allow(clippy::too_many_arguments)]
    fn simulate_event(
        &mut self,
        ev: &Event,
        salt: u64,
        native: &mut NativeTimeline,
        queue: &Queue,
        is_gpu: bool,
        dev: &mut DevHandles,
    ) -> Result<(u64, u64, f64, f64)> {
        native.set_noise_salt(salt);
        queue.set_noise_salt(salt);
        let mut e_in = 0f64;
        let mut e_dep = 0f64;

        // Request every block of the event up front, in consumption
        // order: 3 uniforms per real hit per particle, then the floor in
        // FLOOR_CHUNK blocks. A pooled source submits all of these to its
        // shards here and generates while the host deposits below.
        let plan = self.plan_event(ev);
        let mut sizes: Vec<usize> =
            plan.particles.iter().map(|&(_, _, _, real)| 3 * real).collect();
        let mut floor_left = plan.floor;
        while floor_left > 0 {
            let chunk = floor_left.min(FLOOR_CHUNK);
            sizes.push(chunk);
            floor_left -= chunk;
        }
        let mut draws = self.source.request(&sizes).into_iter();

        for (p, &(id, ref table, n_hits, real_hits)) in
            ev.particles.iter().zip(&plan.particles)
        {
            // Parameterization load (t t̄: 20-30 of these, §5.2). The
            // loading particle's hit command is the upload's first user.
            let (_, h2d_bytes) = self.params.fetch(id);
            let mut fresh_param: Option<SyclEvent> = None;
            if h2d_bytes > 0 && is_gpu {
                match self.cfg.api {
                    FcsApi::Native => native.transfer(h2d_bytes, TransferDir::H2D),
                    FcsApi::Sycl => {
                        let param_id = queue.malloc_device::<f32>(0).id();
                        dev.param_ids.insert(id, param_id);
                        fresh_param = Some(queue.submit_usm(
                            "param:h2d",
                            CommandClass::TransferH2D,
                            CommandCost::Transfer {
                                bytes: h2d_bytes,
                                dir: TransferDir::H2D,
                            },
                            &[],
                            vec![Access::usm(param_id, AccessMode::Write)],
                            |_| {},
                        ));
                    }
                }
            }

            e_in += p.energy_gev as f64;

            // Host bookkeeping per particle.
            match self.cfg.api {
                FcsApi::Native => native.host("particle", HOST_NS_PER_PARTICLE),
                FcsApi::Sycl => queue.advance_host(HOST_NS_PER_PARTICLE),
            }

            // RNG + hit kernels (intra-event parallelism only).
            let n_rns = 3 * n_hits;
            let rng_cost = CommandCost::Kernel {
                bytes_read: 0,
                bytes_written: n_rns * 4,
                items: n_rns,
                tpb: 0,
            };
            let hit_cost = if is_gpu {
                CommandCost::Kernel {
                    bytes_read: n_rns * 4,
                    bytes_written: n_hits * 8,
                    items: n_hits,
                    tpb: 0,
                }
            } else {
                CommandCost::HostCompute { ns: (n_hits as f64 * CPU_NS_PER_HIT) as u64 }
            };
            match self.cfg.api {
                FcsApi::Native => {
                    // Pipelined launches; one sync per event (below).
                    native.kernel_async("rng", CommandClass::Generate, rng_cost);
                    native.kernel_async("hits", CommandClass::Transform, hit_cost);
                }
                FcsApi::Sycl => {
                    // USM-path submissions with explicit deps + declared
                    // access sets (DESIGN.md S17): each particle's rng
                    // kernel writes its own disjoint range of the rng
                    // buffer (no ordering needed between particles), its
                    // hit kernel reads exactly that range (RAW edge on
                    // `ev_rng`) and read-modify-writes the shared deposit
                    // buffer, serialised on the event's deposit chain.
                    let rng_at = dev.rng_cursor;
                    dev.rng_cursor += n_rns as usize;
                    let ev_rng = queue.submit_usm(
                        "rng",
                        CommandClass::Generate,
                        rng_cost,
                        &[],
                        vec![Access::usm(dev.rng_id, AccessMode::Write)
                            .with_range(rng_at, n_rns as usize)],
                        |_| {},
                    );
                    let mut deps = vec![ev_rng];
                    match (&dev.chain, &dev.geo_ev) {
                        // First hits command of the upload's window orders
                        // after the geometry H2D; later ones reach it
                        // through the deposit chain.
                        (Some(chain), _) => deps.push(chain.clone()),
                        (None, Some(geo)) => deps.push(geo.clone()),
                        (None, None) => {}
                    }
                    if let Some(pv) = fresh_param {
                        deps.push(pv);
                    }
                    let mut accesses = vec![
                        Access::usm(dev.rng_id, AccessMode::Read)
                            .with_range(rng_at, n_rns as usize),
                        Access::usm(dev.dep_id, AccessMode::ReadWrite),
                    ];
                    if is_gpu {
                        accesses.push(Access::usm(dev.geo_id, AccessMode::Read));
                        if let Some(&param_id) = dev.param_ids.get(&id) {
                            accesses.push(Access::usm(param_id, AccessMode::Read));
                        }
                    }
                    let ev_hits = queue.submit_usm(
                        "hits",
                        CommandClass::Transform,
                        hit_cost,
                        &deps,
                        accesses,
                        |_| {},
                    );
                    dev.chain = Some(ev_hits);
                }
            }

            // Real hit computation (capped): same math as the L2 graph,
            // fed from the pre-requested source block.
            let block = draws.next().expect("plan/draw mismatch").take()?;
            debug_assert_eq!(block.len(), 3 * real_hits);
            if real_hits > 0 {
                let scale = n_hits as f32 / real_hits as f32;
                let e_per_hit = p.energy_gev / n_hits as f32;
                let layers = self.geometry.layers_at(p.eta);
                for h in 0..real_hits {
                    let u_e = block[3 * h];
                    let u_eta = block[3 * h + 1];
                    let u_phi = block[3 * h + 2];
                    let e = e_per_hit * -(1.0 - u_e).ln();
                    let eta = p.eta + table.sigma_eta * (2.0 * u_eta - 1.0);
                    let phi = p.phi + table.sigma_phi * (2.0 * u_phi - 1.0);
                    // Deposit split over covered layers by the table
                    // weights (renormalised to the covered subset).
                    let wsum: f32 = layers.iter().map(|&l| table.layer_weights[l]).sum();
                    for &l in &layers {
                        let frac = table.layer_weights[l] / wsum.max(1e-6);
                        let idx = self.geometry.cell_index(l, eta, phi);
                        self.deposits[idx] += scale * e * frac;
                        e_dep += (scale * e * frac) as f64;
                    }
                }
            }
        }

        // Per-event RN floor (~one per cell): drawn for real — and
        // discarded — so the stream position is source-independent, but
        // recorded as one kernel (the chunking is a *request* shape for
        // shard spread, not a submission shape).
        let event_hits = plan.virt_hits;
        let event_rns = (3 * event_hits).max(MIN_RNS_PER_EVENT);
        if plan.floor > 0 {
            for d in draws {
                let _ = d.take()?;
            }
            let extra = plan.floor as u64;
            let cost = CommandCost::Kernel {
                bytes_read: 0,
                bytes_written: extra * 4,
                items: extra,
                tpb: 0,
            };
            match self.cfg.api {
                FcsApi::Native => native.kernel_async("rng:floor", CommandClass::Generate, cost),
                FcsApi::Sycl => {
                    let at = dev.rng_cursor;
                    dev.rng_cursor += plan.floor;
                    queue.submit_usm(
                        "rng:floor",
                        CommandClass::Generate,
                        cost,
                        &[],
                        vec![Access::usm(dev.rng_id, AccessMode::Write)
                            .with_range(at, plan.floor)],
                        |_| {},
                    );
                }
            }
        }

        // Result readback (deposited-cell list, ~a few hundred KB).
        if is_gpu {
            let bytes = (self.geometry.n_cells() as u64) * 4;
            match self.cfg.api {
                FcsApi::Native => {
                    native.sync();
                    native.transfer(bytes, TransferDir::D2H)
                }
                FcsApi::Sycl => {
                    let deps: Vec<SyclEvent> = dev.chain.iter().cloned().collect();
                    let ev_d2h = queue.submit_usm(
                        "result:d2h",
                        CommandClass::TransferD2H,
                        CommandCost::Transfer { bytes, dir: TransferDir::D2H },
                        &deps,
                        vec![Access::usm(dev.dep_id, AccessMode::Read)],
                        |_| {},
                    );
                    // Next event's first deposit write orders after this
                    // read (WAR edge across the window boundary).
                    dev.chain = Some(ev_d2h);
                }
            }
        }
        Ok((event_hits, event_rns, e_in, e_dep))
    }

    /// Accumulated deposits (real-computed subset).
    pub fn deposits(&self) -> &[f32] {
        &self.deposits
    }
}

/// FNV-1a over the deposit bit patterns + totals: cheap, order-sensitive,
/// and exact — any single-ulp physics divergence flips it.
fn physics_checksum(deposits: &[f32], hits: u64, rns: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    for d in deposits {
        eat(d.to_bits() as u64);
    }
    eat(hits);
    eat(rns);
    h
}

/// Convenience driver: simulate `workload` on (platform, api) with the
/// standalone host engine.
pub fn run_fastcalosim(
    platform: PlatformId,
    api: FcsApi,
    workload: Workload,
    seed: u64,
) -> Result<FcsReport> {
    let events = workload.events(seed);
    let mut sim = Simulator::new(FcsConfig::new(platform, api));
    let mut report = sim.simulate(&events)?;
    sim.finish_source()?;
    report.workload = workload.label();
    Ok(report)
}

/// A pooled FastCaloSim run: the physics report plus the serving stack's
/// view of it.
#[derive(Debug)]
pub struct FcsPoolRun {
    /// The physics/timing report (bit-identical to the standalone run).
    pub report: FcsReport,
    /// Telemetry snapshot with the per-event `fcs` block folded in
    /// (schema `portarng-telemetry-v7`).
    pub telemetry: TelemetrySnapshot,
    /// Final per-shard pool stats.
    pub stats: PoolStats,
    /// Merged span snapshot from the request tracer (what
    /// `fastcalosim --pool N --trace <path>` exports as Chrome trace
    /// JSON). Empty when tracing was not enabled.
    pub spans: Vec<crate::trace::Span>,
}

/// Convenience driver: simulate `workload` with every uniform served by a
/// sharded [`ServicePool`](crate::coordinator::ServicePool) — `shards`
/// workers, optional tile executor shape, optional chaos plan. The
/// engine seed is [`FcsConfig`]'s (the pool must share it for
/// bit-identity); `seed` only shapes the generated events.
pub fn run_fastcalosim_pooled(
    platform: PlatformId,
    api: FcsApi,
    workload: Workload,
    seed: u64,
    shards: usize,
    tiling: Option<(usize, usize)>,
    chaos: Option<crate::fault::FaultSpec>,
) -> Result<FcsPoolRun> {
    run_fastcalosim_pooled_opts(platform, api, workload, seed, shards, tiling, chaos, None)
}

/// [`run_fastcalosim_pooled`] with an optional request-tracer
/// configuration (`fastcalosim --pool N --trace <path>`, DESIGN.md S18):
/// the pool records spans into per-shard rings, the run carries the
/// merged snapshot in [`FcsPoolRun::spans`], and — combined with a chaos
/// plan that kills workers — the supervisor leaves flight-recorder dumps
/// in the config's `flight_dir`.
#[allow(clippy::too_many_arguments)]
pub fn run_fastcalosim_pooled_opts(
    platform: PlatformId,
    api: FcsApi,
    workload: Workload,
    seed: u64,
    shards: usize,
    tiling: Option<(usize, usize)>,
    chaos: Option<crate::fault::FaultSpec>,
    trace: Option<crate::trace::TraceConfig>,
) -> Result<FcsPoolRun> {
    let events = workload.events(seed);
    let cfg = FcsConfig::new(platform, api);
    let mut pool_cfg = PoolConfig::new(platform, cfg.seed, shards);
    pool_cfg.tiling = tiling;
    if let Some(plan) = chaos {
        pool_cfg.fault = Some(plan);
        // Transient chaos trips surface as retries; give the supervisor
        // headroom so a soak-level fault rate cannot exhaust the budget.
        pool_cfg.ingress.max_retries = 12;
    }
    pool_cfg.trace = trace;
    let source = super::PooledSource::spawn(pool_cfg);
    let registry = source.registry();
    let tracer = source.tracer();
    let mut sim = Simulator::with_source(cfg, Box::new(source));
    let mut report = sim.simulate(&events)?;
    report.workload = workload.label();
    let stats = sim
        .finish_source()?
        .expect("pooled simulator owns a pool");
    for s in &report.splits {
        registry.record_fcs_event(s.hits, s.gen_ns, s.transform_ns, s.d2h_ns);
    }
    let telemetry = registry.snapshot();
    let spans = tracer.map(|t| t.snapshot()).unwrap_or_default();
    Ok(FcsPoolRun { report, telemetry, stats, spans })
}

/// The RNG engine FastCaloSim requests from the portable API.
pub const FCS_ENGINE: crate::rng::EngineKind =
    crate::rng::EngineKind::Philox4x32x10;

#[cfg(test)]
mod tests {
    use super::*;

    fn small(workload: Workload) -> FcsReport {
        run_fastcalosim(PlatformId::A100, FcsApi::Sycl, workload, 42).unwrap()
    }

    #[test]
    fn single_electron_hits_in_window() {
        let r = small(Workload::SingleElectron { events: 20 });
        let hits_per_event = r.hits as f64 / r.events as f64;
        assert!(
            (4000.0..6500.0).contains(&hits_per_event),
            "hits/event = {hits_per_event}"
        );
        // 12000-19500 RNs/event before the 200k floor -> floor applies.
        assert!(r.rns >= r.events as u64 * 200_000);
    }

    #[test]
    fn energy_approximately_conserved() {
        let r = small(Workload::SingleElectron { events: 5 });
        // Real compute covers all single-e hits (< cap): deposits ~ input.
        let ratio = r.energy_dep / r.energy_in;
        assert!((0.9..1.1).contains(&ratio), "dep/in = {ratio}");
    }

    #[test]
    fn ttbar_loads_many_tables_and_is_slower() {
        let se = small(Workload::SingleElectron { events: 5 });
        let tt = small(Workload::TTbar { events: 5 });
        assert_eq!(se.tables_loaded, 1);
        assert!((15..=40).contains(&tt.tables_loaded), "tables={}", tt.tables_loaded);
        assert!(tt.mean_event_ms() > 10.0 * se.mean_event_ms());
    }

    #[test]
    fn gpu_beats_cpu_on_single_electrons() {
        // The paper's ~80% reduction on GPUs vs CPUs (Fig. 5a).
        let gpu = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let cpu = run_fastcalosim(
            PlatformId::CoreI7_10875H,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let reduction = 1.0 - gpu.mean_event_ms() / cpu.mean_event_ms();
        assert!(reduction > 0.5, "reduction = {reduction}");
    }

    #[test]
    fn sycl_close_to_native() {
        let nat = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Native,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let syc = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let eff = crate::metrics::vavs_efficiency(nat.mean_event_ms(), syc.mean_event_ms());
        assert!((0.7..1.4).contains(&eff), "VAVS eff = {eff}");
    }

    #[test]
    fn sycl_event_splits_are_populated() {
        let r = small(Workload::SingleElectron { events: 3 });
        assert_eq!(r.splits.len(), 3);
        for s in &r.splits {
            assert!(s.gen_ns > 0, "gen split empty");
            assert!(s.transform_ns > 0, "transform split empty");
            assert!(s.d2h_ns > 0, "d2h split empty");
            assert!(s.hits > 0);
        }
    }

    #[test]
    fn native_report_has_no_splits_but_same_checksum() {
        let nat = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Native,
            Workload::SingleElectron { events: 3 },
            7,
        )
        .unwrap();
        let syc = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 3 },
            7,
        )
        .unwrap();
        assert!(nat.splits.is_empty());
        assert_eq!(nat.checksum, syc.checksum, "physics must not depend on the port");
        assert_eq!(nat.hits, syc.hits);
    }
}
