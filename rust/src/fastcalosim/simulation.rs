//! The FastCaloSim event loop over the portable RNG API.
//!
//! Per event, per particle: bin into a parameterization table (loading it
//! to the device on first use), derive the hit count, draw 3 uniforms per
//! hit through the RNG backend, and deposit hit energies into the
//! calorimeter cells. The paper's §5.2/§7 observations are reproduced
//! structurally: intra-event hit parallelism only (no inter-event
//! batching), parameterization H2D traffic dominating t t̄, and the RNG
//! contribution being small but mandatory for portability.

use crate::backends::NativeTimeline;
use crate::error::Result;
use crate::platform::{CommandCost, PlatformId, PlatformKind, TransferDir};
use crate::rng::engines::PhiloxEngine;
use crate::rng::{u32_to_uniform_f32, Engine};
use crate::sycl::{CommandClass, Queue, SyclRuntimeProfile};

use super::event::Event;
use super::geometry::Geometry;
use super::param::{ParamStore, TableId};

/// Which FastCaloSim port runs (paper §5.2: C++/CUDA native vs SYCL).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FcsApi {
    /// The original codes: C++ on CPUs, CUDA on NVIDIA.
    Native,
    /// The SYCL port with the oneMKL RNG integration.
    Sycl,
}

impl FcsApi {
    /// CLI token.
    pub fn token(self) -> &'static str {
        match self {
            FcsApi::Native => "native",
            FcsApi::Sycl => "sycl",
        }
    }

    /// Parse CLI token.
    pub fn parse(s: &str) -> Option<FcsApi> {
        match s {
            "native" => Some(FcsApi::Native),
            "sycl" => Some(FcsApi::Sycl),
            _ => None,
        }
    }
}

/// The two paper workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// 1000 (paper: 10^3) single 65 GeV electrons.
    SingleElectron {
        /// Event count.
        events: usize,
    },
    /// 500 t t̄ events.
    TTbar {
        /// Event count.
        events: usize,
    },
}

impl Workload {
    /// Paper-sized single-electron workload.
    pub fn single_electron() -> Workload {
        Workload::SingleElectron { events: 1000 }
    }

    /// Paper-sized t t̄ workload.
    pub fn ttbar() -> Workload {
        Workload::TTbar { events: 500 }
    }

    /// Label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Workload::SingleElectron { .. } => "single-e",
            Workload::TTbar { .. } => "ttbar",
        }
    }

    /// Build the events.
    pub fn events(&self, seed: u64) -> Vec<Event> {
        match *self {
            Workload::SingleElectron { events } => super::event::single_electron_events(events, seed),
            Workload::TTbar { events } => super::event::ttbar_events(events, seed),
        }
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct FcsConfig {
    /// Target platform.
    pub platform: PlatformId,
    /// Port (native vs SYCL).
    pub api: FcsApi,
    /// RNG seed.
    pub seed: u64,
    /// Real per-hit computation cap per event (virtual accounting is
    /// always exact; see DESIGN.md on tractability).
    pub real_hit_cap: usize,
}

impl FcsConfig {
    /// Defaults for a platform/api pair.
    pub fn new(platform: PlatformId, api: FcsApi) -> FcsConfig {
        FcsConfig { platform, api, seed: 0xFC5, real_hit_cap: 20_000 }
    }
}

/// Simulation outcome + virtual timing.
#[derive(Debug, Clone)]
pub struct FcsReport {
    /// Config echoed.
    pub platform: PlatformId,
    /// Port.
    pub api: FcsApi,
    /// Workload label.
    pub workload: &'static str,
    /// Events simulated.
    pub events: usize,
    /// Virtual per-event times, ns.
    pub per_event_ns: Vec<f64>,
    /// Total virtual time, ns.
    pub total_ns: u64,
    /// Total hits simulated (virtual count).
    pub hits: u64,
    /// Random numbers consumed (virtual count; 3 per hit + minimum floor).
    pub rns: u64,
    /// Distinct parameterization tables loaded.
    pub tables_loaded: usize,
    /// Energy entering the calorimeter (real-computed subset).
    pub energy_in: f64,
    /// Energy deposited (real-computed subset).
    pub energy_dep: f64,
    /// Wall time of the run, ns.
    pub wall_ns: u64,
}

impl FcsReport {
    /// Mean virtual time per event, ms.
    pub fn mean_event_ms(&self) -> f64 {
        crate::metrics::mean(&self.per_event_ns) / 1e6
    }
}

/// Per-hit host cost for the CPU ports, ns (calibrated so 1000 single-e
/// events take O(seconds) on CPUs, matching Fig. 5's scale).
const CPU_NS_PER_HIT: f64 = 350.0;
/// Host-side per-particle bookkeeping, ns.
const HOST_NS_PER_PARTICLE: u64 = 4_000;
/// Minimum random numbers per event (paper: "the minimum set to 200,000 —
/// approximately one per calorimeter cell").
const MIN_RNS_PER_EVENT: u64 = 200_000;

/// The simulator: owns geometry, parameterizations and the RNG stream.
pub struct Simulator {
    cfg: FcsConfig,
    geometry: Geometry,
    params: ParamStore,
    rng: PhiloxEngine,
    deposits: Vec<f32>,
}

impl Simulator {
    /// Build a simulator (geometry upload happens on first `simulate`).
    pub fn new(cfg: FcsConfig) -> Simulator {
        let geometry = Geometry::build();
        let params = ParamStore::new(geometry.n_layers());
        Simulator {
            rng: PhiloxEngine::new(cfg.seed),
            geometry,
            params,
            cfg,
            deposits: Vec::new(),
        }
    }

    /// The detector geometry.
    pub fn geometry(&self) -> &Geometry {
        &self.geometry
    }

    /// Run the full workload.
    pub fn simulate(&mut self, events: &[Event]) -> Result<FcsReport> {
        let wall_start = std::time::Instant::now();
        let spec = self.cfg.platform.spec();
        let is_gpu = spec.kind != PlatformKind::Cpu;
        self.deposits = vec![0f32; self.geometry.n_cells()];

        // Timelines: the native port uses the sequential native clock; the
        // SYCL port pays queue/DAG costs. Both share the kernel cost model.
        let mut native = NativeTimeline::new(self.cfg.platform);
        let queue = Queue::new(
            self.cfg.platform,
            SyclRuntimeProfile::for_platform(&spec),
        );

        // Geometry upload (~20 MB) once, GPU only.
        if is_gpu {
            match self.cfg.api {
                FcsApi::Native => {
                    native.transfer(self.geometry.device_bytes(), TransferDir::H2D)
                }
                FcsApi::Sycl => {
                    let bytes = self.geometry.device_bytes();
                    queue.submit(|cgh| {
                        cgh.host_task(
                            "geometry:h2d",
                            CommandClass::TransferH2D,
                            CommandCost::Transfer { bytes, dir: TransferDir::H2D },
                            |_| {},
                        );
                    });
                }
            }
        }

        let mut per_event_ns = Vec::with_capacity(events.len());
        let (mut hits_total, mut rns_total) = (0u64, 0u64);
        let (mut energy_in, mut energy_dep) = (0f64, 0f64);

        for (i, ev) in events.iter().enumerate() {
            let start_ns = match self.cfg.api {
                FcsApi::Native => native.total_ns(),
                FcsApi::Sycl => queue.virtual_now_ns(),
            };
            let (hits, rns, e_in, e_dep) =
                self.simulate_event(ev, i as u64, &mut native, &queue, is_gpu)?;
            hits_total += hits;
            rns_total += rns;
            energy_in += e_in;
            energy_dep += e_dep;
            let end_ns = match self.cfg.api {
                FcsApi::Native => native.total_ns(),
                FcsApi::Sycl => queue.wait(),
            };
            per_event_ns.push((end_ns - start_ns) as f64);
        }

        let total_ns = match self.cfg.api {
            FcsApi::Native => native.total_ns(),
            FcsApi::Sycl => queue.wait(),
        };

        Ok(FcsReport {
            platform: self.cfg.platform,
            api: self.cfg.api,
            workload: if events.first().map(|e| e.particles.len() > 1).unwrap_or(false) {
                "ttbar"
            } else {
                "single-e"
            },
            events: events.len(),
            per_event_ns,
            total_ns,
            hits: hits_total,
            rns: rns_total,
            tables_loaded: self.params.loaded_count(),
            energy_in,
            energy_dep,
            wall_ns: wall_start.elapsed().as_nanos() as u64,
        })
    }

    /// One event: per-particle table fetch, RNG draw, hit deposition.
    fn simulate_event(
        &mut self,
        ev: &Event,
        salt: u64,
        native: &mut NativeTimeline,
        queue: &Queue,
        is_gpu: bool,
    ) -> Result<(u64, u64, f64, f64)> {
        native.set_noise_salt(salt);
        queue.set_noise_salt(salt);
        let mut event_hits = 0u64;
        let mut e_in = 0f64;
        let mut e_dep = 0f64;
        let mut real_hits_left = self.cfg.real_hit_cap;

        for p in &ev.particles {
            let id = TableId::for_particle(p.pdg, p.energy_gev, p.eta);
            let (table, h2d_bytes) = self.params.fetch(id);

            // Parameterization load (t t̄: 20-30 of these, §5.2).
            if h2d_bytes > 0 && is_gpu {
                match self.cfg.api {
                    FcsApi::Native => native.transfer(h2d_bytes, TransferDir::H2D),
                    FcsApi::Sycl => {
                        queue.submit(|cgh| {
                            cgh.host_task(
                                "param:h2d",
                                CommandClass::TransferH2D,
                                CommandCost::Transfer { bytes: h2d_bytes, dir: TransferDir::H2D },
                                |_| {},
                            );
                        });
                    }
                }
            }

            let n_hits = (p.energy_gev * table.hits_per_gev) as u64;
            event_hits += n_hits;
            e_in += p.energy_gev as f64;

            // Host bookkeeping per particle.
            match self.cfg.api {
                FcsApi::Native => native.host("particle", HOST_NS_PER_PARTICLE),
                FcsApi::Sycl => queue.advance_host(HOST_NS_PER_PARTICLE),
            }

            // RNG + hit kernels (intra-event parallelism only).
            let n_rns = 3 * n_hits;
            let rng_cost = CommandCost::Kernel {
                bytes_read: 0,
                bytes_written: n_rns * 4,
                items: n_rns,
                tpb: 0,
            };
            let hit_cost = if is_gpu {
                CommandCost::Kernel {
                    bytes_read: n_rns * 4,
                    bytes_written: n_hits * 8,
                    items: n_hits,
                    tpb: 0,
                }
            } else {
                CommandCost::HostCompute { ns: (n_hits as f64 * CPU_NS_PER_HIT) as u64 }
            };
            match self.cfg.api {
                FcsApi::Native => {
                    // Pipelined launches; one sync per event (below).
                    native.kernel_async("rng", CommandClass::Generate, rng_cost);
                    native.kernel_async("hits", CommandClass::Other, hit_cost);
                }
                FcsApi::Sycl => {
                    // Buffer-path submissions (the FastCaloSim SYCL port
                    // uses accessors; RAW dependency rng -> hits).
                    let ev1 = queue.submit(|cgh| {
                        cgh.host_task("rng", CommandClass::Generate, rng_cost, |_| {});
                    });
                    let _ = queue.submit(|cgh| {
                        cgh.depends_on(&ev1);
                        cgh.host_task("hits", CommandClass::Other, hit_cost, |_| {});
                    });
                }
            }

            // Real hit computation (capped): same math as the L2 graph.
            let real_hits = (n_hits as usize).min(real_hits_left);
            real_hits_left -= real_hits;
            if real_hits > 0 {
                let scale = n_hits as f32 / real_hits as f32;
                let e_per_hit = p.energy_gev / n_hits as f32;
                let layers = self.geometry.layers_at(p.eta);
                for _ in 0..real_hits {
                    let u_e = u32_to_uniform_f32(self.rng.next_u32());
                    let u_eta = u32_to_uniform_f32(self.rng.next_u32());
                    let u_phi = u32_to_uniform_f32(self.rng.next_u32());
                    let e = e_per_hit * -(1.0 - u_e).ln();
                    let eta = p.eta + table.sigma_eta * (2.0 * u_eta - 1.0);
                    let phi = p.phi + table.sigma_phi * (2.0 * u_phi - 1.0);
                    // Deposit split over covered layers by the table
                    // weights (renormalised to the covered subset).
                    let wsum: f32 = layers.iter().map(|&l| table.layer_weights[l]).sum();
                    for &l in &layers {
                        let frac = table.layer_weights[l] / wsum.max(1e-6);
                        let idx = self.geometry.cell_index(l, eta, phi);
                        self.deposits[idx] += scale * e * frac;
                        e_dep += (scale * e * frac) as f64;
                    }
                }
            }
        }

        // Per-event RN floor (~one per cell).
        let event_rns = (3 * event_hits).max(MIN_RNS_PER_EVENT);
        if 3 * event_hits < MIN_RNS_PER_EVENT {
            let extra = MIN_RNS_PER_EVENT - 3 * event_hits;
            let cost = CommandCost::Kernel {
                bytes_read: 0,
                bytes_written: extra * 4,
                items: extra,
                tpb: 0,
            };
            match self.cfg.api {
                FcsApi::Native => native.kernel_async("rng:floor", CommandClass::Generate, cost),
                FcsApi::Sycl => {
                    queue.submit(|cgh| {
                        cgh.host_task("rng:floor", CommandClass::Generate, cost, |_| {});
                    });
                }
            }
        }

        // Result readback (deposited-cell list, ~a few hundred KB).
        if is_gpu {
            let bytes = (self.geometry.n_cells() as u64) * 4;
            match self.cfg.api {
                FcsApi::Native => {
                    native.sync();
                    native.transfer(bytes, TransferDir::D2H)
                }
                FcsApi::Sycl => {
                    queue.submit(|cgh| {
                        cgh.host_task(
                            "result:d2h",
                            CommandClass::TransferD2H,
                            CommandCost::Transfer { bytes, dir: TransferDir::D2H },
                            |_| {},
                        );
                    });
                }
            }
        }
        Ok((event_hits, event_rns, e_in, e_dep))
    }

    /// Accumulated deposits (real-computed subset).
    pub fn deposits(&self) -> &[f32] {
        &self.deposits
    }
}

/// Convenience driver: simulate `workload` on (platform, api).
pub fn run_fastcalosim(
    platform: PlatformId,
    api: FcsApi,
    workload: Workload,
    seed: u64,
) -> Result<FcsReport> {
    let events = workload.events(seed);
    let mut sim = Simulator::new(FcsConfig::new(platform, api));
    let mut report = sim.simulate(&events)?;
    report.workload = workload.label();
    Ok(report)
}

/// The RNG engine FastCaloSim requests from the portable API.
pub const FCS_ENGINE: crate::rng::EngineKind =
    crate::rng::EngineKind::Philox4x32x10;

#[cfg(test)]
mod tests {
    use super::*;

    fn small(workload: Workload) -> FcsReport {
        run_fastcalosim(PlatformId::A100, FcsApi::Sycl, workload, 42).unwrap()
    }

    #[test]
    fn single_electron_hits_in_window() {
        let r = small(Workload::SingleElectron { events: 20 });
        let hits_per_event = r.hits as f64 / r.events as f64;
        assert!(
            (4000.0..6500.0).contains(&hits_per_event),
            "hits/event = {hits_per_event}"
        );
        // 12000-19500 RNs/event before the 200k floor -> floor applies.
        assert!(r.rns >= r.events as u64 * 200_000);
    }

    #[test]
    fn energy_approximately_conserved() {
        let r = small(Workload::SingleElectron { events: 5 });
        // Real compute covers all single-e hits (< cap): deposits ~ input.
        let ratio = r.energy_dep / r.energy_in;
        assert!((0.9..1.1).contains(&ratio), "dep/in = {ratio}");
    }

    #[test]
    fn ttbar_loads_many_tables_and_is_slower() {
        let se = small(Workload::SingleElectron { events: 5 });
        let tt = small(Workload::TTbar { events: 5 });
        assert_eq!(se.tables_loaded, 1);
        assert!((15..=40).contains(&tt.tables_loaded), "tables={}", tt.tables_loaded);
        assert!(tt.mean_event_ms() > 10.0 * se.mean_event_ms());
    }

    #[test]
    fn gpu_beats_cpu_on_single_electrons() {
        // The paper's ~80% reduction on GPUs vs CPUs (Fig. 5a).
        let gpu = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let cpu = run_fastcalosim(
            PlatformId::CoreI7_10875H,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let reduction = 1.0 - gpu.mean_event_ms() / cpu.mean_event_ms();
        assert!(reduction > 0.5, "reduction = {reduction}");
    }

    #[test]
    fn sycl_close_to_native() {
        let nat = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Native,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let syc = run_fastcalosim(
            PlatformId::A100,
            FcsApi::Sycl,
            Workload::SingleElectron { events: 10 },
            1,
        )
        .unwrap();
        let eff = crate::metrics::vavs_efficiency(nat.mean_event_ms(), syc.mean_event_ms());
        assert!((0.7..1.4).contains(&eff), "VAVS eff = {eff}");
    }
}
