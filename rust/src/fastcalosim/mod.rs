//! FastCaloSim substrate (DESIGN.md S8): the paper's real-world benchmark.
//!
//! A parameterized calorimeter simulation in the style of the ATLAS
//! FastCaloSim ports ([17], [21]): synthetic detector geometry (~190k
//! sensitive cells over 17 sampling layers), synthetic energy/shower-shape
//! parameterization tables loaded on demand, and an event loop whose hit
//! sampling consumes three uniforms per hit through the portable RNG API —
//! the integration point the paper §5.2 describes.
//!
//! The ATLAS inputs (real geometry, O(1) GB parameterizations, MC samples)
//! are not public; DESIGN.md §1 documents how the synthetic substitutes
//! preserve the computational characteristics the paper's measurements
//! depend on.

mod event;
mod geometry;
mod param;
mod simulation;

pub use event::{single_electron_events, ttbar_events, Event, Particle};
pub use geometry::{Geometry, LayerSpec, LAYERS};
pub use param::{ParamStore, ParamTable, TableId};
pub use simulation::{run_fastcalosim, FcsApi, FcsConfig, FcsReport, Simulator, Workload, FCS_ENGINE};
