//! FastCaloSim substrate (DESIGN.md S8, S17): the paper's real-world
//! benchmark, servable through the pooled SYCL stack.
//!
//! A parameterized calorimeter simulation in the style of the ATLAS
//! FastCaloSim ports ([17], [21]): synthetic detector geometry (~190k
//! sensitive cells over 17 sampling layers), synthetic energy/shower-shape
//! parameterization tables loaded on demand, and an event loop whose hit
//! sampling consumes three uniforms per hit through the portable RNG API —
//! the integration point the paper §5.2 describes.
//!
//! Where those uniforms come from is pluggable ([`RngSource`], DESIGN.md
//! S17): the standalone [`HostSource`] engine, or a [`PooledSource`] that
//! batches every per-event draw into
//! [`ServicePool`](crate::coordinator::ServicePool) submissions —
//! bit-identical to standalone for any shard count × tile size × chaos
//! plan, because the pool assigns O(1) skip-ahead stream offsets in
//! submission order. The SYCL event loop records its rng/hits/d2h
//! commands with real [`Access`](crate::sycl::Access) sets, so the S14
//! hazard analyzer proves each event's DAG race-free (`portarng
//! lint-dag`'s `fastcalosim` workload).
//!
//! The ATLAS inputs (real geometry, O(1) GB parameterizations, MC samples)
//! are not public; DESIGN.md §1 documents how the synthetic substitutes
//! preserve the computational characteristics the paper's measurements
//! depend on.

mod event;
mod geometry;
mod param;
mod simulation;
mod source;

pub use event::{single_electron_events, ttbar_events, Event, Particle};
pub use geometry::{Geometry, LayerSpec, LAYERS};
pub use param::{ParamStore, ParamTable, TableId};
pub use simulation::{
    run_fastcalosim, run_fastcalosim_pooled, run_fastcalosim_pooled_opts, FcsApi, FcsConfig,
    FcsEventSplit, FcsPoolRun, FcsReport, Simulator, Workload, FCS_ENGINE,
};
pub use source::{Draw, HostSource, PooledSource, RngSource};
