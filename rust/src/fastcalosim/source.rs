//! Pluggable RNG sources for the FastCaloSim event loop (DESIGN.md S17).
//!
//! The simulator consumes one logical canonical-uniform stream. Where that
//! stream is produced is a deployment decision, not a physics one:
//!
//! * [`HostSource`] — the standalone path: a private [`PhiloxEngine`]
//!   filling each requested block inline on the simulation thread (what
//!   the paper's §5.2 FastCaloSim port does per kernel launch).
//! * [`PooledSource`] — the serving path: every block becomes a
//!   [`ServicePool::generate`] request at range `(0.0, 1.0)` (an exact
//!   identity transform), so generation runs on the pool's shard workers
//!   — through their SYCL queues, USM arenas and (when configured) the
//!   tile executor — and overlaps the host-side hit deposition.
//!
//! **Bit-identity invariant.** The pool assigns global stream offsets
//! from an atomic cursor at `generate()` call time, and [`RngSource::
//! request`] submits blocks in stream-consumption order from a single
//! thread — so block *i*'s offset is exactly the cumulative size of the
//! blocks before it, i.e. the position a dedicated host engine would
//! have reached. Philox is counter-based with O(1) absolute seek, each
//! worker regenerates from the recorded offset, and the `(0.0, 1.0)`
//! range transform is an exact no-op — hence pooled replies are
//! bit-identical to [`HostSource`] for any shard count × tile size ×
//! team width × chaos plan (pinned by the tests below and the FCS
//! determinism properties in `tests/fastcalosim_integration.rs`).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{PoolConfig, PoolStats, ServicePool};
use crate::error::{Error, Result};
use crate::rng::engines::PhiloxEngine;
use crate::rng::Engine;
use crate::telemetry::TelemetryRegistry;
use crate::trace::Tracer;

/// One requested block of canonical uniforms, possibly still in flight.
///
/// Deferring resolution is what buys the pooled path its overlap: the
/// event loop requests every block of an event up front, then resolves
/// each one right before deposition — shard workers generate the later
/// blocks while the host deposits the earlier ones.
pub enum Draw {
    /// Generated eagerly, inline (host engine / empty block).
    Ready(Vec<f32>),
    /// In flight through a [`ServicePool`]; resolved on [`Draw::take`].
    Pending(mpsc::Receiver<Result<Vec<f32>>>),
}

impl Draw {
    /// Resolve the block (blocking for pending pool replies). Pool-side
    /// failures (shed, deadline, terminal injected fault) surface here
    /// as typed errors; a worker that died without answering — which the
    /// supervisor should make impossible — is a timeout, not a hang.
    pub fn take(self) -> Result<Vec<f32>> {
        match self {
            Draw::Ready(v) => Ok(v),
            Draw::Pending(rx) => rx
                .recv_timeout(Duration::from_secs(60))
                .map_err(|_| Error::Coordinator("pool worker dropped FCS draw reply".into()))?,
        }
    }
}

/// Where the simulator's canonical-uniform stream comes from.
///
/// Implementations must hand out one gapless logical stream: the
/// concatenation of all returned blocks, across calls, is the stream a
/// single dedicated engine would produce (zero-size blocks consume
/// nothing). The simulator relies on this for standalone/pooled
/// bit-identity.
pub trait RngSource {
    /// Identifying label for reports (`"host"` / `"pooled"`).
    fn label(&self) -> &'static str;

    /// Request the next `sizes` consecutive blocks of the stream, in
    /// consumption order. Returns one [`Draw`] per entry.
    fn request(&mut self, sizes: &[usize]) -> Vec<Draw>;

    /// Tear down any backing service (idempotent). The pooled source
    /// shuts its pool down and reports final per-shard stats; the host
    /// engine has nothing to tear down.
    fn finish(&mut self) -> Result<Option<PoolStats>> {
        Ok(None)
    }
}

/// The standalone source: a private host-side Philox engine, filled
/// inline — byte-for-byte the stream the pre-S17 simulator drew.
pub struct HostSource {
    engine: PhiloxEngine,
}

impl HostSource {
    /// Engine at stream position 0 for `seed`.
    pub fn new(seed: u64) -> HostSource {
        HostSource { engine: PhiloxEngine::new(seed) }
    }
}

impl RngSource for HostSource {
    fn label(&self) -> &'static str {
        "host"
    }

    fn request(&mut self, sizes: &[usize]) -> Vec<Draw> {
        sizes
            .iter()
            .map(|&n| {
                let mut block = vec![0f32; n];
                self.engine.fill_uniform_f32(&mut block);
                Draw::Ready(block)
            })
            .collect()
    }
}

/// The serving source: blocks are pooled `generate` requests, flushed
/// once per [`RngSource::request`] call.
///
/// The source must be its pool's only client — a concurrent requester
/// would interleave cursor reservations and shift the stream.
pub struct PooledSource {
    pool: Option<ServicePool>,
    registry: Arc<TelemetryRegistry>,
    tracer: Option<Arc<Tracer>>,
}

impl PooledSource {
    /// Spawn the backing pool.
    pub fn spawn(cfg: PoolConfig) -> PooledSource {
        let pool = ServicePool::spawn(cfg);
        let registry = pool.telemetry().clone();
        let tracer = pool.tracer();
        PooledSource { pool: Some(pool), registry, tracer }
    }

    /// The pool's telemetry registry (stays readable after `finish`; the
    /// pooled FCS driver folds the per-event `fcs` block into it).
    pub fn registry(&self) -> Arc<TelemetryRegistry> {
        self.registry.clone()
    }

    /// The pool's request tracer, when the config enabled tracing (stays
    /// snapshottable after `finish` — the driver exports spans from it).
    pub fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.clone()
    }
}

impl RngSource for PooledSource {
    fn label(&self) -> &'static str {
        "pooled"
    }

    fn request(&mut self, sizes: &[usize]) -> Vec<Draw> {
        let pool = self.pool.as_ref().expect("PooledSource used after finish()");
        // Submit every block before flushing: offsets are reserved in
        // stream order, then all shards launch at once.
        let draws: Vec<Draw> = sizes
            .iter()
            .map(|&n| {
                if n == 0 {
                    Draw::Ready(Vec::new())
                } else {
                    Draw::Pending(pool.generate(n, (0.0, 1.0)))
                }
            })
            .collect();
        pool.flush();
        draws
    }

    fn finish(&mut self) -> Result<Option<PoolStats>> {
        match self.pool.take() {
            Some(pool) => Ok(Some(pool.shutdown()?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    /// Mixed block sizes, incl. zero-size (a particle past the real-hit
    /// cap) and a floor-chunk-sized block, split across two request
    /// calls (two events).
    const SIZES_A: [usize; 4] = [3 * 4971, 0, 65_536, 17];
    const SIZES_B: [usize; 3] = [1, 3 * 333, 40_000];

    fn drain(source: &mut dyn RngSource) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> =
            source.request(&SIZES_A).into_iter().map(|d| d.take().unwrap()).collect();
        out.extend(source.request(&SIZES_B).into_iter().map(|d| d.take().unwrap()));
        out
    }

    #[test]
    fn host_source_is_the_dedicated_engine_stream() {
        let mut host = HostSource::new(0xFC5);
        let blocks = drain(&mut host);
        let total: usize = SIZES_A.iter().chain(&SIZES_B).sum();
        let mut engine = PhiloxEngine::new(0xFC5);
        let mut want = vec![0f32; total];
        engine.fill_uniform_f32(&mut want);
        let got: Vec<f32> = blocks.into_iter().flatten().collect();
        assert_eq!(got.len(), total);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "host stream diverged at {i}");
        }
    }

    #[test]
    fn pooled_source_bit_identical_to_host_for_any_shape() {
        let mut host = HostSource::new(0xFC5);
        let want = drain(&mut host);
        for shards in [1usize, 3] {
            for tiling in [None, Some((256, 2))] {
                let mut cfg = PoolConfig::new(PlatformId::A100, 0xFC5, shards);
                cfg.tiling = tiling;
                let mut pooled = PooledSource::spawn(cfg);
                let got = drain(&mut pooled);
                let stats = pooled.finish().unwrap().expect("pooled source owns a pool");
                assert_eq!(stats.shards.len(), shards);
                assert!(pooled.finish().unwrap().is_none(), "finish is idempotent");
                assert_eq!(got.len(), want.len());
                for (b, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.len(), w.len(), "block {b} length (shards={shards})");
                    for (i, (x, y)) in g.iter().zip(w).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "pooled stream diverged at block {b} element {i} \
                             (shards={shards}, tiling={tiling:?})"
                        );
                    }
                }
            }
        }
    }
}
