//! Synthetic calorimeter geometry: ~190k sensitive elements, 17 layers
//! ("The detector geometry includes nearly 190,000 sensitive elements,
//! O(10) MB" — paper §5.2). About 20 MB is uploaded to the GPU at startup.

/// One sampling layer's readout granularity.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Layer name (ATLAS-style).
    pub name: &'static str,
    /// Cells in eta.
    pub neta: usize,
    /// Cells in phi.
    pub nphi: usize,
    /// Covered |eta| range.
    pub eta_min: f32,
    /// Covered |eta| range.
    pub eta_max: f32,
}

impl LayerSpec {
    /// Cells in this layer.
    pub fn cells(&self) -> usize {
        self.neta * self.nphi
    }
}

/// The synthetic layer stack (granularities chosen so the total is ~190k,
/// finest in the EM strips as in ATLAS).
pub const LAYERS: [LayerSpec; 17] = [
    LayerSpec { name: "PreSamplerB", neta: 64, nphi: 64, eta_min: -1.5, eta_max: 1.5 },
    LayerSpec { name: "EMB1", neta: 256, nphi: 64, eta_min: -1.5, eta_max: 1.5 },
    LayerSpec { name: "EMB2", neta: 64, nphi: 256, eta_min: -1.5, eta_max: 1.5 },
    LayerSpec { name: "EMB3", neta: 32, nphi: 256, eta_min: -1.5, eta_max: 1.5 },
    LayerSpec { name: "PreSamplerE", neta: 128, nphi: 128, eta_min: -2.5, eta_max: 2.5 },
    LayerSpec { name: "EME1", neta: 64, nphi: 128, eta_min: -2.5, eta_max: 2.5 },
    LayerSpec { name: "EME2", neta: 128, nphi: 64, eta_min: -2.5, eta_max: 2.5 },
    LayerSpec { name: "EME3", neta: 96, nphi: 128, eta_min: -2.5, eta_max: 2.5 },
    LayerSpec { name: "HEC0", neta: 128, nphi: 96, eta_min: -3.1, eta_max: 3.1 },
    LayerSpec { name: "HEC1", neta: 160, nphi: 128, eta_min: -3.1, eta_max: 3.1 },
    LayerSpec { name: "HEC2", neta: 128, nphi: 160, eta_min: -3.1, eta_max: 3.1 },
    LayerSpec { name: "HEC3", neta: 112, nphi: 128, eta_min: -3.1, eta_max: 3.1 },
    LayerSpec { name: "TileBar0", neta: 128, nphi: 112, eta_min: -1.0, eta_max: 1.0 },
    LayerSpec { name: "TileBar1", neta: 64, nphi: 96, eta_min: -1.0, eta_max: 1.0 },
    LayerSpec { name: "TileBar2", neta: 96, nphi: 64, eta_min: -1.0, eta_max: 1.0 },
    LayerSpec { name: "FCal1", neta: 48, nphi: 64, eta_min: -4.9, eta_max: 4.9 },
    LayerSpec { name: "FCal2", neta: 41, nphi: 64, eta_min: -4.9, eta_max: 4.9 },
];

/// The full detector: layers + flattened cell indexing.
#[derive(Debug, Clone)]
pub struct Geometry {
    /// Per-layer first-cell offsets into the flattened cell array.
    offsets: Vec<usize>,
    total: usize,
}

impl Geometry {
    /// Build the synthetic geometry.
    pub fn build() -> Geometry {
        let mut offsets = Vec::with_capacity(LAYERS.len());
        let mut total = 0;
        for l in &LAYERS {
            offsets.push(total);
            total += l.cells();
        }
        Geometry { offsets, total }
    }

    /// Total sensitive elements (~190k).
    pub fn n_cells(&self) -> usize {
        self.total
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        LAYERS.len()
    }

    /// Approximate on-device size: ~110 B per cell descriptor (id, layer,
    /// eta/phi centres + widths, position) -> ~20 MB, the paper's figure.
    pub fn device_bytes(&self) -> u64 {
        (self.total as u64) * 110
    }

    /// Flattened cell index for (layer, eta, phi); clamps to layer bounds.
    pub fn cell_index(&self, layer: usize, eta: f32, phi: f32) -> usize {
        let l = &LAYERS[layer];
        let deta = (l.eta_max - l.eta_min) / l.neta as f32;
        let dphi = (2.0 * std::f32::consts::PI) / l.nphi as f32;
        let ieta = (((eta - l.eta_min) / deta) as isize).clamp(0, l.neta as isize - 1) as usize;
        let phi_w = phi.rem_euclid(2.0 * std::f32::consts::PI);
        let iphi = ((phi_w / dphi) as usize).min(l.nphi - 1);
        self.offsets[layer] + ieta * l.nphi + iphi
    }

    /// Layers whose eta range covers `eta`.
    pub fn layers_at(&self, eta: f32) -> Vec<usize> {
        LAYERS
            .iter()
            .enumerate()
            .filter(|(_, l)| eta >= l.eta_min && eta <= l.eta_max)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_is_about_190k() {
        let g = Geometry::build();
        assert!(
            (185_000..195_000).contains(&g.n_cells()),
            "cells={}",
            g.n_cells()
        );
        assert_eq!(g.n_layers(), 17);
    }

    #[test]
    fn device_footprint_about_20mb() {
        let g = Geometry::build();
        let mb = g.device_bytes() as f64 / 1e6;
        assert!((15.0..25.0).contains(&mb), "geometry {mb} MB");
    }

    #[test]
    fn cell_index_in_bounds_and_unique_per_layer() {
        let g = Geometry::build();
        for layer in 0..g.n_layers() {
            let a = g.cell_index(layer, 0.0, 0.1);
            let b = g.cell_index(layer, 0.0, 0.1);
            assert_eq!(a, b);
            assert!(a < g.n_cells());
        }
        // Different layers map to disjoint index ranges.
        let i0 = g.cell_index(0, 0.0, 0.0);
        let i1 = g.cell_index(1, 0.0, 0.0);
        assert_ne!(i0, i1);
    }

    #[test]
    fn out_of_range_eta_clamps() {
        let g = Geometry::build();
        let idx = g.cell_index(0, 99.0, 0.0);
        assert!(idx < g.n_cells());
    }

    #[test]
    fn central_eta_covered_by_barrel_and_more() {
        let g = Geometry::build();
        let layers = g.layers_at(0.3);
        assert!(layers.len() >= 10);
        let fwd = g.layers_at(4.0);
        assert!(fwd.len() <= 3); // only FCal reaches |eta| = 4
    }
}
