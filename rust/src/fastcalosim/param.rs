//! Parameterization store: energy and shower-shape tables.
//!
//! "Various parameterization inputs, O(1) GB, are used for different
//! particles' energy and shower shapes ... due to the large file size of
//! the parameterization inputs, only those data required — based on the
//! particle type and kinematics — are transferred during runtime" (§5.2).
//! Single-electron events need one table; t t̄ needs 20–30, which is where
//! the extra H2D traffic in Fig. 5(b) comes from.

use std::collections::HashSet;

/// Table key: (particle family, energy bin, |eta| bin).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TableId {
    /// PDG-family bucket (11 e±, 22 γ, 211 π±, 2112 hadronic other).
    pub pdg_family: i32,
    /// log2 energy bin.
    pub energy_bin: u8,
    /// |eta| bin (0.0–4.9 in 0.7 steps).
    pub eta_bin: u8,
}

impl TableId {
    /// Bin a particle into its table. Binning is coarse — 4 families x 3
    /// energy decades x 3 |eta| regions — so a t t̄ sample touches the
    /// paper's "20-30 separate parameterizations" (§5.2).
    pub fn for_particle(pdg: i32, energy_gev: f32, eta: f32) -> TableId {
        let pdg_family = match pdg.abs() {
            11 => 11,
            22 => 22,
            211 | 321 => 211,
            _ => 2112,
        };
        let energy_bin =
            (((energy_gev.max(0.5).log2() + 1.0) / 3.0) as i32).clamp(0, 2) as u8;
        let eta_bin = ((eta.abs() / 1.75) as u8).min(2);
        TableId { pdg_family, energy_bin, eta_bin }
    }

    fn hash64(&self) -> u64 {
        crate::platform::jitter("param-table", self.pdg_family as u64, self.energy_bin as u64, self.eta_bin as u64)
            .to_bits()
    }
}

/// One synthetic parameterization table.
#[derive(Debug, Clone)]
pub struct ParamTable {
    /// Key.
    pub id: TableId,
    /// Fraction of the particle's energy deposited per layer (sums to 1
    /// over the layers covering the particle's eta).
    pub layer_weights: Vec<f32>,
    /// Lateral shower width in eta.
    pub sigma_eta: f32,
    /// Lateral shower width in phi.
    pub sigma_phi: f32,
    /// Hits produced per GeV of particle energy (so a 65 GeV electron
    /// lands in the paper's 4000–6500 hits/event window).
    pub hits_per_gev: f32,
    /// Host->device payload when first used, bytes (tables are 30–80 MB).
    pub size_bytes: u64,
}

impl ParamTable {
    /// Deterministic synthesis from the table id.
    pub fn synthesize(id: TableId, n_layers: usize) -> ParamTable {
        let h = id.hash64();
        let mix = |k: u64| {
            let mut x = h ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            x ^= x >> 31;
            x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            x ^= x >> 29;
            (x >> 11) as f64 / (1u64 << 53) as f64 // [0, 1)
        };
        // EM particles deposit early, hadrons late: a deterministic profile
        // peaked at a family-dependent depth.
        let peak = match id.pdg_family {
            11 | 22 => 1.5 + mix(1) as f32,
            211 => 6.0 + 3.0 * mix(1) as f32,
            _ => 8.0 + 4.0 * mix(1) as f32,
        };
        let mut w: Vec<f32> = (0..n_layers)
            .map(|l| {
                let d = (l as f32 - peak) / 2.5;
                (-0.5 * d * d).exp().max(1e-4)
            })
            .collect();
        let sum: f32 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= sum);
        ParamTable {
            id,
            layer_weights: w,
            sigma_eta: 0.02 + 0.06 * mix(2) as f32,
            sigma_phi: 0.02 + 0.06 * mix(3) as f32,
            hits_per_gev: match id.pdg_family {
                11 | 22 => 70.0 + 20.0 * mix(4) as f32, // 65 GeV -> 4.5k-5.8k hits
                _ => 30.0 + 20.0 * mix(4) as f32,
            },
            size_bytes: 30_000_000 + (mix(5) * 50_000_000.0) as u64,
        }
    }
}

/// On-demand table loader with device residency tracking.
#[derive(Debug)]
pub struct ParamStore {
    n_layers: usize,
    loaded: HashSet<TableId>,
}

impl ParamStore {
    /// Empty store over a geometry with `n_layers` layers.
    pub fn new(n_layers: usize) -> ParamStore {
        ParamStore { n_layers, loaded: HashSet::new() }
    }

    /// Get a table, reporting the H2D bytes needed if it was not resident
    /// (0 when cached).
    pub fn fetch(&mut self, id: TableId) -> (ParamTable, u64) {
        let table = ParamTable::synthesize(id, self.n_layers);
        let bytes = if self.loaded.insert(id) { table.size_bytes } else { 0 };
        (table, bytes)
    }

    /// Number of distinct tables loaded so far.
    pub fn loaded_count(&self) -> usize {
        self.loaded.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_is_deterministic_and_normalised() {
        let id = TableId::for_particle(11, 65.0, 0.3);
        let a = ParamTable::synthesize(id, 17);
        let b = ParamTable::synthesize(id, 17);
        assert_eq!(a.layer_weights, b.layer_weights);
        let sum: f32 = a.layer_weights.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn electron_table_hits_in_paper_window() {
        // 65 GeV single electron -> 4000..6500 hits (paper §5.2).
        let id = TableId::for_particle(11, 65.0, 0.25);
        let t = ParamTable::synthesize(id, 17);
        let hits = 65.0 * t.hits_per_gev;
        assert!((4000.0..6500.0).contains(&hits), "hits={hits}");
    }

    #[test]
    fn em_vs_hadronic_depth_profiles_differ() {
        let e = ParamTable::synthesize(TableId::for_particle(11, 50.0, 0.1), 17);
        let h = ParamTable::synthesize(TableId::for_particle(2112, 50.0, 0.1), 17);
        let depth = |t: &ParamTable| -> f32 {
            t.layer_weights.iter().enumerate().map(|(i, w)| i as f32 * w).sum()
        };
        assert!(depth(&h) > depth(&e) + 2.0, "e={} h={}", depth(&e), depth(&h));
    }

    #[test]
    fn store_loads_once() {
        let mut s = ParamStore::new(17);
        let id = TableId::for_particle(211, 20.0, 1.0);
        let (_, b1) = s.fetch(id);
        let (_, b2) = s.fetch(id);
        assert!(b1 >= 30_000_000);
        assert_eq!(b2, 0);
        assert_eq!(s.loaded_count(), 1);
    }

    #[test]
    fn binning_buckets_particles() {
        assert_eq!(
            TableId::for_particle(11, 65.0, 0.2),
            TableId::for_particle(-11, 70.0, -0.3)
        );
        assert_ne!(
            TableId::for_particle(11, 65.0, 0.2),
            TableId::for_particle(211, 65.0, 0.2)
        );
    }
}
