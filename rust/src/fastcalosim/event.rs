//! Event generation: the two simulation scenarios of paper §5.2.

use crate::rng::engines::{Engine, PhiloxEngine};
use crate::rng::u32_to_uniform_f32;

/// A truth particle entering the calorimeter.
#[derive(Debug, Clone, Copy)]
pub struct Particle {
    /// PDG id (11 e-, 22 γ, 211 π+, 2112 n, ...).
    pub pdg: i32,
    /// Kinetic energy, GeV.
    pub energy_gev: f32,
    /// Pseudorapidity at the calorimeter face.
    pub eta: f32,
    /// Azimuth.
    pub phi: f32,
}

/// One physics event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Particles to simulate.
    pub particles: Vec<Particle>,
}

impl Event {
    /// Total incoming energy.
    pub fn total_energy(&self) -> f32 {
        self.particles.iter().map(|p| p.energy_gev).sum()
    }
}

fn u01(e: &mut PhiloxEngine) -> f32 {
    u32_to_uniform_f32(e.next_u32())
}

/// The first scenario: `n` single-electron events, 65 GeV each, confined
/// to a small angular region ("traverse a small angular region of the
/// calorimeters" — one parameterization suffices).
pub fn single_electron_events(n: usize, seed: u64) -> Vec<Event> {
    let mut rng = PhiloxEngine::new(seed ^ 0xE1EC);
    (0..n)
        .map(|_| Event {
            particles: vec![Particle {
                pdg: 11,
                energy_gev: 65.0,
                eta: 0.20 + 0.05 * u01(&mut rng),
                phi: 1.00 + 0.05 * u01(&mut rng),
            }],
        })
        .collect()
}

/// The second scenario: `n` t t̄ events — many particles of mixed species
/// and energies across the full detector, requiring 20-30 distinct
/// parameterizations.
pub fn ttbar_events(n: usize, seed: u64) -> Vec<Event> {
    let mut rng = PhiloxEngine::new(seed ^ 0x77BA);
    (0..n)
        .map(|_| {
            // 250-350 calorimeter-entering particles per t t̄ event.
            let n_part = 250 + (u01(&mut rng) * 100.0) as usize;
            let particles = (0..n_part)
                .map(|_| {
                    let species = u01(&mut rng);
                    let pdg = if species < 0.25 {
                        22
                    } else if species < 0.45 {
                        211
                    } else if species < 0.55 {
                        11
                    } else {
                        2112
                    };
                    // Energy spectrum ~ exp falling, 0.5-120 GeV.
                    let energy = 0.5 + 119.5 * u01(&mut rng).powi(3);
                    Particle {
                        pdg,
                        energy_gev: energy,
                        eta: -4.5 + 9.0 * u01(&mut rng),
                        phi: 2.0 * std::f32::consts::PI * u01(&mut rng),
                    }
                })
                .collect();
            Event { particles }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastcalosim::param::TableId;
    use std::collections::HashSet;

    #[test]
    fn single_electron_events_shape() {
        let evs = single_electron_events(100, 1);
        assert_eq!(evs.len(), 100);
        for ev in &evs {
            assert_eq!(ev.particles.len(), 1);
            let p = ev.particles[0];
            assert_eq!(p.pdg, 11);
            assert_eq!(p.energy_gev, 65.0);
            assert!((0.2..0.25).contains(&p.eta));
        }
        // All electrons share a single parameterization (paper: "only
        // requires a single energy and shower shape parameterization").
        let tables: HashSet<TableId> = evs
            .iter()
            .map(|e| TableId::for_particle(11, 65.0, e.particles[0].eta))
            .collect();
        assert_eq!(tables.len(), 1);
    }

    #[test]
    fn ttbar_needs_20_to_30_tables() {
        let evs = ttbar_events(50, 3);
        let tables: HashSet<TableId> = evs
            .iter()
            .flat_map(|e| e.particles.iter())
            .map(|p| TableId::for_particle(p.pdg, p.energy_gev, p.eta))
            .collect();
        // Species x energy x eta binning lands in the paper's 20-30 range
        // (we allow a little slack on the high side).
        assert!(
            (20..=40).contains(&tables.len()),
            "distinct tables = {}",
            tables.len()
        );
    }

    #[test]
    fn ttbar_is_much_busier_than_single_e() {
        let se = single_electron_events(10, 1);
        let tt = ttbar_events(10, 1);
        let se_parts: usize = se.iter().map(|e| e.particles.len()).sum();
        let tt_parts: usize = tt.iter().map(|e| e.particles.len()).sum();
        assert!(tt_parts > 100 * se_parts);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ttbar_events(3, 9);
        let b = ttbar_events(3, 9);
        assert_eq!(a[0].particles.len(), b[0].particles.len());
        assert_eq!(a[2].total_energy(), b[2].total_energy());
    }
}
