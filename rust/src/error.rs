//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the portarng library.
#[derive(Error, Debug)]
pub enum Error {
    /// A generate entry point was called with a (engine, distribution,
    /// method) combination the selected backend does not implement —
    /// mirroring the paper's "20 of the 36 generate functions are supported
    /// by our cuRAND backend as the remaining 16 use ICDF methods".
    #[error("backend `{backend}` does not support {what}")]
    Unsupported { backend: &'static str, what: String },

    /// Invalid argument (sizes, ranges, seeds).
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// A SYCL-runtime usage error (double accessor conflict, queue misuse,
    /// use-after-destroy of a generator...).
    #[error("sycl runtime error: {0}")]
    Sycl(String),

    /// Artifact registry / manifest problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Underlying XLA/PJRT failure.
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    /// JSON parsing failure (manifest.json).
    #[error("json error: {0}")]
    Json(String),

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Coordinator/service errors (channel closed, worker panicked).
    #[error("coordinator error: {0}")]
    Coordinator(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for unsupported-feature errors.
    pub fn unsupported(backend: &'static str, what: impl Into<String>) -> Self {
        Error::Unsupported { backend, what: what.into() }
    }
}
