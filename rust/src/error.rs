//! Crate-wide error type (hand-rolled — `thiserror` is unavailable
//! offline, like the other external-crate roles listed in `lib.rs`).

use std::fmt;

use crate::xla;

/// Errors surfaced by the portarng library.
#[derive(Debug)]
pub enum Error {
    /// A generate entry point was called with a (engine, distribution,
    /// method) combination the selected backend does not implement —
    /// mirroring the paper's "20 of the 36 generate functions are supported
    /// by our cuRAND backend as the remaining 16 use ICDF methods".
    Unsupported {
        /// Backend that rejected the request.
        backend: &'static str,
        /// Human-readable description of what was requested.
        what: String,
    },

    /// Invalid argument (sizes, ranges, seeds).
    InvalidArgument(String),

    /// A SYCL-runtime usage error (double accessor conflict, queue misuse,
    /// use-after-destroy of a generator...).
    Sycl(String),

    /// Artifact registry / manifest problems.
    Artifact(String),

    /// Underlying XLA/PJRT failure.
    Xla(xla::Error),

    /// JSON parsing failure (manifest.json).
    Json(String),

    /// I/O failure.
    Io(std::io::Error),

    /// Coordinator/service errors (channel closed, worker panicked).
    Coordinator(String),

    /// Ingress gate shed the request: the pool already carries `in_flight`
    /// requests against a configured depth bound of `limit`.
    Overloaded {
        /// In-flight requests at the moment the request was shed.
        in_flight: usize,
        /// Configured bound (`IngressConfig::max_inflight`).
        limit: usize,
    },

    /// The request's deadline budget expired before a shard produced its
    /// payload (checked at worker dequeue and at supervisor redispatch).
    DeadlineExceeded,

    /// The owning shard died and the request could not be re-dispatched
    /// (pool shutting down, or the caller raced a terminal sweep).
    ShardLost,

    /// A fault deliberately injected by the active chaos plan
    /// ([`crate::fault`]). Transient by construction: the ingress retry
    /// policy may re-dispatch the request, and the counter-based stream
    /// addressing guarantees the retried payload is bit-identical.
    Injected {
        /// Injection-site token (`"generate"`, `"submit"`, `"d2h"`).
        site: &'static str,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unsupported { backend, what } => {
                write!(f, "backend `{backend}` does not support {what}")
            }
            Error::InvalidArgument(msg) => write!(f, "invalid argument: {msg}"),
            Error::Sycl(msg) => write!(f, "sycl runtime error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json(msg) => write!(f, "json error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::Overloaded { in_flight, limit } => {
                write!(f, "overloaded: {in_flight} requests in flight (limit {limit})")
            }
            Error::DeadlineExceeded => write!(f, "deadline exceeded"),
            Error::ShardLost => write!(f, "shard lost"),
            Error::Injected { site } => write!(f, "injected fault at {site}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for unsupported-feature errors.
    pub fn unsupported(backend: &'static str, what: impl Into<String>) -> Self {
        Error::Unsupported { backend, what: what.into() }
    }

    /// `true` for failures that a retry can plausibly clear without any
    /// operator action. Today that is exactly the injected chaos faults:
    /// real backend/queue failures are treated as persistent so a broken
    /// device cannot melt into a silent retry storm.
    pub fn is_transient(&self) -> bool {
        matches!(self, Error::Injected { .. })
    }

    /// Injection-site token when this error is an injected fault.
    pub fn injected_site(&self) -> Option<&'static str> {
        match self {
            Error::Injected { site } => Some(site),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_legacy_derive() {
        assert_eq!(
            Error::unsupported("cuRAND", "icdf").to_string(),
            "backend `cuRAND` does not support icdf"
        );
        assert_eq!(
            Error::InvalidArgument("n".into()).to_string(),
            "invalid argument: n"
        );
        assert!(Error::from(crate::xla::Error("x".into()))
            .to_string()
            .starts_with("xla error"));
    }

    #[test]
    fn resilience_variant_displays_are_stable() {
        assert_eq!(
            Error::Overloaded { in_flight: 9, limit: 8 }.to_string(),
            "overloaded: 9 requests in flight (limit 8)"
        );
        assert_eq!(Error::DeadlineExceeded.to_string(), "deadline exceeded");
        assert_eq!(Error::ShardLost.to_string(), "shard lost");
        assert_eq!(Error::Injected { site: "d2h" }.to_string(), "injected fault at d2h");
    }

    #[test]
    fn only_injected_faults_are_transient() {
        assert!(Error::Injected { site: "generate" }.is_transient());
        assert_eq!(Error::Injected { site: "generate" }.injected_site(), Some("generate"));
        for e in [
            Error::DeadlineExceeded,
            Error::ShardLost,
            Error::Overloaded { in_flight: 1, limit: 1 },
            Error::Coordinator("x".into()),
            Error::Sycl("x".into()),
        ] {
            assert!(!e.is_transient(), "{e} must not be retried");
            assert_eq!(e.injected_site(), None);
        }
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
