//! Crate-wide error type (hand-rolled — `thiserror` is unavailable
//! offline, like the other external-crate roles listed in `lib.rs`).

use std::fmt;

use crate::xla;

/// Errors surfaced by the portarng library.
#[derive(Debug)]
pub enum Error {
    /// A generate entry point was called with a (engine, distribution,
    /// method) combination the selected backend does not implement —
    /// mirroring the paper's "20 of the 36 generate functions are supported
    /// by our cuRAND backend as the remaining 16 use ICDF methods".
    Unsupported {
        /// Backend that rejected the request.
        backend: &'static str,
        /// Human-readable description of what was requested.
        what: String,
    },

    /// Invalid argument (sizes, ranges, seeds).
    InvalidArgument(String),

    /// A SYCL-runtime usage error (double accessor conflict, queue misuse,
    /// use-after-destroy of a generator...).
    Sycl(String),

    /// Artifact registry / manifest problems.
    Artifact(String),

    /// Underlying XLA/PJRT failure.
    Xla(xla::Error),

    /// JSON parsing failure (manifest.json).
    Json(String),

    /// I/O failure.
    Io(std::io::Error),

    /// Coordinator/service errors (channel closed, worker panicked).
    Coordinator(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unsupported { backend, what } => {
                write!(f, "backend `{backend}` does not support {what}")
            }
            Error::InvalidArgument(msg) => write!(f, "invalid argument: {msg}"),
            Error::Sycl(msg) => write!(f, "sycl runtime error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json(msg) => write!(f, "json error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for unsupported-feature errors.
    pub fn unsupported(backend: &'static str, what: impl Into<String>) -> Self {
        Error::Unsupported { backend, what: what.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_legacy_derive() {
        assert_eq!(
            Error::unsupported("cuRAND", "icdf").to_string(),
            "backend `cuRAND` does not support icdf"
        );
        assert_eq!(
            Error::InvalidArgument("n".into()).to_string(),
            "invalid argument: n"
        );
        assert!(Error::from(crate::xla::Error("x".into()))
            .to_string()
            .starts_with("xla error"));
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
