//! # portarng — cross-platform performance-portable RNG through interoperability
//!
//! Reproduction of Pascuzzi & Goli, *"Achieving near native runtime
//! performance and cross-platform performance portability for random number
//! generation through SYCL interoperability"* (2021), rebuilt on a
//! Rust + JAX + Pallas three-layer stack (see `DESIGN.md` at the
//! repository root for the layer map, substitution table and subsystem
//! sections referenced throughout these docs).
//!
//! The crate is organised exactly along the paper's stack:
//!
//! * [`sycl`] — a faithful mini SYCL-runtime substrate: queues, command
//!   groups, buffer accessors with an automatically derived dependency DAG,
//!   USM allocations with explicit event dependencies, and host-task
//!   interoperability handles (the paper's `codeplay_host_task`).
//! * [`rng`] — the oneMKL-like front-end: engines (Philox4x32x10, MRG32k3a,
//!   XORWOW, MT19937, Sobol32), distributions, the generate API and the
//!   range-transformation kernel the native libraries lack.
//! * [`backends`] — "vendor" backends: cuRAND- and hipRAND-shaped native
//!   simulators, oneMKL CPU/iGPU natives, and the real-compute PJRT backend
//!   executing the AOT-compiled Pallas Philox kernel.
//! * [`platform`] — platform descriptors and calibrated performance models
//!   (virtual clock) for the paper's six test machines.
//! * [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt` (gated
//!   through the in-tree [`xla`] binding substrate when the real
//!   xla_extension bindings are not linked).
//! * [`fastcalosim`] — the real-world benchmark substrate: ATLAS-like
//!   calorimeter geometry, parameterization store, event generation and hit
//!   simulation, drawing its uniforms through a pluggable
//!   [`fastcalosim::RngSource`] — the standalone host engine, or a
//!   [`fastcalosim::PooledSource`] that serves every draw through the
//!   sharded service pool, bit-identically (DESIGN.md S17).
//! * [`burner`] — the paper's §5.1 RNG-burner benchmark application, plus
//!   the pooled variant that drives it through the service pool.
//! * [`metrics`] — VAVS efficiency and the Pennycook performance-portability
//!   metric (paper eq. 1).
//! * [`coordinator`] — backend registry/dispatch, request batcher, the
//!   §8 "heuristic backend selection" extension, and the sharded RNG
//!   service pool (below).
//! * [`telemetry`] — lock-free metrics registry: atomic counters plus
//!   log₂-bucketed latency/occupancy histograms per shard / lane /
//!   backend, with cheap `jsonlite` snapshots (DESIGN.md S11).
//! * [`autotune`] — the adaptive half of the §8 heuristic: startup
//!   calibration probes, persisted calibration profiles, and the online
//!   controller that retunes the pool from telemetry (DESIGN.md S12,
//!   below).
//! * [`trace`] — end-to-end request tracing and crash flight recorder
//!   (DESIGN.md S18): lock-free per-shard span rings stitched by
//!   request/flush id, a Chrome trace-event exporter (`--trace`), and
//!   supervisor-driven flight dumps when a shard worker dies.
//! * [`fault`] — deterministic, seeded fault injection (the chaos half of
//!   the resilience layer, DESIGN.md S15): op-count-scheduled faults at
//!   the four serving seams, armed via `serve --chaos` /
//!   `PORTARNG_FAULT_PLAN` and inert (one thread-local null check) when
//!   unconfigured.
//! * [`repro`] — drivers that regenerate every table and figure.
//! * [`benchkit`] / [`testkit`] / [`jsonlite`] / [`xla`] — in-tree
//!   substrates for the criterion / proptest / serde_json / xla_extension
//!   roles (unavailable offline).
//!
//! ## The sharded service pool
//!
//! The §8 extension point — backend coordination under sustained,
//! concurrent load — is served by [`coordinator::ServicePool`]:
//!
//! ```text
//!                       ServicePool::generate(n, range)
//!                                   |
//!                 global stream cursor (AtomicU64): offset = cursor += n
//!                                   |
//!              DispatchPolicy (coordinator::heuristic): n >= threshold?
//!                    |                                     |
//!              round-robin                             overflow lane
//!             /     |     \                                 |
//!        shard 0  shard 1  ...  shard N-1              shard N (unbatched)
//!        [worker thread: own backend set (BackendRegistry::shard_set) —
//!         batched lanes generate on the host backend, the overflow lane
//!         on the device-native backend (§8: host for small, GPU for
//!         large); own RequestBatcher; each batch member is generated at
//!         its *global* stream offset via counter-based skip-ahead]
//! ```
//!
//! The pool-wide invariant (pinned by the `testkit` property tests in
//! `tests/coordinator.rs`): **every requester observes exactly the
//! sub-stream a dedicated engine at its assigned global offset would
//! produce** — bit-identical for any shard count, any batching thresholds
//! and any interleaving, because Philox is counter-based and
//! `Engine::skip_ahead` / `VendorGenerator::set_offset` are O(1). Requests
//! at or above the dispatch policy's size threshold take the overflow lane
//! (a dedicated unbatched shard), modelling the paper's "host for small
//! workloads, GPU for larger ones" heuristic at the service layer.
//! [`coordinator::RngService`] remains as the single-shard facade over the
//! same machinery.
//!
//! The same invariant is what makes the pool *supervisable* (DESIGN.md
//! S15): every accepted request is recorded in an in-flight ledger with
//! its global offset, a supervisor thread respawns dead shard workers and
//! re-dispatches their ledger entries, and because a stream is addressed
//! by offset — not by generator state — the redelivered payload is
//! provably bit-identical to the fault-free answer. An ingress gate adds
//! bounded depth ([`Error::Overloaded`]), deadline budgets
//! ([`Error::DeadlineExceeded`]) and bounded-backoff retry of transient
//! faults; `benches/chaos_soak.rs` gates the whole layer under an
//! injected 5% fault rate.
//!
//! ## The telemetry → autotune loop
//!
//! The dispatch threshold is measured, not guessed. Every shard records
//! into a shared lock-free [`telemetry::TelemetryRegistry`] (relaxed
//! atomics + log₂ histograms — nothing on the request path locks or
//! allocates), and the [`autotune`] controller closes the loop:
//!
//! ```text
//!   calibrate (startup probe bursts        ProfileStore (JSON, keyed by
//!   over the virtual clock)  ────────────▶ platform; warm starts skip
//!        │                                 probing)
//!        ▼                                      │ load
//!   TuningHandle (lock-free knobs) ◀────────────┘
//!        ▲            │ relaxed loads
//!        │ retune     ▼
//!   PoolAutoTuner   ServicePool dispatcher + shard batchers
//!        ▲            │ relaxed stores
//!        │ window     ▼
//!        └── TelemetrySnapshot deltas (delivered-throughput objective)
//! ```
//!
//! Retunes preserve the stream invariant by construction: global offsets
//! are assigned *before* routing, so any interleaving of retunes and
//! requests yields bit-identical per-request streams. The
//! `autotune_convergence` bench gates the loop (≥ 90% of the best fixed
//! threshold from a mis-specified start); `portarng serve --autotune`,
//! `portarng calibrate` and `portarng burner --stats-json` expose it on
//! the CLI.

pub mod autotune;
pub mod backends;
pub mod benchkit;
pub mod burner;
pub mod coordinator;
pub mod error;
pub mod fastcalosim;
pub mod fault;
pub mod jsonlite;
pub mod metrics;
pub mod platform;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod sycl;
pub mod telemetry;
pub mod testkit;
pub mod trace;
pub mod xla;

pub use error::{Error, Result};
