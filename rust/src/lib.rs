//! # portarng — cross-platform performance-portable RNG through interoperability
//!
//! Reproduction of Pascuzzi & Goli, *"Achieving near native runtime
//! performance and cross-platform performance portability for random number
//! generation through SYCL interoperability"* (2021), rebuilt on a
//! Rust + JAX + Pallas three-layer stack (see `DESIGN.md`).
//!
//! The crate is organised exactly along the paper's stack:
//!
//! * [`sycl`] — a faithful mini SYCL-runtime substrate: queues, command
//!   groups, buffer accessors with an automatically derived dependency DAG,
//!   USM allocations with explicit event dependencies, and host-task
//!   interoperability handles (the paper's `codeplay_host_task`).
//! * [`rng`] — the oneMKL-like front-end: engines (Philox4x32x10, MRG32k3a,
//!   XORWOW, MT19937, Sobol32), distributions, the generate API and the
//!   range-transformation kernel the native libraries lack.
//! * [`backends`] — "vendor" backends: cuRAND- and hipRAND-shaped native
//!   simulators, oneMKL CPU/iGPU natives, and the real-compute PJRT backend
//!   executing the AOT-compiled Pallas Philox kernel.
//! * [`platform`] — platform descriptors and calibrated performance models
//!   (virtual clock) for the paper's six test machines.
//! * [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt`.
//! * [`fastcalosim`] — the real-world benchmark substrate: ATLAS-like
//!   calorimeter geometry, parameterization store, event generation and hit
//!   simulation.
//! * [`burner`] — the paper's §5.1 RNG-burner benchmark application.
//! * [`metrics`] — VAVS efficiency and the Pennycook performance-portability
//!   metric (paper eq. 1).
//! * [`coordinator`] — backend registry/dispatch, request batcher, and the
//!   §8 "heuristic backend selection" extension.
//! * [`repro`] — drivers that regenerate every table and figure.
//! * [`benchkit`] / [`testkit`] / [`jsonlite`] — in-tree substrates for the
//!   criterion / proptest / serde_json roles (unavailable offline).

pub mod backends;
pub mod benchkit;
pub mod burner;
pub mod coordinator;
pub mod error;
pub mod fastcalosim;
pub mod jsonlite;
pub mod metrics;
pub mod platform;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod sycl;
pub mod testkit;

pub use error::{Error, Result};
