//! Deterministic measurement noise.
//!
//! The paper runs 100 iterations per batch size "for statistically
//! meaningful measurements". Our virtual clock is deterministic, so we
//! superimpose reproducible pseudo-noise — hash-seeded, ±1.5%
//! multiplicative — so iteration statistics (mean/CI) behave like real
//! measurements while staying bit-reproducible across runs.

/// Multiplicative jitter factor in [1-amp, 1+amp] derived from the
/// (domain, a, b, c) tuple. Same inputs -> same factor, forever.
pub fn jitter(domain: &str, a: u64, b: u64, c: u64) -> f64 {
    jitter_amp(domain, a, b, c, 0.015)
}

/// Alias used by the SYCL queue: jitter keyed on (domain, salt, id, cost).
pub fn jitter_from(domain: &str, salt: u64, id: u64, cost: u64) -> f64 {
    jitter(domain, salt, id, cost)
}

/// Jitter with a caller-chosen amplitude.
pub fn jitter_amp(domain: &str, a: u64, b: u64, c: u64, amp: f64) -> f64 {
    fn mix(h: &mut u64, x: u64) {
        for byte in x.to_le_bytes() {
            *h ^= byte as u64;
            *h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in domain.bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    mix(&mut h, a);
    mix(&mut h, b);
    mix(&mut h, c);
    // Final avalanche.
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    let unit = (h >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
    1.0 + amp * (2.0 * unit - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(jitter("x", 1, 2, 3), jitter("x", 1, 2, 3));
    }

    #[test]
    fn bounded() {
        for i in 0..1000 {
            let j = jitter("bench", i, i * 7, 0);
            assert!((0.985..=1.015).contains(&j), "j={j}");
        }
    }

    #[test]
    fn varies_with_inputs() {
        let a = jitter("bench", 1, 0, 0);
        let b = jitter("bench", 2, 0, 0);
        let c = jitter("other", 1, 0, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn mean_is_centered() {
        let n = 10_000;
        let sum: f64 = (0..n).map(|i| jitter("m", i, 0, 0)).sum();
        assert!((sum / n as f64 - 1.0).abs() < 0.001);
    }
}
