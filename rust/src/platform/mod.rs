//! Platform descriptors and calibrated performance models.
//!
//! The paper's evaluation runs on six machines (Table 1). We cannot run on
//! that hardware, so each platform is modelled by a [`PlatformSpec`] whose
//! constants drive a **virtual clock**: every command executed through the
//! mini-SYCL runtime or a native backend advances virtual time by a cost
//! derived from the platform's latency/bandwidth/throughput figures. The
//! paper's figures are *shapes over batch size*; those shapes come from the
//! cost structure encoded here (see DESIGN.md §1 substitution table).

mod noise;
mod occupancy;
mod perf_model;
mod spec;

pub use noise::{jitter, jitter_amp, jitter_from};
pub use occupancy::{occupancy, OccupancyReport};
pub use perf_model::{CommandCost, PerfModel, TransferDir};
pub use spec::{PlatformId, PlatformKind, PlatformSpec};
