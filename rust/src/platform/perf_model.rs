//! Virtual-clock cost model: maps commands to nanoseconds on a platform.

use super::occupancy::occupancy;
use super::spec::{PlatformKind, PlatformSpec};

/// Host<->device transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferDir {
    /// Host to device.
    H2D,
    /// Device to host.
    D2H,
}

/// Cost description attached to every command executed through the runtime.
#[derive(Debug, Clone, Copy)]
pub enum CommandCost {
    /// A device kernel: bytes moved through device memory plus item count
    /// for the compute-throughput term. `tpb` is the thread-block size in
    /// effect (native apps hardcode it; the SYCL runtime chooses — Fig 4b).
    Kernel {
        /// Bytes read from device memory.
        bytes_read: u64,
        /// Bytes written to device memory.
        bytes_written: u64,
        /// Work items (numbers generated / transformed).
        items: u64,
        /// Thread-block size in effect.
        tpb: u32,
    },
    /// Host<->device copy.
    Transfer {
        /// Payload size.
        bytes: u64,
        /// Direction.
        dir: TransferDir,
    },
    /// Device memory allocation ({cuda,hip}Malloc).
    Malloc,
    /// Generator construction + seeding (curandCreateGenerator +
    /// curandSetPseudoRandomGeneratorSeed).
    GeneratorSetup,
    /// Host-side computation of a known duration.
    HostCompute {
        /// Duration in ns.
        ns: u64,
    },
}

/// Performance model for one platform.
#[derive(Debug, Clone)]
pub struct PerfModel {
    spec: PlatformSpec,
}

impl PerfModel {
    /// Model for `spec`.
    pub fn new(spec: PlatformSpec) -> Self {
        PerfModel { spec }
    }

    /// The platform spec behind this model.
    pub fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    /// Pure execution time of a command, excluding launch/callback
    /// overheads (those belong to the runtime profile / native app model).
    pub fn execution_ns(&self, cost: &CommandCost) -> u64 {
        match *cost {
            CommandCost::Kernel { bytes_read, bytes_written, items, tpb } => {
                self.kernel_ns(bytes_read, bytes_written, items, tpb)
            }
            CommandCost::Transfer { bytes, dir: _ } => self.transfer_ns(bytes),
            CommandCost::Malloc => self.spec.malloc_ns,
            CommandCost::GeneratorSetup => self.spec.generator_setup_ns,
            CommandCost::HostCompute { ns } => ns,
        }
    }

    /// Kernel time: max of the bandwidth term and the throughput term,
    /// divided by achieved occupancy, plus the launch pipeline latency.
    pub fn kernel_ns(&self, bytes_read: u64, bytes_written: u64, items: u64, tpb: u32) -> u64 {
        let s = &self.spec;
        match s.kind {
            PlatformKind::Cpu => {
                // Host path: throughput-bound, no occupancy model.
                let ns = items as f64 / s.host_gnum_per_s; // Gnum/s == num/ns
                ns.ceil() as u64 + s.launch_latency_ns
            }
            _ => {
                let bw_ns = (bytes_read + bytes_written) as f64 / s.mem_bw_gbps;
                let compute_ns = items as f64 / s.rng_gnum_per_s;
                let occ = occupancy(items, tpb, s).achieved.max(0.02);
                let ns = bw_ns.max(compute_ns) / occ;
                ns.ceil() as u64 + s.launch_latency_ns
            }
        }
    }

    /// Host<->device transfer time (zero for UMA platforms — the paper's
    /// zero-copy point for the UHD 630).
    pub fn transfer_ns(&self, bytes: u64) -> u64 {
        if self.spec.uma {
            return 0;
        }
        // Fixed DMA setup + payload over PCIe.
        const DMA_SETUP_NS: u64 = 9_000;
        DMA_SETUP_NS + (bytes as f64 / self.spec.pcie_gbps).ceil() as u64
    }

    /// Native-application per-call completion overhead (stream callback /
    /// synchronize) — what the paper's native burner pays after each of its
    /// kernels.
    pub fn native_callback_ns(&self) -> u64 {
        self.spec.native_callback_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    fn model(p: PlatformId) -> PerfModel {
        PerfModel::new(p.spec())
    }

    #[test]
    fn kernel_time_monotonic_in_items() {
        for p in PlatformId::ALL {
            let m = model(p);
            let tpb = m.spec().native_tpb;
            let mut prev = 0;
            for items in [1u64, 100, 10_000, 1_000_000, 100_000_000] {
                let ns = m.kernel_ns(0, items * 4, items, tpb);
                assert!(ns >= prev, "{:?} items={items}", p);
                prev = ns;
            }
        }
    }

    #[test]
    fn latency_floor_dominates_small_batches() {
        let m = model(PlatformId::A100);
        let small = m.kernel_ns(0, 4, 1, 256);
        let smallish = m.kernel_ns(0, 400, 100, 256);
        // Both dominated by launch latency: within 2x of each other.
        assert!(smallish < small * 2);
    }

    #[test]
    fn bandwidth_slope_dominates_large_batches() {
        let m = model(PlatformId::A100);
        let n1 = 100_000_000u64;
        let t1 = m.kernel_ns(0, n1 * 4, n1, 256);
        let t2 = m.kernel_ns(0, 2 * n1 * 4, 2 * n1, 256);
        let ratio = t2 as f64 / t1 as f64;
        assert!((ratio - 2.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn uma_transfers_are_free() {
        assert_eq!(model(PlatformId::Uhd630).transfer_ns(1 << 30), 0);
        assert!(model(PlatformId::A100).transfer_ns(1 << 30) > 0);
    }

    #[test]
    fn pcie_transfer_dominates_large_d2h() {
        // 4e8 bytes over 16 GB/s ~ 25 ms: the paper's large-batch regime.
        let ns = model(PlatformId::A100).transfer_ns(400_000_000);
        assert!((20e6..35e6).contains(&(ns as f64)), "ns={ns}");
    }
}
