//! The six evaluation platforms (paper Table 1 + §6.2) with the hardware
//! constants used by the performance model.

/// Identifier for each platform in the paper's test fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformId {
    /// AMD Rome 7742 (DGX A100 host, 16 of 128 cores used).
    Rome7742,
    /// Intel Core i7-10875H (8C/16T consumer CPU).
    CoreI7_10875H,
    /// Intel Xeon Gold 5220 (Vega host).
    XeonGold5220,
    /// Intel UHD Graphics 630 iGPU (UMA, zero-copy).
    Uhd630,
    /// MSI Radeon RX Vega 56.
    Vega56,
    /// NVIDIA A100 (DGX, one GPU).
    A100,
}

/// Broad device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    /// Host CPU device.
    Cpu,
    /// Integrated GPU sharing host memory (UMA).
    IntegratedGpu,
    /// Discrete GPU behind PCIe.
    DiscreteGpu,
}

/// Hardware + software constants for one platform.
///
/// Latencies/bandwidths are calibrated to reproduce the *shape* of the
/// paper's measurements (latency floor, bandwidth slope, crossovers), not
/// absolute wall-clock — see EXPERIMENTS.md for the shape comparison.
#[derive(Debug, Clone)]
pub struct PlatformSpec {
    /// Platform identity.
    pub id: PlatformId,
    /// Display name (Table 1).
    pub name: &'static str,
    /// Device class.
    pub kind: PlatformKind,
    /// Device memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Host<->device interconnect bandwidth, GB/s (ignored when `uma`).
    pub pcie_gbps: f64,
    /// Kernel-launch latency, ns.
    pub launch_latency_ns: u64,
    /// Completion-callback latency of the *native* runtime, ns
    /// (CUDA stream callbacks vs the "nearly callback-free" HIP runtime —
    /// paper §7).
    pub native_callback_ns: u64,
    /// Device-memory allocation latency, ns ({cuda,hip}Malloc analogue).
    pub malloc_ns: u64,
    /// Generator-construction cost, ns (curandCreateGenerator analogue).
    pub generator_setup_ns: u64,
    /// RNG kernel arithmetic throughput ceiling, Gnumbers/s (the kernel is
    /// memory-bound on GPUs, so min(this, bw/4B) applies).
    pub rng_gnum_per_s: f64,
    /// Number of SMs / CUs / cores.
    pub compute_units: u32,
    /// Max resident threads per compute unit (occupancy model).
    pub max_threads_per_cu: u32,
    /// Thread-block size the native application hardcodes (paper: 256).
    pub native_tpb: u32,
    /// Unified memory architecture: zero-copy buffers (UHD 630).
    pub uma: bool,
    /// Host-side RNG throughput, Gnumbers/s (CPU platforms; also used for
    /// host fallbacks).
    pub host_gnum_per_s: f64,
    /// Table 1 columns: OS / compiler / native RNG library.
    pub os: &'static str,
    /// Native compiler toolchain (Table 1).
    pub compiler: &'static str,
    /// Native RNG library (Table 1).
    pub rng_library: &'static str,
}

impl PlatformId {
    /// All platforms, Table 1 order.
    pub const ALL: [PlatformId; 6] = [
        PlatformId::Rome7742,
        PlatformId::CoreI7_10875H,
        PlatformId::XeonGold5220,
        PlatformId::Uhd630,
        PlatformId::Vega56,
        PlatformId::A100,
    ];

    /// The platform's spec sheet.
    pub fn spec(self) -> PlatformSpec {
        match self {
            PlatformId::Rome7742 => PlatformSpec {
                id: self,
                name: "AMD Rome 7742 (16 cores)",
                kind: PlatformKind::Cpu,
                mem_bw_gbps: 95.0,
                pcie_gbps: f64::INFINITY,
                launch_latency_ns: 400,
                native_callback_ns: 100,
                malloc_ns: 2_000,
                generator_setup_ns: 6_000,
                rng_gnum_per_s: 14.0,
                compute_units: 16,
                max_threads_per_cu: 2,
                native_tpb: 1,
                uma: true,
                host_gnum_per_s: 14.0,
                os: "OpenSUSE 15.0 / 4.12",
                compiler: "GNU 8.2.0 + DPC++",
                rng_library: "oneMKL (x86)",
            },
            PlatformId::CoreI7_10875H => PlatformSpec {
                id: self,
                name: "Intel Core i7-10875H",
                kind: PlatformKind::Cpu,
                mem_bw_gbps: 41.6,
                pcie_gbps: f64::INFINITY,
                launch_latency_ns: 400,
                native_callback_ns: 100,
                malloc_ns: 2_000,
                generator_setup_ns: 6_000,
                rng_gnum_per_s: 7.0,
                compute_units: 8,
                max_threads_per_cu: 2,
                native_tpb: 1,
                uma: true,
                host_gnum_per_s: 7.0,
                os: "Ubuntu 20.04 / 5.8.18",
                compiler: "GNU 8.4.0 + DPC++",
                rng_library: "oneMKL (x86)",
            },
            PlatformId::XeonGold5220 => PlatformSpec {
                id: self,
                name: "Intel Xeon Gold 5220",
                kind: PlatformKind::Cpu,
                mem_bw_gbps: 107.0,
                pcie_gbps: f64::INFINITY,
                launch_latency_ns: 400,
                native_callback_ns: 100,
                malloc_ns: 2_000,
                generator_setup_ns: 6_000,
                rng_gnum_per_s: 10.0,
                compute_units: 18,
                max_threads_per_cu: 2,
                native_tpb: 1,
                uma: true,
                host_gnum_per_s: 10.0,
                os: "CentOS 7 / 3.10.0",
                compiler: "GNU + hipSYCL 0.9.0",
                rng_library: "oneMKL (x86)",
            },
            PlatformId::Uhd630 => PlatformSpec {
                id: self,
                name: "Intel UHD Graphics 630",
                kind: PlatformKind::IntegratedGpu,
                mem_bw_gbps: 41.6, // shares host DDR4
                pcie_gbps: f64::INFINITY,
                launch_latency_ns: 18_000,
                native_callback_ns: 4_000,
                malloc_ns: 8_000,
                generator_setup_ns: 30_000,
                rng_gnum_per_s: 9.0,
                compute_units: 24,
                max_threads_per_cu: 448,
                native_tpb: 256,
                uma: true, // zero-copy buffers (paper §6.2)
                host_gnum_per_s: 7.0,
                os: "Ubuntu 20.04 / 5.8.18",
                compiler: "DPC++ (21.11.19310)",
                rng_library: "oneMKL (Intel GPU)",
            },
            PlatformId::Vega56 => PlatformSpec {
                id: self,
                name: "MSI Radeon RX Vega 56",
                kind: PlatformKind::DiscreteGpu,
                mem_bw_gbps: 410.0,
                pcie_gbps: 11.0,
                launch_latency_ns: 12_000,
                // "The nearly callback-free hipRAND runtime therefore
                // offers higher task throughput" (§7): the native HIP app
                // barely pays per-kernel completion costs.
                native_callback_ns: 2_000,
                malloc_ns: 40_000,
                generator_setup_ns: 180_000,
                rng_gnum_per_s: 60.0,
                compute_units: 56,
                max_threads_per_cu: 2_560,
                native_tpb: 256,
                uma: false,
                host_gnum_per_s: 10.0,
                os: "CentOS 7 / 3.10.0",
                compiler: "HIP 4.0.0 + hipSYCL 0.9.0",
                rng_library: "hipRAND 4.0.0",
            },
            PlatformId::A100 => PlatformSpec {
                id: self,
                name: "NVIDIA A100",
                kind: PlatformKind::DiscreteGpu,
                mem_bw_gbps: 1_555.0,
                pcie_gbps: 16.0,
                launch_latency_ns: 8_000,
                native_callback_ns: 10_000,
                malloc_ns: 60_000,
                generator_setup_ns: 250_000,
                rng_gnum_per_s: 220.0,
                compute_units: 108,
                max_threads_per_cu: 2_048,
                native_tpb: 256,
                uma: false,
                host_gnum_per_s: 14.0,
                os: "OpenSUSE 15.0 / 4.12",
                compiler: "CUDA 10.2.89 + DPC++",
                rng_library: "cuRAND 10.2.89",
            },
        }
    }

    /// Short token for CLI / CSV use.
    pub fn token(self) -> &'static str {
        match self {
            PlatformId::Rome7742 => "rome7742",
            PlatformId::CoreI7_10875H => "i7-10875h",
            PlatformId::XeonGold5220 => "xeon5220",
            PlatformId::Uhd630 => "uhd630",
            PlatformId::Vega56 => "vega56",
            PlatformId::A100 => "a100",
        }
    }

    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<PlatformId> {
        PlatformId::ALL.iter().copied().find(|p| p.token() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrip() {
        for p in PlatformId::ALL {
            assert_eq!(PlatformId::parse(p.token()), Some(p));
        }
        assert_eq!(PlatformId::parse("tpu"), None);
    }

    #[test]
    fn discrete_gpus_are_not_uma() {
        for p in PlatformId::ALL {
            let s = p.spec();
            match s.kind {
                PlatformKind::DiscreteGpu => assert!(!s.uma, "{:?}", p),
                PlatformKind::IntegratedGpu => assert!(s.uma, "{:?}", p),
                PlatformKind::Cpu => assert!(s.uma, "{:?}", p),
            }
        }
    }

    #[test]
    fn gpu_rng_is_memory_bound() {
        // Sanity: the model must put GPU RNG in the memory-bound regime,
        // as the paper asserts ("memory-bound nature of RNG operations").
        for p in [PlatformId::A100, PlatformId::Vega56] {
            let s = p.spec();
            assert!(s.rng_gnum_per_s * 4.0 < s.mem_bw_gbps,
                "{:?} would be compute-bound", p);
        }
    }
}
