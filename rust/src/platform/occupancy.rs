//! GPU occupancy model for the Fig. 4(b) reproduction.
//!
//! The paper observes that the DPC++ SYCL runtime picks 1024
//! threads-per-block on the A100 while the native CUDA code hardcodes 256,
//! producing visibly different occupancy in the 10^2–10^4 batch region even
//! though kernel *durations* are statistically identical. This model
//! captures exactly that mechanism: achieved occupancy is the fraction of
//! resident thread slots filled, with block granularity.

use super::spec::{PlatformKind, PlatformSpec};

/// Occupancy computation result.
#[derive(Debug, Clone, Copy)]
pub struct OccupancyReport {
    /// Thread blocks launched.
    pub blocks: u64,
    /// Threads per block in effect.
    pub tpb: u32,
    /// Achieved occupancy in [0, 1]: resident threads / max resident.
    pub achieved: f64,
    /// Waves needed to drain the grid.
    pub waves: u64,
}

/// Occupancy for a kernel of `items` work items at block size `tpb`.
pub fn occupancy(items: u64, tpb: u32, spec: &PlatformSpec) -> OccupancyReport {
    if spec.kind == PlatformKind::Cpu {
        return OccupancyReport { blocks: 1, tpb: 1, achieved: 1.0, waves: 1 };
    }
    let tpb = tpb.max(1) as u64;
    // Each thread handles 4 outputs (Philox4x32 block granularity).
    let threads_needed = items.div_ceil(4).max(1);
    let blocks = threads_needed.div_ceil(tpb);
    let max_resident =
        (spec.compute_units as u64) * (spec.max_threads_per_cu as u64);
    // Block-granular residency: a partially filled block still occupies
    // tpb-worth of scheduler slots.
    let resident_threads = (blocks * tpb).min(max_resident);
    let waves = (blocks * tpb).div_ceil(max_resident);
    // In the final (or only) wave, achieved occupancy is the filled
    // fraction; full waves run at 1.0. Weighted average:
    let full_waves = waves.saturating_sub(1);
    let tail_threads = blocks * tpb - full_waves * max_resident;
    let tail_occ = tail_threads.min(max_resident) as f64 / max_resident as f64;
    let achieved = if waves <= 1 {
        resident_threads as f64 / max_resident as f64
    } else {
        (full_waves as f64 + tail_occ) / waves as f64
    };
    OccupancyReport { blocks, tpb: tpb as u32, achieved: achieved.min(1.0), waves }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PlatformId;

    #[test]
    fn tiny_batch_low_occupancy() {
        let spec = PlatformId::A100.spec();
        let r = occupancy(100, 256, &spec);
        assert!(r.achieved < 0.01, "achieved={}", r.achieved);
        assert_eq!(r.blocks, 1);
    }

    #[test]
    fn tpb_1024_fills_faster_than_256() {
        // The paper's Fig 4b: SYCL (tpb=1024) shows a large occupancy jump
        // between 10^2 and 10^4 relative to native (tpb=256).
        let spec = PlatformId::A100.spec();
        for items in [1_000u64, 10_000] {
            let sycl = occupancy(items, 1024, &spec);
            let native = occupancy(items, 256, &spec);
            assert!(
                sycl.achieved >= native.achieved,
                "items={items}: sycl {} < native {}",
                sycl.achieved,
                native.achieved
            );
        }
    }

    #[test]
    fn saturates_at_one() {
        let spec = PlatformId::A100.spec();
        let r = occupancy(100_000_000, 256, &spec);
        assert!(r.achieved > 0.99);
        assert!(r.waves > 1);
    }

    #[test]
    fn cpu_is_always_full() {
        let spec = PlatformId::Rome7742.spec();
        assert_eq!(occupancy(10, 1, &spec).achieved, 1.0);
    }
}
