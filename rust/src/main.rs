//! `portarng` CLI — leader entry point.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! portarng platforms                         # Table-1 inventory
//! portarng burner --platform a100 --api sycl-buffer --batch 65536 [--iters 100]
//! portarng fastcalosim --platform a100 --api sycl --workload single-e [--events N]
//! portarng repro --experiment fig3 [--quick] [--outdir results]
//! portarng serve --batch-max 1048576 --demo-requests 32
//! portarng check-artifacts                   # PJRT round-trip smoke test
//! ```

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use portarng::burner::{run_burner_auto, run_burner_with_runtime, BurnerApi, BurnerConfig};
use portarng::coordinator::{DispatchPolicy, PoolConfig, ServicePool};
use portarng::fastcalosim::{run_fastcalosim, FcsApi, Workload};
use portarng::platform::PlatformId;
use portarng::repro::ExperimentId;
use portarng::runtime::PjrtRuntime;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", USAGE);
        return ExitCode::FAILURE;
    };
    let opts = parse_opts(rest);
    let result = match cmd.as_str() {
        "platforms" => cmd_platforms(),
        "burner" => cmd_burner(&opts),
        "fastcalosim" => cmd_fastcalosim(&opts),
        "repro" => cmd_repro(&opts),
        "serve" => cmd_serve(&opts),
        "check-artifacts" => cmd_check_artifacts(),
        "--help" | "-h" | "help" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n{USAGE}").into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "portarng — cross-platform performance-portable RNG (paper reproduction)

USAGE:
  portarng platforms
  portarng burner --platform <p> --api <native|sycl-buffer|sycl-usm|pjrt>
                  --batch <n> [--iters <n>] [--range a,b]
                  [--distr <name> --params a,b,..] [--pool <shards>]
  portarng fastcalosim --platform <p> --api <native|sycl>
                  --workload <single-e|ttbar> [--events <n>]
  portarng repro --experiment <table1|fig2|fig3|fig4|table2|fig5|ablation-heuristic|all>
                  [--quick] [--outdir <dir>]
  portarng serve [--batch-max <n>] [--demo-requests <n>] [--shards <n>]
                 [--overflow-at <n>]
  portarng check-artifacts

Distributions: uniform a b | gaussian mean stddev | lognormal m s |
               exponential lambda | poisson lambda | bits
Platforms: rome7742, i7-10875h, xeon5220, uhd630, vega56, a100";

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            map.insert(key.to_string(), val);
        }
        i += 1;
    }
    map
}

fn need<'a>(opts: &'a HashMap<String, String>, key: &str) -> Result<&'a str, String> {
    opts.get(key).map(String::as_str).ok_or_else(|| format!("missing --{key}"))
}

fn cmd_platforms() -> CliResult {
    println!("{}", portarng::repro::table1().to_markdown());
    Ok(())
}

fn cmd_burner(opts: &HashMap<String, String>) -> CliResult {
    let platform = PlatformId::parse(need(opts, "platform")?)
        .ok_or("unknown platform; see `portarng platforms`")?;
    let api = BurnerApi::parse(need(opts, "api")?).ok_or("bad --api")?;
    let batch: usize = need(opts, "batch")?.parse()?;
    let iters: usize = opts.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(100);

    let mut cfg = BurnerConfig::paper_default(platform, api, batch);
    cfg.iterations = iters;
    if opts.contains_key("range") && opts.contains_key("distr") {
        return Err("--range and --distr conflict; pass the range as --distr uniform a,b".into());
    }
    if opts.contains_key("params") && !opts.contains_key("distr") {
        return Err("--params requires --distr <name>".into());
    }
    if let Some(range) = opts.get("range") {
        let (a, b) = range.split_once(',').ok_or("bad --range, want a,b")?;
        cfg.distr = portarng::rng::Distribution::uniform(a.parse()?, b.parse()?);
    }
    if let Some(name) = opts.get("distr") {
        let params: Vec<f32> = match opts.get("params") {
            None => Vec::new(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(str::parse)
                .collect::<Result<_, _>>()?,
        };
        cfg.distr = portarng::rng::parse_distribution(name, &params)?;
    }

    // Pooled mode: drive the workload through the sharded service pool.
    if let Some(shards) = opts.get("pool") {
        let shards: usize = shards.parse()?;
        let r = portarng::burner::run_burner_pooled(&cfg, shards, iters)?;
        println!(
            "pooled burner {} shards={} requests={} batch={}\n  \
             {:.1} M numbers/s wall ({:.2} ms total), {} launches, checksum {:016x}",
            platform.token(),
            r.shards,
            r.requests,
            batch,
            r.throughput_m_per_s(),
            r.wall_ns as f64 / 1e6,
            r.stats.total().launches,
            r.checksum
        );
        return Ok(());
    }

    let report = if api == BurnerApi::Pjrt {
        let rt = Arc::new(PjrtRuntime::discover()?);
        run_burner_with_runtime(&cfg, Some(rt))?
    } else {
        run_burner_auto(&cfg)?
    };
    let s = portarng::metrics::Summary::of(&report.totals_ns);
    println!(
        "burner {} {} batch={} iters={}\n  total: {:.4} ms ± {:.4} (median {:.4})",
        platform.token(),
        api.token(),
        batch,
        iters,
        s.mean / 1e6,
        s.stddev / 1e6,
        s.median / 1e6
    );
    let b = report.breakdown;
    println!(
        "  kernels: setup {:.4} ms | generate {:.4} ms (occ {:.2}, tpb {}) | \
         transform {:.4} ms | h2d {:.4} | d2h {:.4}",
        b.setup_ns as f64 / 1e6,
        b.generate_ns as f64 / 1e6,
        b.generate_occupancy,
        b.tpb,
        b.transform_ns as f64 / 1e6,
        b.h2d_ns as f64 / 1e6,
        b.d2h_ns as f64 / 1e6
    );
    if !report.sample.is_empty() {
        println!("  sample: {:?}", &report.sample);
    }
    println!("  wall: {:.1} ms", report.wall_ns as f64 / 1e6);
    Ok(())
}

fn cmd_fastcalosim(opts: &HashMap<String, String>) -> CliResult {
    let platform = PlatformId::parse(need(opts, "platform")?).ok_or("unknown platform")?;
    let api = FcsApi::parse(need(opts, "api")?).ok_or("bad --api (native|sycl)")?;
    let events: Option<usize> = opts.get("events").map(|s| s.parse()).transpose()?;
    let workload = match need(opts, "workload")? {
        "single-e" => Workload::SingleElectron { events: events.unwrap_or(1000) },
        "ttbar" => Workload::TTbar { events: events.unwrap_or(500) },
        other => return Err(format!("unknown workload `{other}`").into()),
    };
    let r = run_fastcalosim(platform, api, workload, 2024)?;
    println!(
        "fastcalosim {} {} {}: {} events in {:.3} s (virtual), {:.2} ms/event",
        platform.token(),
        api.token(),
        r.workload,
        r.events,
        r.total_ns as f64 / 1e9,
        r.mean_event_ms()
    );
    println!(
        "  hits {} | rns {} | tables {} | E_in {:.1} GeV -> E_dep {:.1} GeV | wall {:.1} ms",
        r.hits,
        r.rns,
        r.tables_loaded,
        r.energy_in,
        r.energy_dep,
        r.wall_ns as f64 / 1e6
    );
    Ok(())
}

fn cmd_repro(opts: &HashMap<String, String>) -> CliResult {
    let quick = opts.contains_key("quick");
    let outdir = std::path::PathBuf::from(
        opts.get("outdir").cloned().unwrap_or_else(|| "results".into()),
    );
    let which = need(opts, "experiment")?;
    let ids: Vec<ExperimentId> = if which == "all" {
        ExperimentId::ALL.to_vec()
    } else {
        vec![ExperimentId::parse(which).ok_or("unknown experiment id")?]
    };
    for id in ids {
        for table in id.run(quick)? {
            println!("{}", table.to_markdown());
            let path = table.write_csv(&outdir)?;
            println!("[wrote {}]\n", path.display());
        }
    }
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> CliResult {
    let batch_max: usize =
        opts.get("batch-max").map(|s| s.parse()).transpose()?.unwrap_or(1 << 20);
    let n_req: usize =
        opts.get("demo-requests").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let shards: usize = opts.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let overflow_at: Option<usize> =
        opts.get("overflow-at").map(|s| s.parse()).transpose()?;

    let mut cfg = PoolConfig::new(PlatformId::A100, 0x5EED, shards);
    cfg.max_batch = batch_max;
    if let Some(t) = overflow_at {
        cfg.policy = DispatchPolicy::fixed(t);
    }
    let pool = ServicePool::spawn(cfg);
    let mut receivers = Vec::new();
    for i in 0..n_req {
        receivers.push(pool.generate(1000 + 137 * i, (0.0, 1.0)));
    }
    pool.flush();
    let mut total = 0usize;
    for rx in receivers {
        total += rx.recv()??.len();
    }
    let stats = pool.shutdown()?;
    let t = stats.total();
    println!(
        "served {} requests / {} numbers in {} launches across {} shard(s)",
        t.requests,
        total,
        t.launches,
        stats.shards.len()
    );
    for (i, s) in stats.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} requests, {} launches, {} numbers",
            s.requests, s.launches, s.numbers
        );
    }
    Ok(())
}

fn cmd_check_artifacts() -> CliResult {
    let rt = PjrtRuntime::discover()?;
    println!("manifest: {} artifacts", rt.manifest().artifacts.len());
    for name in rt.manifest().artifacts.keys() {
        print!("  compiling {name} ... ");
        rt.load(name)?;
        println!("ok");
    }
    // Numeric round-trip on the smallest burner artifact.
    let out = rt.run_burner("burner_uniform_4096", [1234, 5678], [0, 0], -2.0, 3.0)?;
    let mut engine = portarng::rng::PhiloxEngine::new((5678u64 << 32) | 1234u64);
    let mut want = vec![0f32; 4096];
    portarng::rng::Engine::fill_uniform_f32(&mut engine, &mut want);
    let max_err = out
        .iter()
        .zip(want.iter().map(|&u| -2.0 + u * 5.0))
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pjrt round-trip max |err| vs rust philox: {max_err:.2e}");
    if max_err > 1e-6 {
        return Err("PJRT output diverges from the Rust Philox reference".into());
    }
    println!("artifacts OK");
    Ok(())
}
