//! `portarng` CLI — leader entry point.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! portarng platforms                         # Table-1 inventory
//! portarng burner --platform a100 --api sycl-buffer --batch 65536 [--iters 100]
//! portarng fastcalosim --platform a100 --api sycl --workload single-e [--events N]
//! portarng fastcalosim --platform a100 --api sycl --pool 4 [--tile-size 256]
//! portarng repro --experiment fig3 [--quick] [--outdir results]
//! portarng serve --batch-max 1048576 --demo-requests 32
//! portarng serve --autotune [--profile profiles.json]   # adaptive dispatch
//! portarng calibrate --platform a100 [--profile profiles.json]
//! portarng check-artifacts                   # PJRT round-trip smoke test
//! portarng lint-dag                          # hazard-analyze burner + FCS DAGs
//! ```
//!
//! Flags are validated per subcommand: unknown or misspelled `--options`
//! are rejected (a typo'd `--shard` must not silently serve defaults).

use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

use portarng::autotune::{calibrate, PoolAutoTuner, ProfileStore};
use portarng::burner::{run_burner_auto, run_burner_with_runtime, BurnerApi, BurnerConfig};
use portarng::coordinator::{DispatchPolicy, PoolConfig, ServicePool};
use portarng::fastcalosim::{run_fastcalosim, FcsApi, Workload};
use portarng::fault::FaultSpec;
use portarng::platform::PlatformId;
use portarng::repro::ExperimentId;
use portarng::runtime::PjrtRuntime;
use portarng::testkit::Gen;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", USAGE);
        return ExitCode::FAILURE;
    };
    let result = dispatch(cmd, rest);
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Per-subcommand flag allowlists: [`parse_opts`] rejects anything not
/// listed here, so a typo'd flag fails loudly instead of silently running
/// with defaults.
const OPTS_BURNER: &[&str] = &[
    "platform", "api", "batch", "iters", "range", "distr", "params", "pool", "stats-json",
    "chaos", "trace",
];
const OPTS_FASTCALOSIM: &[&str] = &[
    "platform", "api", "workload", "events", "pool", "tile-size", "team-width", "chaos",
    "stats-json", "trace",
];
const OPTS_REPRO: &[&str] = &["experiment", "quick", "outdir"];
const OPTS_SERVE: &[&str] = &[
    "platform", "batch-max", "demo-requests", "shards", "overflow-at", "chaos", "tile-size",
    "team-width", "autotune", "profile", "windows", "save-profile", "trace",
];
const OPTS_CALIBRATE: &[&str] = &["platform", "shards", "profile"];
const OPTS_LINT_DAG: &[&str] = &["verbose"];

fn dispatch(cmd: &str, rest: &[String]) -> CliResult {
    match cmd {
        "platforms" => {
            parse_opts(cmd, rest, &[])?;
            cmd_platforms()
        }
        "burner" => cmd_burner(&parse_opts(cmd, rest, OPTS_BURNER)?),
        "fastcalosim" => cmd_fastcalosim(&parse_opts(cmd, rest, OPTS_FASTCALOSIM)?),
        "repro" => cmd_repro(&parse_opts(cmd, rest, OPTS_REPRO)?),
        "serve" => cmd_serve(&parse_opts(cmd, rest, OPTS_SERVE)?),
        "calibrate" => cmd_calibrate(&parse_opts(cmd, rest, OPTS_CALIBRATE)?),
        "check-artifacts" => {
            parse_opts(cmd, rest, &[])?;
            cmd_check_artifacts()
        }
        "lint-dag" => cmd_lint_dag(&parse_opts(cmd, rest, OPTS_LINT_DAG)?),
        "--help" | "-h" | "help" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n{USAGE}").into()),
    }
}

const USAGE: &str = "portarng — cross-platform performance-portable RNG (paper reproduction)

USAGE:
  portarng platforms
  portarng burner --platform <p> --api <native|sycl-buffer|sycl-usm|pjrt>
                  --batch <n> [--iters <n>] [--range a,b]
                  [--distr <name> --params a,b,..] [--pool <shards>]
                  [--stats-json <path>] [--chaos <spec>] [--trace <path>]
                                                           (pooled mode only)
  portarng fastcalosim --platform <p> --api <native|sycl>
                  --workload <single-e|ttbar> [--events <n>]
                  [--pool <shards> [--tile-size <n> [--team-width <w>]]
                   [--chaos <spec>] [--stats-json <path>] [--trace <path>]]
  portarng repro --experiment <table1|fig2|fig3|fig4|table2|fig5|ablation-heuristic|all>
                  [--quick] [--outdir <dir>]
  portarng serve [--platform <p>] [--batch-max <n>] [--demo-requests <n>]
                 [--shards <n>] [--overflow-at <n>] [--chaos <spec>]
                 [--tile-size <n> [--team-width <w>]] [--trace <path>]
  portarng serve --autotune [--platform <p>] [--shards <n>] [--windows <n>]
                 [--demo-requests <n>] [--profile <path>] [--save-profile]
                 [--tile-size <n> [--team-width <w>]]
  portarng calibrate --platform <p> [--shards <n>] [--profile <path>]
  portarng check-artifacts
  portarng lint-dag [--verbose]                (prove recorded DAGs race-free,
                                                incl. the fastcalosim event loop)

Distributions: uniform a b | gaussian mean stddev | lognormal m s |
               exponential lambda | poisson lambda | bits
Platforms: rome7742, i7-10875h, xeon5220, uhd630, vega56, a100
Chaos spec:  seed=<u64>,rate=<0..1>,sites=<generate+submit+d2h>,kill=<shard>@<op>+..
             (also read from PORTARNG_FAULT_PLAN when --chaos is absent)
Executor:    --tile-size turns flushes into per-tile work items on a
             worker-local team (bit-identical to serial); also read from
             PORTARNG_TILE=<tile>,<width> when the flags are absent
Tracing:     --trace <path> records per-shard request spans and writes a
             Chrome trace-event JSON (load in Perfetto / chrome://tracing);
             with --chaos kills, flight-recorder dumps land next to it";

type CliResult = Result<(), Box<dyn std::error::Error>>;

/// Parse `--key [value]` pairs, validated against the subcommand's
/// allowlist: unknown flags, stray positionals and repeated flags are all
/// errors (historically `--shard 4` silently served 1 shard — typos must
/// fail loudly, same policy as the conflict validation in `cmd_serve`).
fn parse_opts(
    cmd: &str,
    args: &[String],
    known: &[&str],
) -> Result<HashMap<String, String>, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            return Err(format!(
                "unexpected argument `{}` for `portarng {cmd}` (flags are --key [value])",
                args[i]
            ));
        };
        if !known.contains(&key) {
            let hint = if known.is_empty() {
                format!("`portarng {cmd}` takes no flags")
            } else {
                format!(
                    "`portarng {cmd}` accepts: {}",
                    known
                        .iter()
                        .map(|k| format!("--{k}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            };
            return Err(format!("unknown flag --{key}; {hint}"));
        }
        let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            i += 1;
            args[i].clone()
        } else {
            "true".to_string()
        };
        if map.insert(key.to_string(), val).is_some() {
            return Err(format!("--{key} given more than once"));
        }
        i += 1;
    }
    Ok(map)
}

fn need<'a>(opts: &'a HashMap<String, String>, key: &str) -> Result<&'a str, String> {
    opts.get(key).map(String::as_str).ok_or_else(|| format!("missing --{key}"))
}

/// Resolve the deterministic chaos plan for a pooled command: an explicit
/// `--chaos <spec>` wins; the `PORTARNG_FAULT_PLAN` environment variable
/// is the fallback (so CI can chaos-wrap a job without editing every
/// command line); neither means no plan.
fn chaos_spec(opts: &HashMap<String, String>) -> Result<Option<FaultSpec>, String> {
    let spec = match opts.get("chaos") {
        Some(s) => Some(s.clone()),
        None => std::env::var("PORTARNG_FAULT_PLAN").ok().filter(|s| !s.is_empty()),
    };
    spec.map(|s| FaultSpec::parse(&s).map_err(|e| format!("bad chaos spec `{s}`: {e}")))
        .transpose()
}

/// Resolve the request-tracer configuration for a pooled command
/// (DESIGN.md S18): `--trace <path>` enables span recording and names
/// the Chrome trace-event JSON to export; flight-recorder dumps (taken
/// when a chaos plan kills a worker) land in the same directory.
fn trace_config(opts: &HashMap<String, String>) -> Option<portarng::trace::TraceConfig> {
    opts.get("trace").map(|path| {
        let parent = Path::new(path)
            .parent()
            .filter(|d| !d.as_os_str().is_empty())
            .map(Path::to_path_buf)
            .unwrap_or_else(|| std::path::PathBuf::from("."));
        portarng::trace::TraceConfig {
            flight_dir: Some(parent),
            ..Default::default()
        }
    })
}

/// Export a traced run's spans as Chrome trace JSON at the `--trace`
/// path and report what was written.
fn export_trace(
    opts: &HashMap<String, String>,
    spans: &[portarng::trace::Span],
) -> CliResult {
    if let Some(path) = opts.get("trace") {
        portarng::trace::chrome::export(spans, Path::new(path))?;
        println!("[wrote {} span(s) as Chrome trace JSON to {path}]", spans.len());
    }
    Ok(())
}

/// Parse the tile-executor flags. `--team-width` without `--tile-size`
/// is rejected (a team with nothing to tile), as are zero values — the
/// serial path is selected by *omitting* the flags, never by 0. When
/// both flags are absent the pool still honours `PORTARNG_TILE`.
fn tiling_opts(opts: &HashMap<String, String>) -> Result<Option<(usize, usize)>, String> {
    if opts.contains_key("team-width") && !opts.contains_key("tile-size") {
        return Err("--team-width requires --tile-size (it sizes the tile executor team)".into());
    }
    let Some(raw) = opts.get("tile-size") else {
        return Ok(None);
    };
    let tile: usize = raw.parse().map_err(|_| format!("bad --tile-size `{raw}`"))?;
    if tile == 0 {
        return Err("--tile-size must be >= 1 (omit the flag for the serial path)".into());
    }
    let width = match opts.get("team-width") {
        Some(w) => {
            let w: usize = w.parse().map_err(|_| format!("bad --team-width `{w}`"))?;
            if w == 0 {
                return Err("--team-width must be >= 1".into());
            }
            w
        }
        None => 4,
    };
    Ok(Some((tile, width)))
}

fn cmd_platforms() -> CliResult {
    println!("{}", portarng::repro::table1().to_markdown());
    Ok(())
}

fn cmd_burner(opts: &HashMap<String, String>) -> CliResult {
    let platform = PlatformId::parse(need(opts, "platform")?)
        .ok_or("unknown platform; see `portarng platforms`")?;
    let api = BurnerApi::parse(need(opts, "api")?).ok_or("bad --api")?;
    let batch: usize = need(opts, "batch")?.parse()?;
    let iters: usize = opts.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(100);

    let mut cfg = BurnerConfig::paper_default(platform, api, batch);
    cfg.iterations = iters;
    if opts.contains_key("range") && opts.contains_key("distr") {
        return Err("--range and --distr conflict; pass the range as --distr uniform a,b".into());
    }
    if opts.contains_key("params") && !opts.contains_key("distr") {
        return Err("--params requires --distr <name>".into());
    }
    if let Some(range) = opts.get("range") {
        let (a, b) = range.split_once(',').ok_or("bad --range, want a,b")?;
        cfg.distr = portarng::rng::Distribution::uniform(a.parse()?, b.parse()?);
    }
    if let Some(name) = opts.get("distr") {
        let params: Vec<f32> = match opts.get("params") {
            None => Vec::new(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(str::parse)
                .collect::<Result<_, _>>()?,
        };
        cfg.distr = portarng::rng::parse_distribution(name, &params)?;
    }

    // --stats-json serializes the pool telemetry snapshot, so it only
    // means something in pooled mode: reject instead of silently ignoring.
    if opts.contains_key("stats-json") && !opts.contains_key("pool") {
        return Err("--stats-json requires --pool <shards> (it dumps pool telemetry)".into());
    }
    if opts.contains_key("chaos") && !opts.contains_key("pool") {
        return Err(
            "--chaos requires --pool <shards> (faults inject into the supervised pool)".into()
        );
    }
    if opts.contains_key("trace") && !opts.contains_key("pool") {
        return Err("--trace requires --pool <shards> (spans record in the serving pool)".into());
    }

    // Pooled mode: drive the workload through the sharded service pool.
    if let Some(shards) = opts.get("pool") {
        let shards: usize = shards.parse()?;
        let chaos = chaos_spec(opts)?;
        let trace = trace_config(opts);
        let r = portarng::burner::run_burner_pooled_opts(
            &cfg,
            shards,
            iters,
            chaos.as_ref(),
            trace.as_ref(),
        )?;
        println!(
            "pooled burner {} shards={} requests={} batch={}\n  \
             {:.1} M numbers/s wall ({:.2} ms total), {} launches, checksum {:016x}",
            platform.token(),
            r.shards,
            r.requests,
            batch,
            r.throughput_m_per_s(),
            r.wall_ns as f64 / 1e6,
            r.stats.total().launches,
            r.checksum
        );
        // Fig.-4-style per-kernel-class split of the serving path, from
        // the workers' drained queue records (virtual clock).
        let k = r.telemetry.command_breakdown();
        let arena = r.telemetry.arena_totals();
        println!(
            "  kernels (virtual): generate {:.3} ms / {} | transform {:.3} ms / {} | \
             d2h {:.3} ms / {} | other {:.3} ms",
            k.generate.virt_ns as f64 / 1e6,
            k.generate.cmds,
            k.transform.virt_ns as f64 / 1e6,
            k.transform.cmds,
            k.d2h.virt_ns as f64 / 1e6,
            k.d2h.cmds,
            k.other.virt_ns as f64 / 1e6
        );
        println!(
            "  arena: {} checkouts, {:.1}% hit rate, {} mallocs, {} KiB pooled",
            arena.checkouts,
            arena.hit_rate() * 100.0,
            arena.misses,
            arena.pooled_bytes / 1024
        );
        if let Some(spec) = &chaos {
            let res = r.telemetry.resilience_totals();
            println!(
                "  chaos [{spec}]: {} fault(s) injected, {} respawn(s), {} retried, \
                 {} shed, {} deadline-exceeded",
                res.faults_injected,
                res.shard_respawns,
                res.requests_retried,
                res.requests_shed,
                res.deadline_exceeded
            );
        }
        if trace.is_some() {
            println!(
                "  trace: {} span(s) recorded, {} dropped (ring wrap), {} flight dump(s)",
                r.telemetry.trace.spans,
                r.telemetry.trace.dropped,
                r.telemetry.trace.flight_dumps
            );
        }
        export_trace(opts, &r.spans)?;
        if let Some(path) = opts.get("stats-json") {
            let json = r.telemetry.to_json().to_json();
            // Guarantee the documented round-trip property before writing.
            portarng::telemetry::TelemetrySnapshot::from_json(
                &portarng::jsonlite::Value::parse(&json)?,
            )?;
            std::fs::write(path, &json)?;
            println!("[wrote telemetry snapshot to {path}]");
        }
        return Ok(());
    }

    let report = if api == BurnerApi::Pjrt {
        let rt = Arc::new(PjrtRuntime::discover()?);
        run_burner_with_runtime(&cfg, Some(rt))?
    } else {
        run_burner_auto(&cfg)?
    };
    let s = portarng::metrics::Summary::of(&report.totals_ns);
    println!(
        "burner {} {} batch={} iters={}\n  total: {:.4} ms ± {:.4} (median {:.4})",
        platform.token(),
        api.token(),
        batch,
        iters,
        s.mean / 1e6,
        s.stddev / 1e6,
        s.median / 1e6
    );
    let b = report.breakdown;
    println!(
        "  kernels: setup {:.4} ms | generate {:.4} ms (occ {:.2}, tpb {}) | \
         transform {:.4} ms | h2d {:.4} | d2h {:.4}",
        b.setup_ns as f64 / 1e6,
        b.generate_ns as f64 / 1e6,
        b.generate_occupancy,
        b.tpb,
        b.transform_ns as f64 / 1e6,
        b.h2d_ns as f64 / 1e6,
        b.d2h_ns as f64 / 1e6
    );
    if !report.sample.is_empty() {
        println!("  sample: {:?}", &report.sample);
    }
    println!("  wall: {:.1} ms", report.wall_ns as f64 / 1e6);
    Ok(())
}

fn cmd_fastcalosim(opts: &HashMap<String, String>) -> CliResult {
    let platform = PlatformId::parse(need(opts, "platform")?).ok_or("unknown platform")?;
    let api = FcsApi::parse(need(opts, "api")?).ok_or("bad --api (native|sycl)")?;
    let events: Option<usize> = match opts.get("events") {
        None => None,
        Some(raw) => {
            let n: usize =
                raw.parse().map_err(|_| format!("bad --events `{raw}` (want a count)"))?;
            if n == 0 {
                return Err("--events must be >= 1 (omit the flag for the paper size)".into());
            }
            Some(n)
        }
    };
    let workload = match need(opts, "workload")? {
        "single-e" => Workload::SingleElectron { events: events.unwrap_or(1000) },
        "ttbar" => Workload::TTbar { events: events.unwrap_or(500) },
        other => return Err(format!("unknown workload `{other}` (single-e|ttbar)").into()),
    };

    // The pooled-only flags mean nothing on the standalone path: reject
    // instead of silently ignoring (same policy as `burner`).
    for flag in ["tile-size", "team-width", "chaos", "stats-json", "trace"] {
        if opts.contains_key(flag) && !opts.contains_key("pool") {
            return Err(format!(
                "--{flag} requires --pool <shards> (it configures the serving pool)"
            )
            .into());
        }
    }

    // Pooled mode: every uniform served by the sharded SYCL stack —
    // bit-identical physics to the standalone run (same checksum).
    if let Some(shards) = opts.get("pool") {
        let shards: usize =
            shards.parse().map_err(|_| format!("bad --pool `{shards}` (want a shard count)"))?;
        if shards == 0 {
            return Err("--pool must be >= 1 shard".into());
        }
        let tiling = tiling_opts(opts)?;
        let chaos = chaos_spec(opts)?;
        let trace = trace_config(opts);
        let run = portarng::fastcalosim::run_fastcalosim_pooled_opts(
            platform,
            api,
            workload,
            2024,
            shards,
            tiling,
            chaos.clone(),
            trace.clone(),
        )?;
        let r = &run.report;
        println!(
            "fastcalosim {} {} {} [pooled x{}]: {} events in {:.3} s (virtual), \
             {:.2} ms/event, checksum {:016x}",
            platform.token(),
            api.token(),
            r.workload,
            shards,
            r.events,
            r.total_ns as f64 / 1e9,
            r.mean_event_ms(),
            r.checksum
        );
        println!(
            "  hits {} | rns {} | tables {} | E_in {:.1} GeV -> E_dep {:.1} GeV | wall {:.1} ms",
            r.hits,
            r.rns,
            r.tables_loaded,
            r.energy_in,
            r.energy_dep,
            r.wall_ns as f64 / 1e6
        );
        let f = run.telemetry.fcs;
        println!(
            "  per-event splits (virtual): generate {:.3} ms | transform {:.3} ms | \
             d2h {:.3} ms over {} event(s)",
            f.gen_ns as f64 / 1e6 / f.events.max(1) as f64,
            f.transform_ns as f64 / 1e6 / f.events.max(1) as f64,
            f.d2h_ns as f64 / 1e6 / f.events.max(1) as f64,
            f.events
        );
        println!(
            "  pool: {} draw request(s), {} launches, {} numbers delivered across {} shard(s)",
            run.telemetry.total_requests(),
            run.stats.total().launches,
            run.telemetry.total_delivered(),
            run.stats.shards.len()
        );
        if let Some(spec) = &chaos {
            let res = run.telemetry.resilience_totals();
            println!(
                "  chaos [{spec}]: {} fault(s) injected, {} respawn(s), {} retried, \
                 {} shed, {} deadline-exceeded",
                res.faults_injected,
                res.shard_respawns,
                res.requests_retried,
                res.requests_shed,
                res.deadline_exceeded
            );
        }
        if trace.is_some() {
            println!(
                "  trace: {} span(s) recorded, {} dropped (ring wrap), {} flight dump(s)",
                run.telemetry.trace.spans,
                run.telemetry.trace.dropped,
                run.telemetry.trace.flight_dumps
            );
        }
        export_trace(opts, &run.spans)?;
        if let Some(path) = opts.get("stats-json") {
            let json = run.telemetry.to_json().to_json();
            // Guarantee the documented round-trip property before writing.
            portarng::telemetry::TelemetrySnapshot::from_json(
                &portarng::jsonlite::Value::parse(&json)?,
            )?;
            std::fs::write(path, &json)?;
            println!("[wrote telemetry snapshot to {path}]");
        }
        return Ok(());
    }

    let r = run_fastcalosim(platform, api, workload, 2024)?;
    println!(
        "fastcalosim {} {} {}: {} events in {:.3} s (virtual), {:.2} ms/event, \
         checksum {:016x}",
        platform.token(),
        api.token(),
        r.workload,
        r.events,
        r.total_ns as f64 / 1e9,
        r.mean_event_ms(),
        r.checksum
    );
    println!(
        "  hits {} | rns {} | tables {} | E_in {:.1} GeV -> E_dep {:.1} GeV | wall {:.1} ms",
        r.hits,
        r.rns,
        r.tables_loaded,
        r.energy_in,
        r.energy_dep,
        r.wall_ns as f64 / 1e6
    );
    Ok(())
}

fn cmd_repro(opts: &HashMap<String, String>) -> CliResult {
    let quick = opts.contains_key("quick");
    let outdir = std::path::PathBuf::from(
        opts.get("outdir").cloned().unwrap_or_else(|| "results".into()),
    );
    let which = need(opts, "experiment")?;
    let ids: Vec<ExperimentId> = if which == "all" {
        ExperimentId::ALL.to_vec()
    } else {
        vec![ExperimentId::parse(which).ok_or("unknown experiment id")?]
    };
    for id in ids {
        for table in id.run(quick)? {
            println!("{}", table.to_markdown());
            let path = table.write_csv(&outdir)?;
            println!("[wrote {}]\n", path.display());
        }
    }
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> CliResult {
    let autotune = opts.contains_key("autotune");
    // Conflict validation, same policy as the --range/--distr pair above:
    // errors instead of silent precedence.
    if autotune && opts.contains_key("overflow-at") {
        return Err(
            "--autotune and --overflow-at conflict: the autotuner owns the threshold \
             (drop --overflow-at, or drop --autotune for a fixed threshold)"
                .into(),
        );
    }
    if autotune && opts.contains_key("batch-max") {
        return Err(
            "--autotune and --batch-max conflict: batcher limits come from the \
             calibration profile under autotuning"
                .into(),
        );
    }
    if opts.contains_key("profile") && !autotune {
        return Err("--profile requires --autotune (profiles feed the autotuner)".into());
    }
    if opts.contains_key("windows") && !autotune {
        return Err("--windows requires --autotune (it counts observation windows)".into());
    }
    if opts.contains_key("save-profile") && !opts.contains_key("profile") {
        return Err("--save-profile requires --profile <path> (nowhere to save)".into());
    }
    if autotune && opts.contains_key("chaos") {
        return Err(
            "--autotune and --chaos conflict: injected faults would poison the tuner's \
             throughput observations (chaos-test the fixed-threshold pool)"
                .into(),
        );
    }

    let platform = match opts.get("platform") {
        Some(p) => PlatformId::parse(p).ok_or("unknown platform; see `portarng platforms`")?,
        None => PlatformId::A100,
    };
    let n_req: usize =
        opts.get("demo-requests").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let shards: usize = opts.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let tiling = tiling_opts(opts)?;

    if autotune {
        return serve_autotuned(opts, platform, shards, n_req, tiling);
    }

    let batch_max: usize =
        opts.get("batch-max").map(|s| s.parse()).transpose()?.unwrap_or(1 << 20);
    let overflow_at: Option<usize> =
        opts.get("overflow-at").map(|s| s.parse()).transpose()?;

    let mut cfg = PoolConfig::new(platform, 0x5EED, shards);
    cfg.max_batch = batch_max;
    cfg.tiling = tiling;
    if let Some(t) = overflow_at {
        cfg.policy = DispatchPolicy::fixed(t);
    }
    let chaos = chaos_spec(opts)?;
    if chaos.is_some() {
        cfg.fault = chaos.clone();
        cfg.ingress.max_retries = 12;
    }
    cfg.trace = trace_config(opts);
    let pool = ServicePool::spawn(cfg);
    let mut receivers = Vec::new();
    for i in 0..n_req {
        receivers.push(pool.generate(1000 + 137 * i, (0.0, 1.0)));
    }
    pool.flush();
    let mut total = 0usize;
    for rx in receivers {
        total += rx.recv_timeout(std::time::Duration::from_secs(60))??.len();
    }
    let registry = pool.telemetry().clone();
    let tracer = pool.tracer();
    let stats = pool.shutdown()?;
    let snapshot = registry.snapshot();
    let t = stats.total();
    println!(
        "served {} requests / {} numbers in {} launches across {} shard(s)",
        t.requests,
        total,
        t.launches,
        stats.shards.len()
    );
    for (i, s) in stats.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} requests, {} launches, {} numbers",
            s.requests, s.launches, s.numbers
        );
    }
    let tiles = snapshot.tile_totals();
    let pipe = snapshot.pipeline_totals();
    if tiles.tiles > 0 {
        println!(
            "  executor: {} tile(s), {:.3} ms tile wall | pipeline: {}/{} flushes \
             overlapped ({:.0}% occupancy)",
            tiles.tiles,
            tiles.wall_ns as f64 / 1e6,
            pipe.overlapped,
            pipe.flushes,
            pipe.occupancy() * 100.0
        );
    }
    if let Some(spec) = &chaos {
        let res = snapshot.resilience_totals();
        println!(
            "  chaos [{spec}]: {} fault(s) injected, {} respawn(s), {} retried, \
             {} shed, {} deadline-exceeded, {} shard(s) lost at shutdown",
            res.faults_injected,
            res.shard_respawns,
            res.requests_retried,
            res.requests_shed,
            res.deadline_exceeded,
            stats.lost_shards
        );
    }
    if let Some(tr) = tracer {
        println!(
            "  trace: {} span(s) recorded, {} dropped (ring wrap), {} flight dump(s)",
            snapshot.trace.spans, snapshot.trace.dropped, snapshot.trace.flight_dumps
        );
        export_trace(opts, &tr.snapshot())?;
    }
    Ok(())
}

/// `serve --autotune`: calibrate (or warm-start from a profile), spawn an
/// adaptive pool, and drive demo traffic in observation windows with the
/// online tuner closing the loop after each one.
fn serve_autotuned(
    opts: &HashMap<String, String>,
    platform: PlatformId,
    shards: usize,
    n_req: usize,
    tiling: Option<(usize, usize)>,
) -> CliResult {
    let windows: usize = opts.get("windows").map(|s| s.parse()).transpose()?.unwrap_or(12);
    let profile_path = opts.get("profile").map(Path::new);

    let mut store = match profile_path {
        Some(p) => ProfileStore::load(p)?,
        None => ProfileStore::new(),
    };
    let (profile, warm) = match store.get(platform) {
        // A stored profile only warm-starts a pool with the shard count
        // it was calibrated for — the optimum moves with the lane count.
        Some(p) if p.shards == shards => (p.clone(), true),
        Some(p) => {
            println!(
                "stored {} profile was calibrated for {} shard(s), serving with {}: re-probing",
                platform.token(),
                p.shards,
                shards
            );
            (calibrate(platform, shards), false)
        }
        None => (calibrate(platform, shards), false),
    };
    println!(
        "{} calibration for {}: threshold {}, flush {}, {:.1} M numbers/s ({})",
        if warm { "warm-start" } else { "probe" },
        platform.token(),
        profile.params.threshold,
        profile.params.flush_requests,
        profile.mnum_per_s,
        profile.source
    );

    let mut cfg = PoolConfig::new(platform, 0x5EED, shards);
    cfg.policy = profile.params.policy();
    cfg.max_requests = profile.params.flush_requests;
    cfg.max_batch = profile.params.max_batch;
    cfg.adaptive = true;
    // Flags enable the executor; the tuner then hill-climbs tile size
    // and team width alongside the dispatch knobs. Without flags the
    // profile's stored executor shape (serial in pre-tiling profiles)
    // carries over via the initial TuningParams.
    cfg.tiling = tiling.or({
        if profile.params.tile_size > 0 {
            Some((profile.params.tile_size, profile.params.team_width))
        } else {
            None
        }
    });
    cfg.trace = trace_config(opts);
    let pool = ServicePool::spawn(cfg);
    let mut tuner = PoolAutoTuner::new(&pool);

    for window in 0..windows {
        // Deterministic mixed-size demo traffic (log-uniform 2^6..2^14).
        let mut g = Gen::new(0xD3_0000 + window as u64);
        let receivers: Vec<_> = (0..n_req.max(1))
            .map(|_| {
                let base = 1usize << g.usize_in(6, 13);
                pool.generate(base + g.usize_in(0, base - 1), (0.0, 1.0))
            })
            .collect();
        pool.flush();
        for rx in receivers {
            rx.recv()??;
        }
        let params = tuner.step(&pool);
        let (_, best_tput) = tuner.tuner().best();
        let executor = if params.tile_size > 0 {
            format!(", tile {} x{}", params.tile_size, params.team_width)
        } else {
            String::new()
        };
        println!(
            "window {window:>2}: threshold {:>9}, flush {:>3}{executor} | best so far {:.1} M/s{}",
            params.threshold,
            params.flush_requests,
            best_tput / 1e6,
            if tuner.tuner().converged() { " [holding optimum]" } else { "" }
        );
    }

    let snap = pool.telemetry().snapshot();
    println!(
        "served {} requests / {} numbers, {} launches, {} retunes, {} overflow-routed",
        snap.total_requests(),
        snap.total_delivered(),
        snap.total_launches(),
        snap.retunes,
        snap.dispatched_overflow
    );

    // Persisting knobs fit to this serve session's traffic is opt-in:
    // it REPLACES the platform's stored calibration, which may have come
    // from a probe or a production run.
    if opts.contains_key("save-profile") {
        if let Some(path) = profile_path {
            let (best, best_tput) = tuner.tuner().best();
            store.put(portarng::autotune::CalibrationProfile {
                platform,
                shards,
                params: best,
                mnum_per_s: best_tput / 1e6,
                source: "autotune".into(),
            });
            store.save(path)?;
            println!("[wrote calibration profile to {}]", path.display());
        }
    }
    let tracer = pool.tracer();
    pool.shutdown()?;
    if let Some(tr) = tracer {
        export_trace(opts, &tr.snapshot())?;
    }
    Ok(())
}

fn cmd_calibrate(opts: &HashMap<String, String>) -> CliResult {
    let platform = PlatformId::parse(need(opts, "platform")?)
        .ok_or("unknown platform; see `portarng platforms`")?;
    let shards: usize = opts.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let profile = calibrate(platform, shards);
    println!(
        "calibrated {} ({} batched shards):\n  \
         threshold {} (requests at/above overflow to the device lane)\n  \
         flush {} requests per batch\n  \
         {:.1} M numbers/s on the virtual clock",
        platform.token(),
        shards,
        profile.params.threshold,
        profile.params.flush_requests,
        profile.mnum_per_s
    );
    if let Some(path) = opts.get("profile") {
        let path = Path::new(path);
        let mut store = ProfileStore::load(path)?;
        store.put(profile);
        store.save(path)?;
        println!("[wrote calibration profile to {}]", path.display());
    }
    Ok(())
}

/// `lint-dag`: run burner-shaped workloads over every platform spec, drain
/// the recorded command DAGs, and hand each window to the hazard analyzer
/// (DESIGN.md S14). Structural validation (`Dag::validate`) and the
/// memory-hazard proof both have to pass on every platform; any diagnostic
/// fails the command — this is the CI gate behind the `lint-dag` job.
fn cmd_lint_dag(opts: &HashMap<String, String>) -> CliResult {
    use portarng::rng::{
        generate_batch_usm, generate_buffer, generate_usm, BatchSlice, Distribution, EngineKind,
    };
    use portarng::sycl::{Buffer, Dag, HazardReport, Queue, SyclRuntimeProfile, UsmArena};

    /// Validate one drained window structurally, then analyze it for
    /// memory hazards.
    fn lint_window(records: &[portarng::sycl::CommandRecord]) -> Result<HazardReport, String> {
        let dag = Dag::new(records);
        dag.validate().map_err(|e| format!("structural validation failed: {e}"))?;
        Ok(dag.analyze_hazards())
    }

    let verbose = opts.contains_key("verbose");
    let n = 4096usize;
    println!(
        "lint-dag: proving recorded command DAGs race-free on {} platforms \
         (debug enforcement: {})",
        PlatformId::ALL.len(),
        if portarng::sycl::Queue::hazard_check_enabled() { "on" } else { "off" }
    );

    let mut failures: Vec<String> = Vec::new();
    for platform in PlatformId::ALL {
        let profile = SyclRuntimeProfile::for_platform(&platform.spec());
        let backend = portarng::burner::native_backend_for(platform);
        // Keep each window's records so a diagnostic can be printed with
        // its offending commands' trace spans (virtual timestamps, lease
        // generations) next to the typed hazard.
        let mut windows: Vec<(&str, HazardReport, Vec<portarng::sycl::CommandRecord>)> =
            Vec::new();

        // 1. Buffer API: accessor-declared accesses, runtime-derived
        //    RAW/WAR/WAW edges (generate -> transform -> D2H readback).
        {
            let queue = Queue::new(platform, profile);
            let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 0x11E7)?;
            let buf = Buffer::<f32>::new(n);
            generate_buffer(&queue, &mut gen, Distribution::uniform(-2.0, 3.0), n, &buf)?;
            let _ = queue.host_read(&buf);
            queue.wait();
            let records = queue.drain_records();
            windows.push(("buffer", lint_window(&records)?, records));
        }

        // 2. USM API: explicit event chains (paper §4.1) — generate ->
        //    range transform -> blocking D2H copy.
        {
            let queue = Queue::new(platform, profile);
            let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 0x11E8)?;
            let usm = queue.malloc_device::<f32>(n);
            let ev =
                generate_usm(&queue, &mut gen, Distribution::uniform(0.5, 2.5), n, &usm, &[])?;
            let _ = queue.usm_to_host(&usm, std::slice::from_ref(&ev));
            queue.wait();
            let records = queue.drain_records();
            windows.push(("usm", lint_window(&records)?, records));
        }

        // 3. Arena serving path: two coalesced flushes through one
        //    recycled launch buffer — cross-generation reuse must be
        //    proved ordered through the lease's pending events (S13/S14).
        {
            let queue = Queue::new(platform, profile);
            let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 0x11E9)?;
            let arena: UsmArena<f32> = UsmArena::new();
            let half = n / 2;
            for flush in 0..2u64 {
                let mut lease = arena.checkout(&queue, n);
                let base = flush * n as u64;
                let members = [
                    BatchSlice {
                        buffer_offset: 0,
                        stream_offset: base,
                        n: half,
                        range: (0.0, 1.0),
                    },
                    BatchSlice {
                        buffer_offset: half,
                        stream_offset: base + half as u64,
                        n: half,
                        range: (-1.0, 1.0),
                    },
                ];
                let deps = lease.deps().to_vec();
                let batch = generate_batch_usm(
                    &queue,
                    gen.as_mut(),
                    &members,
                    n,
                    lease.buffer(),
                    Some(lease.generation()),
                    &deps,
                )?;
                for payload in &batch.payloads {
                    if let Err(e) = payload {
                        return Err(format!("arena flush member failed: {e}").into());
                    }
                }
                lease.set_pending(batch.last_events());
                lease.recycle();
            }
            queue.wait();
            let records = queue.drain_records();
            windows.push(("arena", lint_window(&records)?, records));
        }

        // 4. FastCaloSim event loop (DESIGN.md S17): two single-electron
        //    events' rng / hits / rng:floor / d2h commands with their
        //    declared access sets — the documented rng->hits RAW edge and
        //    the serial deposit chain must be proved, not assumed. The
        //    per-event windows are concatenated so cross-event deposit
        //    edges resolve in-window for structural validation.
        {
            let mut cfg = portarng::fastcalosim::FcsConfig::new(platform, FcsApi::Sycl);
            cfg.keep_windows = true;
            let events = Workload::SingleElectron { events: 2 }.events(7);
            let mut sim = portarng::fastcalosim::Simulator::new(cfg);
            sim.simulate(&events)?;
            sim.finish_source()?;
            let records: Vec<portarng::sycl::CommandRecord> =
                sim.take_windows().into_iter().flatten().collect();
            windows.push(("fastcalosim", lint_window(&records)?, records));
        }

        let commands: usize = windows.iter().map(|(_, r, _)| r.commands).sum();
        let external: usize = windows.iter().map(|(_, r, _)| r.external_deps).sum();
        let diagnostics: usize = windows.iter().map(|(_, r, _)| r.hazards.len()).sum();
        println!(
            "  {:<12} {:>3} command(s) across {} window(s), {} external dep(s): {}",
            platform.token(),
            commands,
            windows.len(),
            external,
            if diagnostics == 0 {
                "clean".to_string()
            } else {
                format!("{diagnostics} DIAGNOSTIC(S)")
            }
        );
        for (label, report, records) in &windows {
            if verbose || !report.is_clean() {
                for line in report.pretty().lines() {
                    println!("    [{label}] {line}");
                }
            }
            if !report.is_clean() {
                // Print each offending command's trace span next to the
                // typed diagnostic: virtual timestamps, command id and
                // lease generation place the hazard on the timeline a
                // `--trace` export of the same run would show.
                for hz in &report.hazards {
                    for cmd_id in [hz.first, hz.second] {
                        let Some(rec) = records.iter().find(|r| r.id == cmd_id) else {
                            continue;
                        };
                        if let Some(span) =
                            portarng::trace::span_for_record(rec, 0, portarng::trace::NONE_ID)
                        {
                            println!("    [{label}]   {}", span.pretty());
                        }
                    }
                }
                failures.push(format!("{}/{label}", platform.token()));
            }
        }
    }

    // 4. Serving pool end-to-end: the per-flush analyzer runs inside the
    //    workers and feeds the telemetry `hazards` block — assert the
    //    aggregated counters stay clean.
    let pool_totals = {
        let cfg = PoolConfig::new(PlatformId::A100, 0x5EED, 2);
        let pool = ServicePool::spawn(cfg);
        let receivers: Vec<_> =
            (0..8).map(|i| pool.generate(512 + 64 * i, (0.0, 1.0))).collect();
        pool.flush();
        for rx in receivers {
            rx.recv()??;
        }
        let snap = pool.telemetry().snapshot();
        pool.shutdown()?;
        snap.hazard_totals()
    };
    println!(
        "  service pool: {} window(s), {} command(s), {} external dep(s): {}",
        pool_totals.windows,
        pool_totals.commands,
        pool_totals.external_deps,
        if pool_totals.clean() {
            "clean".to_string()
        } else {
            format!("{} DIAGNOSTIC(S)", pool_totals.total())
        }
    );
    if !pool_totals.clean() {
        failures.push("pool/telemetry".into());
    }

    if failures.is_empty() {
        println!("lint-dag OK: every recorded DAG proved race-free");
        Ok(())
    } else {
        Err(format!("lint-dag found hazards in: {}", failures.join(", ")).into())
    }
}

fn cmd_check_artifacts() -> CliResult {
    let rt = PjrtRuntime::discover()?;
    println!("manifest: {} artifacts", rt.manifest().artifacts.len());
    for name in rt.manifest().artifacts.keys() {
        print!("  compiling {name} ... ");
        rt.load(name)?;
        println!("ok");
    }
    // Numeric round-trip on the smallest burner artifact.
    let out = rt.run_burner("burner_uniform_4096", [1234, 5678], [0, 0], -2.0, 3.0)?;
    let mut engine = portarng::rng::PhiloxEngine::new((5678u64 << 32) | 1234u64);
    let mut want = vec![0f32; 4096];
    portarng::rng::Engine::fill_uniform_f32(&mut engine, &mut want);
    let max_err = out
        .iter()
        .zip(want.iter().map(|&u| -2.0 + u * 5.0))
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pjrt round-trip max |err| vs rust philox: {max_err:.2e}");
    if max_err > 1e-6 {
        return Err("PJRT output diverges from the Rust Philox reference".into());
    }
    println!("artifacts OK");
    Ok(())
}
