//! Chrome trace-event JSON sink (DESIGN.md S18).
//!
//! Exports a span snapshot as the Trace Event Format consumed by
//! Perfetto and `chrome://tracing`: duration (`ph:"X"`) events on one
//! track per shard (pid 1, "coordinator", [`Clock`](super::Clock)
//! time) plus one track per queue (pid 2, "queues", the SYCL runtime's
//! virtual-clock time for `cmd.*` spans), and async flow arrows
//! (`ph:"s"/"t"/"f"`, id = request id) stitching each request's
//! admit → flush → reply edge across tracks. Surfaced on the CLI as
//! `serve --trace <path>`, `burner --trace <path>` and `fastcalosim
//! --trace <path>`.
//!
//! Events are emitted in [`super::canonical_order`], so exports are
//! deterministic under a virtual clock.

use std::collections::BTreeMap;
use std::path::Path;

use crate::jsonlite::Value;

use super::{canonical_order, Span, SpanKind, NONE_ID};

/// Coordinator-track process id.
pub const PID_COORDINATOR: u64 = 1;
/// Queue-track (virtual-clock `cmd.*`) process id.
pub const PID_QUEUES: u64 = 2;

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn num(v: u64) -> Value {
    Value::Number(v as f64)
}

fn us(ns: u64) -> Value {
    Value::Number(ns as f64 / 1_000.0)
}

fn meta(name: &str, pid: u64, tid: Option<u64>, arg: &str) -> Value {
    let mut pairs = vec![
        ("ph", Value::String("M".into())),
        ("name", Value::String(name.into())),
        ("pid", num(pid)),
        ("args", obj(vec![("name", Value::String(arg.into()))])),
    ];
    if let Some(tid) = tid {
        pairs.push(("tid", num(tid)));
    }
    obj(pairs)
}

fn span_args(s: &Span) -> Value {
    let mut pairs: Vec<(&str, Value)> = Vec::new();
    if s.request_id != NONE_ID {
        pairs.push(("request_id", num(s.request_id)));
    }
    if s.flush_id != NONE_ID {
        pairs.push(("flush_id", num(s.flush_id)));
    }
    match s.kind {
        SpanKind::IngressAdmit => {
            pairs.push(("n", num(s.aux)));
            pairs.push(("overflow", Value::Bool(s.aux2 == 1)));
        }
        SpanKind::BatcherStage => pairs.push(("n", num(s.aux))),
        SpanKind::FlushLaunch => {
            pairs.push(("launch_n", num(s.aux)));
            pairs.push(("members", num(s.aux2)));
        }
        SpanKind::CmdGenerate | SpanKind::CmdTransform | SpanKind::CmdD2h => {
            pairs.push(("cmd", num(s.aux2)));
            if s.aux != NONE_ID {
                pairs.push(("lease_gen", num(s.aux)));
            }
        }
        SpanKind::PipelineOverlap => pairs.push(("overlap_ns", num(s.aux))),
        SpanKind::SupervisorRedispatch => {
            pairs.push(("redispatches", num(s.aux)));
            pairs.push(("retry", Value::Bool(s.aux2 == 1)));
        }
        SpanKind::ReplySend => {
            pairs.push(("attempt", num(s.aux)));
            pairs.push(("error", Value::Bool(s.aux2 == 1)));
        }
    }
    obj(pairs)
}

fn duration_event(s: &Span) -> Value {
    let pid = if s.kind.is_command() { PID_QUEUES } else { PID_COORDINATOR };
    let cat = if s.kind.is_command() { "queue" } else { "coordinator" };
    obj(vec![
        ("ph", Value::String("X".into())),
        ("name", Value::String(s.kind.token().into())),
        ("cat", Value::String(cat.into())),
        ("pid", num(pid)),
        ("tid", num(s.shard as u64)),
        ("ts", us(s.start_ns)),
        ("dur", us(s.end_ns - s.start_ns)),
        ("args", span_args(s)),
    ])
}

fn flow_event(ph: &str, request_id: u64, s: &Span) -> Value {
    let mut pairs = vec![
        ("ph", Value::String(ph.into())),
        ("name", Value::String("request".into())),
        ("cat", Value::String("request".into())),
        ("id", num(request_id)),
        ("pid", num(PID_COORDINATOR)),
        ("tid", num(s.shard as u64)),
        ("ts", us(s.start_ns)),
    ];
    if ph == "f" {
        // Bind the finish arrow to the enclosing slice's start.
        pairs.push(("bp", Value::String("e".into())));
    }
    obj(pairs)
}

/// Build the trace document for a span snapshot. See [`export`] for
/// the file-writing wrapper.
pub fn trace_document(spans: &[Span]) -> Value {
    let mut spans = spans.to_vec();
    canonical_order(&mut spans);

    let mut events: Vec<Value> = Vec::with_capacity(spans.len() * 2 + 8);
    events.push(meta("process_name", PID_COORDINATOR, None, "coordinator"));
    events.push(meta("process_name", PID_QUEUES, None, "queues"));

    // One named track per shard (coordinator time) and per queue
    // (virtual-clock command time), for each shard that appears.
    let mut coord_shards: Vec<u64> = Vec::new();
    let mut queue_shards: Vec<u64> = Vec::new();
    for s in &spans {
        let shards = if s.kind.is_command() { &mut queue_shards } else { &mut coord_shards };
        if !shards.contains(&(s.shard as u64)) {
            shards.push(s.shard as u64);
        }
    }
    coord_shards.sort();
    queue_shards.sort();
    for &t in &coord_shards {
        events.push(meta(
            "thread_name",
            PID_COORDINATOR,
            Some(t),
            &format!("shard {t}"),
        ));
    }
    for &t in &queue_shards {
        events.push(meta("thread_name", PID_QUEUES, Some(t), &format!("queue {t}")));
    }

    for s in &spans {
        events.push(duration_event(s));
    }

    // Async arrows: admit --s--> launch --t--> reply, one flow per
    // request that completed (has a reply span). The reply's flush_id
    // locates the launch step.
    for s in &spans {
        if s.kind != SpanKind::ReplySend || s.request_id == NONE_ID {
            continue;
        }
        let Some(admit) = spans
            .iter()
            .find(|a| a.kind == SpanKind::IngressAdmit && a.request_id == s.request_id)
        else {
            continue;
        };
        events.push(flow_event("s", s.request_id, admit));
        if s.flush_id != NONE_ID {
            if let Some(launch) = spans
                .iter()
                .find(|l| l.kind == SpanKind::FlushLaunch && l.flush_id == s.flush_id && l.shard == s.shard)
            {
                events.push(flow_event("t", s.request_id, launch));
            }
        }
        events.push(flow_event("f", s.request_id, s));
    }

    obj(vec![
        ("traceEvents", Value::Array(events)),
        ("displayTimeUnit", Value::String("ms".into())),
        (
            "otherData",
            obj(vec![("exporter", Value::String("portarng-trace".into()))]),
        ),
    ])
}

/// Export a span snapshot as Chrome trace-event JSON at `path`.
pub fn export(spans: &[Span], path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, trace_document(spans).to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Span> {
        vec![
            Span::range(SpanKind::IngressAdmit, 0, 0, 10).req(5).aux(4096).aux2(0),
            Span::event(SpanKind::BatcherStage, 0, 20).req(5).aux(4096),
            Span::range(SpanKind::FlushLaunch, 0, 30, 90).flush(2).aux(4096).aux2(1),
            Span::range(SpanKind::CmdGenerate, 0, 100, 300).flush(2).aux(1).aux2(7),
            Span::range(SpanKind::CmdD2h, 0, 300, 350).flush(2).aux(1).aux2(8),
            Span::event(SpanKind::ReplySend, 0, 95).req(5).flush(2).aux(0).aux2(0),
        ]
    }

    #[test]
    fn document_has_tracks_events_and_flow_arrows() {
        let doc = trace_document(&sample());
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        let ph = |p: &str| {
            events
                .iter()
                .filter(|e| e.get("ph").unwrap().as_str() == Some(p))
                .count()
        };
        // 2 process names + "shard 0" + "queue 0".
        assert_eq!(ph("M"), 4);
        assert_eq!(ph("X"), 6);
        // One complete flow: s at admit, t at launch, f at reply.
        assert_eq!((ph("s"), ph("t"), ph("f")), (1, 1, 1));
        let shard_track = events.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("M")
                && e.get("args").and_then(|a| a.get("name")).and_then(Value::as_str)
                    == Some("shard 0")
        });
        let queue_track = events.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("M")
                && e.get("args").and_then(|a| a.get("name")).and_then(Value::as_str)
                    == Some("queue 0")
        });
        assert!(shard_track && queue_track);
        // Command spans land on the queue process, coordinator spans on
        // the coordinator process.
        for e in events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")) {
            let name = e.get("name").unwrap().as_str().unwrap();
            let pid = e.get("pid").unwrap().as_usize().unwrap() as u64;
            if name.starts_with("cmd.") {
                assert_eq!(pid, PID_QUEUES);
            } else {
                assert_eq!(pid, PID_COORDINATOR);
            }
        }
    }

    #[test]
    fn export_round_trips_through_the_parser() {
        let doc = trace_document(&sample());
        let text = doc.to_json();
        let back = Value::parse(&text).unwrap();
        assert!(back.get("traceEvents").unwrap().as_array().unwrap().len() >= 10);
    }

    #[test]
    fn orphan_reply_gets_no_flow_arrow() {
        // A reply with no matching admit (e.g. the admit span was
        // overwritten in the ring) must not emit a dangling arrow.
        let spans = vec![Span::event(SpanKind::ReplySend, 1, 5).req(9)];
        let doc = trace_document(&spans);
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        assert!(events.iter().all(|e| {
            !matches!(e.get("ph").unwrap().as_str(), Some("s") | Some("t") | Some("f"))
        }));
    }

    #[test]
    fn timestamps_are_microseconds() {
        let spans = vec![Span::range(SpanKind::FlushLaunch, 0, 1_500, 4_500).flush(0)];
        let doc = trace_document(&spans);
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        let x = events
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .unwrap();
        assert_eq!(x.get("ts").unwrap().as_f64(), Some(1.5));
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(3.0));
    }
}
