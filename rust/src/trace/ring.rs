//! Lock-free, fixed-capacity, overwrite-oldest span ring (DESIGN.md
//! S18).
//!
//! One ring per shard. Writers (the shard worker, and for the
//! coordinator ring the admitting caller and supervisor) claim a slot
//! with a single `fetch_add` and publish through a per-slot seqlock;
//! readers (Chrome export at end of run, the flight recorder at reap
//! time) validate the slot's sequence word around a volatile copy and
//! skip slots that moved mid-read — **a snapshot never contains a torn
//! span**, pinned by the concurrent property test below and in
//! `tests/trace.rs`.
//!
//! Seqlock protocol per slot, for the writer of global index `h`
//! (slot `h % cap`, wrap `w = h / cap`):
//!
//! ```text
//! seq.swap(2w + 1)   // odd: write in progress
//! volatile write span
//! seq.store(2w + 2)  // even: generation w complete
//! ```
//!
//! A reader accepts a slot only if it loads the same even, nonzero
//! sequence value before and after copying the span (with an acquire
//! fence between the copy and the re-check). Each `(slot, wrap)` pair
//! has exactly one writer and a unique completion value `2w + 2`, so a
//! stable sequence word proves the copied bytes belong to that single
//! complete write. `seq == 0` means the slot was never written.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::Span;

struct Slot {
    seq: AtomicU64,
    span: UnsafeCell<Span>,
}

// The UnsafeCell is only ever accessed under the seqlock protocol
// above: writes are exclusive per (slot, wrap), reads are validated
// volatile copies.
unsafe impl Sync for Slot {}

/// The per-shard span ring. See module docs for the concurrency
/// protocol.
pub struct TraceRing {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl TraceRing {
    /// A ring holding the most recent `capacity` spans (min 2).
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(2);
        let slots = (0..capacity)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                span: UnsafeCell::new(Span::default()),
            })
            .collect();
        TraceRing { head: AtomicU64::new(0), slots }
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever pushed.
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Spans lost to overwrite (recorded beyond capacity).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Record a span, overwriting the oldest once full. Never blocks.
    pub fn push(&self, span: Span) {
        let cap = self.slots.len() as u64;
        let h = self.head.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(h % cap) as usize];
        let wrap = h / cap;
        // Odd marks the write in progress; the RMW orders it against
        // readers' acquire loads.
        slot.seq.swap(2 * wrap + 1, Ordering::AcqRel);
        unsafe { std::ptr::write_volatile(slot.span.get(), span) };
        slot.seq.store(2 * wrap + 2, Ordering::Release);
    }

    /// Copy out every valid span (unordered; callers sort by
    /// [`Span::seq`] or [`super::canonical_order`]). In-progress and
    /// torn slots are skipped after a bounded retry, so the result may
    /// momentarily miss a span being overwritten but can never contain
    /// torn bytes.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.slots.len());
        'slots: for slot in self.slots.iter() {
            for _ in 0..4 {
                let before = slot.seq.load(Ordering::Acquire);
                if before == 0 {
                    continue 'slots; // never written
                }
                if before & 1 == 1 {
                    std::hint::spin_loop();
                    continue; // write in progress
                }
                let span = unsafe { std::ptr::read_volatile(slot.span.get()) };
                fence(Ordering::Acquire);
                let after = slot.seq.load(Ordering::Relaxed);
                if before == after {
                    out.push(span);
                    continue 'slots;
                }
                // Overwritten mid-copy; retry against the new value.
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SpanKind, NONE_ID};
    use std::sync::Arc;

    fn probe(i: u64) -> Span {
        // Fields derived from one another so a torn mix of two writes
        // is detectable (see `coherent` below).
        Span::range(SpanKind::BatcherStage, (i % 7) as u32, i * 3, i * 3 + 1)
            .req(i)
            .flush(i ^ 0x5a5a)
            .aux(i.wrapping_mul(0x9e37_79b9))
            .aux2(!i)
    }

    fn coherent(s: &Span) -> bool {
        let i = s.request_id;
        s.shard == (i % 7) as u32
            && s.start_ns == i * 3
            && s.end_ns == i * 3 + 1
            && s.flush_id == (i ^ 0x5a5a)
            && s.aux == i.wrapping_mul(0x9e37_79b9)
            && s.aux2 == !i
    }

    #[test]
    fn fills_then_overwrites_oldest() {
        let r = TraceRing::new(4);
        for i in 0..3u64 {
            r.push(probe(i));
        }
        let mut ids: Vec<u64> = r.snapshot().iter().map(|s| s.request_id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(r.dropped(), 0);
        for i in 3..10u64 {
            r.push(probe(i));
        }
        let mut ids: Vec<u64> = r.snapshot().iter().map(|s| s.request_id).collect();
        ids.sort();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert_eq!(r.recorded(), 10);
        assert_eq!(r.dropped(), 6);
    }

    #[test]
    fn capacity_floor_is_two() {
        let r = TraceRing::new(0);
        assert_eq!(r.capacity(), 2);
        r.push(probe(1));
        assert_eq!(r.snapshot().len(), 1);
    }

    #[test]
    fn empty_ring_snapshots_empty() {
        let r = TraceRing::new(8);
        assert!(r.snapshot().is_empty());
        // The default filler span is never surfaced.
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn concurrent_overwrite_never_tears_a_span() {
        // Small ring, many writers lapping it, readers snapshotting
        // throughout: every span a reader sees must be internally
        // coherent (all fields derived from the same request_id) —
        // the "ring overwrite never tears a span" property.
        let ring = Arc::new(TraceRing::new(8));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let r = ring.clone();
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        r.push(probe(w * 1_000_000 + i));
                    }
                })
            })
            .collect();
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let r = ring.clone();
                std::thread::spawn(move || {
                    let mut seen = 0usize;
                    for _ in 0..2_000 {
                        for s in r.snapshot() {
                            assert!(coherent(&s), "torn span surfaced: {s:?}");
                            seen += 1;
                        }
                    }
                    seen
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        let mut total = 0;
        for r in readers {
            total += r.join().unwrap();
        }
        assert!(total > 0, "readers never observed a span");
        assert_eq!(ring.recorded(), 20_000);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 8);
        assert!(snap.iter().all(coherent));
        assert!(snap.iter().all(|s| s.aux != NONE_ID));
    }
}
