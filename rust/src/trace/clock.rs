//! Trace timestamp sources (DESIGN.md S18).
//!
//! Coordinator-side spans (admit / stage / launch / reply /
//! redispatch) are stamped through this trait so the same record sites
//! serve two regimes: production uses the monotonic [`WallClock`];
//! tests use the driver-advanced [`VirtualClock`] (the autotune-style
//! deterministic clock), which makes trace contents — and therefore
//! flight-recorder dumps — byte-identical across runs of the same
//! seeded chaos plan. `cmd.*` spans bypass this entirely: they carry
//! the queue's virtual-clock `virt_start_ns`/`virt_end_ns`, which are
//! deterministic by construction.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A nanosecond timestamp source. Implementations must be monotone
/// non-decreasing and cheap (called on the request hot path).
pub trait Clock: Send + Sync {
    /// Current time in nanoseconds since the clock's epoch.
    fn now_ns(&self) -> u64;
}

/// Production clock: nanoseconds since the clock was created, from the
/// OS monotonic clock.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// Epoch = now.
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Test clock: time advances only when the driver says so, making every
/// coordinator span timestamp deterministic. Shared across threads
/// (`Arc<VirtualClock>`); reads are relaxed loads.
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    /// Start at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock { now: AtomicU64::new(0) }
    }

    /// Advance by `ns`.
    pub fn advance(&self, ns: u64) {
        self.now.fetch_add(ns, Ordering::Relaxed);
    }

    /// Jump to an absolute time (monotonicity is the driver's problem).
    pub fn set(&self, ns: u64) {
        self.now.store(ns, Ordering::Relaxed);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_moves_only_when_driven() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        assert_eq!(c.now_ns(), 0);
        c.advance(250);
        assert_eq!(c.now_ns(), 250);
        c.set(1_000);
        assert_eq!(c.now_ns(), 1_000);
    }
}
