//! End-to-end request tracing and crash flight recorder (DESIGN.md S18).
//!
//! The telemetry registry (S11) answers *how much* — counters and
//! histograms — but nothing causal: it cannot replay "request N from
//! ingress through batcher, flush DAG and D2H to reply", nor show the
//! last milliseconds before a shard worker died. This module adds the
//! time-ordered record: a lock-free, per-shard ring of typed [`Span`]s
//! stitched by `request_id` / `flush_id`, with two sinks —
//!
//! 1. a Chrome trace-event exporter ([`chrome::export`]) loadable in
//!    Perfetto / `chrome://tracing`, one track per shard plus one per
//!    queue, async arrows for request→flush→reply edges; and
//! 2. a crash **flight recorder**: when the supervisor reaps a dead
//!    worker (injected kill, hazard-enforcement panic, any panic), it
//!    drains that shard's ring into a dump file and counts it in the
//!    telemetry `trace` block (`portarng-telemetry-v7`).
//!
//! Design contracts:
//!
//! * **Near-zero cost when disabled.** Every record site is guarded by a
//!   static atomic ([`enabled`]) plus a thread-local writer
//!   ([`install`] / [`with`]), mirroring [`crate::fault`]'s
//!   install/trip idiom: unconfigured, a record site is one relaxed
//!   atomic load. The pool bench gates this (≤ 5% with tracing on,
//!   noise with it off — `benches/pool_throughput.rs`).
//! * **Lock-free, tear-free recording.** [`TraceRing`] is a
//!   fixed-capacity overwrite-oldest ring of seqlock slots: writers
//!   never block, readers never observe a torn span (they skip slots
//!   whose sequence word moved mid-read).
//! * **Deterministic under test.** Timestamps come through the
//!   [`Clock`] trait — monotonic wall clock in production, a
//!   driver-advanced [`VirtualClock`] in tests — and sinks emit spans in
//!   [`canonical_order`], so the same seeded chaos plan yields
//!   byte-identical flight dumps across runs.
//!
//! Span taxonomy and the join keys against the S14 hazard analyzer's
//! command DAG are documented on [`SpanKind`].

pub mod chrome;
mod clock;
mod ring;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

pub use clock::{Clock, VirtualClock, WallClock};
pub use ring::TraceRing;

use crate::jsonlite::Value;
use crate::sycl::{CommandClass, CommandRecord};

/// Sentinel for "no id" in [`Span::request_id`] / [`Span::flush_id`] /
/// aux fields (serialised as JSON `null`).
pub const NONE_ID: u64 = u64::MAX;

/// Schema tag written into flight-recorder dump files.
pub const FLIGHT_SCHEMA: &str = "portarng-flight-v1";

/// Default per-shard ring capacity (spans).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Typed span taxonomy. Spans are stitched into request chains by
/// `request_id` (assigned at ingress by the in-flight ledger) and
/// `flush_id` (per-shard monotone flush counter): a request's causal
/// chain is `ingress.admit ≤ batcher.stage ≤ flush.launch ≤ cmd.d2h ≤
/// reply.send`, where the request joins its flush through
/// `reply.send.flush_id` and the flush joins the S14 command DAG
/// through the `cmd.*` spans' command ids and lease generations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Request admitted by `ServicePool::generate`: ledger registration
    /// through shard send. `aux` = n, `aux2` = 1 if overflow lane.
    IngressAdmit,
    /// Worker staged the request into its batcher. `aux` = n.
    BatcherStage,
    /// One flush: the single DAG submission covering the staged batch.
    /// `aux` = launch_n (padded), `aux2` = member count.
    FlushLaunch,
    /// A drained `Generate` command record (virtual-clock timestamps).
    /// `aux` = lease generation ([`NONE_ID`] if unleased), `aux2` =
    /// command id — the join key against the hazard analyzer's DAG.
    CmdGenerate,
    /// A drained `Transform` command record (same keys as generate).
    CmdTransform,
    /// A drained `TransferD2H` command record (same keys as generate).
    CmdD2h,
    /// Cross-flush pipelining: this flush's generate overlapped the
    /// previous flush's tail. `aux` = overlap_ns on the virtual clock.
    PipelineOverlap,
    /// Supervisor re-dispatched a ledger entry after reaping a dead
    /// worker or bouncing a transient fault. `aux` = redispatch count
    /// for the request, `aux2` = 1 if the stream offset was re-leased
    /// via retry (attempt bump) rather than respawn.
    SupervisorRedispatch,
    /// Reply sent to the requester. `aux` = attempt, `aux2` = 1 for an
    /// error reply.
    ReplySend,
}

impl SpanKind {
    /// All kinds, canonical (pipeline) order.
    pub const ALL: [SpanKind; 9] = [
        SpanKind::IngressAdmit,
        SpanKind::BatcherStage,
        SpanKind::FlushLaunch,
        SpanKind::CmdGenerate,
        SpanKind::CmdTransform,
        SpanKind::CmdD2h,
        SpanKind::PipelineOverlap,
        SpanKind::SupervisorRedispatch,
        SpanKind::ReplySend,
    ];

    /// Stable dotted token, used by both sinks.
    pub fn token(self) -> &'static str {
        match self {
            SpanKind::IngressAdmit => "ingress.admit",
            SpanKind::BatcherStage => "batcher.stage",
            SpanKind::FlushLaunch => "flush.launch",
            SpanKind::CmdGenerate => "cmd.generate",
            SpanKind::CmdTransform => "cmd.transform",
            SpanKind::CmdD2h => "cmd.d2h",
            SpanKind::PipelineOverlap => "pipeline.overlap",
            SpanKind::SupervisorRedispatch => "supervisor.redispatch",
            SpanKind::ReplySend => "reply.send",
        }
    }

    /// Parse a token back (sink round-trips and tests).
    pub fn parse(token: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.token() == token)
    }

    /// Command-record spans live on the virtual-clock queue timeline;
    /// everything else is coordinator time ([`Clock`]).
    pub fn is_command(self) -> bool {
        matches!(
            self,
            SpanKind::CmdGenerate | SpanKind::CmdTransform | SpanKind::CmdD2h
        )
    }

    fn rank(self) -> usize {
        SpanKind::ALL.iter().position(|&k| k == self).unwrap()
    }
}

/// One recorded span. `Copy` so the seqlock ring can snapshot it with a
/// single volatile read; all fields are plain words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Span type (see [`SpanKind`] for per-kind `aux` meanings).
    pub kind: SpanKind,
    /// Shard (lane) the span belongs to.
    pub shard: u32,
    /// Request id from the in-flight ledger, or [`NONE_ID`].
    pub request_id: u64,
    /// Per-shard flush counter, or [`NONE_ID`].
    pub flush_id: u64,
    /// Start timestamp: [`Clock`] ns for coordinator spans, virtual-clock
    /// ns for `cmd.*` spans.
    pub start_ns: u64,
    /// End timestamp (same timeline as `start_ns`; `== start_ns` for
    /// instant spans).
    pub end_ns: u64,
    /// Kind-specific payload (n / lease generation / overlap / attempt).
    pub aux: u64,
    /// Kind-specific payload (command id / member count / flags).
    pub aux2: u64,
    /// Global admission-order sequence number assigned by the
    /// [`Tracer`]: causally ordered within a request regardless of which
    /// thread recorded the span.
    pub seq: u64,
}

impl Default for Span {
    fn default() -> Self {
        // Filler for unwritten ring slots; never surfaced (readers skip
        // slots whose sequence word is still zero).
        Span {
            kind: SpanKind::IngressAdmit,
            shard: 0,
            request_id: NONE_ID,
            flush_id: NONE_ID,
            start_ns: 0,
            end_ns: 0,
            aux: NONE_ID,
            aux2: NONE_ID,
            seq: NONE_ID,
        }
    }
}

impl Span {
    /// An instant span (`end == start`).
    pub fn event(kind: SpanKind, shard: u32, t_ns: u64) -> Span {
        Span::range(kind, shard, t_ns, t_ns)
    }

    /// A duration span.
    pub fn range(kind: SpanKind, shard: u32, start_ns: u64, end_ns: u64) -> Span {
        Span {
            kind,
            shard,
            request_id: NONE_ID,
            flush_id: NONE_ID,
            start_ns,
            end_ns: end_ns.max(start_ns),
            aux: NONE_ID,
            aux2: NONE_ID,
            seq: 0,
        }
    }

    /// Attach the request id.
    pub fn req(mut self, id: u64) -> Span {
        self.request_id = id;
        self
    }

    /// Attach the flush id.
    pub fn flush(mut self, id: u64) -> Span {
        self.flush_id = id;
        self
    }

    /// Attach the kind-specific `aux` payload.
    pub fn aux(mut self, v: u64) -> Span {
        self.aux = v;
        self
    }

    /// Attach the kind-specific `aux2` payload.
    pub fn aux2(mut self, v: u64) -> Span {
        self.aux2 = v;
        self
    }

    /// JSON shape shared by the flight dump and tests. `NONE_ID` fields
    /// serialise as `null` (u64::MAX is not representable in an f64).
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        let id = |v: u64| {
            if v == NONE_ID {
                Value::Null
            } else {
                Value::Number(v as f64)
            }
        };
        m.insert("kind".into(), Value::String(self.kind.token().into()));
        m.insert("shard".into(), Value::Number(self.shard as f64));
        m.insert("request_id".into(), id(self.request_id));
        m.insert("flush_id".into(), id(self.flush_id));
        m.insert("start_ns".into(), Value::Number(self.start_ns as f64));
        m.insert("end_ns".into(), Value::Number(self.end_ns as f64));
        m.insert("aux".into(), id(self.aux));
        m.insert("aux2".into(), id(self.aux2));
        m.insert("seq".into(), Value::Number(self.seq as f64));
        Value::Object(m)
    }

    /// One-line human rendering (`lint-dag` prints this next to hazard
    /// diagnostics so reports are self-localizing).
    pub fn pretty(&self) -> String {
        let opt = |v: u64| {
            if v == NONE_ID {
                "-".to_string()
            } else {
                v.to_string()
            }
        };
        format!(
            "span {:<14} shard={} req={} flush={} t=[{}..{}]ns aux={} aux2={}",
            self.kind.token(),
            self.shard,
            opt(self.request_id),
            opt(self.flush_id),
            self.start_ns,
            self.end_ns,
            opt(self.aux),
            opt(self.aux2),
        )
    }
}

/// Build the `cmd.*` span for a drained [`CommandRecord`]: virtual-clock
/// timestamps, command id in `aux2`, lease generation (if any access is
/// arena-leased) in `aux` — the join keys against the S14 hazard DAG.
/// Returns `None` for command classes the trace does not track (setup,
/// malloc, H2D).
pub fn span_for_record(rec: &CommandRecord, shard: u32, flush_id: u64) -> Option<Span> {
    let kind = match rec.class {
        CommandClass::Generate => SpanKind::CmdGenerate,
        CommandClass::Transform => SpanKind::CmdTransform,
        CommandClass::TransferD2H => SpanKind::CmdD2h,
        _ => return None,
    };
    let lease = rec
        .accesses
        .iter()
        .find_map(|a| a.generation)
        .unwrap_or(NONE_ID);
    Some(
        Span::range(kind, shard, rec.virt_start_ns, rec.virt_end_ns)
            .flush(flush_id)
            .aux(lease)
            .aux2(rec.id),
    )
}

/// Sort spans into the canonical sink order and renumber `seq`
/// 0..n. Ring insertion order is racy (the admitting caller and the
/// shard worker interleave), but the span *set* under a seeded plan and
/// a [`VirtualClock`] is deterministic — so sinks emit this order and
/// byte-compare across runs. Key: timestamps, then pipeline rank, then
/// ids, so equal-time spans (a never-advanced virtual clock) still
/// order deterministically.
pub fn canonical_order(spans: &mut Vec<Span>) {
    spans.sort_by_key(|s| {
        (
            s.start_ns,
            s.end_ns,
            s.kind.rank(),
            s.shard,
            s.request_id,
            s.flush_id,
            s.aux,
            s.aux2,
        )
    });
    for (i, s) in spans.iter_mut().enumerate() {
        s.seq = i as u64;
    }
}

/// Trace configuration carried on
/// [`PoolConfig`](crate::coordinator::PoolConfig).
#[derive(Clone)]
pub struct TraceConfig {
    /// Per-shard ring capacity in spans (overwrite-oldest beyond it).
    pub capacity: usize,
    /// Directory for flight-recorder dumps; `None` counts dumps in
    /// telemetry without writing files.
    pub flight_dir: Option<PathBuf>,
    /// Timestamp source; `None` means monotonic [`WallClock`].
    pub clock: Option<Arc<dyn Clock>>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            capacity: DEFAULT_RING_CAPACITY,
            flight_dir: None,
            clock: None,
        }
    }
}

impl std::fmt::Debug for TraceConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceConfig")
            .field("capacity", &self.capacity)
            .field("flight_dir", &self.flight_dir)
            .field(
                "clock",
                &if self.clock.is_some() { "custom" } else { "wall" },
            )
            .finish()
    }
}

/// Count of live [`Tracer`]s: the static-atomic half of the disabled
/// fast path. [`with`] returns immediately while this is zero.
static LIVE_TRACERS: AtomicUsize = AtomicUsize::new(0);

/// True while any pool has tracing configured.
pub fn enabled() -> bool {
    LIVE_TRACERS.load(Ordering::Relaxed) > 0
}

thread_local! {
    /// The thread-local half of the disabled fast path (the
    /// [`crate::fault::install`] idiom): worker threads install their
    /// shard's writer at entry; record sites route through [`with`].
    static WRITER: RefCell<Option<ShardWriter>> = const { RefCell::new(None) };
}

/// Install (or clear) this thread's shard writer. Worker threads call
/// this at entry, exactly like `fault::install`.
pub fn install(writer: Option<ShardWriter>) {
    WRITER.with(|w| *w.borrow_mut() = writer);
}

/// Run `f` against this thread's writer, if tracing is enabled and a
/// writer is installed. Disabled cost: one relaxed static load.
pub fn with<F: FnOnce(&ShardWriter)>(f: F) {
    if !enabled() {
        return;
    }
    WRITER.with(|w| {
        if let Some(writer) = &*w.borrow() {
            f(writer);
        }
    });
}

/// A shard worker's handle into the tracer: records into that shard's
/// ring with the shard id pre-bound.
#[derive(Clone)]
pub struct ShardWriter {
    tracer: Arc<Tracer>,
    lane: u32,
}

impl ShardWriter {
    /// Build a writer bound to `lane`.
    pub fn new(tracer: Arc<Tracer>, lane: u32) -> ShardWriter {
        ShardWriter { tracer, lane }
    }

    /// The bound lane.
    pub fn lane(&self) -> u32 {
        self.lane
    }

    /// Current coordinator time.
    pub fn now_ns(&self) -> u64 {
        self.tracer.now_ns()
    }

    /// Claim the next flush id for this lane.
    pub fn next_flush_id(&self) -> u64 {
        self.tracer.next_flush_id(self.lane as usize)
    }

    /// Record `span` into this lane's ring (the span's `shard` field is
    /// forced to the bound lane).
    pub fn record(&self, mut span: Span) {
        span.shard = self.lane;
        self.tracer.record(self.lane as usize, span);
    }
}

/// The per-pool trace recorder: one [`TraceRing`] per worker lane plus
/// one coordinator ring (ingress + supervisor spans), a global
/// admission-order sequence counter, per-lane flush-id counters that
/// survive worker respawns, and the flight-recorder sink.
pub struct Tracer {
    rings: Vec<Arc<TraceRing>>,
    clock: Arc<dyn Clock>,
    seq: AtomicU64,
    flush_ids: Vec<AtomicU64>,
    dump_seq: AtomicU64,
    flight_dir: Option<PathBuf>,
    flight_dumps: AtomicU64,
}

impl Tracer {
    /// Build a tracer for a pool with `lanes` worker lanes (batched
    /// shards + overflow lane). Ring `lanes` is the coordinator ring.
    pub fn new(lanes: usize, cfg: &TraceConfig) -> Arc<Tracer> {
        let capacity = cfg.capacity.max(2);
        let rings = (0..=lanes)
            .map(|_| Arc::new(TraceRing::new(capacity)))
            .collect();
        let flush_ids = (0..lanes).map(|_| AtomicU64::new(0)).collect();
        let clock = cfg
            .clock
            .clone()
            .unwrap_or_else(|| Arc::new(WallClock::new()) as Arc<dyn Clock>);
        LIVE_TRACERS.fetch_add(1, Ordering::Relaxed);
        Arc::new(Tracer {
            rings,
            clock,
            seq: AtomicU64::new(0),
            flush_ids,
            dump_seq: AtomicU64::new(0),
            flight_dir: cfg.flight_dir.clone(),
            flight_dumps: AtomicU64::new(0),
        })
    }

    /// Worker lanes (excluding the coordinator ring).
    pub fn lanes(&self) -> usize {
        self.rings.len() - 1
    }

    /// Current coordinator time from the configured [`Clock`].
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Claim the next flush id for `lane` (monotone across respawns —
    /// the counter lives here, not in the worker).
    pub fn next_flush_id(&self, lane: usize) -> u64 {
        self.flush_ids[lane].fetch_add(1, Ordering::Relaxed)
    }

    /// Record into lane `ring_idx`'s ring, assigning the global
    /// admission-order `seq`.
    pub fn record(&self, ring_idx: usize, mut span: Span) {
        span.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.rings[ring_idx].push(span);
    }

    /// Record a coordinator-side span (ingress admit, supervisor
    /// redispatch) into the coordinator ring; `span.shard` still names
    /// the worker lane the event concerns.
    pub fn record_coord(&self, span: Span) {
        let idx = self.rings.len() - 1;
        self.record(idx, span);
    }

    /// Snapshot every ring, merged in global `seq` order (raw recording
    /// order; sinks re-sort via [`canonical_order`]).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut all: Vec<Span> = self
            .rings
            .iter()
            .flat_map(|r| r.snapshot())
            .collect();
        all.sort_by_key(|s| s.seq);
        all
    }

    /// Snapshot one lane's ring, `seq`-ordered.
    pub fn lane_snapshot(&self, lane: usize) -> Vec<Span> {
        let mut v = self.rings[lane].snapshot();
        v.sort_by_key(|s| s.seq);
        v
    }

    /// Spans recorded so far (including any since overwritten).
    pub fn spans_recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Spans lost to ring overwrite.
    pub fn spans_dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum()
    }

    /// Flight dumps taken.
    pub fn flight_dumps(&self) -> u64 {
        self.flight_dumps.load(Ordering::Relaxed)
    }

    /// Flight-record `lane`: drain its ring into a canonical-order dump.
    /// Called by the supervisor when it reaps a dead worker. Returns the
    /// dump file path when a flight directory is configured (the dump is
    /// always counted, file or not). Dump contents are deterministic
    /// under a [`VirtualClock`] and a seeded plan: spans are emitted in
    /// [`canonical_order`] and the file carries no wall-clock state.
    pub fn flight_dump(&self, lane: usize) -> Option<PathBuf> {
        let n = self.dump_seq.fetch_add(1, Ordering::Relaxed);
        self.flight_dumps.fetch_add(1, Ordering::Relaxed);
        let mut spans = self.rings[lane].snapshot();
        canonical_order(&mut spans);
        let dir = self.flight_dir.as_ref()?;
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Value::String(FLIGHT_SCHEMA.into()));
        m.insert("shard".into(), Value::Number(lane as f64));
        m.insert("dump".into(), Value::Number(n as f64));
        m.insert(
            "dumped_at_ns".into(),
            Value::Number(self.now_ns() as f64),
        );
        m.insert(
            "spans".into(),
            Value::Array(spans.iter().map(Span::to_value).collect()),
        );
        let path = dir.join(format!("flight-shard{lane}-{n}.json"));
        let _ = std::fs::create_dir_all(dir);
        std::fs::write(&path, Value::Object(m).to_json()).ok()?;
        Some(path)
    }
}

impl Drop for Tracer {
    fn drop(&mut self) {
        LIVE_TRACERS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Parse a flight dump back into spans (tests and tooling).
pub fn parse_flight_dump(text: &str) -> crate::Result<(usize, Vec<Span>)> {
    let v = Value::parse(text)?;
    let bad = |m: &str| crate::Error::Json(format!("flight dump: {m}"));
    match v.get("schema").and_then(Value::as_str) {
        Some(FLIGHT_SCHEMA) => {}
        other => return Err(bad(&format!("schema {other:?}"))),
    }
    let shard = v
        .get("shard")
        .and_then(Value::as_usize)
        .ok_or_else(|| bad("missing shard"))?;
    let spans = v
        .get("spans")
        .and_then(Value::as_array)
        .ok_or_else(|| bad("missing spans"))?
        .iter()
        .map(|s| span_from_value(s).ok_or_else(|| bad("bad span")))
        .collect::<crate::Result<Vec<_>>>()?;
    Ok((shard, spans))
}

fn span_from_value(v: &Value) -> Option<Span> {
    let num = |key: &str| v.get(key).and_then(Value::as_f64).map(|f| f as u64);
    let id = |key: &str| match v.get(key) {
        Some(Value::Null) | None => Some(NONE_ID),
        Some(x) => x.as_f64().map(|f| f as u64),
    };
    Some(Span {
        kind: SpanKind::parse(v.get("kind")?.as_str()?)?,
        shard: num("shard")? as u32,
        request_id: id("request_id")?,
        flush_id: id("flush_id")?,
        start_ns: num("start_ns")?,
        end_ns: num("end_ns")?,
        aux: id("aux")?,
        aux2: id("aux2")?,
        seq: num("seq")?,
    })
}

/// Read every flight dump in `dir` (sorted by file name).
pub fn read_flight_dumps(dir: &Path) -> Vec<(PathBuf, usize, Vec<Span>)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("flight-") && n.ends_with(".json"))
        })
        .collect();
    paths.sort();
    for p in paths {
        if let Ok(text) = std::fs::read_to_string(&p) {
            if let Ok((shard, spans)) = parse_flight_dump(&text) {
                out.push((p, shard, spans));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sycl::Access;

    #[test]
    fn span_kind_tokens_round_trip() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::parse(k.token()), Some(k));
        }
        assert_eq!(SpanKind::parse("nope"), None);
    }

    #[test]
    fn span_json_round_trips_including_none_ids() {
        let s = Span::range(SpanKind::FlushLaunch, 3, 10, 25)
            .flush(7)
            .aux(4096)
            .aux2(2);
        let v = s.to_value();
        assert_eq!(v.get("request_id"), Some(&Value::Null));
        let mut back = span_from_value(&v).unwrap();
        back.seq = s.seq;
        assert_eq!(back, s);
    }

    #[test]
    fn span_for_record_extracts_lease_generation() {
        let rec = CommandRecord {
            id: 42,
            name: "generate".into(),
            class: CommandClass::Generate,
            dep_ids: vec![],
            virt_start_ns: 100,
            virt_end_ns: 300,
            wall_ns: 0,
            tpb: 0,
            occupancy: 0.0,
            accesses: vec![Access::usm_leased(
                9,
                crate::sycl::AccessMode::Write,
                Some(5),
            )],
        };
        let s = span_for_record(&rec, 2, 11).unwrap();
        assert_eq!(s.kind, SpanKind::CmdGenerate);
        assert_eq!((s.start_ns, s.end_ns), (100, 300));
        assert_eq!(s.aux, 5);
        assert_eq!(s.aux2, 42);
        assert_eq!(s.flush_id, 11);
        // Setup-class records do not produce spans.
        let setup = CommandRecord {
            class: CommandClass::Setup,
            ..rec
        };
        assert!(span_for_record(&setup, 2, 11).is_none());
    }

    #[test]
    fn canonical_order_is_deterministic_under_equal_timestamps() {
        // All-zero timestamps (a never-advanced virtual clock): order
        // must still be fully determined by kind/ids.
        let a = Span::event(SpanKind::ReplySend, 0, 0).req(1);
        let b = Span::event(SpanKind::IngressAdmit, 0, 0).req(1);
        let c = Span::event(SpanKind::IngressAdmit, 0, 0).req(0);
        let mut one = vec![a, b, c];
        let mut two = vec![c, a, b];
        canonical_order(&mut one);
        canonical_order(&mut two);
        assert_eq!(one, two);
        assert_eq!(one[0].request_id, 0);
        assert_eq!(one[0].seq, 0);
        assert_eq!(one[2].kind, SpanKind::ReplySend);
    }

    #[test]
    fn tracer_counts_and_flight_dump_shape() {
        let cfg = TraceConfig {
            capacity: 8,
            flight_dir: None,
            clock: Some(Arc::new(VirtualClock::new()) as Arc<dyn Clock>),
        };
        let t = Tracer::new(2, &cfg);
        assert!(enabled());
        assert_eq!(t.lanes(), 2);
        t.record(0, Span::event(SpanKind::BatcherStage, 0, 0).req(1));
        t.record_coord(Span::event(SpanKind::IngressAdmit, 0, 0).req(1));
        assert_eq!(t.spans_recorded(), 2);
        assert_eq!(t.snapshot().len(), 2);
        assert_eq!(t.lane_snapshot(0).len(), 1);
        assert_eq!(t.next_flush_id(1), 0);
        assert_eq!(t.next_flush_id(1), 1);
        // No flight dir: counted, no file.
        assert!(t.flight_dump(0).is_none());
        assert_eq!(t.flight_dumps(), 1);
    }

    #[test]
    fn live_tracer_gate_closes_on_drop() {
        let before = enabled();
        {
            let _t = Tracer::new(1, &TraceConfig::default());
            assert!(enabled());
        }
        // Other tests may hold tracers concurrently; only assert the
        // gate closes when no tracer existed before.
        if !before {
            assert!(!enabled());
        }
    }

    #[test]
    fn thread_local_writer_routes_to_lane_ring() {
        let t = Tracer::new(1, &TraceConfig::default());
        install(Some(ShardWriter::new(t.clone(), 0)));
        with(|w| {
            let now = w.now_ns();
            w.record(Span::event(SpanKind::ReplySend, 99, now).req(7));
        });
        install(None);
        let spans = t.lane_snapshot(0);
        assert_eq!(spans.len(), 1);
        // The writer forces the shard field to its bound lane.
        assert_eq!(spans[0].shard, 0);
        assert_eq!(spans[0].request_id, 7);
        // After uninstall, record sites are inert.
        with(|_| panic!("writer should be uninstalled"));
    }
}
