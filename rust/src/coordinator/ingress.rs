//! Ingress gate and in-flight ledger for the service pool (DESIGN.md S15).
//!
//! Every request accepted by [`super::ServicePool::generate`] is recorded
//! in an [`InflightTable`] entry *before* it is handed to a shard: the
//! entry carries the request's global stream offset and a clone of the
//! caller's reply sender. That ledger is what makes the pool supervisable
//! — a dead worker takes its queued `ServiceRequest`s down with it, but
//! the table still knows everything needed to re-dispatch them
//! bit-identically (the offset addresses the stream; the cloned sender
//! keeps the caller's receiver open no matter how many workers die).
//!
//! [`IngressConfig`] bounds the admission side: queue depth (typed
//! shedding with [`Error::Overloaded`]), per-request deadline budgets
//! ([`Error::DeadlineExceeded`]) and the bounded-exponential retry policy
//! the supervisor applies to transient injected faults.
//!
//! [`Error::Overloaded`]: crate::error::Error::Overloaded
//! [`Error::DeadlineExceeded`]: crate::error::Error::DeadlineExceeded

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;

use super::heuristic::{Route, TuningHandle};
use super::pool::ServiceRequest;

/// Admission and retry policy for a pool's ingress gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IngressConfig {
    /// Shed (reply [`Error::Overloaded`]) when this many requests are
    /// already in flight. Default: unbounded.
    ///
    /// [`Error::Overloaded`]: crate::error::Error::Overloaded
    pub max_inflight: usize,
    /// Wall-clock budget per request, checked at worker dequeue and at
    /// supervisor redispatch. Default: none.
    pub deadline: Option<Duration>,
    /// Retry re-dispatches allowed per request for transient faults
    /// before the caller gets the fault as a typed error.
    pub max_retries: u32,
    /// First-retry backoff; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling (bounded exponential).
    pub backoff_cap: Duration,
    /// Hard ceiling on total re-dispatches (retry bumps *plus*
    /// post-respawn redeliveries) any single request may accumulate —
    /// asserted in [`InflightTable::reissue`]. A request crossing it
    /// means the supervisor is looping; the assertion turns that
    /// livelock into a loud failure. Sized well above `max_retries` so
    /// legitimate chaos-soak respawn storms never trip it.
    pub redispatch_cap: u32,
}

impl Default for IngressConfig {
    fn default() -> IngressConfig {
        IngressConfig {
            max_inflight: usize::MAX,
            deadline: None,
            max_retries: 4,
            backoff_base: Duration::from_micros(50),
            backoff_cap: Duration::from_millis(2),
            redispatch_cap: 64,
        }
    }
}

impl IngressConfig {
    /// Backoff before retry number `attempt` (1-based):
    /// `base * 2^(attempt-1)`, capped.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(16);
        (self.backoff_base * 2u32.pow(shift)).min(self.backoff_cap)
    }
}

/// One accepted, not-yet-answered request.
pub(crate) struct Inflight {
    pub(crate) n: usize,
    pub(crate) range: (f32, f32),
    /// Absolute offset in the global engine stream — assigned once at
    /// admission; every re-dispatch reuses it, which is the whole
    /// bit-identical-retry argument.
    pub(crate) offset: u64,
    /// Shard currently responsible for the entry.
    pub(crate) shard: usize,
    /// Retry re-dispatches performed so far.
    pub(crate) attempts: u32,
    /// Total re-dispatches of any kind (retry bumps + post-respawn
    /// redeliveries) — the trace's `supervisor.redispatch` span payload
    /// and the quantity the `redispatch_cap` assertion bounds.
    pub(crate) redispatches: u32,
    pub(crate) deadline: Option<Instant>,
    /// Clone of the caller's reply sender. The caller's receiver stays
    /// open as long as this entry lives, even when the worker holding the
    /// other clone dies.
    pub(crate) reply: mpsc::Sender<Result<Vec<f32>>>,
}

/// The pool's in-flight ledger. Entries are added at admission, removed
/// when a reply is sent, and re-issued (same offset, fresh message) by the
/// supervisor after a worker death or a transient fault.
pub(crate) struct InflightTable {
    entries: Mutex<HashMap<u64, Inflight>>,
    /// Monotone id source: the id returned by [`register`] is the
    /// pool-global `request_id` every trace span for the request
    /// carries, and [`reissue`] reuses it — retried work stays
    /// attributable to the original request.
    ///
    /// [`register`]: InflightTable::register
    /// [`reissue`]: InflightTable::reissue
    next_id: AtomicU64,
    redispatch_cap: u32,
}

impl InflightTable {
    pub(crate) fn new(redispatch_cap: u32) -> Arc<InflightTable> {
        Arc::new(InflightTable {
            entries: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            redispatch_cap,
        })
    }

    /// Live entries (the ingress depth the shed gate compares against).
    pub(crate) fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Admit a request; returns its pool-global id.
    pub(crate) fn register(
        &self,
        n: usize,
        range: (f32, f32),
        offset: u64,
        shard: usize,
        deadline: Option<Instant>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    ) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.entries.lock().unwrap().insert(
            id,
            Inflight {
                n,
                range,
                offset,
                shard,
                attempts: 0,
                redispatches: 0,
                deadline,
                reply,
            },
        );
        id
    }

    /// Remove a completed entry (a reply was sent). Idempotent: a worker
    /// that died between send and complete leaves the entry to the
    /// supervisor, whose re-dispatch produces a second, bit-identical
    /// reply — benign, because the caller reads exactly one.
    pub(crate) fn complete(&self, id: u64) {
        self.entries.lock().unwrap().remove(&id);
    }

    /// Remove and return an entry for a terminal (error) reply.
    pub(crate) fn take(&self, id: u64) -> Option<Inflight> {
        self.entries.lock().unwrap().remove(&id)
    }

    /// Peek the retry-relevant fields: (attempts so far, deadline, n).
    pub(crate) fn retry_info(&self, id: u64) -> Option<(u32, Option<Instant>, usize)> {
        let entries = self.entries.lock().unwrap();
        entries.get(&id).map(|e| (e.attempts, e.deadline, e.n))
    }

    /// Rebuild the wire request for a live entry, reassigning it to
    /// `shard` (and bumping its attempt count when `bump` — supervisor
    /// retries bump; post-respawn redispatches of untouched entries do
    /// not). The offset — and the id itself — are the ones assigned at
    /// admission, so the re-dispatch stays attributable to the original
    /// request. Returns the request plus its total redispatch count;
    /// asserts the count against the configured per-request cap.
    pub(crate) fn reissue(
        &self,
        id: u64,
        shard: usize,
        bump: bool,
    ) -> Option<(ServiceRequest, u32)> {
        let mut entries = self.entries.lock().unwrap();
        let e = entries.get_mut(&id)?;
        if bump {
            e.attempts += 1;
        }
        e.redispatches += 1;
        assert!(
            e.redispatches <= self.redispatch_cap,
            "request {id} redispatched {} times (cap {}): supervisor livelock",
            e.redispatches,
            self.redispatch_cap,
        );
        e.shard = shard;
        let req = ServiceRequest {
            id,
            n: e.n,
            range: e.range,
            offset: e.offset,
            deadline: e.deadline,
            attempt: e.attempts,
            reply: e.reply.clone(),
        };
        Some((req, e.redispatches))
    }

    /// Ids of every live entry assigned to `shard` (ascending, so
    /// redispatch order is deterministic).
    pub(crate) fn assigned_to(&self, shard: usize) -> Vec<u64> {
        let entries = self.entries.lock().unwrap();
        let mut ids: Vec<u64> =
            entries.iter().filter(|(_, e)| e.shard == shard).map(|(&id, _)| id).collect();
        ids.sort_unstable();
        ids
    }

    /// Drain every live entry (terminal shutdown sweep).
    pub(crate) fn drain_all(&self) -> Vec<Inflight> {
        self.entries.lock().unwrap().drain().map(|(_, e)| e).collect()
    }
}

/// The dispatcher's routing state, shared between the pool handle (fresh
/// admissions) and the supervisor (retry re-dispatches): size-aware
/// overflow routing through the live [`TuningHandle`] plus the
/// round-robin cursor over batched shards.
pub(crate) struct Router {
    n_batched: usize,
    overflow: Option<usize>,
    tuning: Arc<TuningHandle>,
    next: AtomicUsize,
}

impl Router {
    pub(crate) fn new(
        n_batched: usize,
        overflow: Option<usize>,
        tuning: Arc<TuningHandle>,
    ) -> Arc<Router> {
        Arc::new(Router { n_batched, overflow, tuning, next: AtomicUsize::new(0) })
    }

    /// Pick the shard for an `n`-number request; the bool is true when the
    /// overflow lane took it.
    pub(crate) fn route(&self, n: usize) -> (usize, bool) {
        match (self.overflow, self.tuning.policy().route(n)) {
            (Some(ov), Route::Overflow) => (ov, true),
            _ => (self.next.fetch_add(1, Ordering::Relaxed) % self.n_batched, false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_exponential() {
        let cfg = IngressConfig {
            backoff_base: Duration::from_micros(100),
            backoff_cap: Duration::from_micros(650),
            ..IngressConfig::default()
        };
        assert_eq!(cfg.backoff(1), Duration::from_micros(100));
        assert_eq!(cfg.backoff(2), Duration::from_micros(200));
        assert_eq!(cfg.backoff(3), Duration::from_micros(400));
        assert_eq!(cfg.backoff(4), Duration::from_micros(650)); // capped
        assert_eq!(cfg.backoff(40), Duration::from_micros(650)); // shift clamped
        assert_eq!(cfg.backoff(0), Duration::from_micros(100)); // defensive
    }

    #[test]
    fn ledger_register_reissue_complete() {
        let table = InflightTable::new(64);
        let (tx, rx) = mpsc::channel();
        let id = table.register(64, (0.0, 1.0), 1000, 2, None, tx);
        assert_eq!(table.len(), 1);
        assert_eq!(table.retry_info(id), Some((0, None, 64)));
        assert_eq!(table.assigned_to(2), vec![id]);
        assert!(table.assigned_to(0).is_empty());

        // A bumping reissue moves the entry and increments attempts, but
        // keeps the admission-time offset — and the admission-time id.
        let (req, redispatches) = table.reissue(id, 0, true).unwrap();
        assert_eq!((req.id, req.offset, req.attempt), (id, 1000, 1));
        assert_eq!(redispatches, 1);
        assert_eq!(table.retry_info(id), Some((1, None, 64)));
        assert_eq!(table.assigned_to(0), vec![id]);

        // A non-bumping (post-respawn) reissue keeps attempts but still
        // counts as a redispatch.
        let (req2, redispatches) = table.reissue(id, 1, false).unwrap();
        assert_eq!((req2.id, req2.attempt), (id, 1));
        assert_eq!(redispatches, 2);

        // The reissued sender reaches the caller's receiver.
        req.reply.send(Ok(vec![1.0])).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![1.0]);

        table.complete(id);
        assert_eq!(table.len(), 0);
        assert!(table.reissue(id, 0, true).is_none());
        table.complete(id); // idempotent
    }

    #[test]
    fn redispatch_order_is_deterministic() {
        let table = InflightTable::new(64);
        let mut ids = Vec::new();
        for i in 0..5 {
            let (tx, _rx) = mpsc::channel();
            ids.push(table.register(8, (0.0, 1.0), i * 8, 1, None, tx));
        }
        assert_eq!(table.assigned_to(1), ids); // ascending admission order
        assert_eq!(table.drain_all().len(), 5);
        assert_eq!(table.len(), 0);
    }

    #[test]
    #[should_panic(expected = "supervisor livelock")]
    fn redispatch_cap_assertion_fires_on_livelock() {
        let table = InflightTable::new(3);
        let (tx, _rx) = mpsc::channel();
        let id = table.register(8, (0.0, 1.0), 0, 0, None, tx);
        for _ in 0..4 {
            table.reissue(id, 0, false);
        }
    }
}
