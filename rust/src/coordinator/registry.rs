//! Backend registry: (platform, api) -> vendor backend factory.

use crate::backends::{
    CurandBackend, HiprandBackend, MklCpuBackend, OneMklIntelGpuBackend, PjrtBackend, RngBackend,
};
use crate::error::{Error, Result};
use crate::platform::{PlatformId, PlatformKind};
use crate::runtime::PjrtRuntime;
use std::sync::Arc;

/// Creates vendor backends on demand. Backends are not `Send` (the PJRT
/// client is `Rc`-based), so each worker thread builds its own from a
/// shared registry description.
pub struct BackendRegistry {
    pjrt: Option<Arc<PjrtRuntime>>,
}

impl BackendRegistry {
    /// Registry without the real-compute backend.
    pub fn new() -> Self {
        BackendRegistry { pjrt: None }
    }

    /// Registry with the PJRT artifact runtime attached.
    pub fn with_pjrt(runtime: Arc<PjrtRuntime>) -> Self {
        BackendRegistry { pjrt: Some(runtime) }
    }

    /// Whether real-compute dispatch is available.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt.is_some()
    }

    /// The native vendor backend for a platform (what the paper's oneMKL
    /// interop layer glues in on that machine).
    pub fn native_for(&self, platform: PlatformId) -> Box<dyn RngBackend> {
        match platform {
            PlatformId::A100 => Box::new(CurandBackend::new()),
            PlatformId::Vega56 => Box::new(HiprandBackend::new()),
            PlatformId::Uhd630 => Box::new(OneMklIntelGpuBackend::new()),
            p => Box::new(MklCpuBackend::new(p)),
        }
    }

    /// The real-compute backend (AOT Pallas kernel via PJRT).
    pub fn pjrt_backend(&self) -> Result<Box<dyn RngBackend>> {
        let rt = self
            .pjrt
            .clone()
            .ok_or_else(|| Error::Coordinator("no PJRT runtime registered".into()))?;
        Ok(Box::new(PjrtBackend::new(rt)?))
    }

    /// The host CPU paired with a device platform (Table 1's machine
    /// pairings) — the platform the batched lanes and the heuristic's
    /// host side run on. CPU platforms are their own host.
    pub fn host_platform(platform: PlatformId) -> PlatformId {
        match platform {
            PlatformId::A100 => PlatformId::Rome7742, // DGX host
            PlatformId::Vega56 => PlatformId::XeonGold5220,
            PlatformId::Uhd630 => PlatformId::CoreI7_10875H,
            p => p,
        }
    }

    /// The host-fallback backend paired with a device platform (for the
    /// heuristic selector): the device's host CPU.
    pub fn host_for(&self, platform: PlatformId) -> Box<dyn RngBackend> {
        Box::new(MklCpuBackend::new(Self::host_platform(platform)))
    }

    /// The backend set one pool shard owns: the platform's native backend
    /// plus its paired host fallback. Backends are not `Send`, so each
    /// worker thread calls this from inside the thread (the coordinator
    /// gives each worker its own set).
    pub fn shard_set(&self, platform: PlatformId) -> ShardBackendSet {
        ShardBackendSet {
            native: self.native_for(platform),
            host: self.host_for(platform),
        }
    }

    /// All platforms whose class matches `kind`.
    pub fn platforms(kind: Option<PlatformKind>) -> Vec<PlatformId> {
        PlatformId::ALL
            .into_iter()
            .filter(|p| kind.is_none_or(|k| p.spec().kind == k))
            .collect()
    }
}

impl Default for BackendRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// The per-worker backend set a pool shard owns (see
/// [`BackendRegistry::shard_set`]). The pool's lane picks the generating
/// half: batched small-request lanes use `host`, the overflow lane uses
/// `native` — the §8 heuristic applied at the service layer.
pub struct ShardBackendSet {
    /// The platform's native vendor backend (overflow/device lane).
    pub native: Box<dyn RngBackend>,
    /// The paired host-CPU backend (batched small-request lanes).
    pub host: Box<dyn RngBackend>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_mapping_matches_table1() {
        let reg = BackendRegistry::new();
        assert_eq!(reg.native_for(PlatformId::A100).name(), "cuRAND");
        assert_eq!(reg.native_for(PlatformId::Vega56).name(), "hipRAND");
        assert_eq!(reg.native_for(PlatformId::Uhd630).name(), "oneMKL-iGPU");
        assert_eq!(reg.native_for(PlatformId::Rome7742).name(), "oneMKL-x86");
    }

    #[test]
    fn host_pairing() {
        let reg = BackendRegistry::new();
        assert_eq!(reg.host_for(PlatformId::A100).platform(), PlatformId::Rome7742);
        assert_eq!(reg.host_for(PlatformId::Vega56).platform(), PlatformId::XeonGold5220);
        // CPU platforms are their own host.
        assert_eq!(reg.host_for(PlatformId::Rome7742).platform(), PlatformId::Rome7742);
    }

    #[test]
    fn pjrt_requires_registration() {
        let reg = BackendRegistry::new();
        assert!(!reg.has_pjrt());
        assert!(reg.pjrt_backend().is_err());
    }

    #[test]
    fn shard_set_pairs_native_with_host() {
        let reg = BackendRegistry::new();
        let set = reg.shard_set(PlatformId::A100);
        assert_eq!(set.native.name(), "cuRAND");
        assert_eq!(set.host.platform(), PlatformId::Rome7742);
        // CPU platforms: native generation, host == itself.
        let cpu = reg.shard_set(PlatformId::Rome7742);
        assert!(!cpu.native.is_device());
        assert_eq!(cpu.host.platform(), PlatformId::Rome7742);
    }

    #[test]
    fn platform_filter() {
        let gpus = BackendRegistry::platforms(Some(PlatformKind::DiscreteGpu));
        assert_eq!(gpus.len(), 2);
        assert_eq!(BackendRegistry::platforms(None).len(), 6);
    }
}
