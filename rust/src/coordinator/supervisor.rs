//! Shard supervision: detect dead workers, respawn, re-dispatch
//! bit-identically (DESIGN.md S15).
//!
//! One supervisor thread per pool runs a two-part loop:
//!
//! * **Retry handling** — workers forward transient injected faults here
//!   ([`SupMsg::Retry`]) instead of failing the caller. The supervisor
//!   checks the deadline and retry budgets against the in-flight ledger,
//!   sleeps the bounded-exponential backoff, re-routes the request
//!   through the live dispatch policy and re-issues it (same pool-global
//!   id, same global stream offset) followed by a flush so a lone retry
//!   never strands in a batcher.
//! * **Health sweep** — every loop tick it reaps worker threads that
//!   finished without a shutdown handshake (panic — injected or genuine —
//!   or injected kill), respawns the shard with the same shard id, lane,
//!   seed, telemetry and fault plan but a fresh queue + arena, and
//!   re-dispatches every ledger entry still assigned to that shard by its
//!   recorded offset.
//!
//! Determinism argument, in one line: a request's payload is a pure
//! function of `(pool seed, offset, n, range)` — the ledger preserves all
//! four across any number of deaths and retries, so a re-dispatched reply
//! is bit-identical to the fault-free one. A worker that died *between*
//! sending a reply and completing the ledger entry causes one duplicate
//! reply — benign for the same reason (the caller reads exactly one, and
//! both are identical).
//!
//! Shutdown ordering matters: [`ServicePool::shutdown`] stops the
//! supervisor *first* (draining queued retries with typed errors), then
//! handshakes the workers, then fails any ledger stragglers — so no
//! retry can race a dying pool into a hung caller.
//!
//! [`ServicePool::shutdown`]: super::ServicePool::shutdown

use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::telemetry::TelemetryRegistry;
use crate::trace::{Span, SpanKind, Tracer};

use super::ingress::{InflightTable, IngressConfig, Router};
use super::pool::{Msg, ShardSlot};

/// Messages workers (and the pool) send the supervisor.
pub(crate) enum SupMsg {
    /// A transient injected fault hit request `id`; re-dispatch it after
    /// backoff, or fail it with the site's typed error when budgets are
    /// exhausted.
    Retry {
        /// Pool-global request id (ledger key).
        id: u64,
        /// Injection-site token, for the exhaustion error.
        site: &'static str,
    },
    /// Stop the supervisor loop.
    Shutdown,
}

/// Handle to the supervisor thread.
pub(crate) struct Supervisor {
    tx: mpsc::Sender<SupMsg>,
    worker: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Start supervising `slots`. `tx`/`rx` are the pre-built channel
    /// whose sender clones the slots already hold as their retry path.
    pub(crate) fn spawn(
        slots: Vec<Arc<ShardSlot>>,
        inflight: Arc<InflightTable>,
        registry: Arc<TelemetryRegistry>,
        router: Arc<Router>,
        cfg: IngressConfig,
        tracer: Option<Arc<Tracer>>,
        tx: mpsc::Sender<SupMsg>,
        rx: mpsc::Receiver<SupMsg>,
    ) -> Supervisor {
        let worker = std::thread::spawn(move || {
            let state = State { slots, inflight, registry, router, cfg, tracer };
            loop {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(SupMsg::Retry { id, site }) => state.handle_retry(id, site),
                    Ok(SupMsg::Shutdown) | Err(RecvTimeoutError::Disconnected) => break,
                    Err(RecvTimeoutError::Timeout) => {}
                }
                state.sweep();
                state.publish_trace();
            }
            // The pool is shutting down: answer queued retries with the
            // fault they hit rather than re-dispatching into dying shards
            // — typed errors, never hangs.
            while let Ok(msg) = rx.try_recv() {
                if let SupMsg::Retry { id, site } = msg {
                    if let Some(e) = state.inflight.take(id) {
                        state.registry.shard(e.shard).record_failure();
                        let _ = e.reply.send(Err(Error::Injected { site }));
                    }
                }
            }
        });
        Supervisor { tx, worker: Some(worker) }
    }

    /// Stop the loop and join the thread (idempotent).
    pub(crate) fn stop(&mut self) {
        let _ = self.tx.send(SupMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.stop();
    }
}

struct State {
    slots: Vec<Arc<ShardSlot>>,
    inflight: Arc<InflightTable>,
    registry: Arc<TelemetryRegistry>,
    router: Arc<Router>,
    cfg: IngressConfig,
    tracer: Option<Arc<Tracer>>,
}

impl State {
    /// Re-dispatch one transient-faulted request, or fail it when its
    /// deadline or retry budget ran out.
    fn handle_retry(&self, id: u64, site: &'static str) {
        // Entry already answered (e.g. a duplicate retry) — nothing to do.
        let Some((attempts, deadline, n)) = self.inflight.retry_info(id) else { return };
        if deadline.is_some_and(|dl| Instant::now() > dl) {
            if let Some(e) = self.inflight.take(id) {
                self.registry.shard(e.shard).record_deadline_exceeded();
                let _ = e.reply.send(Err(Error::DeadlineExceeded));
            }
            return;
        }
        if attempts >= self.cfg.max_retries {
            // Budget exhausted: the caller gets the fault as a typed
            // error (the worker-side check usually catches this first;
            // this is the backstop for stale retry messages).
            if let Some(e) = self.inflight.take(id) {
                self.registry.shard(e.shard).record_failure();
                let _ = e.reply.send(Err(Error::Injected { site }));
            }
            return;
        }
        std::thread::sleep(self.cfg.backoff(attempts + 1));
        let (idx, _overflow) = self.router.route(n);
        if let Some((req, redispatches)) = self.inflight.reissue(id, idx, true) {
            self.registry.record_retry();
            self.record_redispatch(id, idx, redispatches, true);
            // A failed send means the target worker just died: the entry
            // stays assigned to `idx` in the ledger, and the next sweep
            // respawns that shard and re-dispatches it.
            if self.slots[idx].send(Msg::Generate(req)) {
                let _ = self.slots[idx].send(Msg::Flush);
            }
        }
    }

    /// `supervisor.redispatch` span into the coordinator ring: the
    /// request's id ties the re-dispatch back to the original admit.
    fn record_redispatch(&self, id: u64, shard: usize, redispatches: u32, retry: bool) {
        if let Some(tr) = &self.tracer {
            tr.record_coord(
                Span::event(SpanKind::SupervisorRedispatch, shard as u32, tr.now_ns())
                    .req(id)
                    .aux(redispatches as u64)
                    .aux2(retry as u64),
            );
        }
    }

    /// Publish the tracer's running counters into the telemetry `trace`
    /// block (cheap relaxed stores; runs every sweep tick so snapshots
    /// taken mid-run stay fresh).
    fn publish_trace(&self) {
        if let Some(tr) = &self.tracer {
            self.registry
                .set_trace_activity(tr.spans_recorded(), tr.spans_dropped());
        }
    }

    /// Reap and respawn any worker thread that exited without a shutdown
    /// handshake, then re-dispatch its ledger entries.
    fn sweep(&self) {
        for slot in &self.slots {
            if !slot.reap_dead_worker() {
                continue;
            }
            let telemetry = self.registry.shard(slot.idx);
            telemetry.record_respawn();
            if let Some(plan) = slot.fault_plan() {
                // The dead worker can't publish its final fault count
                // (an injected kill is itself an injected fault) — the
                // supervisor publishes on its behalf.
                telemetry.set_faults_injected(plan.injected());
            }
            // Flight recorder: drain the dead shard's ring into a dump
            // BEFORE respawning, so the dump holds exactly the spans the
            // dead incarnation recorded (its last flushes, in canonical
            // order) and the fresh worker's spans can't mix in.
            if let Some(tr) = &self.tracer {
                tr.flight_dump(slot.idx);
                self.registry.record_flight_dump();
            }
            slot.respawn();
            for id in self.inflight.assigned_to(slot.idx) {
                // Same shard, no attempt bump: a worker death is not the
                // request's fault. Deadlines are re-checked at dequeue.
                if let Some((req, redispatches)) = self.inflight.reissue(id, slot.idx, false) {
                    self.record_redispatch(id, slot.idx, redispatches, false);
                    let _ = slot.send(Msg::Generate(req));
                }
            }
            // Flush so redispatched requests can't strand in the batcher
            // waiting for traffic that may never come.
            let _ = slot.send(Msg::Flush);
        }
    }
}
