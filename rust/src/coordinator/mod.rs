//! Layer-3 coordinator: backend registry, dispatch heuristic, request
//! batching, and a threaded RNG service (DESIGN.md S10).
//!
//! The paper's contribution is a library, so the coordinator stays thin:
//! it owns process lifecycle, routes generate requests to the right
//! backend for the configured platform/API, and implements the paper's §8
//! future-work extension — heuristic host-vs-device backend selection by
//! problem size ("using the host for small workloads and GPU for larger
//! ones").

mod batcher;
mod heuristic;
mod registry;
mod service;

pub use batcher::{BatchOutcome, RequestBatcher};
pub use heuristic::BackendHeuristic;
pub use registry::BackendRegistry;
pub use service::{RngService, ServiceRequest, ServiceStats};
