//! Layer-3 coordinator: backend registry, dispatch heuristic, request
//! batching, and the sharded RNG service pool (DESIGN.md S10).
//!
//! The paper's contribution is a library; the coordinator turns it into a
//! serving layer: it owns process lifecycle, routes generate requests to
//! the right backend for the configured platform/API, implements the
//! paper's §8 future-work extension — heuristic host-vs-device backend
//! selection by problem size — and scales the request path across N
//! worker shards ([`ServicePool`]) while preserving bit-exact stream
//! semantics through counter-based partitioning (see the crate-level docs
//! in `lib.rs` for the architecture diagram).
//!
//! The dispatch policy is live, not frozen: dispatcher and workers read
//! it through the lock-free [`TuningHandle`], the pool's counters live in
//! a [`telemetry`](crate::telemetry) registry, and the
//! [`autotune`](crate::autotune) controller closes the measure→retune
//! loop (DESIGN.md S11–S12).
//!
//! The pool is also *supervised* (DESIGN.md S15): an ingress gate bounds
//! admission ([`IngressConfig`]), an in-flight ledger records every
//! accepted request before it reaches a shard, and a supervisor thread
//! respawns dead workers and re-dispatches their requests bit-identically
//! — with transient [`crate::fault`] injections retried under bounded
//! exponential backoff. Every caller gets its exact fault-free payload or
//! a typed error, never a hang.

mod batcher;
mod heuristic;
mod ingress;
mod pool;
mod registry;
mod service;
mod supervisor;

pub use batcher::{BatchMember, BatchOutcome, PendingRequest, RequestBatcher};
pub use heuristic::{BackendHeuristic, DispatchPolicy, Route, TuningHandle, TuningParams};
pub use ingress::IngressConfig;
pub use pool::{PoolConfig, PoolStats, ServicePool, ServiceRequest, ServiceStats};
pub use registry::{BackendRegistry, ShardBackendSet};
pub use service::RngService;
