//! Heuristic backend selection (paper §8 future work, implemented here):
//! "Integrating a heuristic approach to select the best backend for the
//! problem size — e.g., using the host for small workloads and GPU for
//! larger ones". [`DispatchPolicy`] applies the same size-awareness at the
//! service-pool layer: small requests coalesce through the batched
//! round-robin shards, large ones overflow to a dedicated unbatched lane.

use crate::burner::{run_burner_virtual, BurnerApi, BurnerConfig};
use crate::platform::{PlatformId, PlatformKind};

/// Routing decision for one request in the service pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Coalesce with other small requests on a round-robin shard.
    Batched,
    /// Large enough to saturate a launch alone: dedicated overflow lane.
    Overflow,
}

/// Size-aware dispatch policy for [`super::ServicePool`].
///
/// The threshold doubles as the pool-layer reading of the §8 heuristic: a
/// request at/above the host-vs-device crossover already amortises its own
/// launch, so batching it with small requests only adds latency for both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DispatchPolicy {
    /// Requests with `n >= threshold` take the overflow lane.
    pub threshold: usize,
}

impl DispatchPolicy {
    /// Fixed threshold.
    pub fn fixed(threshold: usize) -> DispatchPolicy {
        DispatchPolicy { threshold }
    }

    /// Derive the threshold from a calibrated [`BackendHeuristic`]
    /// crossover.
    pub fn from_heuristic(h: &BackendHeuristic) -> DispatchPolicy {
        DispatchPolicy { threshold: h.crossover }
    }

    /// No overflow lane: everything round-robins through the batched
    /// shards.
    pub fn disabled() -> DispatchPolicy {
        DispatchPolicy { threshold: usize::MAX }
    }

    /// Whether the policy can ever route to the overflow lane.
    pub fn is_enabled(&self) -> bool {
        self.threshold != usize::MAX
    }

    /// Route a request of `n` numbers.
    pub fn route(&self, n: usize) -> Route {
        if n >= self.threshold {
            Route::Overflow
        } else {
            Route::Batched
        }
    }
}

/// Size-based host-vs-device selector.
#[derive(Debug, Clone)]
pub struct BackendHeuristic {
    device: PlatformId,
    host: PlatformId,
    /// Batch size at/above which the device wins.
    pub crossover: usize,
}

impl BackendHeuristic {
    /// Calibrate the crossover by sweeping the virtual cost model — a
    /// binary search over batch sizes comparing host vs device time for a
    /// *device-resident consumer* (the §8 scenario: FastCaloSim consumes
    /// the numbers on the GPU, so the D2H copy is not on the path — with
    /// readback included, host generation wins at every size because PCIe
    /// is slower than a vectorised host Philox).
    pub fn calibrate(device: PlatformId, host: PlatformId) -> BackendHeuristic {
        assert_ne!(device.spec().kind, PlatformKind::Cpu, "device must be a GPU");
        let probe = |platform: PlatformId, batch: usize| -> f64 {
            let mut cfg = BurnerConfig::paper_default(platform, BurnerApi::SyclBuffer, batch);
            cfg.iterations = 3;
            run_burner_virtual(&cfg)
                .map(|r| {
                    // Total minus the readback (breakdown is per-iteration
                    // of the final iteration — structure is identical).
                    (r.mean_total_ns() - r.breakdown.d2h_ns as f64).max(1.0)
                })
                .unwrap_or(f64::INFINITY)
        };
        // Exponential scan then refine.
        let mut hi = 1usize << 30;
        let mut found = hi;
        let mut batch = 1usize;
        while batch <= hi {
            if probe(device, batch) < probe(host, batch) {
                found = batch;
                break;
            }
            batch *= 4;
        }
        if found < hi {
            let mut lo = (found / 4).max(1);
            hi = found;
            while lo + 1 < hi {
                let mid = lo + (hi - lo) / 2;
                if probe(device, mid) < probe(host, mid) {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
        }
        BackendHeuristic { device, host, crossover: hi }
    }

    /// Fixed crossover (tests / config override).
    pub fn fixed(device: PlatformId, host: PlatformId, crossover: usize) -> BackendHeuristic {
        BackendHeuristic { device, host, crossover }
    }

    /// Pick the platform for a batch.
    pub fn select(&self, batch: usize) -> PlatformId {
        if batch >= self.crossover {
            self.device
        } else {
            self.host
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_crossover_is_sane() {
        let h = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
        // Device launch+transfer overheads mean the crossover is far above
        // one number, far below the full sweep.
        assert!(h.crossover > 1_000, "crossover={}", h.crossover);
        assert!(h.crossover < 1 << 30, "crossover={}", h.crossover);
        assert_eq!(h.select(1), PlatformId::Rome7742);
        assert_eq!(h.select(1 << 30), PlatformId::A100);
    }

    #[test]
    fn dispatch_policy_routes_by_size() {
        let p = DispatchPolicy::fixed(1000);
        assert!(p.is_enabled());
        assert_eq!(p.route(999), Route::Batched);
        assert_eq!(p.route(1000), Route::Overflow);
        let off = DispatchPolicy::disabled();
        assert!(!off.is_enabled());
        assert_eq!(off.route(usize::MAX - 1), Route::Batched);
    }

    #[test]
    fn dispatch_policy_follows_calibrated_crossover() {
        let h = BackendHeuristic::fixed(PlatformId::A100, PlatformId::Rome7742, 50_000);
        let p = DispatchPolicy::from_heuristic(&h);
        assert_eq!(p.threshold, 50_000);
        assert_eq!(p.route(49_999), Route::Batched);
        assert_eq!(p.route(50_000), Route::Overflow);
    }

    #[test]
    fn selection_is_monotone() {
        let h = BackendHeuristic::fixed(PlatformId::Vega56, PlatformId::XeonGold5220, 100_000);
        let mut was_device = false;
        for batch in [1usize, 10, 1_000, 99_999, 100_000, 10_000_000] {
            let dev = h.select(batch) == PlatformId::Vega56;
            assert!(!was_device || dev, "flipped back at {batch}");
            was_device = dev;
        }
    }
}
