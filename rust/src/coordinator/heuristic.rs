//! Heuristic backend selection (paper §8 future work, implemented here):
//! "Integrating a heuristic approach to select the best backend for the
//! problem size — e.g., using the host for small workloads and GPU for
//! larger ones". [`DispatchPolicy`] applies the same size-awareness at the
//! service-pool layer: small requests coalesce through the batched
//! round-robin shards, large ones overflow to a dedicated unbatched lane.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::burner::{run_burner_virtual, BurnerApi, BurnerConfig};
use crate::platform::{PlatformId, PlatformKind};

/// Routing decision for one request in the service pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Coalesce with other small requests on a round-robin shard.
    Batched,
    /// Large enough to saturate a launch alone: dedicated overflow lane.
    Overflow,
}

/// Size-aware dispatch policy for [`super::ServicePool`].
///
/// The threshold doubles as the pool-layer reading of the §8 heuristic: a
/// request at/above the host-vs-device crossover already amortises its own
/// launch, so batching it with small requests only adds latency for both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DispatchPolicy {
    /// Requests with `n >= threshold` take the overflow lane.
    pub threshold: usize,
}

impl DispatchPolicy {
    /// Fixed threshold.
    pub fn fixed(threshold: usize) -> DispatchPolicy {
        DispatchPolicy { threshold }
    }

    /// Derive the threshold from a calibrated [`BackendHeuristic`]
    /// crossover.
    pub fn from_heuristic(h: &BackendHeuristic) -> DispatchPolicy {
        DispatchPolicy { threshold: h.crossover }
    }

    /// No overflow lane: everything round-robins through the batched
    /// shards.
    pub fn disabled() -> DispatchPolicy {
        DispatchPolicy { threshold: usize::MAX }
    }

    /// Whether the policy can ever route to the overflow lane.
    pub fn is_enabled(&self) -> bool {
        self.threshold != usize::MAX
    }

    /// Route a request of `n` numbers. A disabled policy never overflows
    /// (including the `n == usize::MAX == threshold` corner); an enabled
    /// `threshold == 0` policy sends everything to the overflow lane.
    pub fn route(&self, n: usize) -> Route {
        if self.is_enabled() && n >= self.threshold {
            Route::Overflow
        } else {
            Route::Batched
        }
    }
}

/// Atomically swappable tuning parameters: the dispatch threshold, the
/// batcher's flush limits, and the flush executor's tiling shape — i.e.
/// every knob the autotuner turns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningParams {
    /// Requests with `n >= threshold` take the overflow lane
    /// (`usize::MAX` disables the lane).
    pub threshold: usize,
    /// Batcher: close a batch at this many queued requests.
    pub flush_requests: usize,
    /// Batcher: close a batch at this many queued items.
    pub max_batch: usize,
    /// Executor: elements per tile of a tiled flush; `0` keeps the flush
    /// serial (the default single-submission shape).
    pub tile_size: usize,
    /// Executor: team threads running tiles; `1` keeps the flush serial.
    pub team_width: usize,
}

impl TuningParams {
    /// Parameters carrying a fixed policy with the given batcher limits
    /// (serial executor — tiling is opted into via [`TuningParams::tiled`]
    /// or a retune).
    pub fn new(policy: DispatchPolicy, flush_requests: usize, max_batch: usize) -> Self {
        TuningParams {
            threshold: policy.threshold,
            flush_requests: flush_requests.max(1),
            max_batch: max_batch.max(1),
            tile_size: 0,
            team_width: 1,
        }
    }

    /// The same parameters with the executor's tiling shape set.
    pub fn tiled(mut self, tile_size: usize, team_width: usize) -> Self {
        self.tile_size = tile_size;
        self.team_width = team_width.max(1);
        self
    }

    /// The dispatch policy these parameters encode.
    pub fn policy(&self) -> DispatchPolicy {
        DispatchPolicy { threshold: self.threshold }
    }
}

/// Shared, lock-free handle to the pool's live [`TuningParams`] — the
/// ArcSwap role filled with plain atomics, which works because every knob
/// is word-sized: the dispatcher and workers `load` with relaxed ordering
/// on the hot path (no locks, no RMW), and the autotuner publishes a
/// retune with plain `store`s. Readers may observe a retune's knobs
/// non-atomically with respect to each other; every combination of old
/// and new knobs is a valid configuration, and the stream invariant never
/// depends on routing (offsets are assigned before the route), so torn
/// retunes are benign.
#[derive(Debug)]
pub struct TuningHandle {
    threshold: AtomicUsize,
    flush_requests: AtomicUsize,
    max_batch: AtomicUsize,
    tile_size: AtomicUsize,
    team_width: AtomicUsize,
    generation: AtomicU64,
}

impl TuningHandle {
    /// Handle initialized to `params` (generation 0).
    pub fn new(params: TuningParams) -> TuningHandle {
        TuningHandle {
            threshold: AtomicUsize::new(params.threshold),
            flush_requests: AtomicUsize::new(params.flush_requests.max(1)),
            max_batch: AtomicUsize::new(params.max_batch.max(1)),
            tile_size: AtomicUsize::new(params.tile_size),
            team_width: AtomicUsize::new(params.team_width.max(1)),
            generation: AtomicU64::new(0),
        }
    }

    /// Current dispatch policy (hot path: one relaxed load).
    pub fn policy(&self) -> DispatchPolicy {
        DispatchPolicy { threshold: self.threshold.load(Ordering::Relaxed) }
    }

    /// Current batcher flush-request limit.
    pub fn flush_requests(&self) -> usize {
        self.flush_requests.load(Ordering::Relaxed).max(1)
    }

    /// Current batcher item limit.
    pub fn max_batch(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed).max(1)
    }

    /// Current executor tile size (`0` = serial flush).
    pub fn tile_size(&self) -> usize {
        self.tile_size.load(Ordering::Relaxed)
    }

    /// Current executor team width (`1` = serial flush).
    pub fn team_width(&self) -> usize {
        self.team_width.load(Ordering::Relaxed).max(1)
    }

    /// All current knobs.
    pub fn params(&self) -> TuningParams {
        TuningParams {
            threshold: self.threshold.load(Ordering::Relaxed),
            flush_requests: self.flush_requests(),
            max_batch: self.max_batch(),
            tile_size: self.tile_size(),
            team_width: self.team_width(),
        }
    }

    /// Publish a retune; returns the new generation number.
    pub fn retune(&self, params: TuningParams) -> u64 {
        self.threshold.store(params.threshold, Ordering::Relaxed);
        self.flush_requests.store(params.flush_requests.max(1), Ordering::Relaxed);
        self.max_batch.store(params.max_batch.max(1), Ordering::Relaxed);
        self.tile_size.store(params.tile_size, Ordering::Relaxed);
        self.team_width.store(params.team_width.max(1), Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Retunes published so far.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }
}

/// Size-based host-vs-device selector.
#[derive(Debug, Clone)]
pub struct BackendHeuristic {
    device: PlatformId,
    host: PlatformId,
    /// Batch size at/above which the device wins.
    pub crossover: usize,
}

impl BackendHeuristic {
    /// Calibrate the crossover by sweeping the virtual cost model — a
    /// binary search over batch sizes comparing host vs device time for a
    /// *device-resident consumer* (the §8 scenario: FastCaloSim consumes
    /// the numbers on the GPU, so the D2H copy is not on the path — with
    /// readback included, host generation wins at every size because PCIe
    /// is slower than a vectorised host Philox).
    pub fn calibrate(device: PlatformId, host: PlatformId) -> BackendHeuristic {
        assert_ne!(device.spec().kind, PlatformKind::Cpu, "device must be a GPU");
        let probe = |platform: PlatformId, batch: usize| -> f64 {
            let mut cfg = BurnerConfig::paper_default(platform, BurnerApi::SyclBuffer, batch);
            cfg.iterations = 3;
            run_burner_virtual(&cfg)
                .map(|r| {
                    // Total minus the readback (breakdown is per-iteration
                    // of the final iteration — structure is identical).
                    (r.mean_total_ns() - r.breakdown.d2h_ns as f64).max(1.0)
                })
                .unwrap_or(f64::INFINITY)
        };
        // Exponential scan then refine.
        let mut hi = 1usize << 30;
        let mut found = hi;
        let mut batch = 1usize;
        while batch <= hi {
            if probe(device, batch) < probe(host, batch) {
                found = batch;
                break;
            }
            batch *= 4;
        }
        if found < hi {
            let mut lo = (found / 4).max(1);
            hi = found;
            while lo + 1 < hi {
                let mid = lo + (hi - lo) / 2;
                if probe(device, mid) < probe(host, mid) {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
        }
        BackendHeuristic { device, host, crossover: hi }
    }

    /// Fixed crossover (tests / config override).
    pub fn fixed(device: PlatformId, host: PlatformId, crossover: usize) -> BackendHeuristic {
        BackendHeuristic { device, host, crossover }
    }

    /// Pick the platform for a batch.
    pub fn select(&self, batch: usize) -> PlatformId {
        if batch >= self.crossover {
            self.device
        } else {
            self.host
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_crossover_is_sane() {
        let h = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
        // Device launch+transfer overheads mean the crossover is far above
        // one number, far below the full sweep.
        assert!(h.crossover > 1_000, "crossover={}", h.crossover);
        assert!(h.crossover < 1 << 30, "crossover={}", h.crossover);
        assert_eq!(h.select(1), PlatformId::Rome7742);
        assert_eq!(h.select(1 << 30), PlatformId::A100);
    }

    #[test]
    fn dispatch_policy_routes_by_size() {
        let p = DispatchPolicy::fixed(1000);
        assert!(p.is_enabled());
        assert_eq!(p.route(999), Route::Batched);
        assert_eq!(p.route(1000), Route::Overflow); // n == threshold overflows
        let off = DispatchPolicy::disabled();
        assert!(!off.is_enabled());
        assert_eq!(off.route(usize::MAX - 1), Route::Batched);
        // Disabled means *never* overflow, even at n == usize::MAX.
        assert_eq!(off.route(usize::MAX), Route::Batched);
        // threshold == 0 sends everything to the overflow lane.
        let all = DispatchPolicy::fixed(0);
        assert_eq!(all.route(0), Route::Overflow);
        assert_eq!(all.route(1), Route::Overflow);
    }

    #[test]
    fn tuning_handle_swaps_without_locking_readers() {
        let h = TuningHandle::new(TuningParams::new(DispatchPolicy::fixed(1000), 16, 1 << 20));
        assert_eq!(h.policy().threshold, 1000);
        assert_eq!(h.flush_requests(), 16);
        assert_eq!(h.generation(), 0);
        let g = h.retune(
            TuningParams::new(DispatchPolicy::fixed(5000), 8, 1 << 16).tiled(1 << 16, 4),
        );
        assert_eq!(g, 1);
        assert_eq!(h.policy().threshold, 5000);
        assert_eq!(h.flush_requests(), 8);
        assert_eq!(h.max_batch(), 1 << 16);
        assert_eq!(h.tile_size(), 1 << 16);
        assert_eq!(h.team_width(), 4);
        assert_eq!(h.params().policy().route(5000), Route::Overflow);
        // Degenerate limits are clamped, never zero (tile_size 0 is the
        // legitimate "serial" setting and passes through).
        h.retune(TuningParams {
            threshold: 0,
            flush_requests: 0,
            max_batch: 0,
            tile_size: 0,
            team_width: 0,
        });
        assert_eq!(h.flush_requests(), 1);
        assert_eq!(h.max_batch(), 1);
        assert_eq!(h.tile_size(), 0);
        assert_eq!(h.team_width(), 1);
    }

    #[test]
    fn dispatch_policy_follows_calibrated_crossover() {
        let h = BackendHeuristic::fixed(PlatformId::A100, PlatformId::Rome7742, 50_000);
        let p = DispatchPolicy::from_heuristic(&h);
        assert_eq!(p.threshold, 50_000);
        assert_eq!(p.route(49_999), Route::Batched);
        assert_eq!(p.route(50_000), Route::Overflow);
    }

    #[test]
    fn selection_is_monotone() {
        let h = BackendHeuristic::fixed(PlatformId::Vega56, PlatformId::XeonGold5220, 100_000);
        let mut was_device = false;
        for batch in [1usize, 10, 1_000, 99_999, 100_000, 10_000_000] {
            let dev = h.select(batch) == PlatformId::Vega56;
            assert!(!was_device || dev, "flipped back at {batch}");
            was_device = dev;
        }
    }
}
