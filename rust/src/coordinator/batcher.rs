//! Request batching: coalesce small generate requests into one kernel.
//!
//! Because Philox is counter-based, a batch of requests can be served by a
//! single generation over the concatenated counter range and sliced back —
//! each requester observes exactly the stream it would have gotten from a
//! dedicated engine at its own offset (the invariant the property tests
//! pin down).

/// One queued request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PendingRequest {
    /// Request id (caller-assigned).
    pub id: u64,
    /// Numbers wanted.
    pub n: usize,
}

/// Outcome of closing a batch.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Kernel launch size (sum of member sizes, padded to `pad_to`).
    pub launch_n: usize,
    /// (request id, offset-in-batch, n) for slicing results.
    pub members: Vec<(u64, usize, usize)>,
}

/// Size/occupancy-driven batcher.
#[derive(Debug)]
pub struct RequestBatcher {
    /// Close the batch when total items reach this.
    pub max_batch: usize,
    /// Close the batch when this many requests are queued.
    pub max_requests: usize,
    /// Pad launches to a multiple (kernel block granularity).
    pub pad_to: usize,
    queue: Vec<PendingRequest>,
    queued_items: usize,
}

impl RequestBatcher {
    /// New batcher.
    pub fn new(max_batch: usize, max_requests: usize, pad_to: usize) -> Self {
        RequestBatcher {
            max_batch,
            max_requests,
            pad_to: pad_to.max(1),
            queue: Vec::new(),
            queued_items: 0,
        }
    }

    /// Enqueue; returns a closed batch if thresholds tripped.
    pub fn push(&mut self, req: PendingRequest) -> Option<BatchOutcome> {
        self.queue.push(req);
        self.queued_items += req.n;
        if self.queued_items >= self.max_batch || self.queue.len() >= self.max_requests {
            Some(self.flush_inner())
        } else {
            None
        }
    }

    /// Close the current batch regardless of thresholds.
    pub fn flush(&mut self) -> Option<BatchOutcome> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.flush_inner())
        }
    }

    /// Queued-but-unflushed request count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    fn flush_inner(&mut self) -> BatchOutcome {
        let mut members = Vec::with_capacity(self.queue.len());
        let mut offset = 0usize;
        for req in self.queue.drain(..) {
            members.push((req.id, offset, req.n));
            offset += req.n;
        }
        self.queued_items = 0;
        let launch_n = offset.div_ceil(self.pad_to) * self.pad_to;
        BatchOutcome { launch_n, members }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn batches_close_on_item_threshold() {
        let mut b = RequestBatcher::new(1000, 100, 4);
        assert!(b.push(PendingRequest { id: 1, n: 400 }).is_none());
        assert!(b.push(PendingRequest { id: 2, n: 400 }).is_none());
        let out = b.push(PendingRequest { id: 3, n: 400 }).unwrap();
        assert_eq!(out.members.len(), 3);
        assert_eq!(out.launch_n, 1200);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn offsets_are_contiguous_and_disjoint() {
        testkit::forall("batcher-offsets", 50, |g| {
            let mut b = RequestBatcher::new(usize::MAX, usize::MAX, g.usize_in(1, 64));
            let k = g.usize_in(1, 20);
            for id in 0..k as u64 {
                b.push(PendingRequest { id, n: g.usize_in(1, 5000) });
            }
            let out = b.flush().unwrap();
            let mut expect_offset = 0usize;
            for (i, &(id, off, n)) in out.members.iter().enumerate() {
                if id != i as u64 {
                    return Err(format!("order broken at {i}"));
                }
                if off != expect_offset {
                    return Err(format!("gap/overlap at {i}: {off} != {expect_offset}"));
                }
                expect_offset += n;
            }
            if out.launch_n < expect_offset {
                return Err("launch smaller than payload".into());
            }
            if out.launch_n % b.pad_to != 0 {
                return Err("padding violated".into());
            }
            Ok(())
        });
    }

    #[test]
    fn flush_on_empty_is_none() {
        let mut b = RequestBatcher::new(10, 10, 4);
        assert!(b.flush().is_none());
    }
}
