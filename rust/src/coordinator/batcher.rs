//! Request batching: coalesce small generate requests into one kernel.
//!
//! Because Philox is counter-based, a batch of requests can be served by a
//! single launch whose members are generated at their own *global* stream
//! offsets and sliced back — each requester observes exactly the stream it
//! would have gotten from a dedicated engine at its own offset, no matter
//! how the pool batches or shards the work (the invariant the property
//! tests pin down).

/// One queued request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PendingRequest {
    /// Request id (caller-assigned, shard-local).
    pub id: u64,
    /// Numbers wanted.
    pub n: usize,
    /// Absolute offset of this request in the global engine stream
    /// (assigned by the pool dispatcher at submission time).
    pub stream_offset: u64,
}

/// One member of a closed batch, with everything the launch needs to
/// generate and slice its sub-stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMember {
    /// Request id (caller-assigned, shard-local).
    pub id: u64,
    /// Offset of the member's slice inside the launch buffer.
    pub batch_offset: usize,
    /// Absolute offset in the global engine stream.
    pub stream_offset: u64,
    /// Numbers wanted.
    pub n: usize,
}

/// Outcome of closing a batch.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Kernel launch size (sum of member sizes, padded to `pad_to`).
    pub launch_n: usize,
    /// Members with their slice/stream coordinates.
    pub members: Vec<BatchMember>,
}

/// Size/occupancy-driven batcher.
#[derive(Debug)]
pub struct RequestBatcher {
    /// Close the batch when total items reach this.
    pub max_batch: usize,
    /// Close the batch when this many requests are queued.
    pub max_requests: usize,
    /// Pad launches to a multiple (kernel block granularity).
    pub pad_to: usize,
    queue: Vec<PendingRequest>,
    queued_items: usize,
}

impl RequestBatcher {
    /// New batcher.
    pub fn new(max_batch: usize, max_requests: usize, pad_to: usize) -> Self {
        RequestBatcher {
            max_batch,
            max_requests,
            pad_to: pad_to.max(1),
            queue: Vec::new(),
            queued_items: 0,
        }
    }

    /// Adopt new flush thresholds (autotuner retune). Applies from the
    /// next `push`; an already-queued batch keeps its members — a shrink
    /// below the current queue depth simply closes the batch on the next
    /// push, so no request is ever dropped or reordered by a retune.
    pub fn set_limits(&mut self, max_batch: usize, max_requests: usize) {
        self.max_batch = max_batch.max(1);
        self.max_requests = max_requests.max(1);
    }

    /// Enqueue; returns a closed batch if thresholds tripped.
    pub fn push(&mut self, req: PendingRequest) -> Option<BatchOutcome> {
        self.queue.push(req);
        self.queued_items += req.n;
        if self.queued_items >= self.max_batch || self.queue.len() >= self.max_requests {
            Some(self.flush_inner())
        } else {
            None
        }
    }

    /// Close the current batch regardless of thresholds.
    pub fn flush(&mut self) -> Option<BatchOutcome> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.flush_inner())
        }
    }

    /// Queued-but-unflushed request count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    fn flush_inner(&mut self) -> BatchOutcome {
        let mut members = Vec::with_capacity(self.queue.len());
        let mut offset = 0usize;
        for req in self.queue.drain(..) {
            members.push(BatchMember {
                id: req.id,
                batch_offset: offset,
                stream_offset: req.stream_offset,
                n: req.n,
            });
            offset += req.n;
        }
        self.queued_items = 0;
        let launch_n = offset.div_ceil(self.pad_to) * self.pad_to;
        BatchOutcome { launch_n, members }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn req(id: u64, n: usize) -> PendingRequest {
        PendingRequest { id, n, stream_offset: 1000 * id }
    }

    #[test]
    fn batches_close_on_item_threshold() {
        let mut b = RequestBatcher::new(1000, 100, 4);
        assert!(b.push(req(1, 400)).is_none());
        assert!(b.push(req(2, 400)).is_none());
        let out = b.push(req(3, 400)).unwrap();
        assert_eq!(out.members.len(), 3);
        assert_eq!(out.launch_n, 1200);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn members_preserve_stream_offsets() {
        let mut b = RequestBatcher::new(usize::MAX, 2, 4);
        b.push(PendingRequest { id: 0, n: 8, stream_offset: 777 });
        let out = b.push(PendingRequest { id: 1, n: 4, stream_offset: 31 }).unwrap();
        assert_eq!(out.members[0].stream_offset, 777);
        assert_eq!(out.members[1].stream_offset, 31);
        assert_eq!(out.members[1].batch_offset, 8);
    }

    #[test]
    fn offsets_are_contiguous_and_disjoint() {
        testkit::forall("batcher-offsets", 50, |g| {
            let mut b = RequestBatcher::new(usize::MAX, usize::MAX, g.usize_in(1, 64));
            let k = g.usize_in(1, 20);
            for id in 0..k as u64 {
                b.push(PendingRequest {
                    id,
                    n: g.usize_in(1, 5000),
                    stream_offset: g.u64() >> 16,
                });
            }
            let out = b.flush().unwrap();
            let mut expect_offset = 0usize;
            for (i, m) in out.members.iter().enumerate() {
                if m.id != i as u64 {
                    return Err(format!("order broken at {i}"));
                }
                if m.batch_offset != expect_offset {
                    return Err(format!(
                        "gap/overlap at {i}: {} != {expect_offset}",
                        m.batch_offset
                    ));
                }
                expect_offset += m.n;
            }
            if out.launch_n < expect_offset {
                return Err("launch smaller than payload".into());
            }
            if out.launch_n % b.pad_to != 0 {
                return Err("padding violated".into());
            }
            Ok(())
        });
    }

    #[test]
    fn flush_on_empty_is_none() {
        let mut b = RequestBatcher::new(10, 10, 4);
        assert!(b.flush().is_none());
    }

    #[test]
    fn retuned_limits_apply_without_dropping_queued_requests() {
        let mut b = RequestBatcher::new(usize::MAX, 100, 4);
        assert!(b.push(req(0, 8)).is_none());
        assert!(b.push(req(1, 8)).is_none());
        // Shrink below the current queue depth: next push closes the batch
        // with everything queued so far.
        b.set_limits(usize::MAX, 2);
        let out = b.push(req(2, 8)).unwrap();
        assert_eq!(out.members.len(), 3);
        assert_eq!(b.pending(), 0);
        // Zero limits are clamped to 1, never a stuck batcher.
        b.set_limits(0, 0);
        assert!(b.push(req(3, 8)).is_some());
    }
}
