//! Single-shard RNG service: the legacy facade over the sharded pool.
//!
//! [`RngService`] keeps the original one-worker API (spawn / generate /
//! flush / shutdown) but is now a thin wrapper over a one-shard
//! [`ServicePool`], so both paths share the worker, batching and
//! stream-partitioning machinery — and the batching invariant: each
//! request is answered with exactly the sub-stream a dedicated engine at
//! its assigned global offset would produce, independent of batching
//! decisions.

use std::sync::mpsc;

use crate::error::Result;
use crate::platform::PlatformId;

use super::pool::{PoolConfig, ServicePool, ServiceStats};

/// Handle to a running single-shard RNG service.
pub struct RngService {
    pool: ServicePool,
}

impl RngService {
    /// Spawn a service for `platform` with the given batching policy.
    /// The worker builds its own engine/backends (they are not `Send`).
    pub fn spawn(platform: PlatformId, seed: u64, max_batch: usize, max_requests: usize) -> Self {
        let mut cfg = PoolConfig::new(platform, seed, 1);
        cfg.max_batch = max_batch;
        cfg.max_requests = max_requests;
        RngService { pool: ServicePool::spawn(cfg) }
    }

    /// Submit a request; returns the receiver for the reply.
    pub fn generate(&self, n: usize, range: (f32, f32)) -> mpsc::Receiver<Result<Vec<f32>>> {
        self.pool.generate(n, range)
    }

    /// Force pending requests out.
    pub fn flush(&self) {
        self.pool.flush()
    }

    /// Stop the worker, returning counters.
    pub fn shutdown(self) -> Result<ServiceStats> {
        Ok(self.pool.shutdown()?.total())
    }

    /// The underlying one-shard pool.
    pub fn pool(&self) -> &ServicePool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Engine, PhiloxEngine};

    #[test]
    fn batched_responses_match_dedicated_stream() {
        let svc = RngService::spawn(PlatformId::A100, 42, 1 << 20, 3);
        let r1 = svc.generate(100, (0.0, 1.0));
        let r2 = svc.generate(200, (0.0, 1.0));
        let r3 = svc.generate(44, (0.0, 1.0)); // trips max_requests=3
        let a = r1.recv().unwrap().unwrap();
        let b = r2.recv().unwrap().unwrap();
        let c = r3.recv().unwrap().unwrap();

        // The concatenation equals one dedicated stream.
        let mut want = vec![0f32; 344];
        PhiloxEngine::new(42).fill_uniform_f32(&mut want);
        let got: Vec<f32> = a.iter().chain(&b).chain(&c).copied().collect();
        assert_eq!(got, want);

        let stats = svc.shutdown().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.launches, 1);
        assert_eq!(stats.numbers, 344); // padded to /4 already exact
    }

    #[test]
    fn flush_serves_partial_batches() {
        let svc = RngService::spawn(PlatformId::A100, 7, 1 << 20, 1000);
        let r1 = svc.generate(10, (2.0, 4.0));
        svc.flush();
        let v = r1.recv().unwrap().unwrap();
        assert_eq!(v.len(), 10);
        assert!(v.iter().all(|&x| (2.0..4.0).contains(&x)));
        svc.shutdown().unwrap();
    }

    #[test]
    fn shutdown_flushes_remaining() {
        let svc = RngService::spawn(PlatformId::Vega56, 7, 1 << 20, 1000);
        let r1 = svc.generate(5, (0.0, 1.0));
        let stats = svc.shutdown().unwrap();
        assert!(r1.recv().unwrap().is_ok());
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn facade_is_a_one_shard_pool() {
        let svc = RngService::spawn(PlatformId::A100, 1, 1 << 20, 16);
        assert_eq!(svc.pool().shard_count(), 1);
        assert!(!svc.pool().has_overflow_lane());
        svc.shutdown().unwrap();
    }
}
