//! Threaded RNG service: the coordinator's request loop.
//!
//! A worker thread owns the (non-`Send`) backend set and serves generate
//! requests from an mpsc channel, batching small requests per
//! [`super::RequestBatcher`]. Each request is answered with exactly the
//! sub-stream it would have received from a dedicated engine at its
//! assigned offset — counter-based slicing keeps responses independent of
//! batching decisions.

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::platform::PlatformId;
use crate::rng::engines::PhiloxEngine;
use crate::rng::Engine;

use super::batcher::{PendingRequest, RequestBatcher};

/// A generate request.
pub struct ServiceRequest {
    /// Numbers wanted.
    pub n: usize,
    /// Range [a, b).
    pub range: (f32, f32),
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Generate(ServiceRequest),
    Flush,
    Shutdown(mpsc::Sender<ServiceStats>),
}

/// Aggregate service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests served.
    pub requests: u64,
    /// Kernel launches issued (batches).
    pub launches: u64,
    /// Numbers generated (padded launch totals).
    pub numbers: u64,
}

/// Handle to a running RNG service.
pub struct RngService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl RngService {
    /// Spawn a service for `platform` with the given batching policy.
    /// The worker builds its own engine/backends (they are not `Send`).
    pub fn spawn(platform: PlatformId, seed: u64, max_batch: usize, max_requests: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let _ = platform; // reserved for timing-model integration
            let mut engine = PhiloxEngine::new(seed);
            let mut batcher = RequestBatcher::new(max_batch, max_requests, 4);
            let mut waiting: Vec<ServiceRequest> = Vec::new();
            let mut stats = ServiceStats::default();

            let serve = |engine: &mut PhiloxEngine,
                         batcher: &mut RequestBatcher,
                         waiting: &mut Vec<ServiceRequest>,
                         stats: &mut ServiceStats| {
                if let Some(batch) = batcher.flush() {
                    launch(engine, batch.launch_n, &batch.members, waiting, stats);
                }
            };

            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Generate(req) => {
                        let id = waiting.len() as u64;
                        let n = req.n;
                        waiting.push(req);
                        stats.requests += 1;
                        if let Some(batch) = batcher.push(PendingRequest { id, n }) {
                            launch(&mut engine, batch.launch_n, &batch.members, &mut waiting, &mut stats);
                        }
                    }
                    Msg::Flush => serve(&mut engine, &mut batcher, &mut waiting, &mut stats),
                    Msg::Shutdown(ack) => {
                        serve(&mut engine, &mut batcher, &mut waiting, &mut stats);
                        let _ = ack.send(stats);
                        break;
                    }
                }
            }
        });
        RngService { tx, worker: Some(worker) }
    }

    /// Submit a request; returns the receiver for the reply.
    pub fn generate(&self, n: usize, range: (f32, f32)) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Generate(ServiceRequest { n, range, reply }));
        rx
    }

    /// Force pending requests out.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Stop the worker, returning counters.
    pub fn shutdown(mut self) -> Result<ServiceStats> {
        let (ack, rx) = mpsc::channel();
        self.tx
            .send(Msg::Shutdown(ack))
            .map_err(|_| Error::Coordinator("worker gone".into()))?;
        let stats = rx
            .recv()
            .map_err(|_| Error::Coordinator("worker dropped ack".into()))?;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        Ok(stats)
    }
}

impl Drop for RngService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (ack, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(ack));
            let _ = w.join();
        }
    }
}

fn launch(
    engine: &mut PhiloxEngine,
    launch_n: usize,
    members: &[(u64, usize, usize)],
    waiting: &mut Vec<ServiceRequest>,
    stats: &mut ServiceStats,
) {
    let mut out = vec![0f32; launch_n];
    engine.fill_uniform_f32(&mut out);
    stats.launches += 1;
    stats.numbers += launch_n as u64;
    for &(id, offset, n) in members {
        let req = &waiting[id as usize];
        let (a, b) = req.range;
        let mut slice = out[offset..offset + n].to_vec();
        if a != 0.0 || b != 1.0 {
            crate::rng::range_transform::range_transform_inplace(&mut slice, a, b);
        }
        let _ = req.reply.send(Ok(slice));
    }
    waiting.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_responses_match_dedicated_stream() {
        let svc = RngService::spawn(PlatformId::A100, 42, 1 << 20, 3);
        let r1 = svc.generate(100, (0.0, 1.0));
        let r2 = svc.generate(200, (0.0, 1.0));
        let r3 = svc.generate(44, (0.0, 1.0)); // trips max_requests=3
        let a = r1.recv().unwrap().unwrap();
        let b = r2.recv().unwrap().unwrap();
        let c = r3.recv().unwrap().unwrap();

        // The concatenation equals one dedicated stream.
        let mut want = vec![0f32; 344];
        PhiloxEngine::new(42).fill_uniform_f32(&mut want);
        let got: Vec<f32> = a.iter().chain(&b).chain(&c).copied().collect();
        assert_eq!(got, want);

        let stats = svc.shutdown().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.launches, 1);
        assert_eq!(stats.numbers, 344); // padded to /4 already exact
    }

    #[test]
    fn flush_serves_partial_batches() {
        let svc = RngService::spawn(PlatformId::A100, 7, 1 << 20, 1000);
        let r1 = svc.generate(10, (2.0, 4.0));
        svc.flush();
        let v = r1.recv().unwrap().unwrap();
        assert_eq!(v.len(), 10);
        assert!(v.iter().all(|&x| (2.0..4.0).contains(&x)));
        svc.shutdown().unwrap();
    }

    #[test]
    fn shutdown_flushes_remaining() {
        let svc = RngService::spawn(PlatformId::Vega56, 7, 1 << 20, 1000);
        let r1 = svc.generate(5, (0.0, 1.0));
        let stats = svc.shutdown().unwrap();
        assert!(r1.recv().unwrap().is_ok());
        assert_eq!(stats.requests, 1);
    }
}
