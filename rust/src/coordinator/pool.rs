//! Sharded RNG service pool: N worker shards behind a round-robin
//! dispatcher with a size-aware overflow lane (DESIGN.md S10, paper §8).
//!
//! Each shard is a worker thread owning its own (non-`Send`) backend set
//! (built through [`super::BackendRegistry::shard_set`]) and its own
//! [`RequestBatcher`]. The dispatcher assigns every request an absolute
//! offset in the *global* engine stream from an atomic cursor before
//! routing it, so the stream a requester observes is a pure function of
//! submission order — independent of shard count, batching decisions,
//! worker interleaving **and any mid-stream policy retune** (the offset is
//! assigned before the route is computed). Workers realise the
//! sub-streams with counter-based skip-ahead
//! (`VendorGenerator::set_offset`, i.e. `Engine::skip_ahead`), O(1) for
//! Philox.
//!
//! Requests at or above the [`DispatchPolicy`] threshold bypass the
//! batched shards and go to a dedicated unbatched overflow shard: a large
//! request already saturates a launch on its own, and coalescing it would
//! only add latency for the small requests sharing its batch. The lane
//! also picks the generating half of the shard's backend set — batched
//! lanes run on the host backend, the overflow lane on the device-native
//! backend (§8: "host for small workloads, GPU for larger ones") — which
//! is observationally free because every backend is bit-exact Philox.
//!
//! Serving runs **through the SYCL runtime** (DESIGN.md S13): every
//! worker owns a [`Queue`] on its lane's platform and a [`UsmArena`] of
//! recycled allocations, both reused across requests. A flush is one DAG
//! submission — one interop generate host task writing every member's
//! sub-stream straight into arena USM, at most one range-transform
//! kernel, and one event-chained D2H slice per member that becomes the
//! reply buffer ([`crate::rng::generate_batch_usm`]). At steady state the
//! generate/launch path performs zero per-request allocations — no
//! staging vecs, no device mallocs (the launch buffer is an arena hit);
//! per request only the reply payload and the substrate's per-command
//! bookkeeping remain. After each flush the worker drains the queue's
//! command records into the telemetry registry (per-class virtual
//! timings + arena counters), so autotune sees where the time actually
//! goes.
//!
//! With tiling enabled ([`PoolConfig::tiling`], the `PORTARNG_TILE` env
//! knob, or a live retune of `tile_size`/`team_width`), a flush instead
//! runs through the worker-local [`TileExecutor`] (DESIGN.md S16): the
//! generate and transform passes execute as an nd-range of independent
//! tiles on a scoped thread team — bit-identical to the serial pass
//! because every tile O(1)-seeks its own forked engine — and each tile is
//! recorded as its own ranged command, so the hazard analyzer proves tile
//! disjointness. Tiled flushes also pipeline *across* flushes: the worker
//! holds the previous flush's arena lease one flush longer (double
//! buffering), so flush N+1's generate chains behind flush N−1's events,
//! not flush N's — its compute overlaps the previous flush's D2H on the
//! virtual clock, and the achieved overlap is published as the telemetry
//! `pipeline` block.
//!
//! The policy is not frozen at construction: dispatcher and workers read
//! it through a shared lock-free [`TuningHandle`] (DESIGN.md S12), so the
//! [`autotune`](crate::autotune) controller can retune the threshold and
//! the batcher flush limits under live load without stalling the request
//! path. All service counters live in a [`TelemetryRegistry`]
//! (DESIGN.md S11) shared between workers and the pool handle — which is
//! also why shutdown can never drop in-flight counts: the registry
//! outlives the workers' ack channels.
//!
//! ## The resilience layer (DESIGN.md S15)
//!
//! The pool is *supervised*. Admission runs through an ingress gate
//! ([`IngressConfig`]: bounded depth → [`Error::Overloaded`], deadline
//! budgets → [`Error::DeadlineExceeded`]) and every accepted request is
//! recorded in an in-flight ledger
//! ([`super::ingress::InflightTable`]) — global stream offset plus a
//! clone of the caller's reply sender — *before* it reaches a shard. A
//! [`Supervisor`] thread reaps workers that die (panic, or a
//! [`crate::fault`] injected kill), respawns the shard, and re-dispatches
//! its ledger entries at their recorded offsets; because a stream is
//! addressed by offset rather than generator state, the re-delivered
//! payload is bit-identical to the fault-free answer. Transient injected
//! faults ([`Error::Injected`]) are retried through the supervisor with
//! bounded exponential backoff instead of surfacing to the caller. The
//! guarantee the chaos soak pins: every caller gets exactly the fault-free
//! bytes or a typed error — never a hang.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::fault::{self, FaultSpec, ShardFaultPlan};
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::{generate_batch_usm, generate_batch_usm_tiled, BatchSlice};
use crate::sycl::{
    CommandClass, Queue, SyclRuntimeProfile, TileExecutor, TilingSpec, UsmArena, UsmLease,
};
use crate::telemetry::{
    ArenaCounters, CommandKind, HazardCounters, Lane, ShardTelemetry, TelemetryRegistry,
    TelemetrySnapshot,
};
use crate::trace::{self, ShardWriter, Span, SpanKind, TraceConfig, Tracer};

use super::batcher::{BatchOutcome, PendingRequest, RequestBatcher};
use super::heuristic::{DispatchPolicy, Route, TuningHandle, TuningParams};
use super::ingress::{InflightTable, IngressConfig, Router};
use super::registry::BackendRegistry;
use super::supervisor::{SupMsg, Supervisor};

/// A generate request, as delivered to a shard worker.
pub struct ServiceRequest {
    /// Pool-global request id (the in-flight ledger key). Distinct from
    /// the batcher's shard-local positional id.
    pub id: u64,
    /// Numbers wanted.
    pub n: usize,
    /// Range [a, b).
    pub range: (f32, f32),
    /// Absolute offset of this request in the global engine stream.
    pub offset: u64,
    /// Admission-time deadline, if the ingress gate set one.
    pub deadline: Option<Instant>,
    /// Retry re-dispatches already performed for this request.
    pub attempt: u32,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

pub(crate) enum Msg {
    Generate(ServiceRequest),
    Flush,
    Shutdown(mpsc::Sender<()>),
}

/// Aggregate per-shard (and pool-total) service counters — a plain view
/// derived from the pool's [`TelemetryRegistry`] (the authoritative,
/// always-live store; this struct survives as the stable summary type).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests served.
    pub requests: u64,
    /// Kernel launches issued (batches).
    pub launches: u64,
    /// Numbers generated (padded launch totals).
    pub numbers: u64,
}

impl ServiceStats {
    /// Component-wise sum (pool aggregation).
    pub fn merged(self, other: ServiceStats) -> ServiceStats {
        ServiceStats {
            requests: self.requests + other.requests,
            launches: self.launches + other.launches,
            numbers: self.numbers + other.numbers,
        }
    }
}

/// Per-shard and aggregate counters returned by [`ServicePool::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// One entry per shard, dispatch order (batched shards first, then the
    /// overflow lane if configured).
    pub shards: Vec<ServiceStats>,
    /// Shards whose worker failed the shutdown handshake (died and was
    /// never respawned). Their counters are still present above — the
    /// registry outlives the workers — so the stats are *partial* only in
    /// the sense that those shards stopped counting early.
    pub lost_shards: u64,
}

impl PoolStats {
    /// Pool-wide totals.
    pub fn total(&self) -> ServiceStats {
        self.shards
            .iter()
            .copied()
            .fold(ServiceStats::default(), ServiceStats::merged)
    }

    /// The counter view of a telemetry snapshot.
    pub fn from_snapshot(snap: &TelemetrySnapshot) -> PoolStats {
        PoolStats {
            shards: snap
                .shards
                .iter()
                .map(|s| ServiceStats {
                    requests: s.requests,
                    launches: s.launches,
                    numbers: s.numbers,
                })
                .collect(),
            lost_shards: 0,
        }
    }
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Platform whose backend set each shard builds.
    pub platform: PlatformId,
    /// Seed of the single global engine stream the pool partitions.
    pub seed: u64,
    /// Batched round-robin shards (>= 1).
    pub shards: usize,
    /// Per-shard batcher: close a batch at this many queued items.
    pub max_batch: usize,
    /// Per-shard batcher: close a batch at this many queued requests.
    pub max_requests: usize,
    /// Size-aware routing; an enabled policy adds an unbatched overflow
    /// shard for requests at/above its threshold.
    pub policy: DispatchPolicy,
    /// Spawn the overflow lane even when `policy` starts disabled, so a
    /// later [`ServicePool::retune`] can enable size-aware routing without
    /// respawning the pool (the autotuner sets this).
    pub adaptive: bool,
    /// Tile-executor shape `(tile_size, team_width)` every worker starts
    /// with. `None` consults the `PORTARNG_TILE` env knob
    /// (`"tile_size,team_width"`), falling back to the serial flush shape;
    /// `Some` wins over the env. Either way the knobs stay live-retunable
    /// through [`ServicePool::retune`].
    pub tiling: Option<(usize, usize)>,
    /// Deterministic fault-injection plan (`serve --chaos`); each shard
    /// derives its own [`ShardFaultPlan`] from it. `None` (the default)
    /// costs one thread-local null check per seam.
    pub fault: Option<FaultSpec>,
    /// Admission and retry policy (depth bound, deadlines, backoff).
    pub ingress: IngressConfig,
    /// End-to-end tracing (DESIGN.md S18): per-shard span rings, the
    /// `--trace` Chrome export and the crash flight recorder. `None`
    /// (the default) keeps every record site at one relaxed static
    /// load — the bench-gated disabled path.
    pub trace: Option<TraceConfig>,
}

impl PoolConfig {
    /// Defaults: 1 MiB-numbers batches, 16 requests per batch, no
    /// overflow lane, no adaptive headroom, no fault plan, unbounded
    /// ingress.
    pub fn new(platform: PlatformId, seed: u64, shards: usize) -> PoolConfig {
        PoolConfig {
            platform,
            seed,
            shards: shards.max(1),
            max_batch: 1 << 20,
            max_requests: 16,
            policy: DispatchPolicy::disabled(),
            adaptive: false,
            tiling: None,
            fault: None,
            ingress: IngressConfig::default(),
            trace: None,
        }
    }

    /// The executor shape this config resolves to: the explicit `tiling`
    /// field, else the `PORTARNG_TILE` env knob, else serial.
    fn resolved_tiling(&self) -> Option<(usize, usize)> {
        self.tiling.or_else(tiling_from_env)
    }
}

/// Parse the `PORTARNG_TILE` env knob: `"tile_size,team_width"` (e.g.
/// `131072,4`). Malformed values are ignored rather than panicking a
/// service at spawn — the CLI rejects bad shapes at parse time instead.
fn tiling_from_env() -> Option<(usize, usize)> {
    let raw = std::env::var("PORTARNG_TILE").ok()?;
    let (t, w) = raw.split_once(',')?;
    Some((t.trim().parse().ok()?, w.trim().parse().ok()?))
}

/// Everything a shard worker needs, bundled so the supervisor can respawn
/// the worker with the *same* identity (shard id, lane, seed, telemetry,
/// fault plan, ledger) after a death — only the queue/arena/generator are
/// rebuilt, and those don't carry stream state (offsets do).
#[derive(Clone)]
pub(crate) struct WorkerCtx {
    platform: PlatformId,
    seed: u64,
    /// Shard index: the trace writer's lane and the telemetry row.
    shard: usize,
    lane: Route,
    tuning: Arc<TuningHandle>,
    telemetry: Arc<ShardTelemetry>,
    fault: Option<Arc<ShardFaultPlan>>,
    inflight: Arc<InflightTable>,
    retry_tx: mpsc::Sender<SupMsg>,
    max_retries: u32,
    tracer: Option<Arc<Tracer>>,
}

struct ShardLink {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

/// A shard's stable slot in the pool: the respawnable link to its current
/// worker thread plus the identity ([`WorkerCtx`]) every incarnation
/// shares. The dispatcher sends through it; the supervisor reaps and
/// respawns through it.
pub(crate) struct ShardSlot {
    /// Shard index (telemetry row, ledger assignment key).
    pub(crate) idx: usize,
    ctx: WorkerCtx,
    link: Mutex<ShardLink>,
}

fn spawn_worker(ctx: &WorkerCtx) -> ShardLink {
    let (tx, rx) = mpsc::channel::<Msg>();
    let ctx = ctx.clone();
    let worker = std::thread::spawn(move || {
        // Contain both genuine worker panics and injected kills: the
        // thread finishes instead of unwinding into the runtime, the
        // supervisor's sweep observes `is_finished` and respawns.
        let _ = catch_unwind(AssertUnwindSafe(|| worker_main(&ctx, &rx)));
    });
    ShardLink { tx, worker: Some(worker) }
}

impl ShardSlot {
    fn spawn(idx: usize, ctx: WorkerCtx) -> Arc<ShardSlot> {
        let link = spawn_worker(&ctx);
        Arc::new(ShardSlot { idx, ctx, link: Mutex::new(link) })
    }

    /// Deliver a message to the current worker; false if its channel is
    /// closed (worker dead — the ledger still covers its requests).
    pub(crate) fn send(&self, msg: Msg) -> bool {
        self.link.lock().unwrap().tx.send(msg).is_ok()
    }

    /// Reap a worker thread that finished without a shutdown handshake.
    /// True exactly when a dead worker was collected (caller respawns).
    pub(crate) fn reap_dead_worker(&self) -> bool {
        let mut link = self.link.lock().unwrap();
        let finished = link.worker.as_ref().is_some_and(|w| w.is_finished());
        if finished {
            if let Some(w) = link.worker.take() {
                let _ = w.join();
            }
        }
        finished
    }

    /// Replace a reaped worker with a fresh incarnation of the same shard.
    pub(crate) fn respawn(&self) {
        let mut link = self.link.lock().unwrap();
        *link = spawn_worker(&self.ctx);
    }

    /// Handshake the worker down. True on a clean drain (flush + ack +
    /// join); false when the worker was already dead — robust either way,
    /// and idempotent (a second call is a no-op success).
    pub(crate) fn shutdown_worker(&self) -> bool {
        let mut link = self.link.lock().unwrap();
        let Some(worker) = link.worker.take() else {
            return true; // already shut down (or reaped and never respawned)
        };
        let (ack, rx) = mpsc::channel();
        let clean = link.tx.send(Msg::Shutdown(ack)).is_ok() && rx.recv().is_ok();
        let _ = worker.join();
        clean
    }

    /// The shard's fault plan, if the pool runs under chaos.
    pub(crate) fn fault_plan(&self) -> Option<Arc<ShardFaultPlan>> {
        self.ctx.fault.clone()
    }
}

impl Drop for ShardSlot {
    fn drop(&mut self) {
        self.shutdown_worker();
    }
}

/// One worker incarnation. The worker builds its own engine/backends
/// (they are not `Send`). `ctx.lane` picks which half of the shard's
/// backend set generates: batched (small-request) lanes run on the host
/// backend, the overflow lane on the device-native backend — the paper's
/// §8 "host for small workloads, GPU for larger ones" applied at the
/// service layer. Both halves are bit-exact Philox, so the stream
/// invariant is unaffected by the lane choice. Counters go to
/// `ctx.telemetry` (shared with the pool); batcher limits are re-read
/// from `ctx.tuning` on every request so retunes apply without a
/// round-trip.
fn worker_main(ctx: &WorkerCtx, rx: &mpsc::Receiver<Msg>) {
    // Arm (or explicitly disarm) this worker thread's fault seams, and
    // — same idiom — install (or clear) its trace writer.
    fault::install(ctx.fault.clone());
    trace::install(
        ctx.tracer
            .as_ref()
            .map(|t| ShardWriter::new(t.clone(), ctx.shard as u32)),
    );
    let set = BackendRegistry::new().shard_set(ctx.platform);
    let backend = match ctx.lane {
        Route::Batched => set.host,
        Route::Overflow => set.native,
    };
    ctx.telemetry.set_backend(backend.name());
    let mut gen = match backend.create_generator(EngineKind::Philox4x32x10, ctx.seed) {
        Ok(g) => g,
        Err(e) => {
            // Degraded mode: the backend refused a generator; fail every
            // request with a coordinator error. Requests are still counted
            // so submitted-vs-served reconciles, and ledger entries are
            // completed so the supervisor never re-dispatches them into
            // the same dead end.
            let why = e.to_string();
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Generate(req) => {
                        ctx.telemetry.record_request(req.n);
                        ctx.telemetry.record_failure();
                        ctx.inflight.complete(req.id);
                        let _ = req.reply.send(Err(Error::Coordinator(format!(
                            "shard backend unavailable: {why}"
                        ))));
                    }
                    Msg::Flush => {}
                    Msg::Shutdown(ack) => {
                        let _ = ack.send(());
                        break;
                    }
                }
            }
            return;
        }
    };
    // Worker-owned SYCL runtime state, reused across requests
    // (DESIGN.md S13): a queue on the lane's generating platform
    // and a USM arena of recycled launch allocations. `slices` is
    // the flush scratch — capacity is retained, so steady-state
    // flushes allocate nothing.
    let queue_platform = backend.platform();
    let queue = Queue::new(
        queue_platform,
        SyclRuntimeProfile::for_platform(&queue_platform.spec()),
    );
    let arena: UsmArena<f32> = UsmArena::new();
    let mut slices: Vec<BatchSlice> = Vec::new();
    // Cross-flush pipelining state (tiled mode only; see PipelineState).
    let mut pipeline = PipelineState { prev: None, prev_end_ns: 0 };

    // The overflow lane launches every request immediately; batched
    // lanes track the live tuning limits.
    let fixed_flush = matches!(ctx.lane, Route::Overflow).then_some(1);
    let mut batcher = RequestBatcher::new(
        ctx.tuning.max_batch(),
        fixed_flush.unwrap_or_else(|| ctx.tuning.flush_requests()),
        4,
    );
    let mut waiting: Vec<ServiceRequest> = Vec::new();

    while let Ok(msg) = rx.recv() {
        // Injected worker kill: scheduled by absolute message op on the
        // shard's (respawn-surviving) plan, so each kill fires exactly
        // once. The dropped message's requests live on in the ledger.
        if let Some(plan) = &ctx.fault {
            if plan.trip_kill() {
                panic!("portarng: injected worker kill (chaos plan)");
            }
        }
        match msg {
            Msg::Generate(req) => {
                if req.deadline.is_some_and(|dl| Instant::now() > dl) {
                    ctx.telemetry.record_request(req.n);
                    ctx.telemetry.record_deadline_exceeded();
                    ctx.inflight.complete(req.id);
                    trace::with(|w| {
                        let t = w.now_ns();
                        w.record(
                            Span::event(SpanKind::ReplySend, 0, t)
                                .req(req.id)
                                .aux(req.attempt as u64)
                                .aux2(1),
                        );
                    });
                    let _ = req.reply.send(Err(Error::DeadlineExceeded));
                    continue;
                }
                if fixed_flush.is_none() {
                    batcher.set_limits(ctx.tuning.max_batch(), ctx.tuning.flush_requests());
                }
                let pending = PendingRequest {
                    id: waiting.len() as u64,
                    n: req.n,
                    stream_offset: req.offset,
                };
                ctx.telemetry.record_request(req.n);
                trace::with(|w| {
                    let t = w.now_ns();
                    w.record(
                        Span::event(SpanKind::BatcherStage, 0, t)
                            .req(req.id)
                            .aux(req.n as u64),
                    );
                });
                waiting.push(req);
                if let Some(batch) = batcher.push(pending) {
                    launch(
                        gen.as_mut(),
                        &queue,
                        &arena,
                        &mut slices,
                        &batch,
                        &mut waiting,
                        ctx,
                        &mut pipeline,
                    );
                }
            }
            Msg::Flush => {
                if let Some(batch) = batcher.flush() {
                    launch(
                        gen.as_mut(),
                        &queue,
                        &arena,
                        &mut slices,
                        &batch,
                        &mut waiting,
                        ctx,
                        &mut pipeline,
                    );
                }
            }
            Msg::Shutdown(ack) => {
                if let Some(batch) = batcher.flush() {
                    launch(
                        gen.as_mut(),
                        &queue,
                        &arena,
                        &mut slices,
                        &batch,
                        &mut waiting,
                        ctx,
                        &mut pipeline,
                    );
                }
                let _ = ack.send(());
                break;
            }
        }
    }
    // Return the double buffer's held lease before the arena drops, so a
    // clean shutdown reports `leaked == 0` even mid-pipeline — and
    // republish the settled counters, because the registry outlives the
    // worker and post-shutdown snapshots must see this recycle.
    if let Some(prev) = pipeline.prev.take() {
        prev.recycle();
        let a = arena.stats();
        ctx.telemetry.set_arena(ArenaCounters {
            checkouts: a.checkouts,
            hits: a.hits,
            misses: a.misses,
            recycles: a.recycles,
            leaked: a.leaked,
            pooled: a.pooled,
            pooled_bytes: a.pooled_bytes,
        });
    }
    // Graceful-exit drain (channel closed with requests still queued —
    // only reachable when the pool handle vanished without a handshake):
    // typed errors, never leaked reply channels.
    for req in waiting.drain(..) {
        ctx.inflight.complete(req.id);
        let _ = req.reply.send(Err(Error::ShardLost));
    }
}

/// Cross-flush pipelining state, one per worker (DESIGN.md S16).
///
/// `prev` is the previous tiled flush's arena lease, recycled one flush
/// *late*: holding it keeps its allocation out of the pool, so the next
/// checkout lands on the *other* allocation (double buffering) and its
/// generate chains behind flush N-1's events instead of flush N's — the
/// new flush's compute overlaps the previous flush's D2H on the virtual
/// clock. `prev_end_ns` is the virtual end of the previous flush's last
/// command: the reference the telemetry `pipeline` block measures
/// achieved overlap against.
struct PipelineState<'a> {
    prev: Option<UsmLease<'a, f32>>,
    prev_end_ns: u64,
}

/// One coalesced flush through the SYCL runtime: the closed batch becomes
/// ONE interop generate host task (every member generated at its *global*
/// stream offset via O(1) skip-ahead, straight into recycled arena USM —
/// so responses are independent of batching and sharding), at most ONE
/// range-transform kernel over the launch buffer, and one event-chained
/// D2H slice per member that becomes the member's reply buffer. The
/// padded `launch_n` tail lives inside the arena allocation, which is
/// recycled across flushes: at steady state the generate path allocates
/// no staging and mallocs no device memory per request (the reply
/// payload is the D2H output — the handoff, not scratch).
///
/// With tiling live ([`TuningHandle::tile_size`] > 0 and
/// [`TuningHandle::team_width`] > 1) the flush instead runs through the
/// worker-local [`TileExecutor`]: per-tile generate work items (each
/// member's sub-stream seeked in O(1), so payloads stay bit-identical to
/// the serial path) and double-buffered leases that pipeline this
/// flush's compute under the previous flush's D2H (see
/// [`PipelineState`]).
fn launch<'a>(
    gen: &mut dyn crate::backends::VendorGenerator,
    queue: &Queue,
    arena: &'a UsmArena<f32>,
    slices: &mut Vec<BatchSlice>,
    batch: &BatchOutcome,
    waiting: &mut Vec<ServiceRequest>,
    ctx: &WorkerCtx,
    pipeline: &mut PipelineState<'a>,
) {
    let telemetry = &ctx.telemetry;
    let wall_start = Instant::now();
    // Claim the flush id (per-shard monotone, survives respawns) and the
    // launch start time up front, so every span this flush records —
    // including the cmd.* spans joining the hazard DAG — shares one id.
    let mut flush_id = crate::trace::NONE_ID;
    let mut t_flush = 0u64;
    trace::with(|w| {
        flush_id = w.next_flush_id();
        t_flush = w.now_ns();
    });
    slices.clear();
    slices.extend(batch.members.iter().map(|m| BatchSlice {
        buffer_offset: m.batch_offset,
        stream_offset: m.stream_offset,
        n: m.n,
        range: waiting[m.id as usize].range,
    }));

    // Executor shape is read fresh from the live tuning handle each
    // flush: a retune of `tile_size` / `team_width` (or a retune back to
    // serial) takes effect on the very next launch, no worker restart.
    let spec = TilingSpec::new(ctx.tuning.tile_size(), ctx.tuning.team_width());

    // Checkout inherits the allocation's pending events (the previous
    // flush's D2H copies) and the generate chains behind them — the USM
    // reuse hazard the paper's §4.1 warns about, handled explicitly. In
    // tiled mode the previous flush's lease is still held in `pipeline`,
    // so this checkout double-buffers onto a different allocation and
    // inherits flush N-1's events, not flush N's.
    let mut lease = arena.checkout(queue, batch.launch_n.max(1));
    let outcome = if spec.is_serial() {
        generate_batch_usm(
            queue,
            gen,
            slices.as_slice(),
            batch.launch_n,
            lease.buffer(),
            Some(lease.generation()),
            lease.deps(),
        )
    } else {
        let executor = TileExecutor::new(spec.team_width);
        generate_batch_usm_tiled(
            queue,
            gen,
            slices.as_slice(),
            batch.launch_n,
            lease.buffer(),
            Some(lease.generation()),
            lease.deps(),
            spec,
            &executor,
        )
    };
    let (results, pending, tiles) = match outcome {
        Ok(b) => {
            let pending = b.last_events();
            (b.payloads, pending, b.tiles)
        }
        Err(e) => {
            // Whole-flush failure (empty batches never reach here): fail
            // every member rather than dropping replies — preserving
            // transiency, so an injected submit fault stays retryable
            // per member. Nothing was submitted, so the allocation's
            // inherited hazards stay pending for its next user.
            let injected = e.injected_site();
            let why = e.to_string();
            let fail: Vec<Result<Vec<f32>>> = batch
                .members
                .iter()
                .map(|_| match injected {
                    Some(site) => Err(Error::Injected { site }),
                    None => Err(Error::Coordinator(why.clone())),
                })
                .collect();
            (fail, lease.deps().to_vec(), Vec::new())
        }
    };
    lease.set_pending(pending);
    if spec.is_serial() {
        // Park now: the arena is warm before the next flush. Also drain
        // any lease stranded by a retune from tiled back to serial, or
        // the double buffer would hold an allocation forever.
        if let Some(prev) = pipeline.prev.take() {
            prev.recycle();
        }
        lease.recycle();
    } else {
        // Double buffer: hold THIS lease one flush longer, recycle the
        // previous one — the two allocations alternate, and the next
        // checkout's inherited deps are one flush stale (the overlap).
        if let Some(prev) = pipeline.prev.replace(lease) {
            prev.recycle();
        }
    }

    // The launch span covers submission through lease handoff; it is
    // recorded before the cmd.* spans so the flush's span chain is
    // seq-ordered launch < commands < replies.
    trace::with(|w| {
        let t = w.now_ns();
        w.record(
            Span::range(SpanKind::FlushLaunch, 0, t_flush, t)
                .flush(flush_id)
                .aux(batch.launch_n as u64)
                .aux2(batch.members.len() as u64),
        );
    });

    let mut payload = 0u64;
    for r in &results {
        if let Ok(v) = r {
            payload += v.len() as u64;
        }
    }

    // Per-command-class virtual timings for this flush, drained (not
    // cloned) so a long-lived worker queue's record log stays bounded.
    let records = queue.drain_records();
    // Prove the flush race-free (the analyzer's per-kind counts feed the
    // `hazards` telemetry block; under PORTARNG_HAZARD_CHECK the drain
    // above already panicked on any diagnostic).
    let hazard_report = crate::sycl::analyze_hazards(&records);
    telemetry.record_hazards(HazardCounters::from_window(
        records.len() as u64,
        hazard_report.external_deps as u64,
        hazard_report.counts(),
    ));
    // Pipeline bookkeeping walks the same drained window: the first
    // generate's virtual start against the previous flush's virtual end
    // is the achieved cross-flush overlap (zero in serial mode, where
    // the generate chains directly behind the previous D2H).
    let mut first_generate_ns = u64::MAX;
    let mut last_end_ns = 0u64;
    for r in &records {
        if matches!(r.class, CommandClass::Generate) {
            first_generate_ns = first_generate_ns.min(r.virt_start_ns);
        }
        last_end_ns = last_end_ns.max(r.virt_end_ns);
    }
    for r in records {
        let kind = match r.class {
            CommandClass::Generate => CommandKind::Generate,
            CommandClass::Transform => CommandKind::Transform,
            CommandClass::TransferD2H => CommandKind::TransferD2H,
            _ => CommandKind::Other,
        };
        telemetry.record_command(kind, r.virt_end_ns - r.virt_start_ns);
        // One span per generate/transform/d2h record: virtual-clock
        // timestamps, command id + lease generation as the join keys
        // against the hazard analyzer's DAG.
        trace::with(|w| {
            if let Some(span) = crate::trace::span_for_record(&r, w.lane(), flush_id) {
                w.record(span);
            }
        });
    }
    if !spec.is_serial() {
        let overlap = if first_generate_ns == u64::MAX {
            0
        } else {
            pipeline.prev_end_ns.saturating_sub(first_generate_ns)
        };
        telemetry.record_pipeline_flush(overlap);
        trace::with(|w| {
            let t = w.now_ns();
            w.record(
                Span::event(SpanKind::PipelineOverlap, 0, t)
                    .flush(flush_id)
                    .aux(overlap),
            );
        });
        telemetry.record_tiles(
            tiles.len() as u64,
            tiles.iter().map(|t| t.wall_ns).sum(),
        );
    }
    pipeline.prev_end_ns = pipeline.prev_end_ns.max(last_end_ns);
    let a = arena.stats();
    telemetry.set_arena(ArenaCounters {
        checkouts: a.checkouts,
        hits: a.hits,
        misses: a.misses,
        recycles: a.recycles,
        leaked: a.leaked,
        pooled: a.pooled,
        pooled_bytes: a.pooled_bytes,
    });
    if let Some(plan) = &ctx.fault {
        telemetry.set_faults_injected(plan.injected());
    }

    // Record BEFORE sending any reply: a requester that has its numbers
    // must be able to see this launch in a snapshot (otherwise
    // drain-then-snapshot callers race the last batch's counters).
    telemetry.record_launch(
        batch.members.len(),
        payload,
        batch.launch_n as u64,
        wall_start.elapsed().as_nanos() as u64,
    );
    for (m, reply) in batch.members.iter().zip(results) {
        let req = &waiting[m.id as usize];
        match reply {
            Ok(v) => {
                trace::with(|w| {
                    let t = w.now_ns();
                    w.record(
                        Span::event(SpanKind::ReplySend, 0, t)
                            .req(req.id)
                            .flush(flush_id)
                            .aux(req.attempt as u64)
                            .aux2(0),
                    );
                });
                // Send THEN complete: a worker dying between the two
                // leaves the entry to the supervisor, whose re-dispatch
                // duplicates a bit-identical reply — benign, the caller
                // reads exactly one.
                let _ = req.reply.send(Ok(v));
                ctx.inflight.complete(req.id);
            }
            Err(e) => {
                let site = e.injected_site();
                if e.is_transient() && req.attempt < ctx.max_retries {
                    // Hand the request to the supervisor (no reply — the
                    // ledger entry stays live for the re-dispatch). If the
                    // supervisor is gone (pool shutting down), fall
                    // through to a direct typed error instead of hanging
                    // the caller.
                    let retry = SupMsg::Retry {
                        id: req.id,
                        site: site.unwrap_or("generate"),
                    };
                    if ctx.retry_tx.send(retry).is_ok() {
                        continue;
                    }
                }
                telemetry.record_failure();
                trace::with(|w| {
                    let t = w.now_ns();
                    w.record(
                        Span::event(SpanKind::ReplySend, 0, t)
                            .req(req.id)
                            .flush(flush_id)
                            .aux(req.attempt as u64)
                            .aux2(1),
                    );
                });
                let _ = req.reply.send(Err(e));
                ctx.inflight.complete(req.id);
            }
        }
    }
    waiting.clear();
}

/// Handle to a running sharded RNG service pool.
pub struct ServicePool {
    slots: Vec<Arc<ShardSlot>>,
    n_batched: usize,
    overflow: Option<usize>,
    tuning: Arc<TuningHandle>,
    telemetry: Arc<TelemetryRegistry>,
    router: Arc<Router>,
    inflight: Arc<InflightTable>,
    ingress: IngressConfig,
    supervisor: Option<Supervisor>,
    tracer: Option<Arc<Tracer>>,
    cursor: AtomicU64,
}

impl ServicePool {
    /// Spawn the pool: `cfg.shards` batched round-robin workers plus (when
    /// the policy is enabled or `cfg.adaptive` is set) one unbatched
    /// overflow worker, plus the supervisor thread watching them all.
    pub fn spawn(cfg: PoolConfig) -> ServicePool {
        let n_batched = cfg.shards.max(1);
        let want_overflow = cfg.policy.is_enabled() || cfg.adaptive;
        let mut lanes = vec![Lane::Batched; n_batched];
        if want_overflow {
            lanes.push(Lane::Overflow);
        }
        let telemetry = TelemetryRegistry::new(cfg.platform, &lanes);
        let mut params = TuningParams::new(cfg.policy, cfg.max_requests, cfg.max_batch);
        if let Some((tile_size, team_width)) = cfg.resolved_tiling() {
            params = params.tiled(tile_size, team_width);
        }
        let tuning = Arc::new(TuningHandle::new(params));
        let inflight = InflightTable::new(cfg.ingress.redispatch_cap);
        let tracer = cfg.trace.as_ref().map(|tc| Tracer::new(lanes.len(), tc));
        let (sup_tx, sup_rx) = mpsc::channel();
        let mut slots = Vec::with_capacity(lanes.len());
        for (i, &lane) in lanes.iter().enumerate() {
            let route = match lane {
                Lane::Batched => Route::Batched,
                Lane::Overflow => Route::Overflow,
            };
            slots.push(ShardSlot::spawn(
                i,
                WorkerCtx {
                    platform: cfg.platform,
                    seed: cfg.seed,
                    shard: i,
                    lane: route,
                    tuning: tuning.clone(),
                    telemetry: telemetry.shard(i),
                    fault: cfg.fault.as_ref().map(|spec| spec.shard_plan(i)),
                    inflight: inflight.clone(),
                    retry_tx: sup_tx.clone(),
                    max_retries: cfg.ingress.max_retries,
                    tracer: tracer.clone(),
                },
            ));
        }
        let overflow = want_overflow.then(|| slots.len() - 1);
        let router = Router::new(n_batched, overflow, tuning.clone());
        let supervisor = Supervisor::spawn(
            slots.clone(),
            inflight.clone(),
            telemetry.clone(),
            router.clone(),
            cfg.ingress,
            tracer.clone(),
            sup_tx,
            sup_rx,
        );
        ServicePool {
            slots,
            n_batched,
            overflow,
            tuning,
            telemetry,
            router,
            inflight,
            ingress: cfg.ingress,
            supervisor: Some(supervisor),
            tracer,
            cursor: AtomicU64::new(0),
        }
    }

    /// Batched (round-robin) shard count, excluding the overflow lane.
    pub fn shard_count(&self) -> usize {
        self.n_batched
    }

    /// Whether an overflow lane is attached.
    pub fn has_overflow_lane(&self) -> bool {
        self.overflow.is_some()
    }

    /// The pool's metrics registry (share freely; snapshots are cheap).
    pub fn telemetry(&self) -> &Arc<TelemetryRegistry> {
        &self.telemetry
    }

    /// The live tuning handle the dispatcher and workers read.
    pub fn tuning(&self) -> &Arc<TuningHandle> {
        &self.tuning
    }

    /// The pool's trace recorder, when [`PoolConfig::trace`] configured
    /// one. Snapshot it for the Chrome export; it stays valid (and keeps
    /// its rings) after shutdown, so exporting after the pool is torn
    /// down sees every span.
    pub fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.clone()
    }

    /// Requests admitted but not yet answered (the depth the shed gate
    /// compares against).
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Publish new tuning parameters (threshold + batcher limits). Takes
    /// effect for subsequent requests without blocking in-flight ones;
    /// per-request streams are unaffected (offsets are assigned before
    /// routing). Enabling a threshold on a pool spawned without an
    /// overflow lane (`adaptive: false`) is a no-op routing-wise: requests
    /// keep round-robining, which is safe but unpartitioned.
    pub fn retune(&self, params: TuningParams) -> u64 {
        self.telemetry.record_retune();
        self.tuning.retune(params)
    }

    /// Submit a request; returns the receiver for the reply. The reply is
    /// exactly the sub-stream a dedicated engine skipped to this request's
    /// global offset would produce — or a typed error
    /// ([`Error::Overloaded`] at admission, [`Error::DeadlineExceeded`] /
    /// [`Error::ShardLost`] later); the receiver always yields exactly one
    /// of the two, never a hang.
    pub fn generate(&self, n: usize, range: (f32, f32)) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        let in_flight = self.inflight.len();
        if in_flight >= self.ingress.max_inflight {
            // Shed before touching the cursor or dispatch counters: a
            // rejected request must not perturb the global stream.
            self.telemetry.record_shed();
            let _ = reply.send(Err(Error::Overloaded {
                in_flight,
                limit: self.ingress.max_inflight,
            }));
            return rx;
        }
        let deadline = self.ingress.deadline.map(|d| Instant::now() + d);
        let t_admit = self.tracer.as_ref().map(|t| t.now_ns());
        let offset = self.cursor.fetch_add(n as u64, Ordering::Relaxed);
        let (idx, overflow) = self.router.route(n);
        self.telemetry.record_dispatch(overflow);
        let id = self
            .inflight
            .register(n, range, offset, idx, deadline, reply.clone());
        if let Some(tr) = &self.tracer {
            // The admit span goes to the coordinator ring (not the
            // shard's): a shard's flight dump then contains exactly what
            // its worker observed, which is what makes dumps
            // deterministic under an op-counted kill.
            tr.record_coord(
                Span::range(SpanKind::IngressAdmit, idx as u32, t_admit.unwrap(), tr.now_ns())
                    .req(id)
                    .aux(n as u64)
                    .aux2(overflow as u64),
            );
        }
        // A failed send means the worker died between routing and
        // delivery: the ledger entry stays, and the supervisor's sweep
        // respawns the shard and re-dispatches it.
        let _ = self.slots[idx].send(Msg::Generate(ServiceRequest {
            id,
            n,
            range,
            offset,
            deadline,
            attempt: 0,
            reply,
        }));
        rx
    }

    /// Force pending requests out of every shard.
    pub fn flush(&self) {
        for slot in &self.slots {
            let _ = slot.send(Msg::Flush);
        }
    }

    /// Live counter view (no shutdown required).
    pub fn stats_now(&self) -> PoolStats {
        PoolStats::from_snapshot(&self.telemetry.snapshot())
    }

    /// Stop the supervisor, then all workers, returning per-shard counters
    /// (with `lost_shards` counting workers that failed the handshake).
    /// Counts come from the shared telemetry registry, so a shard whose
    /// ack channel closed early (worker panic) still reports everything it
    /// recorded; any ledger straggler is failed with a typed error rather
    /// than left hanging.
    pub fn shutdown(mut self) -> Result<PoolStats> {
        Ok(self.shutdown_inner())
    }

    /// Idempotent teardown shared by [`ServicePool::shutdown`] and `Drop`.
    /// Ordering is load-bearing (see the supervisor module docs): stop the
    /// supervisor (drains queued retries with typed errors), handshake the
    /// workers (flushes batchers), then sweep the ledger so no caller can
    /// be left holding a channel nobody will answer.
    fn shutdown_inner(&mut self) -> PoolStats {
        if let Some(mut sup) = self.supervisor.take() {
            sup.stop();
        }
        let mut lost = 0u64;
        for slot in &self.slots {
            if !slot.shutdown_worker() {
                lost += 1;
            }
        }
        for e in self.inflight.drain_all() {
            self.telemetry.shard(e.shard).record_failure();
            let _ = e.reply.send(Err(Error::ShardLost));
        }
        // Settle the telemetry `trace` block: the supervisor published it
        // every sweep tick, but spans recorded after its last tick (the
        // final flush's replies) would otherwise be missed.
        if let Some(tr) = &self.tracer {
            self.telemetry.set_trace_activity(tr.spans_recorded(), tr.spans_dropped());
        }
        let mut stats = self.stats_now();
        stats.lost_shards = lost;
        stats
    }
}

impl Drop for ServicePool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Engine, PhiloxEngine};
    use std::time::Duration;

    fn dedicated(seed: u64, offset: u64, n: usize) -> Vec<f32> {
        let mut e = PhiloxEngine::with_offset(seed, offset);
        let mut out = vec![0f32; n];
        e.fill_uniform_f32(&mut out);
        out
    }

    #[test]
    fn single_shard_batched_matches_dedicated_stream() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 42, 1));
        let sizes = [100usize, 200, 44];
        let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
        pool.flush();
        let mut offset = 0u64;
        for (rx, &n) in rxs.iter().zip(&sizes) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got, dedicated(42, offset, n));
            offset += n as u64;
        }
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.total().requests, 3);
        assert_eq!(stats.total().launches, 1);
        assert_eq!(stats.total().numbers, 344);
        assert_eq!(stats.lost_shards, 0);
    }

    #[test]
    fn streams_are_invariant_under_shard_count_and_padding() {
        // Sizes deliberately NOT multiples of 4: the pad tail must not
        // shift anybody's sub-stream.
        let sizes = [3usize, 5, 17, 1, 64, 7];
        for shards in [1usize, 2, 4] {
            let mut cfg = PoolConfig::new(PlatformId::Vega56, 7, shards);
            cfg.max_requests = 2;
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx.recv().unwrap().unwrap();
                assert_eq!(got, dedicated(7, offset, n), "shards={shards} n={n}");
                offset += n as u64;
            }
            pool.shutdown().unwrap();
        }
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 1, 4);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        let rxs: Vec<_> = (0..8).map(|_| pool.generate(16, (0.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 4);
        for s in &stats.shards {
            assert_eq!(s.requests, 2);
        }
    }

    #[test]
    fn overflow_lane_takes_large_requests_unbatched() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 9, 2);
        cfg.policy = DispatchPolicy::fixed(1000);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        assert!(pool.has_overflow_lane());

        let small = pool.generate(10, (0.0, 1.0));
        let large = pool.generate(5000, (0.0, 1.0)); // >= threshold: overflow
        // The overflow lane launches immediately, without a flush.
        let big = large.recv().unwrap().unwrap();
        assert_eq!(big, dedicated(9, 10, 5000));
        pool.flush();
        assert_eq!(small.recv().unwrap().unwrap(), dedicated(9, 0, 10));

        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 3); // 2 batched + overflow
        let overflow = stats.shards[2];
        assert_eq!(overflow.requests, 1);
        assert_eq!(overflow.launches, 1);
        assert_eq!(stats.total().requests, 2);
    }

    #[test]
    fn exact_threshold_routes_overflow_with_exact_offsets() {
        // Boundary bookkeeping: a request of exactly `threshold` numbers
        // goes to the overflow lane, one number under stays batched, and
        // the global offsets reflect pure submission order either way.
        let mut cfg = PoolConfig::new(PlatformId::A100, 21, 1);
        cfg.policy = DispatchPolicy::fixed(1000);
        let pool = ServicePool::spawn(cfg);
        let under = pool.generate(999, (0.0, 1.0)); // offset 0, batched
        let at = pool.generate(1000, (0.0, 1.0)); // offset 999, overflow
        assert_eq!(at.recv().unwrap().unwrap(), dedicated(21, 999, 1000));
        pool.flush();
        assert_eq!(under.recv().unwrap().unwrap(), dedicated(21, 0, 999));

        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.dispatched_batched, 1);
        assert_eq!(snap.dispatched_overflow, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn zero_sized_requests_are_served_and_do_not_shift_streams() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 17, 1));
        let empty = pool.generate(0, (0.0, 1.0));
        let after = pool.generate(32, (0.0, 1.0));
        pool.flush();
        assert_eq!(empty.recv().unwrap().unwrap(), Vec::<f32>::new());
        // n == 0 advances the cursor by zero: the next request still
        // starts at offset 0.
        assert_eq!(after.recv().unwrap().unwrap(), dedicated(17, 0, 32));
        pool.shutdown().unwrap();
    }

    #[test]
    fn max_requests_one_degenerates_to_immediate_launches() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 23, 1);
        cfg.max_requests = 1;
        let pool = ServicePool::spawn(cfg);
        // Every request closes its own batch: replies arrive without any
        // flush, and offsets still follow submission order.
        let a = pool.generate(7, (0.0, 1.0));
        let b = pool.generate(9, (0.0, 1.0));
        assert_eq!(a.recv().unwrap().unwrap(), dedicated(23, 0, 7));
        assert_eq!(b.recv().unwrap().unwrap(), dedicated(23, 7, 9));
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.total().launches, 2);
    }

    #[test]
    fn range_transform_applied_per_request() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::Rome7742, 3, 2));
        let rx = pool.generate(64, (2.0, 4.0));
        pool.flush();
        let got = rx.recv().unwrap().unwrap();
        assert!(got.iter().all(|&x| (2.0..4.0).contains(&x)));
        let mut want = dedicated(3, 0, 64);
        crate::rng::range_transform::range_transform_inplace(&mut want, 2.0, 4.0);
        assert_eq!(got, want);
        pool.shutdown().unwrap();
    }

    #[test]
    fn telemetry_labels_lanes_and_backends() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 5, 1);
        cfg.policy = DispatchPolicy::fixed(1000);
        let pool = ServicePool::spawn(cfg);
        let small = pool.generate(10, (0.0, 1.0));
        let large = pool.generate(2000, (0.0, 1.0));
        large.recv().unwrap().unwrap();
        pool.flush();
        small.recv().unwrap().unwrap();

        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.platform, PlatformId::A100);
        assert_eq!(snap.dispatched_batched, 1);
        assert_eq!(snap.dispatched_overflow, 1);
        assert_eq!(snap.shards.len(), 2);
        assert_eq!(snap.shards[0].lane, Lane::Batched);
        assert_eq!(snap.shards[1].lane, Lane::Overflow);
        // Batched lane generates on the host backend, overflow on the
        // device-native one (workers report in at spawn).
        assert_eq!(snap.shards[0].backend, "oneMKL-x86");
        assert_eq!(snap.shards[1].backend, "cuRAND");
        assert_eq!(snap.shards[0].delivered, 10);
        assert_eq!(snap.shards[1].delivered, 2000);
        assert_eq!(snap.shards[1].launch_ns.count, 1);
        assert_eq!(snap.total_failures(), 0);
        // Fault-free pool: the resilience block stays all-zero.
        assert!(!snap.resilience_totals().any());
        pool.shutdown().unwrap();
    }

    #[test]
    fn adaptive_pool_retunes_overflow_on_and_off() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 13, 2);
        cfg.adaptive = true; // lane exists even though policy starts disabled
        let pool = ServicePool::spawn(cfg);
        assert!(pool.has_overflow_lane());
        assert!(!pool.tuning().policy().is_enabled());

        // Everything batches while disabled.
        let a = pool.generate(5000, (0.0, 1.0));
        // Enable mid-stream: subsequent large requests overflow.
        pool.retune(TuningParams {
            threshold: 1000,
            flush_requests: 16,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        });
        let b = pool.generate(5000, (0.0, 1.0));
        let got_b = b.recv().unwrap().unwrap(); // immediate: unbatched lane
        pool.flush();
        let got_a = a.recv().unwrap().unwrap();

        // Offsets follow submission order regardless of the retune.
        assert_eq!(got_a, dedicated(13, 0, 5000));
        assert_eq!(got_b, dedicated(13, 5000, 5000));

        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.retunes, 1);
        assert_eq!(snap.dispatched_batched, 1);
        assert_eq!(snap.dispatched_overflow, 1);
        assert_eq!(pool.tuning().generation(), 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn flushes_are_single_dag_submissions_with_recycled_arena() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 11, 1);
        cfg.max_requests = 3;
        let pool = ServicePool::spawn(cfg);
        // 4 waves x 3 requests: 4 flushes on one shard, all landing in the
        // same arena size class.
        for _ in 0..4 {
            let rxs: Vec<_> = (0..3).map(|i| pool.generate(100 + i, (0.0, 2.0))).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        }
        let snap = pool.telemetry().snapshot();
        let s = &snap.shards[0];
        assert_eq!(s.launches, 4);
        // Exactly ONE generate host task and ONE transform kernel per
        // flush, one D2H slice per request — the S13 submission shape.
        assert_eq!(s.generate.cmds, 4);
        assert_eq!(s.transform.cmds, 4);
        assert_eq!(s.d2h.cmds, 12);
        assert!(s.generate.virt_ns > 0);
        // Warm arena: one cold malloc, every later flush recycles.
        assert_eq!(s.arena.checkouts, 4);
        assert_eq!(s.arena.misses, 1);
        assert_eq!(s.arena.hits, 3);
        assert_eq!(s.arena.recycles, 4);
        assert_eq!(s.arena.pooled, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn tiled_flush_matches_serial_payloads_with_per_tile_commands() {
        // One flush of 100 (ranged) + 101 + 66 pads to launch_n 268: five
        // 64-element tiles through the worker's TileExecutor. The ranged
        // member covers launch 0..100, so only tiles 0 and 1 carry a
        // transform kernel.
        let mut cfg = PoolConfig::new(PlatformId::A100, 19, 1);
        cfg.max_requests = 3;
        cfg.tiling = Some((64, 4));
        let pool = ServicePool::spawn(cfg);
        let a = pool.generate(100, (0.0, 2.0));
        let b = pool.generate(101, (0.0, 1.0));
        let c = pool.generate(66, (0.0, 1.0));

        // Payloads are bit-identical to the serial dedicated stream.
        let mut want_a = dedicated(19, 0, 100);
        crate::rng::range_transform::range_transform_inplace(&mut want_a, 0.0, 2.0);
        assert_eq!(a.recv().unwrap().unwrap(), want_a);
        assert_eq!(b.recv().unwrap().unwrap(), dedicated(19, 100, 101));
        assert_eq!(c.recv().unwrap().unwrap(), dedicated(19, 201, 66));

        let snap = pool.telemetry().snapshot();
        let s = &snap.shards[0];
        // Per-tile submission shape: one generate per tile, transforms
        // only where a ranged member overlaps, one D2H per member — and
        // the analyzer proves the widened DAG race-free.
        assert_eq!(s.generate.cmds, 5);
        assert_eq!(s.transform.cmds, 2);
        assert_eq!(s.d2h.cmds, 3);
        assert_eq!(s.tiles.tiles, 7);
        assert_eq!(s.pipeline.flushes, 1);
        assert_eq!(s.hazards.windows, 1);
        assert!(s.hazards.clean());
        pool.shutdown().unwrap();
    }

    #[test]
    fn tiled_flushes_double_buffer_the_arena_and_report_zero_leaks() {
        // max_requests 1: every request closes its own flush. With the
        // executor on, the worker holds each flush's lease one flush
        // longer (cross-flush pipelining), so two same-class allocations
        // alternate: cold misses on flushes 1 AND 2, hits after, and
        // each flush recycles the PREVIOUS lease — 3 recycles across 4
        // flushes, with the 4th lease still held.
        let mut cfg = PoolConfig::new(PlatformId::A100, 29, 1);
        cfg.max_requests = 1;
        cfg.tiling = Some((64, 2));
        let pool = ServicePool::spawn(cfg);
        for i in 0..4u64 {
            let rx = pool.generate(100, (0.0, 1.0));
            assert_eq!(rx.recv().unwrap().unwrap(), dedicated(29, i * 100, 100));
        }
        let snap = pool.telemetry().snapshot();
        let s = &snap.shards[0];
        assert_eq!(s.arena.checkouts, 4);
        assert_eq!(s.arena.misses, 2);
        assert_eq!(s.arena.hits, 2);
        assert_eq!(s.arena.recycles, 3);
        assert_eq!(s.arena.pooled, 1);
        assert_eq!(s.pipeline.flushes, 4);
        // Each 100-element launch splits into a 64 + 36 tile pair.
        assert_eq!(s.tiles.tiles, 8);
        assert!(s.hazards.clean());

        // Shutdown returns the held lease: nothing leaks, and the
        // registry (kept alive across the shutdown) sees that final
        // recycle land both allocations back in the pool.
        let keep = pool.telemetry().clone();
        pool.shutdown().unwrap();
        let after = keep.snapshot();
        assert_eq!(after.shards[0].arena.recycles, 4);
        assert_eq!(after.shards[0].arena.leaked, 0);
        assert_eq!(after.shards[0].arena.pooled, 2);
    }

    #[test]
    fn overflow_lane_rides_the_usm_event_chain() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 4, 1);
        cfg.policy = DispatchPolicy::fixed(100);
        let pool = ServicePool::spawn(cfg);
        for i in 0..3 {
            let rx = pool.generate(5000 + i, (0.0, 1.0)); // unbatched, canonical
            rx.recv().unwrap().unwrap();
        }
        let snap = pool.telemetry().snapshot();
        let ov = &snap.shards[1];
        assert_eq!(ov.lane, Lane::Overflow);
        // One generate + one D2H per request, no transform (unit range);
        // the device-lane copies carry real virtual transfer time.
        assert_eq!(ov.generate.cmds, 3);
        assert_eq!(ov.transform.cmds, 0);
        assert_eq!(ov.d2h.cmds, 3);
        assert!(ov.d2h.virt_ns > 0);
        // Size classes: 5000-ish requests share one class — 1 miss.
        assert_eq!(ov.arena.checkouts, 3);
        assert_eq!(ov.arena.misses, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn stats_survive_shutdown_and_live_view_matches() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::Vega56, 2, 2));
        let rxs: Vec<_> = (0..6).map(|_| pool.generate(50, (0.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let live = pool.stats_now();
        assert_eq!(live.total().requests, 6);
        let keep = pool.telemetry().clone();
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.total().requests, 6);
        // The registry outlives the pool: counts are never dropped with
        // the workers' channels.
        assert_eq!(keep.snapshot().total_requests(), 6);
    }

    #[test]
    fn shed_gate_rejects_at_capacity_without_advancing_the_stream() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 31, 1);
        cfg.ingress.max_inflight = 2;
        let pool = ServicePool::spawn(cfg);
        // Two admitted requests sit in the batcher (default flush limits
        // are far away), so the third hits the depth bound.
        let a = pool.generate(10, (0.0, 1.0));
        let b = pool.generate(10, (0.0, 1.0));
        let shed = pool.generate(10, (0.0, 1.0));
        match shed.recv().unwrap() {
            Err(Error::Overloaded { in_flight, limit }) => {
                assert_eq!((in_flight, limit), (2, 2));
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        pool.flush();
        // The shed request never touched the cursor: the admitted pair
        // still covers offsets 0..20.
        assert_eq!(a.recv().unwrap().unwrap(), dedicated(31, 0, 10));
        assert_eq!(b.recv().unwrap().unwrap(), dedicated(31, 10, 10));
        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.requests_shed, 1);
        assert_eq!(snap.total_requests(), 2);
        pool.shutdown().unwrap();
    }

    #[test]
    fn expired_deadlines_fail_typed_at_the_worker() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 33, 1);
        cfg.ingress.deadline = Some(Duration::ZERO);
        let pool = ServicePool::spawn(cfg);
        let rx = pool.generate(10, (0.0, 1.0));
        pool.flush();
        match rx.recv().unwrap() {
            Err(Error::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.resilience_totals().deadline_exceeded, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn injected_worker_kill_respawns_and_replies_bit_identically() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 77, 1);
        // First worker message triggers the kill; the plan survives the
        // respawn, so the re-dispatched message (op 2) sails through.
        cfg.fault = Some(FaultSpec::parse("kill=0@1").unwrap());
        let pool = ServicePool::spawn(cfg);
        let rx = pool.generate(64, (0.0, 1.0));
        pool.flush();
        let got = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("supervisor must re-dispatch, not hang the caller")
            .unwrap();
        assert_eq!(got, dedicated(77, 0, 64));
        let snap = pool.telemetry().snapshot();
        assert!(snap.resilience_totals().shard_respawns >= 1);
        assert!(snap.resilience_totals().faults_injected >= 1);
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.lost_shards, 0); // respawned shard shuts down cleanly
    }

    #[test]
    fn reply_channels_never_leak_on_early_worker_exit() {
        // Regression for the early-exit reply leak: requests queued behind
        // a batcher when the pool goes away must see a typed error (or
        // their payload), never a disconnected channel.
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 55, 2));
        let rxs: Vec<_> = (0..4).map(|_| pool.generate(25, (0.0, 1.0))).collect();
        drop(pool); // no explicit flush/shutdown: Drop must drain
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(payload)) => assert_eq!(payload.len(), 25),
                Ok(Err(Error::ShardLost)) => {}
                Ok(Err(other)) => panic!("unexpected error: {other:?}"),
                Err(_) => panic!("reply channel leaked: caller would hang"),
            }
        }
    }
}
