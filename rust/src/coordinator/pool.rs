//! Sharded RNG service pool: N worker shards behind a round-robin
//! dispatcher with a size-aware overflow lane (DESIGN.md S10, paper §8).
//!
//! Each shard is a worker thread owning its own (non-`Send`) backend set
//! (built through [`super::BackendRegistry::shard_set`]) and its own
//! [`RequestBatcher`]. The dispatcher assigns every request an absolute
//! offset in the *global* engine stream from an atomic cursor before
//! routing it, so the stream a requester observes is a pure function of
//! submission order — independent of shard count, batching decisions,
//! worker interleaving **and any mid-stream policy retune** (the offset is
//! assigned before the route is computed). Workers realise the
//! sub-streams with counter-based skip-ahead
//! (`VendorGenerator::set_offset`, i.e. `Engine::skip_ahead`), O(1) for
//! Philox.
//!
//! Requests at or above the [`DispatchPolicy`] threshold bypass the
//! batched shards and go to a dedicated unbatched overflow shard: a large
//! request already saturates a launch on its own, and coalescing it would
//! only add latency for the small requests sharing its batch. The lane
//! also picks the generating half of the shard's backend set — batched
//! lanes run on the host backend, the overflow lane on the device-native
//! backend (§8: "host for small workloads, GPU for larger ones") — which
//! is observationally free because every backend is bit-exact Philox.
//!
//! Serving runs **through the SYCL runtime** (DESIGN.md S13): every
//! worker owns a [`Queue`] on its lane's platform and a [`UsmArena`] of
//! recycled allocations, both reused across requests. A flush is one DAG
//! submission — one interop generate host task writing every member's
//! sub-stream straight into arena USM, at most one range-transform
//! kernel, and one event-chained D2H slice per member that becomes the
//! reply buffer ([`crate::rng::generate_batch_usm`]). At steady state the
//! generate/launch path performs zero per-request allocations — no
//! staging vecs, no device mallocs (the launch buffer is an arena hit);
//! per request only the reply payload and the substrate's per-command
//! bookkeeping remain. After each flush the worker drains the queue's
//! command records into the telemetry registry (per-class virtual
//! timings + arena counters), so autotune sees where the time actually
//! goes.
//!
//! The policy is not frozen at construction: dispatcher and workers read
//! it through a shared lock-free [`TuningHandle`] (DESIGN.md S12), so the
//! [`autotune`](crate::autotune) controller can retune the threshold and
//! the batcher flush limits under live load without stalling the request
//! path. All service counters live in a [`TelemetryRegistry`]
//! (DESIGN.md S11) shared between workers and the pool handle — which is
//! also why shutdown can never drop in-flight counts: the registry
//! outlives the workers' ack channels.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::{generate_batch_usm, BatchSlice};
use crate::sycl::{CommandClass, Queue, SyclRuntimeProfile, UsmArena};
use crate::telemetry::{
    ArenaCounters, CommandKind, HazardCounters, Lane, ShardTelemetry, TelemetryRegistry,
    TelemetrySnapshot,
};

use super::batcher::{BatchOutcome, PendingRequest, RequestBatcher};
use super::heuristic::{DispatchPolicy, Route, TuningHandle, TuningParams};
use super::registry::BackendRegistry;

/// A generate request, as delivered to a shard worker.
pub struct ServiceRequest {
    /// Numbers wanted.
    pub n: usize,
    /// Range [a, b).
    pub range: (f32, f32),
    /// Absolute offset of this request in the global engine stream.
    pub offset: u64,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Generate(ServiceRequest),
    Flush,
    Shutdown(mpsc::Sender<()>),
}

/// Aggregate per-shard (and pool-total) service counters — a plain view
/// derived from the pool's [`TelemetryRegistry`] (the authoritative,
/// always-live store; this struct survives as the stable summary type).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests served.
    pub requests: u64,
    /// Kernel launches issued (batches).
    pub launches: u64,
    /// Numbers generated (padded launch totals).
    pub numbers: u64,
}

impl ServiceStats {
    /// Component-wise sum (pool aggregation).
    pub fn merged(self, other: ServiceStats) -> ServiceStats {
        ServiceStats {
            requests: self.requests + other.requests,
            launches: self.launches + other.launches,
            numbers: self.numbers + other.numbers,
        }
    }
}

/// Per-shard and aggregate counters returned by [`ServicePool::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// One entry per shard, dispatch order (batched shards first, then the
    /// overflow lane if configured).
    pub shards: Vec<ServiceStats>,
}

impl PoolStats {
    /// Pool-wide totals.
    pub fn total(&self) -> ServiceStats {
        self.shards
            .iter()
            .copied()
            .fold(ServiceStats::default(), ServiceStats::merged)
    }

    /// The counter view of a telemetry snapshot.
    pub fn from_snapshot(snap: &TelemetrySnapshot) -> PoolStats {
        PoolStats {
            shards: snap
                .shards
                .iter()
                .map(|s| ServiceStats {
                    requests: s.requests,
                    launches: s.launches,
                    numbers: s.numbers,
                })
                .collect(),
        }
    }
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Platform whose backend set each shard builds.
    pub platform: PlatformId,
    /// Seed of the single global engine stream the pool partitions.
    pub seed: u64,
    /// Batched round-robin shards (>= 1).
    pub shards: usize,
    /// Per-shard batcher: close a batch at this many queued items.
    pub max_batch: usize,
    /// Per-shard batcher: close a batch at this many queued requests.
    pub max_requests: usize,
    /// Size-aware routing; an enabled policy adds an unbatched overflow
    /// shard for requests at/above its threshold.
    pub policy: DispatchPolicy,
    /// Spawn the overflow lane even when `policy` starts disabled, so a
    /// later [`ServicePool::retune`] can enable size-aware routing without
    /// respawning the pool (the autotuner sets this).
    pub adaptive: bool,
}

impl PoolConfig {
    /// Defaults: 1 MiB-numbers batches, 16 requests per batch, no
    /// overflow lane, no adaptive headroom.
    pub fn new(platform: PlatformId, seed: u64, shards: usize) -> PoolConfig {
        PoolConfig {
            platform,
            seed,
            shards: shards.max(1),
            max_batch: 1 << 20,
            max_requests: 16,
            policy: DispatchPolicy::disabled(),
            adaptive: false,
        }
    }
}

struct ShardHandle {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Spawn one worker shard. The worker builds its own engine/backends
    /// (they are not `Send`). `lane` picks which half of the shard's
    /// backend set generates: batched (small-request) lanes run on the
    /// host backend, the overflow lane on the device-native backend — the
    /// paper's §8 "host for small workloads, GPU for larger ones" applied
    /// at the service layer. Both halves are bit-exact Philox, so the
    /// stream invariant is unaffected by the lane choice. Counters go to
    /// `telemetry` (shared with the pool); batcher limits are re-read from
    /// `tuning` on every request so retunes apply without a round-trip.
    fn spawn(
        platform: PlatformId,
        seed: u64,
        tuning: Arc<TuningHandle>,
        telemetry: Arc<ShardTelemetry>,
        lane: Route,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let set = BackendRegistry::new().shard_set(platform);
            let backend = match lane {
                Route::Batched => set.host,
                Route::Overflow => set.native,
            };
            telemetry.set_backend(backend.name());
            let mut gen = match backend.create_generator(EngineKind::Philox4x32x10, seed) {
                Ok(g) => g,
                Err(e) => {
                    // Degraded mode: the backend refused a generator; fail
                    // every request with a coordinator error. Requests are
                    // still counted so submitted-vs-served reconciles.
                    let why = e.to_string();
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Generate(req) => {
                                telemetry.record_request(req.n);
                                telemetry.record_failure();
                                let _ = req.reply.send(Err(Error::Coordinator(format!(
                                    "shard backend unavailable: {why}"
                                ))));
                            }
                            Msg::Flush => {}
                            Msg::Shutdown(ack) => {
                                let _ = ack.send(());
                                break;
                            }
                        }
                    }
                    return;
                }
            };
            // Worker-owned SYCL runtime state, reused across requests
            // (DESIGN.md S13): a queue on the lane's generating platform
            // and a USM arena of recycled launch allocations. `slices` is
            // the flush scratch — capacity is retained, so steady-state
            // flushes allocate nothing.
            let queue_platform = backend.platform();
            let queue = Queue::new(
                queue_platform,
                SyclRuntimeProfile::for_platform(&queue_platform.spec()),
            );
            let arena: UsmArena<f32> = UsmArena::new();
            let mut slices: Vec<BatchSlice> = Vec::new();

            // The overflow lane launches every request immediately; batched
            // lanes track the live tuning limits.
            let fixed_flush = matches!(lane, Route::Overflow).then_some(1);
            let mut batcher = RequestBatcher::new(
                tuning.max_batch(),
                fixed_flush.unwrap_or_else(|| tuning.flush_requests()),
                4,
            );
            let mut waiting: Vec<ServiceRequest> = Vec::new();

            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Generate(req) => {
                        if fixed_flush.is_none() {
                            batcher.set_limits(tuning.max_batch(), tuning.flush_requests());
                        }
                        let pending = PendingRequest {
                            id: waiting.len() as u64,
                            n: req.n,
                            stream_offset: req.offset,
                        };
                        telemetry.record_request(req.n);
                        waiting.push(req);
                        if let Some(batch) = batcher.push(pending) {
                            launch(
                                gen.as_mut(),
                                &queue,
                                &arena,
                                &mut slices,
                                &batch,
                                &mut waiting,
                                &telemetry,
                            );
                        }
                    }
                    Msg::Flush => {
                        if let Some(batch) = batcher.flush() {
                            launch(
                                gen.as_mut(),
                                &queue,
                                &arena,
                                &mut slices,
                                &batch,
                                &mut waiting,
                                &telemetry,
                            );
                        }
                    }
                    Msg::Shutdown(ack) => {
                        if let Some(batch) = batcher.flush() {
                            launch(
                                gen.as_mut(),
                                &queue,
                                &arena,
                                &mut slices,
                                &batch,
                                &mut waiting,
                                &telemetry,
                            );
                        }
                        let _ = ack.send(());
                        break;
                    }
                }
            }
        });
        ShardHandle { tx, worker: Some(worker) }
    }

    /// Drain and stop the worker. Counter-safe by construction: stats live
    /// in the shared telemetry registry, so a worker that died (closed ack
    /// channel) loses no counts — we just join and move on.
    fn shutdown(&mut self) {
        let (ack, rx) = mpsc::channel();
        if self.tx.send(Msg::Shutdown(ack)).is_ok() {
            let _ = rx.recv();
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One coalesced flush through the SYCL runtime: the closed batch becomes
/// ONE interop generate host task (every member generated at its *global*
/// stream offset via O(1) skip-ahead, straight into recycled arena USM —
/// so responses are independent of batching and sharding), at most ONE
/// range-transform kernel over the launch buffer, and one event-chained
/// D2H slice per member that becomes the member's reply buffer. The
/// padded `launch_n` tail lives inside the arena allocation, which is
/// recycled across flushes: at steady state the generate path allocates
/// no staging and mallocs no device memory per request (the reply
/// payload is the D2H output — the handoff, not scratch).
fn launch(
    gen: &mut dyn crate::backends::VendorGenerator,
    queue: &Queue,
    arena: &UsmArena<f32>,
    slices: &mut Vec<BatchSlice>,
    batch: &BatchOutcome,
    waiting: &mut Vec<ServiceRequest>,
    telemetry: &ShardTelemetry,
) {
    let wall_start = Instant::now();
    slices.clear();
    slices.extend(batch.members.iter().map(|m| BatchSlice {
        buffer_offset: m.batch_offset,
        stream_offset: m.stream_offset,
        n: m.n,
        range: waiting[m.id as usize].range,
    }));

    // Checkout inherits the allocation's pending events (the previous
    // flush's D2H copies) and the generate chains behind them — the USM
    // reuse hazard the paper's §4.1 warns about, handled explicitly.
    let mut lease = arena.checkout(queue, batch.launch_n.max(1));
    let outcome = generate_batch_usm(
        queue,
        gen,
        slices.as_slice(),
        batch.launch_n,
        lease.buffer(),
        Some(lease.generation()),
        lease.deps(),
    );
    let (results, pending) = match outcome {
        Ok(b) => {
            let pending = b.last_events();
            (b.payloads, pending)
        }
        Err(e) => {
            // Defensive whole-flush failure (empty batches never reach
            // here): fail every member rather than dropping replies.
            // Nothing was submitted, so the allocation's inherited
            // hazards stay pending for its next user.
            let why = e.to_string();
            let fail: Vec<Result<Vec<f32>>> = batch
                .members
                .iter()
                .map(|_| Err(Error::Coordinator(why.clone())))
                .collect();
            (fail, lease.deps().to_vec())
        }
    };
    lease.set_pending(pending);
    lease.recycle(); // park now: the arena is warm before the next flush

    let mut payload = 0u64;
    for r in &results {
        match r {
            Ok(v) => payload += v.len() as u64,
            Err(_) => telemetry.record_failure(),
        }
    }

    // Per-command-class virtual timings for this flush, drained (not
    // cloned) so a long-lived worker queue's record log stays bounded.
    let records = queue.drain_records();
    // Prove the flush race-free (the analyzer's per-kind counts feed the
    // v3 `hazards` telemetry block; under PORTARNG_HAZARD_CHECK the drain
    // above already panicked on any diagnostic).
    let hazard_report = crate::sycl::analyze_hazards(&records);
    telemetry.record_hazards(HazardCounters::from_window(
        records.len() as u64,
        hazard_report.external_deps as u64,
        hazard_report.counts(),
    ));
    for r in records {
        let kind = match r.class {
            CommandClass::Generate => CommandKind::Generate,
            CommandClass::Transform => CommandKind::Transform,
            CommandClass::TransferD2H => CommandKind::TransferD2H,
            _ => CommandKind::Other,
        };
        telemetry.record_command(kind, r.virt_end_ns - r.virt_start_ns);
    }
    let a = arena.stats();
    telemetry.set_arena(ArenaCounters {
        checkouts: a.checkouts,
        hits: a.hits,
        misses: a.misses,
        recycles: a.recycles,
        leaked: a.leaked,
        pooled: a.pooled,
        pooled_bytes: a.pooled_bytes,
    });

    // Record BEFORE sending any reply: a requester that has its numbers
    // must be able to see this launch in a snapshot (otherwise
    // drain-then-snapshot callers race the last batch's counters).
    telemetry.record_launch(
        batch.members.len(),
        payload,
        batch.launch_n as u64,
        wall_start.elapsed().as_nanos() as u64,
    );
    for (m, reply) in batch.members.iter().zip(results) {
        let _ = waiting[m.id as usize].reply.send(reply);
    }
    waiting.clear();
}

/// Handle to a running sharded RNG service pool.
pub struct ServicePool {
    shards: Vec<ShardHandle>,
    n_batched: usize,
    overflow: Option<usize>,
    tuning: Arc<TuningHandle>,
    telemetry: Arc<TelemetryRegistry>,
    next: AtomicUsize,
    cursor: AtomicU64,
}

impl ServicePool {
    /// Spawn the pool: `cfg.shards` batched round-robin workers plus (when
    /// the policy is enabled or `cfg.adaptive` is set) one unbatched
    /// overflow worker.
    pub fn spawn(cfg: PoolConfig) -> ServicePool {
        let n_batched = cfg.shards.max(1);
        let want_overflow = cfg.policy.is_enabled() || cfg.adaptive;
        let mut lanes = vec![Lane::Batched; n_batched];
        if want_overflow {
            lanes.push(Lane::Overflow);
        }
        let telemetry = TelemetryRegistry::new(cfg.platform, &lanes);
        let tuning = Arc::new(TuningHandle::new(TuningParams::new(
            cfg.policy,
            cfg.max_requests,
            cfg.max_batch,
        )));
        let mut shards = Vec::with_capacity(lanes.len());
        for (i, &lane) in lanes.iter().enumerate() {
            let route = match lane {
                Lane::Batched => Route::Batched,
                Lane::Overflow => Route::Overflow,
            };
            shards.push(ShardHandle::spawn(
                cfg.platform,
                cfg.seed,
                tuning.clone(),
                telemetry.shard(i),
                route,
            ));
        }
        let overflow = want_overflow.then(|| shards.len() - 1);
        ServicePool {
            shards,
            n_batched,
            overflow,
            tuning,
            telemetry,
            next: AtomicUsize::new(0),
            cursor: AtomicU64::new(0),
        }
    }

    /// Batched (round-robin) shard count, excluding the overflow lane.
    pub fn shard_count(&self) -> usize {
        self.n_batched
    }

    /// Whether an overflow lane is attached.
    pub fn has_overflow_lane(&self) -> bool {
        self.overflow.is_some()
    }

    /// The pool's metrics registry (share freely; snapshots are cheap).
    pub fn telemetry(&self) -> &Arc<TelemetryRegistry> {
        &self.telemetry
    }

    /// The live tuning handle the dispatcher and workers read.
    pub fn tuning(&self) -> &Arc<TuningHandle> {
        &self.tuning
    }

    /// Publish new tuning parameters (threshold + batcher limits). Takes
    /// effect for subsequent requests without blocking in-flight ones;
    /// per-request streams are unaffected (offsets are assigned before
    /// routing). Enabling a threshold on a pool spawned without an
    /// overflow lane (`adaptive: false`) is a no-op routing-wise: requests
    /// keep round-robining, which is safe but unpartitioned.
    pub fn retune(&self, params: TuningParams) -> u64 {
        self.telemetry.record_retune();
        self.tuning.retune(params)
    }

    /// Submit a request; returns the receiver for the reply. The reply is
    /// exactly the sub-stream a dedicated engine skipped to this request's
    /// global offset would produce.
    pub fn generate(&self, n: usize, range: (f32, f32)) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        let offset = self.cursor.fetch_add(n as u64, Ordering::Relaxed);
        let idx = match (self.overflow, self.tuning.policy().route(n)) {
            (Some(ov), Route::Overflow) => {
                self.telemetry.record_dispatch(true);
                ov
            }
            _ => {
                self.telemetry.record_dispatch(false);
                self.next.fetch_add(1, Ordering::Relaxed) % self.n_batched
            }
        };
        let _ = self.shards[idx]
            .tx
            .send(Msg::Generate(ServiceRequest { n, range, offset, reply }));
        rx
    }

    /// Force pending requests out of every shard.
    pub fn flush(&self) {
        for shard in &self.shards {
            let _ = shard.tx.send(Msg::Flush);
        }
    }

    /// Live counter view (no shutdown required).
    pub fn stats_now(&self) -> PoolStats {
        PoolStats::from_snapshot(&self.telemetry.snapshot())
    }

    /// Stop all workers, returning per-shard counters. Counts come from
    /// the shared telemetry registry, so a shard whose ack channel closed
    /// early (worker panic) still reports everything it recorded.
    pub fn shutdown(mut self) -> Result<PoolStats> {
        for shard in &mut self.shards {
            shard.shutdown();
        }
        Ok(self.stats_now())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Engine, PhiloxEngine};

    fn dedicated(seed: u64, offset: u64, n: usize) -> Vec<f32> {
        let mut e = PhiloxEngine::with_offset(seed, offset);
        let mut out = vec![0f32; n];
        e.fill_uniform_f32(&mut out);
        out
    }

    #[test]
    fn single_shard_batched_matches_dedicated_stream() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 42, 1));
        let sizes = [100usize, 200, 44];
        let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
        pool.flush();
        let mut offset = 0u64;
        for (rx, &n) in rxs.iter().zip(&sizes) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got, dedicated(42, offset, n));
            offset += n as u64;
        }
        let stats = pool.shutdown().unwrap().total();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.launches, 1);
        assert_eq!(stats.numbers, 344);
    }

    #[test]
    fn streams_are_invariant_under_shard_count_and_padding() {
        // Sizes deliberately NOT multiples of 4: the pad tail must not
        // shift anybody's sub-stream.
        let sizes = [3usize, 5, 17, 1, 64, 7];
        for shards in [1usize, 2, 4] {
            let mut cfg = PoolConfig::new(PlatformId::Vega56, 7, shards);
            cfg.max_requests = 2;
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx.recv().unwrap().unwrap();
                assert_eq!(got, dedicated(7, offset, n), "shards={shards} n={n}");
                offset += n as u64;
            }
            pool.shutdown().unwrap();
        }
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 1, 4);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        let rxs: Vec<_> = (0..8).map(|_| pool.generate(16, (0.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 4);
        for s in &stats.shards {
            assert_eq!(s.requests, 2);
        }
    }

    #[test]
    fn overflow_lane_takes_large_requests_unbatched() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 9, 2);
        cfg.policy = DispatchPolicy::fixed(1000);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        assert!(pool.has_overflow_lane());

        let small = pool.generate(10, (0.0, 1.0));
        let large = pool.generate(5000, (0.0, 1.0)); // >= threshold: overflow
        // The overflow lane launches immediately, without a flush.
        let big = large.recv().unwrap().unwrap();
        assert_eq!(big, dedicated(9, 10, 5000));
        pool.flush();
        assert_eq!(small.recv().unwrap().unwrap(), dedicated(9, 0, 10));

        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 3); // 2 batched + overflow
        let overflow = stats.shards[2];
        assert_eq!(overflow.requests, 1);
        assert_eq!(overflow.launches, 1);
        assert_eq!(stats.total().requests, 2);
    }

    #[test]
    fn range_transform_applied_per_request() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::Rome7742, 3, 2));
        let rx = pool.generate(64, (2.0, 4.0));
        pool.flush();
        let got = rx.recv().unwrap().unwrap();
        assert!(got.iter().all(|&x| (2.0..4.0).contains(&x)));
        let mut want = dedicated(3, 0, 64);
        crate::rng::range_transform::range_transform_inplace(&mut want, 2.0, 4.0);
        assert_eq!(got, want);
        pool.shutdown().unwrap();
    }

    #[test]
    fn telemetry_labels_lanes_and_backends() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 5, 1);
        cfg.policy = DispatchPolicy::fixed(1000);
        let pool = ServicePool::spawn(cfg);
        let small = pool.generate(10, (0.0, 1.0));
        let large = pool.generate(2000, (0.0, 1.0));
        large.recv().unwrap().unwrap();
        pool.flush();
        small.recv().unwrap().unwrap();

        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.platform, PlatformId::A100);
        assert_eq!(snap.dispatched_batched, 1);
        assert_eq!(snap.dispatched_overflow, 1);
        assert_eq!(snap.shards.len(), 2);
        assert_eq!(snap.shards[0].lane, Lane::Batched);
        assert_eq!(snap.shards[1].lane, Lane::Overflow);
        // Batched lane generates on the host backend, overflow on the
        // device-native one (workers report in at spawn).
        assert_eq!(snap.shards[0].backend, "oneMKL-x86");
        assert_eq!(snap.shards[1].backend, "cuRAND");
        assert_eq!(snap.shards[0].delivered, 10);
        assert_eq!(snap.shards[1].delivered, 2000);
        assert_eq!(snap.shards[1].launch_ns.count, 1);
        assert_eq!(snap.total_failures(), 0);
        pool.shutdown().unwrap();
    }

    #[test]
    fn adaptive_pool_retunes_overflow_on_and_off() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 13, 2);
        cfg.adaptive = true; // lane exists even though policy starts disabled
        let pool = ServicePool::spawn(cfg);
        assert!(pool.has_overflow_lane());
        assert!(!pool.tuning().policy().is_enabled());

        // Everything batches while disabled.
        let a = pool.generate(5000, (0.0, 1.0));
        // Enable mid-stream: subsequent large requests overflow.
        pool.retune(TuningParams { threshold: 1000, flush_requests: 16, max_batch: 1 << 20 });
        let b = pool.generate(5000, (0.0, 1.0));
        let got_b = b.recv().unwrap().unwrap(); // immediate: unbatched lane
        pool.flush();
        let got_a = a.recv().unwrap().unwrap();

        // Offsets follow submission order regardless of the retune.
        assert_eq!(got_a, dedicated(13, 0, 5000));
        assert_eq!(got_b, dedicated(13, 5000, 5000));

        let snap = pool.telemetry().snapshot();
        assert_eq!(snap.retunes, 1);
        assert_eq!(snap.dispatched_batched, 1);
        assert_eq!(snap.dispatched_overflow, 1);
        assert_eq!(pool.tuning().generation(), 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn flushes_are_single_dag_submissions_with_recycled_arena() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 11, 1);
        cfg.max_requests = 3;
        let pool = ServicePool::spawn(cfg);
        // 4 waves x 3 requests: 4 flushes on one shard, all landing in the
        // same arena size class.
        for _ in 0..4 {
            let rxs: Vec<_> = (0..3).map(|i| pool.generate(100 + i, (0.0, 2.0))).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        }
        let snap = pool.telemetry().snapshot();
        let s = &snap.shards[0];
        assert_eq!(s.launches, 4);
        // Exactly ONE generate host task and ONE transform kernel per
        // flush, one D2H slice per request — the S13 submission shape.
        assert_eq!(s.generate.cmds, 4);
        assert_eq!(s.transform.cmds, 4);
        assert_eq!(s.d2h.cmds, 12);
        assert!(s.generate.virt_ns > 0);
        // Warm arena: one cold malloc, every later flush recycles.
        assert_eq!(s.arena.checkouts, 4);
        assert_eq!(s.arena.misses, 1);
        assert_eq!(s.arena.hits, 3);
        assert_eq!(s.arena.recycles, 4);
        assert_eq!(s.arena.pooled, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn overflow_lane_rides_the_usm_event_chain() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 4, 1);
        cfg.policy = DispatchPolicy::fixed(100);
        let pool = ServicePool::spawn(cfg);
        for i in 0..3 {
            let rx = pool.generate(5000 + i, (0.0, 1.0)); // unbatched, canonical
            rx.recv().unwrap().unwrap();
        }
        let snap = pool.telemetry().snapshot();
        let ov = &snap.shards[1];
        assert_eq!(ov.lane, Lane::Overflow);
        // One generate + one D2H per request, no transform (unit range);
        // the device-lane copies carry real virtual transfer time.
        assert_eq!(ov.generate.cmds, 3);
        assert_eq!(ov.transform.cmds, 0);
        assert_eq!(ov.d2h.cmds, 3);
        assert!(ov.d2h.virt_ns > 0);
        // Size classes: 5000-ish requests share one class — 1 miss.
        assert_eq!(ov.arena.checkouts, 3);
        assert_eq!(ov.arena.misses, 1);
        pool.shutdown().unwrap();
    }

    #[test]
    fn stats_survive_shutdown_and_live_view_matches() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::Vega56, 2, 2));
        let rxs: Vec<_> = (0..6).map(|_| pool.generate(50, (0.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let live = pool.stats_now();
        assert_eq!(live.total().requests, 6);
        let keep = pool.telemetry().clone();
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.total().requests, 6);
        // The registry outlives the pool: counts are never dropped with
        // the workers' channels.
        assert_eq!(keep.snapshot().total_requests(), 6);
    }
}
