//! Sharded RNG service pool: N worker shards behind a round-robin
//! dispatcher with a size-aware overflow lane (DESIGN.md S10, paper §8).
//!
//! Each shard is a worker thread owning its own (non-`Send`) backend set
//! (built through [`super::BackendRegistry::shard_set`]) and its own
//! [`RequestBatcher`]. The dispatcher assigns every request an absolute
//! offset in the *global* engine stream from an atomic cursor before
//! routing it, so the stream a requester observes is a pure function of
//! submission order — independent of shard count, batching decisions and
//! worker interleaving. Workers realise the sub-streams with counter-based
//! skip-ahead (`VendorGenerator::set_offset`, i.e. `Engine::skip_ahead`),
//! O(1) for Philox.
//!
//! Requests at or above the [`DispatchPolicy`] threshold bypass the
//! batched shards and go to a dedicated unbatched overflow shard: a large
//! request already saturates a launch on its own, and coalescing it would
//! only add latency for the small requests sharing its batch. The lane
//! also picks the generating half of the shard's backend set — batched
//! lanes run on the host backend, the overflow lane on the device-native
//! backend (§8: "host for small workloads, GPU for larger ones") — which
//! is observationally free because every backend is bit-exact Philox.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::platform::PlatformId;
use crate::rng::engines::EngineKind;
use crate::rng::Distribution;

use super::batcher::{BatchOutcome, PendingRequest, RequestBatcher};
use super::heuristic::{DispatchPolicy, Route};
use super::registry::BackendRegistry;

/// A generate request, as delivered to a shard worker.
pub struct ServiceRequest {
    /// Numbers wanted.
    pub n: usize,
    /// Range [a, b).
    pub range: (f32, f32),
    /// Absolute offset of this request in the global engine stream.
    pub offset: u64,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Generate(ServiceRequest),
    Flush,
    Shutdown(mpsc::Sender<ServiceStats>),
}

/// Aggregate per-shard (and pool-total) service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests served.
    pub requests: u64,
    /// Kernel launches issued (batches).
    pub launches: u64,
    /// Numbers generated (padded launch totals).
    pub numbers: u64,
}

impl ServiceStats {
    /// Component-wise sum (pool aggregation).
    pub fn merged(self, other: ServiceStats) -> ServiceStats {
        ServiceStats {
            requests: self.requests + other.requests,
            launches: self.launches + other.launches,
            numbers: self.numbers + other.numbers,
        }
    }
}

/// Per-shard and aggregate counters returned by [`ServicePool::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// One entry per shard, dispatch order (batched shards first, then the
    /// overflow lane if configured).
    pub shards: Vec<ServiceStats>,
}

impl PoolStats {
    /// Pool-wide totals.
    pub fn total(&self) -> ServiceStats {
        self.shards
            .iter()
            .copied()
            .fold(ServiceStats::default(), ServiceStats::merged)
    }
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Platform whose backend set each shard builds.
    pub platform: PlatformId,
    /// Seed of the single global engine stream the pool partitions.
    pub seed: u64,
    /// Batched round-robin shards (>= 1).
    pub shards: usize,
    /// Per-shard batcher: close a batch at this many queued items.
    pub max_batch: usize,
    /// Per-shard batcher: close a batch at this many queued requests.
    pub max_requests: usize,
    /// Size-aware routing; an enabled policy adds an unbatched overflow
    /// shard for requests at/above its threshold.
    pub policy: DispatchPolicy,
}

impl PoolConfig {
    /// Defaults: 1 MiB-numbers batches, 16 requests per batch, no
    /// overflow lane.
    pub fn new(platform: PlatformId, seed: u64, shards: usize) -> PoolConfig {
        PoolConfig {
            platform,
            seed,
            shards: shards.max(1),
            max_batch: 1 << 20,
            max_requests: 16,
            policy: DispatchPolicy::disabled(),
        }
    }
}

struct ShardHandle {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Spawn one worker shard. The worker builds its own engine/backends
    /// (they are not `Send`). `lane` picks which half of the shard's
    /// backend set generates: batched (small-request) lanes run on the
    /// host backend, the overflow lane on the device-native backend — the
    /// paper's §8 "host for small workloads, GPU for larger ones" applied
    /// at the service layer. Both halves are bit-exact Philox, so the
    /// stream invariant is unaffected by the lane choice.
    fn spawn(
        platform: PlatformId,
        seed: u64,
        max_batch: usize,
        max_requests: usize,
        lane: Route,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || {
            let set = BackendRegistry::new().shard_set(platform);
            let backend = match lane {
                Route::Batched => set.host,
                Route::Overflow => set.native,
            };
            let mut gen = match backend.create_generator(EngineKind::Philox4x32x10, seed) {
                Ok(g) => g,
                Err(e) => {
                    // Degraded mode: the backend refused a generator; fail
                    // every request with a coordinator error. Requests are
                    // still counted so submitted-vs-served reconciles.
                    let why = e.to_string();
                    let mut stats = ServiceStats::default();
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Generate(req) => {
                                stats.requests += 1;
                                let _ = req.reply.send(Err(Error::Coordinator(format!(
                                    "shard backend unavailable: {why}"
                                ))));
                            }
                            Msg::Flush => {}
                            Msg::Shutdown(ack) => {
                                let _ = ack.send(stats);
                                break;
                            }
                        }
                    }
                    return;
                }
            };
            let mut batcher = RequestBatcher::new(max_batch, max_requests, 4);
            let mut waiting: Vec<ServiceRequest> = Vec::new();
            let mut stats = ServiceStats::default();

            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Generate(req) => {
                        let pending = PendingRequest {
                            id: waiting.len() as u64,
                            n: req.n,
                            stream_offset: req.offset,
                        };
                        waiting.push(req);
                        stats.requests += 1;
                        if let Some(batch) = batcher.push(pending) {
                            launch(gen.as_mut(), &batch, &mut waiting, &mut stats);
                        }
                    }
                    Msg::Flush => {
                        if let Some(batch) = batcher.flush() {
                            launch(gen.as_mut(), &batch, &mut waiting, &mut stats);
                        }
                    }
                    Msg::Shutdown(ack) => {
                        if let Some(batch) = batcher.flush() {
                            launch(gen.as_mut(), &batch, &mut waiting, &mut stats);
                        }
                        let _ = ack.send(stats);
                        break;
                    }
                }
            }
        });
        ShardHandle { tx, worker: Some(worker) }
    }

    fn shutdown(&mut self) -> Result<ServiceStats> {
        let (ack, rx) = mpsc::channel();
        self.tx
            .send(Msg::Shutdown(ack))
            .map_err(|_| Error::Coordinator("shard worker gone".into()))?;
        let stats = rx
            .recv()
            .map_err(|_| Error::Coordinator("shard worker dropped ack".into()))?;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        Ok(stats)
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (ack, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(ack));
            let _ = w.join();
        }
    }
}

/// One coalesced kernel launch over a closed batch: every member's
/// payload is generated at the member's *global* stream offset via
/// counter-based skip-ahead, so responses are independent of batching and
/// sharding. Generation goes straight into each member's reply buffer —
/// the padded `launch_n` exists only in the launch accounting (kernel
/// block granularity), not as allocated scratch.
fn launch(
    gen: &mut dyn crate::backends::VendorGenerator,
    batch: &BatchOutcome,
    waiting: &mut Vec<ServiceRequest>,
    stats: &mut ServiceStats,
) {
    stats.launches += 1;
    stats.numbers += batch.launch_n as u64;
    let canonical = Distribution::uniform(0.0, 1.0);
    for m in &batch.members {
        let req = &waiting[m.id as usize];
        let mut payload = vec![0f32; m.n];
        let generated = gen
            .set_offset(m.stream_offset)
            .and_then(|()| gen.generate_canonical(&canonical, &mut payload));
        let reply = match generated {
            Ok(()) => {
                let (a, b) = req.range;
                if a != 0.0 || b != 1.0 {
                    crate::rng::range_transform::range_transform_inplace(&mut payload, a, b);
                }
                Ok(payload)
            }
            Err(e) => Err(e),
        };
        let _ = req.reply.send(reply);
    }
    waiting.clear();
}

/// Handle to a running sharded RNG service pool.
pub struct ServicePool {
    shards: Vec<ShardHandle>,
    n_batched: usize,
    overflow: Option<usize>,
    policy: DispatchPolicy,
    next: AtomicUsize,
    cursor: AtomicU64,
}

impl ServicePool {
    /// Spawn the pool: `cfg.shards` batched round-robin workers plus (when
    /// the policy is enabled) one unbatched overflow worker.
    pub fn spawn(cfg: PoolConfig) -> ServicePool {
        let n_batched = cfg.shards.max(1);
        let mut shards = Vec::with_capacity(n_batched + 1);
        for _ in 0..n_batched {
            shards.push(ShardHandle::spawn(
                cfg.platform,
                cfg.seed,
                cfg.max_batch,
                cfg.max_requests,
                Route::Batched,
            ));
        }
        let overflow = if cfg.policy.is_enabled() {
            // max_requests = 1: every overflow request launches immediately.
            shards.push(ShardHandle::spawn(
                cfg.platform,
                cfg.seed,
                cfg.max_batch,
                1,
                Route::Overflow,
            ));
            Some(shards.len() - 1)
        } else {
            None
        };
        ServicePool {
            shards,
            n_batched,
            overflow,
            policy: cfg.policy,
            next: AtomicUsize::new(0),
            cursor: AtomicU64::new(0),
        }
    }

    /// Batched (round-robin) shard count, excluding the overflow lane.
    pub fn shard_count(&self) -> usize {
        self.n_batched
    }

    /// Whether an overflow lane is attached.
    pub fn has_overflow_lane(&self) -> bool {
        self.overflow.is_some()
    }

    /// Submit a request; returns the receiver for the reply. The reply is
    /// exactly the sub-stream a dedicated engine skipped to this request's
    /// global offset would produce.
    pub fn generate(&self, n: usize, range: (f32, f32)) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        let offset = self.cursor.fetch_add(n as u64, Ordering::Relaxed);
        let idx = match (self.overflow, self.policy.route(n)) {
            (Some(ov), Route::Overflow) => ov,
            _ => self.next.fetch_add(1, Ordering::Relaxed) % self.n_batched,
        };
        let _ = self.shards[idx]
            .tx
            .send(Msg::Generate(ServiceRequest { n, range, offset, reply }));
        rx
    }

    /// Force pending requests out of every shard.
    pub fn flush(&self) {
        for shard in &self.shards {
            let _ = shard.tx.send(Msg::Flush);
        }
    }

    /// Stop all workers, returning per-shard counters.
    pub fn shutdown(mut self) -> Result<PoolStats> {
        let mut per_shard = Vec::with_capacity(self.shards.len());
        for shard in &mut self.shards {
            per_shard.push(shard.shutdown()?);
        }
        Ok(PoolStats { shards: per_shard })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Engine, PhiloxEngine};

    fn dedicated(seed: u64, offset: u64, n: usize) -> Vec<f32> {
        let mut e = PhiloxEngine::with_offset(seed, offset);
        let mut out = vec![0f32; n];
        e.fill_uniform_f32(&mut out);
        out
    }

    #[test]
    fn single_shard_batched_matches_dedicated_stream() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 42, 1));
        let sizes = [100usize, 200, 44];
        let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
        pool.flush();
        let mut offset = 0u64;
        for (rx, &n) in rxs.iter().zip(&sizes) {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got, dedicated(42, offset, n));
            offset += n as u64;
        }
        let stats = pool.shutdown().unwrap().total();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.launches, 1);
        assert_eq!(stats.numbers, 344);
    }

    #[test]
    fn streams_are_invariant_under_shard_count_and_padding() {
        // Sizes deliberately NOT multiples of 4: the pad tail must not
        // shift anybody's sub-stream.
        let sizes = [3usize, 5, 17, 1, 64, 7];
        for shards in [1usize, 2, 4] {
            let mut cfg = PoolConfig::new(PlatformId::Vega56, 7, shards);
            cfg.max_requests = 2;
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx.recv().unwrap().unwrap();
                assert_eq!(got, dedicated(7, offset, n), "shards={shards} n={n}");
                offset += n as u64;
            }
            pool.shutdown().unwrap();
        }
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 1, 4);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        let rxs: Vec<_> = (0..8).map(|_| pool.generate(16, (0.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 4);
        for s in &stats.shards {
            assert_eq!(s.requests, 2);
        }
    }

    #[test]
    fn overflow_lane_takes_large_requests_unbatched() {
        let mut cfg = PoolConfig::new(PlatformId::A100, 9, 2);
        cfg.policy = DispatchPolicy::fixed(1000);
        cfg.max_requests = 1000;
        let pool = ServicePool::spawn(cfg);
        assert!(pool.has_overflow_lane());

        let small = pool.generate(10, (0.0, 1.0));
        let large = pool.generate(5000, (0.0, 1.0)); // >= threshold: overflow
        // The overflow lane launches immediately, without a flush.
        let big = large.recv().unwrap().unwrap();
        assert_eq!(big, dedicated(9, 10, 5000));
        pool.flush();
        assert_eq!(small.recv().unwrap().unwrap(), dedicated(9, 0, 10));

        let stats = pool.shutdown().unwrap();
        assert_eq!(stats.shards.len(), 3); // 2 batched + overflow
        let overflow = stats.shards[2];
        assert_eq!(overflow.requests, 1);
        assert_eq!(overflow.launches, 1);
        assert_eq!(stats.total().requests, 2);
    }

    #[test]
    fn range_transform_applied_per_request() {
        let pool = ServicePool::spawn(PoolConfig::new(PlatformId::Rome7742, 3, 2));
        let rx = pool.generate(64, (2.0, 4.0));
        pool.flush();
        let got = rx.recv().unwrap().unwrap();
        assert!(got.iter().all(|&x| (2.0..4.0).contains(&x)));
        let mut want = dedicated(3, 0, 64);
        crate::rng::range_transform::range_transform_inplace(&mut want, 2.0, 4.0);
        assert_eq!(got, want);
        pool.shutdown().unwrap();
    }
}
