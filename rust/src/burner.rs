//! The RNG-burner benchmark application (paper §5.1).
//!
//! One application, compiled (here: configured) for each (platform, API)
//! pair, following the paper's workflow:
//!
//! 1. platform / API / generator chosen up front,
//! 2. distribution, iterations and batch size chosen at run time
//!    (+ Buffer/USM for SYCL targets),
//! 3. memory allocated, generator constructed and seeded,
//! 4. sequence generated and range-transformed,
//! 5. output copied device-to-host.
//!
//! The *virtual* clock gives the paper-comparable "total execution time";
//! real computation runs underneath for batches up to
//! [`REAL_COMPUTE_CAP`]; the pure-virtual variant covers the 10^8 sweep
//! points with an identical command structure ([`run_burner_auto`] picks).

use crate::backends::{
    CurandBackend, HiprandBackend, MklCpuBackend, NativeTimeline, OneMklIntelGpuBackend,
    PjrtBackend, RngBackend,
};
use crate::coordinator::{PoolConfig, PoolStats, ServicePool};
use crate::error::{Error, Result};
use crate::fault::FaultSpec;
use crate::platform::{CommandCost, PlatformId, PlatformKind, TransferDir};
use crate::rng::engines::EngineKind;
use crate::rng::{generate_buffer, generate_usm, Distribution};
use crate::runtime::PjrtRuntime;
use crate::sycl::{
    Access, AccessMode, Buffer, CommandClass, CommandRecord, Queue, SyclRuntimeProfile,
};
use crate::telemetry::TelemetrySnapshot;
use crate::trace::{Span, TraceConfig};
use std::sync::Arc;

/// Batches above this run through [`run_burner_virtual`] (same command
/// structure, no per-element host work) so the 10^8 sweep points stay
/// tractable on the harness machine.
pub const REAL_COMPUTE_CAP: usize = 1 << 21;

/// Which application variant runs (the paper's per-target `ifdef`s).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BurnerApi {
    /// Native vendor application (CUDA / HIP / plain C++).
    Native,
    /// oneMKL through SYCL, buffer API.
    SyclBuffer,
    /// oneMKL through SYCL, USM API.
    SyclUsm,
    /// Real-compute extension: oneMKL buffer flow dispatching to the
    /// AOT-compiled Pallas kernel via PJRT.
    Pjrt,
}

impl BurnerApi {
    /// CLI token.
    pub fn token(self) -> &'static str {
        match self {
            BurnerApi::Native => "native",
            BurnerApi::SyclBuffer => "sycl-buffer",
            BurnerApi::SyclUsm => "sycl-usm",
            BurnerApi::Pjrt => "pjrt",
        }
    }

    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<BurnerApi> {
        match s {
            "native" => Some(BurnerApi::Native),
            "sycl-buffer" | "buffer" => Some(BurnerApi::SyclBuffer),
            "sycl-usm" | "usm" => Some(BurnerApi::SyclUsm),
            "pjrt" => Some(BurnerApi::Pjrt),
            _ => None,
        }
    }
}

/// Burner run configuration.
#[derive(Debug, Clone)]
pub struct BurnerConfig {
    /// Target platform.
    pub platform: PlatformId,
    /// Application variant.
    pub api: BurnerApi,
    /// Engine (the paper uses Philox4x32x10 throughout).
    pub engine: EngineKind,
    /// Distribution (paper: uniform FP32).
    pub distr: Distribution,
    /// Numbers per iteration.
    pub batch: usize,
    /// Measurement iterations (paper: 100).
    pub iterations: usize,
    /// Generator seed.
    pub seed: u64,
}

impl BurnerConfig {
    /// The paper's defaults: Philox uniforms in [0,1), 100 iterations.
    pub fn paper_default(platform: PlatformId, api: BurnerApi, batch: usize) -> Self {
        BurnerConfig {
            platform,
            api,
            engine: EngineKind::Philox4x32x10,
            distr: Distribution::uniform(0.0, 1.0),
            batch,
            iterations: 100,
            seed: 0x5EED,
        }
    }
}

/// Per-kernel-class virtual durations (the Fig. 4 breakdown).
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelBreakdown {
    /// Generator construction + seeding, ns.
    pub setup_ns: u64,
    /// Generation kernel, ns.
    pub generate_ns: u64,
    /// Range-transform kernel, ns.
    pub transform_ns: u64,
    /// Host-to-device transfers, ns.
    pub h2d_ns: u64,
    /// Device-to-host transfers, ns.
    pub d2h_ns: u64,
    /// Everything else (callbacks, mallocs, host logic), ns.
    pub other_ns: u64,
    /// Mean achieved occupancy of the generate kernel.
    pub generate_occupancy: f64,
    /// Mean achieved occupancy of the transform kernel.
    pub transform_occupancy: f64,
    /// Threads-per-block in effect for kernels.
    pub tpb: u32,
}

/// Streaming accumulator behind [`KernelBreakdown::from_records`] /
/// [`KernelBreakdown::from_queue`].
#[derive(Default)]
struct BreakdownAcc {
    b: KernelBreakdown,
    gen_occ: f64,
    gen_n: u32,
    tr_occ: f64,
    tr_n: u32,
}

impl BreakdownAcc {
    fn push(&mut self, r: &CommandRecord) {
        let dur = r.virt_end_ns - r.virt_start_ns;
        match r.class {
            CommandClass::Setup => self.b.setup_ns += dur,
            CommandClass::Generate => {
                self.b.generate_ns += dur;
                if let Some(o) = r.occupancy {
                    self.gen_occ += o;
                    self.gen_n += 1;
                }
                if let Some(t) = r.tpb {
                    self.b.tpb = t;
                }
            }
            CommandClass::Transform => {
                self.b.transform_ns += dur;
                if let Some(o) = r.occupancy {
                    self.tr_occ += o;
                    self.tr_n += 1;
                }
            }
            CommandClass::TransferH2D => self.b.h2d_ns += dur,
            CommandClass::TransferD2H => self.b.d2h_ns += dur,
            CommandClass::Malloc | CommandClass::Other => self.b.other_ns += dur,
        }
    }

    fn finish(mut self) -> KernelBreakdown {
        if self.gen_n > 0 {
            self.b.generate_occupancy = self.gen_occ / self.gen_n as f64;
        }
        if self.tr_n > 0 {
            self.b.transform_occupancy = self.tr_occ / self.tr_n as f64;
        }
        self.b
    }
}

impl KernelBreakdown {
    /// Aggregate command records into the breakdown.
    pub fn from_records(records: &[CommandRecord]) -> KernelBreakdown {
        let mut acc = BreakdownAcc::default();
        for r in records {
            acc.push(r);
        }
        acc.finish()
    }

    /// Aggregate a queue's retained records without cloning them
    /// ([`Queue::visit_records`]) — the accounting path every burner
    /// iteration takes.
    pub fn from_queue(queue: &Queue) -> KernelBreakdown {
        let mut acc = BreakdownAcc::default();
        queue.visit_records(|r| acc.push(r));
        acc.finish()
    }
}

/// Result of one burner run (all iterations).
#[derive(Debug, Clone)]
pub struct BurnerReport {
    /// The configuration measured.
    pub config: BurnerConfig,
    /// Virtual total time per iteration, ns.
    pub totals_ns: Vec<f64>,
    /// Breakdown of the final iteration.
    pub breakdown: KernelBreakdown,
    /// Real wall time of the whole run, ns (for the §Perf hot-path view).
    pub wall_ns: u64,
    /// First few outputs of the last real fill, for validation.
    pub sample: Vec<f32>,
}

impl BurnerReport {
    /// Mean virtual iteration time, ns.
    pub fn mean_total_ns(&self) -> f64 {
        crate::metrics::mean(&self.totals_ns)
    }
}

/// Build the native backend for a platform.
pub fn native_backend_for(platform: PlatformId) -> Box<dyn RngBackend> {
    match platform {
        PlatformId::A100 => Box::new(CurandBackend::new()),
        PlatformId::Vega56 => Box::new(HiprandBackend::new()),
        PlatformId::Uhd630 => Box::new(OneMklIntelGpuBackend::new()),
        p => Box::new(MklCpuBackend::new(p)),
    }
}

/// Run the burner application with real element computation.
///
/// `cfg.batch` must be <= [`REAL_COMPUTE_CAP`]; use [`run_burner_auto`]
/// for arbitrary sweep sizes.
pub fn run_burner(cfg: &BurnerConfig) -> Result<BurnerReport> {
    run_burner_with_runtime(cfg, None)
}

/// [`run_burner`], supplying a PJRT runtime for [`BurnerApi::Pjrt`].
pub fn run_burner_with_runtime(
    cfg: &BurnerConfig,
    runtime: Option<Arc<PjrtRuntime>>,
) -> Result<BurnerReport> {
    if cfg.batch > REAL_COMPUTE_CAP {
        return Err(Error::InvalidArgument(format!(
            "batch {} exceeds REAL_COMPUTE_CAP {}; use run_burner_auto",
            cfg.batch, REAL_COMPUTE_CAP
        )));
    }
    let wall_start = std::time::Instant::now();
    let mut totals = Vec::with_capacity(cfg.iterations);
    let mut breakdown = KernelBreakdown::default();
    let mut sample = Vec::new();

    for iter in 0..cfg.iterations {
        let (total, bd, s) = match cfg.api {
            BurnerApi::Native => run_native_iteration(cfg, iter as u64)?,
            BurnerApi::SyclBuffer | BurnerApi::SyclUsm => {
                run_sycl_iteration(cfg, iter as u64, None)?
            }
            BurnerApi::Pjrt => {
                let rt = runtime
                    .clone()
                    .ok_or_else(|| Error::InvalidArgument("pjrt api needs a runtime".into()))?;
                run_sycl_iteration(cfg, iter as u64, Some(rt))?
            }
        };
        totals.push(total as f64);
        breakdown = bd;
        sample = s;
    }

    Ok(BurnerReport {
        config: cfg.clone(),
        totals_ns: totals,
        breakdown,
        wall_ns: wall_start.elapsed().as_nanos() as u64,
        sample,
    })
}

/// The native application: sequential vendor API calls, no runtime DAG.
fn run_native_iteration(
    cfg: &BurnerConfig,
    salt: u64,
) -> Result<(u64, KernelBreakdown, Vec<f32>)> {
    let spec = cfg.platform.spec();
    let mut t = NativeTimeline::new(cfg.platform);
    t.set_noise_salt(salt);
    let n = cfg.batch as u64;
    let backend = native_backend_for(cfg.platform);
    if !backend.supports(cfg.engine, &cfg.distr) {
        return Err(Error::unsupported(
            backend.name(),
            format!("{}/{}", cfg.engine.name(), cfg.distr.name()),
        ));
    }

    // 1-3: generator + memory.
    t.create_generator();
    t.malloc();
    // 4: generate + range transform (two kernels, as profiled in Fig. 4).
    t.kernel(
        "generate",
        CommandClass::Generate,
        CommandCost::Kernel { bytes_read: 0, bytes_written: n * 4, items: n, tpb: 0 },
    );
    if cfg.distr.requires_range_transform() {
        t.kernel(
            "transform",
            CommandClass::Transform,
            CommandCost::Kernel { bytes_read: n * 4, bytes_written: n * 4, items: n, tpb: 0 },
        );
    }
    // 5: D2H copy.
    if spec.kind != PlatformKind::Cpu {
        t.transfer(n * 4, TransferDir::D2H);
    }

    // Real numerics underneath.
    let mut gen = backend.create_generator(cfg.engine, cfg.seed)?;
    let mut out = vec![0f32; cfg.batch];
    gen.generate_canonical(&cfg.distr, &mut out)?;
    if let Distribution::Uniform { a, b, .. } = cfg.distr {
        if cfg.distr.requires_range_transform() {
            crate::rng::range_transform::range_transform_inplace(&mut out, a, b);
        }
    }
    let sample = out[..out.len().min(8)].to_vec();

    Ok((t.total_ns(), KernelBreakdown::from_records(t.records()), sample))
}

/// The oneMKL/SYCL application (buffer or USM path, optionally dispatching
/// the generation to the PJRT artifact backend).
fn run_sycl_iteration(
    cfg: &BurnerConfig,
    salt: u64,
    pjrt: Option<Arc<PjrtRuntime>>,
) -> Result<(u64, KernelBreakdown, Vec<f32>)> {
    let profile = SyclRuntimeProfile::for_platform(&cfg.platform.spec());
    let queue = Queue::new(cfg.platform, profile);
    queue.set_noise_salt(salt);
    let n = cfg.batch;

    let backend: Box<dyn RngBackend> = match &pjrt {
        Some(rt) => Box::new(PjrtBackend::new(rt.clone())?),
        None => native_backend_for(cfg.platform),
    };
    if !backend.supports(cfg.engine, &cfg.distr) {
        return Err(Error::unsupported(
            backend.name(),
            format!("{}/{}", cfg.engine.name(), cfg.distr.name()),
        ));
    }

    // Generator construction + seeding (the paper includes it in the total)
    // plus the oneMKL wrapper's API-dependent setup overhead.
    let usm = cfg.api == BurnerApi::SyclUsm;
    queue.advance_host(profile.onemkl_setup_overhead_ns(usm, queue.spec()));
    let mut gen = backend.create_generator(cfg.engine, cfg.seed)?;
    queue.submit(|cgh| {
        cgh.host_task(
            format!("{}::create", backend.name()),
            CommandClass::Setup,
            CommandCost::GeneratorSetup,
            |_| {},
        );
    });

    let sample;
    let total = match cfg.api {
        BurnerApi::SyclUsm => {
            let usm = queue.malloc_device::<f32>(n);
            let ev = generate_usm(&queue, &mut gen, cfg.distr, n, &usm, &[])?;
            let out = queue.usm_to_host(&usm, std::slice::from_ref(&ev));
            sample = out[..out.len().min(8)].to_vec();
            queue.wait()
        }
        _ => {
            let buf = Buffer::<f32>::new(n);
            generate_buffer(&queue, &mut gen, cfg.distr, n, &buf)?;
            let out = queue.host_read(&buf);
            sample = out[..out.len().min(8)].to_vec();
            queue.wait()
        }
    };

    Ok((total, KernelBreakdown::from_queue(&queue), sample))
}

/// Pure-virtual burner run (no real element computation): identical command
/// structure at any batch size. Used by the figure sweeps above
/// [`REAL_COMPUTE_CAP`].
pub fn run_burner_virtual(cfg: &BurnerConfig) -> Result<BurnerReport> {
    let wall_start = std::time::Instant::now();
    let mut totals = Vec::with_capacity(cfg.iterations);
    let mut breakdown = KernelBreakdown::default();
    for iter in 0..cfg.iterations {
        let (total, bd) = virtual_iteration(cfg, iter as u64)?;
        totals.push(total as f64);
        breakdown = bd;
    }
    Ok(BurnerReport {
        config: cfg.clone(),
        totals_ns: totals,
        breakdown,
        wall_ns: wall_start.elapsed().as_nanos() as u64,
        sample: Vec::new(),
    })
}

fn virtual_iteration(cfg: &BurnerConfig, salt: u64) -> Result<(u64, KernelBreakdown)> {
    let n = cfg.batch as u64;
    let gen_cost = CommandCost::Kernel { bytes_read: 0, bytes_written: n * 4, items: n, tpb: 0 };
    let tr_cost =
        CommandCost::Kernel { bytes_read: n * 4, bytes_written: n * 4, items: n, tpb: 0 };
    match cfg.api {
        BurnerApi::Native => {
            let spec = cfg.platform.spec();
            let mut t = NativeTimeline::new(cfg.platform);
            t.set_noise_salt(salt);
            t.create_generator();
            t.malloc();
            t.kernel("generate", CommandClass::Generate, gen_cost);
            if cfg.distr.requires_range_transform() {
                t.kernel("transform", CommandClass::Transform, tr_cost);
            }
            if spec.kind != PlatformKind::Cpu {
                t.transfer(n * 4, TransferDir::D2H);
            }
            Ok((t.total_ns(), KernelBreakdown::from_records(t.records())))
        }
        BurnerApi::SyclBuffer | BurnerApi::Pjrt => {
            let profile = SyclRuntimeProfile::for_platform(&cfg.platform.spec());
            let queue = Queue::new(cfg.platform, profile);
            queue.set_noise_salt(salt);
            queue.advance_host(profile.onemkl_setup_overhead_ns(false, queue.spec()));
            queue.submit(|cgh| {
                cgh.host_task("create", CommandClass::Setup, CommandCost::GeneratorSetup, |_| {});
            });
            let buf = Buffer::<f32>::new(16);
            queue.submit(|cgh| {
                let acc = cgh.require(&buf, AccessMode::ReadWrite);
                cgh.host_task("generate", CommandClass::Generate, gen_cost, move |_| {
                    let _ = acc;
                });
            });
            if cfg.distr.requires_range_transform() {
                queue.submit(|cgh| {
                    let acc = cgh.require(&buf, AccessMode::ReadWrite);
                    cgh.parallel_for("transform", CommandClass::Transform, tr_cost, move |_| {
                        let _ = acc;
                    });
                });
            }
            queue.submit(|cgh| {
                let acc = cgh.require(&buf, AccessMode::Read);
                cgh.host_task(
                    "d2h",
                    CommandClass::TransferD2H,
                    CommandCost::Transfer { bytes: n * 4, dir: TransferDir::D2H },
                    move |_| {
                        let _ = acc;
                    },
                );
            });
            let total = queue.wait();
            Ok((total, KernelBreakdown::from_queue(&queue)))
        }
        BurnerApi::SyclUsm => {
            let profile = SyclRuntimeProfile::for_platform(&cfg.platform.spec());
            let queue = Queue::new(cfg.platform, profile);
            queue.set_noise_salt(salt);
            queue.advance_host(profile.onemkl_setup_overhead_ns(true, queue.spec()));
            queue.submit_usm(
                "create",
                CommandClass::Setup,
                CommandCost::GeneratorSetup,
                &[],
                vec![],
                |_| {},
            );
            let usm = queue.malloc_device::<f32>(16);
            let gen_ev = queue.submit_usm(
                "generate",
                CommandClass::Generate,
                gen_cost,
                &[],
                vec![Access::usm(usm.id(), AccessMode::Write)],
                |_| {},
            );
            let last = if cfg.distr.requires_range_transform() {
                queue.submit_usm(
                    "transform",
                    CommandClass::Transform,
                    tr_cost,
                    std::slice::from_ref(&gen_ev),
                    vec![Access::usm(usm.id(), AccessMode::ReadWrite)],
                    |_| {},
                )
            } else {
                gen_ev
            };
            let _ = queue.submit_usm(
                "d2h",
                CommandClass::TransferD2H,
                CommandCost::Transfer { bytes: n * 4, dir: TransferDir::D2H },
                std::slice::from_ref(&last),
                vec![Access::usm(usm.id(), AccessMode::Read)],
                |_| {},
            );
            let total = queue.wait();
            Ok((total, KernelBreakdown::from_queue(&queue)))
        }
    }
}

/// Sweep helper: real compute below [`REAL_COMPUTE_CAP`], virtual above —
/// the drivers for Figs. 2/3/4 call this.
pub fn run_burner_auto(cfg: &BurnerConfig) -> Result<BurnerReport> {
    if cfg.batch <= REAL_COMPUTE_CAP {
        run_burner(cfg)
    } else {
        run_burner_virtual(cfg)
    }
}

/// Result of driving the burner workload through the service pool.
#[derive(Debug, Clone)]
pub struct PoolBurnerReport {
    /// Batched shard count used.
    pub shards: usize,
    /// Requests submitted.
    pub requests: usize,
    /// Numbers delivered to requesters (excludes launch padding).
    pub numbers: u64,
    /// Real wall time from first submission to last reply, ns.
    pub wall_ns: u64,
    /// Per-shard service counters.
    pub stats: PoolStats,
    /// Full telemetry snapshot taken after the drain (what
    /// `burner --pool --stats-json` serializes).
    pub telemetry: TelemetrySnapshot,
    /// Order-stable checksum over every reply's bit pattern — equal
    /// checksums across shard counts certify bit-identical per-request
    /// streams.
    pub checksum: u64,
    /// Merged span snapshot from the request tracer (what
    /// `burner --pool --trace <path>` exports as Chrome trace JSON).
    /// Empty when tracing was not enabled.
    pub spans: Vec<Span>,
}

impl PoolBurnerReport {
    /// Delivered throughput in millions of numbers per second of wall
    /// time.
    pub fn throughput_m_per_s(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.numbers as f64 / self.wall_ns as f64 * 1e3
    }
}

/// Fold one reply into the running request-stream checksum (FNV over the
/// f32 bit patterns, chained in submission order).
fn checksum_fold(mut h: u64, xs: &[f32]) -> u64 {
    for x in xs {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Drive the burner workload through a [`ServicePool`]: `requests`
/// generate requests of `cfg.batch` numbers each, submitted up front and
/// drained in order — the serving-layer counterpart of [`run_burner`].
///
/// Only uniform distributions are meaningful here (the pool's request API
/// is range-based) and only the SYCL application variants are pooled —
/// the pool's coalesced flushes run through the SYCL runtime (the USM
/// batch path over arena memory, DESIGN.md S13) regardless of which of
/// the two memory-API tokens was passed; native/pjrt are rejected rather
/// than silently substituted.
pub fn run_burner_pooled(
    cfg: &BurnerConfig,
    shards: usize,
    requests: usize,
) -> Result<PoolBurnerReport> {
    run_burner_pooled_chaos(cfg, shards, requests, None)
}

/// [`run_burner_pooled`] with an optional deterministic chaos plan
/// (`burner --pool --chaos <spec>`, DESIGN.md S15). The plan injects
/// transient faults and worker kills at seeded op counts; the resilience
/// layer must absorb them, so the report's checksum is required to equal
/// the fault-free run's. Replies are drained with a timeout so an injected
/// fault that *did* strand a caller fails the run with a typed error
/// instead of hanging it.
pub fn run_burner_pooled_chaos(
    cfg: &BurnerConfig,
    shards: usize,
    requests: usize,
    chaos: Option<&FaultSpec>,
) -> Result<PoolBurnerReport> {
    run_burner_pooled_opts(cfg, shards, requests, chaos, None)
}

/// [`run_burner_pooled_chaos`] with an optional request-tracer
/// configuration (`burner --pool --trace <path>`, DESIGN.md S18). When
/// `trace` is set the pool records spans into per-shard rings and the
/// report carries the merged snapshot in [`PoolBurnerReport::spans`];
/// combined with `--chaos`, worker kills additionally leave
/// flight-recorder dumps in the config's `flight_dir`.
pub fn run_burner_pooled_opts(
    cfg: &BurnerConfig,
    shards: usize,
    requests: usize,
    chaos: Option<&FaultSpec>,
    trace: Option<&TraceConfig>,
) -> Result<PoolBurnerReport> {
    if !matches!(cfg.api, BurnerApi::SyclBuffer | BurnerApi::SyclUsm) {
        return Err(Error::InvalidArgument(format!(
            "pooled burner serves through the SYCL runtime (USM batch path); \
             --api {} is not pooled (drop --pool or use --api sycl-buffer/sycl-usm)",
            cfg.api.token()
        )));
    }
    let range = match cfg.distr {
        Distribution::Uniform { a, b, .. } => (a, b),
        ref other => {
            return Err(Error::InvalidArgument(format!(
                "pooled burner serves uniform requests only, got {}",
                other.name()
            )))
        }
    };
    let mut pool_cfg = PoolConfig::new(cfg.platform, cfg.seed, shards);
    // Coalesce a handful of requests per launch; identical thresholds for
    // every shard count so scaling comparisons are apples-to-apples.
    pool_cfg.max_batch = cfg.batch.saturating_mul(4).max(1);
    pool_cfg.max_requests = 4;
    if let Some(spec) = chaos {
        pool_cfg.fault = Some(spec.clone());
        // A soak at rate ~5% can re-trip an already-retried request; give
        // the supervisor enough attempts that only a deterministic
        // always-fail plan surfaces as a typed error.
        pool_cfg.ingress.max_retries = 12;
    }
    pool_cfg.trace = trace.cloned();
    let pool = ServicePool::spawn(pool_cfg);

    let wall_start = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests).map(|_| pool.generate(cfg.batch, range)).collect();
    pool.flush();
    let mut numbers = 0u64;
    let mut checksum = 0xcbf2_9ce4_8422_2325u64;
    for rx in rxs {
        let reply = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .map_err(|_| Error::Coordinator("pool worker dropped reply".into()))??;
        numbers += reply.len() as u64;
        checksum = checksum_fold(checksum, &reply);
    }
    let wall_ns = wall_start.elapsed().as_nanos() as u64;

    // Snapshot telemetry and spans after shutdown so in-flight flushes
    // have retired and the final trace counters are published (the Arcs
    // keep both registries alive past the pool).
    let registry = pool.telemetry().clone();
    let tracer = pool.tracer();
    let stats = pool.shutdown()?;
    let telemetry = registry.snapshot();
    let spans = tracer.map(|t| t.snapshot()).unwrap_or_default();
    Ok(PoolBurnerReport {
        shards,
        requests,
        numbers,
        wall_ns,
        stats,
        telemetry,
        checksum,
        spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(platform: PlatformId, api: BurnerApi, batch: usize) -> BurnerConfig {
        let mut c = BurnerConfig::paper_default(platform, api, batch);
        c.iterations = 5;
        c
    }

    #[test]
    fn native_a100_flow() {
        let r = run_burner(&cfg(PlatformId::A100, BurnerApi::Native, 65_536)).unwrap();
        assert_eq!(r.totals_ns.len(), 5);
        assert!(r.mean_total_ns() > 0.0);
        assert!(r.breakdown.setup_ns > 0);
        assert!(r.breakdown.generate_ns > 0);
        assert!(r.breakdown.d2h_ns > 0);
        assert_eq!(r.breakdown.tpb, 256); // native hardcodes 256
        assert!(!r.sample.is_empty());
        assert!(r.sample.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn sycl_buffer_vs_usm_same_numbers() {
        let rb = run_burner(&cfg(PlatformId::Vega56, BurnerApi::SyclBuffer, 4096)).unwrap();
        let ru = run_burner(&cfg(PlatformId::Vega56, BurnerApi::SyclUsm, 4096)).unwrap();
        assert_eq!(rb.sample, ru.sample);
    }

    #[test]
    fn sycl_dpcpp_picks_1024_tpb() {
        let r = run_burner(&cfg(PlatformId::A100, BurnerApi::SyclBuffer, 65_536)).unwrap();
        assert_eq!(r.breakdown.tpb, 1024); // Fig 4b mechanism
    }

    #[test]
    fn virtual_and_real_timelines_same_shape() {
        let c = cfg(PlatformId::A100, BurnerApi::SyclBuffer, 65_536);
        let real = run_burner(&c).unwrap();
        let virt = run_burner_virtual(&c).unwrap();
        // Same command structure => totals within noise of each other.
        let ratio = real.mean_total_ns() / virt.mean_total_ns();
        assert!((0.8..1.25).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn large_batches_route_to_virtual() {
        let c = cfg(PlatformId::A100, BurnerApi::SyclUsm, 100_000_000);
        let r = run_burner_auto(&c).unwrap();
        assert_eq!(r.totals_ns.len(), 5);
        // 1e8 at ~25 ms PCIe + ~1.4 ms kernel: tens of ms.
        assert!(r.mean_total_ns() > 10e6, "mean={}", r.mean_total_ns());
    }

    #[test]
    fn cpu_platform_has_no_transfers() {
        let r = run_burner(&cfg(PlatformId::Rome7742, BurnerApi::Native, 65_536)).unwrap();
        assert_eq!(r.breakdown.h2d_ns, 0);
        assert_eq!(r.breakdown.d2h_ns, 0);
    }

    #[test]
    fn gaussian_distribution_works_end_to_end() {
        let mut c = cfg(PlatformId::A100, BurnerApi::SyclBuffer, 65_536);
        c.distr = Distribution::gaussian(5.0, 2.0);
        let r = run_burner(&c).unwrap();
        assert!(r.breakdown.transform_ns > 0); // mean/std transform kernel
    }

    #[test]
    fn pooled_burner_streams_are_shard_count_invariant() {
        use crate::rng::Engine;
        let c = cfg(PlatformId::A100, BurnerApi::SyclBuffer, 1000);
        let one = run_burner_pooled(&c, 1, 12).unwrap();
        let four = run_burner_pooled(&c, 4, 12).unwrap();
        assert_eq!(one.checksum, four.checksum);
        assert_eq!(one.numbers, 12_000);
        assert_eq!(four.numbers, 12_000);
        assert_eq!(four.stats.total().requests, 12);
        // The telemetry snapshot agrees with the report's own accounting.
        assert_eq!(four.telemetry.total_delivered(), 12_000);
        assert_eq!(four.telemetry.total_requests(), 12);
        assert_eq!(four.telemetry.total_launches(), four.stats.total().launches);

        // And the checksum is the dedicated-stream checksum.
        let mut want = vec![0f32; 12_000];
        crate::rng::PhiloxEngine::new(c.seed).fill_uniform_f32(&mut want);
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for chunk in want.chunks(1000) {
            h = checksum_fold(h, chunk);
        }
        assert_eq!(one.checksum, h);
    }

    #[test]
    fn pooled_burner_applies_ranges_and_rejects_non_uniform() {
        let mut c = cfg(PlatformId::Vega56, BurnerApi::SyclBuffer, 64);
        c.distr = Distribution::uniform(-2.0, 2.0);
        let r = run_burner_pooled(&c, 2, 4).unwrap();
        assert_eq!(r.numbers, 256);
        c.distr = Distribution::gaussian(0.0, 1.0);
        assert!(run_burner_pooled(&c, 2, 4).is_err());
        // Non-buffer APIs are rejected, not silently substituted.
        let native = cfg(PlatformId::A100, BurnerApi::Native, 64);
        assert!(run_burner_pooled(&native, 2, 4).is_err());
    }
}
