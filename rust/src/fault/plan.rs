//! Per-shard fault plan: pure-function fault decisions keyed by op count.

use std::sync::atomic::{AtomicU64, Ordering};

use super::spec::FaultSpec;
use super::FaultSite;

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer — a strong 64-bit mixer, used here as a keyed
/// decision function, never as a sequential stream (every call mixes the
/// full `(seed, shard, site, k)` coordinate, so decisions are independent
/// of evaluation order).
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(GOLDEN);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One shard's slice of a [`FaultSpec`]: op counters plus the pure
/// decision function. Shared (`Arc`) between the worker thread and the
/// supervisor, and deliberately *reused across respawns* so op counts —
/// and therefore kill schedules — survive a worker death.
#[derive(Debug)]
pub struct ShardFaultPlan {
    shard: usize,
    seed: u64,
    /// `rate` mapped into the 53-bit decision space; 0 disables all
    /// transient sites, `1 << 53` fires every op.
    threshold: u64,
    enabled: [bool; 3],
    /// Ops consumed so far per transient site (generate / submit / d2h).
    ops: [AtomicU64; 3],
    /// Sorted 1-based worker message-op indices scheduled to kill this
    /// shard's worker.
    kill_at: Vec<u64>,
    msg_ops: AtomicU64,
    injected: AtomicU64,
}

impl ShardFaultPlan {
    pub(super) fn new(spec: &FaultSpec, shard: usize) -> ShardFaultPlan {
        let mut enabled = [false; 3];
        for site in &spec.sites {
            if let Some(lane) = site.transient_lane() {
                enabled[lane] = true;
            }
        }
        let mut kill_at: Vec<u64> =
            spec.kills.iter().filter(|k| k.shard == shard).map(|k| k.op).collect();
        kill_at.sort_unstable();
        kill_at.dedup();
        ShardFaultPlan {
            shard,
            seed: spec.seed,
            threshold: (spec.rate * (1u64 << 53) as f64) as u64,
            enabled,
            ops: std::array::from_fn(|_| AtomicU64::new(0)),
            kill_at,
            msg_ops: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    /// Shard this plan governs.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Consume one op at `site`; `true` means the op must fail. The
    /// decision is a pure function of `(seed, shard, site, k)` where `k`
    /// is this shard's op count at the site — never of time or thread
    /// interleaving. [`FaultSite::WorkerKill`] is schedule-driven and
    /// always returns `false` here.
    pub fn trip(&self, site: FaultSite) -> bool {
        let Some(lane) = site.transient_lane() else { return false };
        if !self.enabled[lane] || self.threshold == 0 {
            return false;
        }
        let k = self.ops[lane].fetch_add(1, Ordering::Relaxed);
        let key = self.seed
            ^ (self.shard as u64).wrapping_mul(GOLDEN)
            ^ ((lane as u64 + 1) << 56)
            ^ k.wrapping_mul(0x94D0_49BB_1331_11EB);
        let fire = (mix(key) >> 11) < self.threshold;
        if fire {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Advance the worker's message-op counter; `true` when this op is a
    /// scheduled kill point. Counts continue across respawns (the pool
    /// re-installs the same plan), so each kill point fires exactly once.
    pub fn trip_kill(&self) -> bool {
        let op = self.msg_ops.fetch_add(1, Ordering::Relaxed) + 1;
        let fire = self.kill_at.binary_search(&op).is_ok();
        if fire {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Total faults (transient trips + kills) injected by this plan.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(spec: &str, shard: usize) -> ShardFaultPlan {
        ShardFaultPlan::new(&FaultSpec::parse(spec).unwrap(), shard)
    }

    #[test]
    fn decisions_are_reproducible_per_op_index() {
        let a = plan("seed=42,rate=0.25", 1);
        let b = plan("seed=42,rate=0.25", 1);
        let fired_a: Vec<bool> = (0..256).map(|_| a.trip(FaultSite::Generate)).collect();
        let fired_b: Vec<bool> = (0..256).map(|_| b.trip(FaultSite::Generate)).collect();
        assert_eq!(fired_a, fired_b);
        assert!(fired_a.iter().any(|&f| f), "25% over 256 ops must fire at least once");
        assert!(fired_a.iter().any(|&f| !f), "25% over 256 ops must also pass ops through");
        assert_eq!(a.injected(), fired_a.iter().filter(|&&f| f).count() as u64);
    }

    #[test]
    fn sites_and_shards_decide_independently() {
        let p = plan("seed=7,rate=0.5", 0);
        let gen: Vec<bool> = (0..64).map(|_| p.trip(FaultSite::Generate)).collect();
        let d2h: Vec<bool> = (0..64).map(|_| p.trip(FaultSite::D2h)).collect();
        assert_ne!(gen, d2h, "sites must not share a decision stream");
        let other = plan("seed=7,rate=0.5", 3);
        let gen3: Vec<bool> = (0..64).map(|_| other.trip(FaultSite::Generate)).collect();
        assert_ne!(gen, gen3, "shards must not share a decision stream");
    }

    #[test]
    fn rate_extremes_and_disabled_sites() {
        let never = plan("seed=1,rate=0.0", 0);
        let always = plan("seed=1,rate=1.0", 0);
        for _ in 0..64 {
            assert!(!never.trip(FaultSite::Submit));
            assert!(always.trip(FaultSite::Submit));
        }
        let gen_only = plan("seed=1,rate=1.0,sites=generate", 0);
        assert!(gen_only.trip(FaultSite::Generate));
        assert!(!gen_only.trip(FaultSite::Submit));
        assert!(!gen_only.trip(FaultSite::D2h));
    }

    #[test]
    fn kill_fires_exactly_once_at_the_scheduled_op() {
        let p = plan("kill=2@3", 2);
        let fired: Vec<bool> = (0..8).map(|_| p.trip_kill()).collect();
        assert_eq!(fired, [false, false, true, false, false, false, false, false]);
        assert_eq!(p.injected(), 1);
        let other_shard = plan("kill=2@3", 0);
        assert!((0..8).all(|_| !other_shard.trip_kill()));
    }

    #[test]
    fn worker_kill_never_trips_the_transient_path() {
        let p = plan("seed=9,rate=1.0", 0);
        assert!(!p.trip(FaultSite::WorkerKill));
    }
}
