//! Deterministic, seeded fault injection for chaos soaks.
//!
//! The resilience layer is only testable if failures are reproducible, so
//! nothing in this module consults a wall clock or an OS entropy source.
//! A [`FaultSpec`] (parsed from `serve --chaos <spec>` or the
//! `PORTARNG_FAULT_PLAN` env var) expands into one [`ShardFaultPlan`] per
//! pool shard; each plan decides every injection *by op count*: the k-th
//! operation a shard performs at a given [`FaultSite`] either always fires
//! or never fires for a given `(seed, shard, site, k)` — independent of
//! timing, interleaving, or how often telemetry is read. Re-running the
//! same spec against the same request sequence reproduces the same faults,
//! which is what lets `benches/chaos_soak.rs` assert bit-identical output
//! under a 5% fault rate.
//!
//! Hot-path cost when chaos is *not* configured: the hooks below reduce to
//! one thread-local read and a `None` check — no plan is ever installed on
//! threads outside a chaos-configured pool, so the fault layer is inert
//! for every existing benchmark and test.
//!
//! Injection seams (the four that exist in the serving stack today):
//!
//! | site                  | hook                                          |
//! |-----------------------|-----------------------------------------------|
//! | [`FaultSite::Generate`] | vendor backend `generate_canonical`         |
//! | [`FaultSite::Submit`]   | `Queue::submit_usm_checked` (flush DAG)     |
//! | [`FaultSite::D2h`]      | `Queue::usm_slice_to_host_checked`          |
//! | [`FaultSite::WorkerKill`] | shard worker loop (whole-worker panic)    |

mod plan;
mod spec;

pub use plan::ShardFaultPlan;
pub use spec::{FaultSpec, KillPoint};

use std::cell::RefCell;
use std::sync::Arc;

use crate::error::{Error, Result};

/// One of the four seams a chaos plan can break.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Vendor backend `generate` call inside the interop host task.
    Generate,
    /// Queue submission of the flush's generate command group.
    Submit,
    /// Per-member device-to-host slice copy.
    D2h,
    /// Whole-worker panic at the message-dequeue boundary (not a
    /// transient site: scheduled by `kill=<shard>@<op>`, not by rate).
    WorkerKill,
}

impl FaultSite {
    /// The three rate-driven sites (everything except [`FaultSite::WorkerKill`]).
    pub const TRANSIENT: [FaultSite; 3] = [FaultSite::Generate, FaultSite::Submit, FaultSite::D2h];

    /// Stable token used in spec grammar, error messages, and telemetry.
    pub fn token(self) -> &'static str {
        match self {
            FaultSite::Generate => "generate",
            FaultSite::Submit => "submit",
            FaultSite::D2h => "d2h",
            FaultSite::WorkerKill => "worker-kill",
        }
    }

    /// Index into the per-site op counters for transient sites.
    pub(crate) fn transient_lane(self) -> Option<usize> {
        match self {
            FaultSite::Generate => Some(0),
            FaultSite::Submit => Some(1),
            FaultSite::D2h => Some(2),
            FaultSite::WorkerKill => None,
        }
    }

    /// Inverse of [`FaultSite::token`] for the spec grammar's `sites=` list.
    fn parse_token(s: &str) -> Option<FaultSite> {
        match s {
            "generate" => Some(FaultSite::Generate),
            "submit" => Some(FaultSite::Submit),
            "d2h" => Some(FaultSite::D2h),
            _ => None,
        }
    }
}

thread_local! {
    /// The plan governing the current thread, if any. Shard workers install
    /// their plan at thread entry; every other thread stays at `None`, so
    /// [`trip`] is a no-op outside a chaos-configured pool.
    static ACTIVE: RefCell<Option<Arc<ShardFaultPlan>>> = const { RefCell::new(None) };
}

/// Install (or clear, with `None`) the fault plan for the current thread.
pub fn install(plan: Option<Arc<ShardFaultPlan>>) {
    ACTIVE.with(|a| *a.borrow_mut() = plan);
}

/// Consume one op at `site` against the current thread's plan. Returns
/// `Err(Error::Injected)` when the plan fires; `Ok(())` when no plan is
/// installed, the site is disabled, or this op is scheduled to survive.
pub fn trip(site: FaultSite) -> Result<()> {
    ACTIVE.with(|a| match a.borrow().as_ref() {
        Some(plan) if plan.trip(site) => Err(Error::Injected { site: site.token() }),
        _ => Ok(()),
    })
}
