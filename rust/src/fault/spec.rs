//! Chaos-spec grammar: the `--chaos` / `PORTARNG_FAULT_PLAN` surface.
//!
//! A spec is a comma-separated list of `key=value` fields:
//!
//! ```text
//! seed=42,rate=0.05,sites=generate+submit+d2h,kill=0@17+1@9
//! ```
//!
//! * `seed=<u64>` — decision seed (default `0xFA17`);
//! * `rate=<f64 in [0,1]>` — transient-fault probability per op (default 0);
//! * `sites=<site>+<site>...` — transient sites to arm, from `generate`,
//!   `submit`, `d2h` (default: all three);
//! * `kill=<shard>@<op>[+<shard>@<op>...]` — kill shard `<shard>`'s worker
//!   at its `<op>`-th message (1-based), repeatable.
//!
//! Unknown keys, malformed values, and out-of-range rates are rejected
//! with `Error::InvalidArgument` so a typo'd soak fails loudly instead of
//! silently running fault-free.

use std::fmt;
use std::sync::Arc;

use super::plan::ShardFaultPlan;
use super::FaultSite;
use crate::error::{Error, Result};

/// One scheduled whole-worker kill: shard `shard`'s worker panics when it
/// dequeues its `op`-th message (1-based, counted across respawns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillPoint {
    /// Target shard index.
    pub shard: usize,
    /// 1-based message-op index at which the worker dies.
    pub op: u64,
}

/// A parsed chaos plan, shared by every shard of a pool. Expand with
/// [`FaultSpec::shard_plan`] to get the per-shard decision state.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Decision seed mixed into every fault decision.
    pub seed: u64,
    /// Per-op transient-fault probability in `[0, 1]`.
    pub rate: f64,
    /// Armed transient sites.
    pub sites: Vec<FaultSite>,
    /// Scheduled whole-worker kills.
    pub kills: Vec<KillPoint>,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 0xFA17,
            rate: 0.0,
            sites: FaultSite::TRANSIENT.to_vec(),
            kills: Vec::new(),
        }
    }
}

fn bad(msg: impl Into<String>) -> Error {
    Error::InvalidArgument(format!("chaos spec: {}", msg.into()))
}

impl FaultSpec {
    /// Parse the `--chaos` grammar (see module docs).
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for field in s.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| bad(format!("expected key=value, got `{field}`")))?;
            let value = value.trim();
            match key.trim() {
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|_| bad(format!("seed must be a u64, got `{value}`")))?;
                }
                "rate" => {
                    let rate: f64 = value
                        .parse()
                        .map_err(|_| bad(format!("rate must be a float, got `{value}`")))?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(bad(format!("rate must be in [0, 1], got {rate}")));
                    }
                    spec.rate = rate;
                }
                "sites" => {
                    spec.sites = value
                        .split('+')
                        .map(|tok| {
                            FaultSite::parse_token(tok.trim()).ok_or_else(|| {
                                bad(format!(
                                    "unknown site `{tok}` (expected generate, submit, or d2h)"
                                ))
                            })
                        })
                        .collect::<Result<Vec<_>>>()?;
                }
                "kill" => {
                    for k in value.split('+') {
                        let (shard, op) = k
                            .trim()
                            .split_once('@')
                            .ok_or_else(|| bad(format!("kill must be <shard>@<op>, got `{k}`")))?;
                        let shard = shard
                            .parse()
                            .map_err(|_| bad(format!("kill shard must be a usize, got `{shard}`")))?;
                        let op: u64 = op
                            .parse()
                            .map_err(|_| bad(format!("kill op must be a u64, got `{op}`")))?;
                        if op == 0 {
                            return Err(bad("kill op is 1-based; `@0` never fires"));
                        }
                        spec.kills.push(KillPoint { shard, op });
                    }
                }
                other => return Err(bad(format!("unknown key `{other}`"))),
            }
        }
        Ok(spec)
    }

    /// Expand this spec into shard `shard`'s decision state.
    pub fn shard_plan(&self, shard: usize) -> Arc<ShardFaultPlan> {
        Arc::new(ShardFaultPlan::new(self, shard))
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={},rate={}", self.seed, self.rate)?;
        let sites: Vec<&str> = self.sites.iter().map(|s| s.token()).collect();
        write!(f, ",sites={}", sites.join("+"))?;
        if !self.kills.is_empty() {
            let kills: Vec<String> =
                self.kills.iter().map(|k| format!("{}@{}", k.shard, k.op)).collect();
            write!(f, ",kill={}", kills.join("+"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grammar_round_trips() {
        let spec = FaultSpec::parse("seed=42,rate=0.05,sites=generate+d2h,kill=0@17+1@9").unwrap();
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.rate, 0.05);
        assert_eq!(spec.sites, vec![FaultSite::Generate, FaultSite::D2h]);
        assert_eq!(
            spec.kills,
            vec![KillPoint { shard: 0, op: 17 }, KillPoint { shard: 1, op: 9 }]
        );
        assert_eq!(FaultSpec::parse(&spec.to_string()).unwrap(), spec);
    }

    #[test]
    fn defaults_arm_all_transient_sites_fault_free() {
        let spec = FaultSpec::parse("").unwrap();
        assert_eq!(spec, FaultSpec::default());
        assert_eq!(spec.rate, 0.0);
        assert_eq!(spec.sites.len(), 3);
        assert!(spec.kills.is_empty());
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for s in [
            "bogus",
            "turbo=1",
            "rate=1.5",
            "rate=-0.1",
            "rate=much",
            "seed=-3",
            "sites=generate+warp",
            "sites=worker-kill",
            "kill=0",
            "kill=a@3",
            "kill=0@x",
            "kill=0@0",
        ] {
            let err = FaultSpec::parse(s).unwrap_err();
            assert!(
                err.to_string().contains("chaos spec"),
                "`{s}` must fail with a chaos-spec error, got: {err}"
            );
        }
    }
}
