//! Lock-free service telemetry (DESIGN.md S11).
//!
//! The adaptive-dispatch subsystem's measurement half: atomic counters
//! plus log₂-bucketed latency and batch-occupancy histograms, kept per
//! shard / per lane / per backend by [`TelemetryRegistry`], recorded by
//! pool workers with relaxed atomics (nothing on the request hot path
//! takes a lock or allocates), and read through cheap [`snapshot`]
//! copies that serialize through `jsonlite` (schema
//! `portarng-telemetry-v7`: per-command-class virtual timings,
//! worker-arena counters, per-shard DAG-hazard counters
//! [`HazardCounters`], the resilience layer's fault / respawn /
//! retry / shed / deadline counters [`ResilienceTotals`], the tile
//! executor's per-shard `tiles` / `pipeline` blocks ([`TileCounters`] /
//! [`PipelineCounters`], DESIGN.md S16), the pooled FastCaloSim
//! driver's `fcs` block ([`FcsCounters`], DESIGN.md S17), and the
//! request tracer's `trace` block ([`TraceCounters`], DESIGN.md S18);
//! v1–v6 superseded). The
//! [`autotune`](crate::autotune) controller
//! closes the loop by turning snapshot deltas into
//! [`DispatchPolicy`](crate::coordinator::DispatchPolicy) retunes.
//!
//! [`snapshot`]: TelemetryRegistry::snapshot

mod histogram;
mod registry;

pub use histogram::{HistogramSnapshot, Log2Histogram, BUCKETS};
pub use registry::{
    ArenaCounters, CommandBreakdown, CommandKind, CommandTiming, FcsCounters, HazardCounters,
    Lane, PipelineCounters, ResilienceTotals, ShardSnapshot, ShardTelemetry, TelemetryRegistry,
    TelemetrySnapshot, TileCounters, TraceCounters, TELEMETRY_SCHEMA,
};
