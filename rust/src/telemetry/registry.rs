//! Shard/lane/backend-labelled metrics registry for the service pool.
//!
//! One [`ShardTelemetry`] per worker shard, allocated by the pool at spawn
//! and shared with the worker thread as an `Arc` — the worker records with
//! relaxed atomics (no locks on the request path) and the registry stays
//! readable from any thread even after the worker is gone, which is what
//! fixes the old `ServiceStats`-over-ack-channel shutdown path: counters
//! live in the registry, not in worker-local state, so nothing is dropped
//! when a shard's ack channel closes.
//!
//! [`TelemetryRegistry::snapshot`] copies everything into a plain
//! [`TelemetrySnapshot`] that serializes through `jsonlite`
//! ([`TelemetrySnapshot::to_json`], schema `portarng-telemetry-v7`, see
//! README "Telemetry snapshot schema"). v2 added per-command-class virtual
//! timings ([`CommandTiming`]: generate / transform / d2h / other, fed
//! from drained queue records) and the worker arena's allocation counters
//! ([`ArenaCounters`]) to every shard — what the autotuner and the Fig. 4
//! style breakdown read. v3 adds the per-shard `hazards` block
//! ([`HazardCounters`]: per-flush DAG hazard-analysis results — see
//! DESIGN.md S14) and the arena `leaked` counter. v4 adds the resilience
//! layer's counters (DESIGN.md S15): per-shard `faults_injected`,
//! `respawns` and `deadline_exceeded`, plus the pool-level
//! `requests_retried` / `requests_shed` ingress counters — all zero on a
//! fault-free run, which is itself a chaos-soak gate. v5 adds the tile
//! executor's counters (DESIGN.md S16): the per-shard `tiles` block
//! ([`TileCounters`]: nd-range tiles executed + their real wall time) and
//! the `pipeline` block ([`PipelineCounters`]: cross-flush pipelining
//! occupancy — tiled flushes, how many overlapped the previous flush, and
//! the summed virtual overlap). v6 adds the pool-level `fcs` block
//! ([`FcsCounters`], DESIGN.md S17): the pooled FastCaloSim driver's
//! per-event hit counts and generate/transform/D2H virtual splits — all
//! zero unless the pool served a FastCaloSim run. v7 adds the pool-level
//! `trace` block ([`TraceCounters`], DESIGN.md S18): spans recorded /
//! dropped by the request tracer's rings plus the flight-recorder dumps
//! the supervisor took from dead shards — all zero on a pool that never
//! enabled tracing. v1–v6 are superseded.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::jsonlite::Value;
use crate::platform::PlatformId;

use super::histogram::{HistogramSnapshot, Log2Histogram};

/// Telemetry snapshot schema identifier (bump on breaking changes).
/// v1 (no per-command-class timings, no arena counters), v2 (no hazard
/// counters, no arena `leaked`), v3 (no resilience counters), v4 (no
/// tile-executor / pipeline counters), v5 (no FastCaloSim `fcs` block)
/// and v6 (no request-tracer `trace` block) are superseded.
pub const TELEMETRY_SCHEMA: &str = "portarng-telemetry-v7";

/// Command classes the serving path times. Mirrors
/// `sycl::CommandClass` for the classes the pool's flushes issue —
/// defined here, like [`Lane`], so the telemetry layer stays independent
/// of the substrate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommandKind {
    /// The interop generate host task.
    Generate,
    /// The range-transform kernel.
    Transform,
    /// Device-to-host slice copies.
    TransferD2H,
    /// Everything else on the worker queue (mallocs, setup, ...).
    Other,
}

impl CommandKind {
    /// All kinds, snapshot order.
    pub const ALL: [CommandKind; 4] = [
        CommandKind::Generate,
        CommandKind::Transform,
        CommandKind::TransferD2H,
        CommandKind::Other,
    ];

    /// Stable label used in snapshots.
    pub fn token(self) -> &'static str {
        match self {
            CommandKind::Generate => "generate",
            CommandKind::Transform => "transform",
            CommandKind::TransferD2H => "d2h",
            CommandKind::Other => "other",
        }
    }

    fn index(self) -> usize {
        match self {
            CommandKind::Generate => 0,
            CommandKind::Transform => 1,
            CommandKind::TransferD2H => 2,
            CommandKind::Other => 3,
        }
    }
}

/// Command count + summed virtual duration of one command class.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommandTiming {
    /// Commands executed.
    pub cmds: u64,
    /// Summed virtual duration, ns.
    pub virt_ns: u64,
}

impl CommandTiming {
    fn merged(self, other: CommandTiming) -> CommandTiming {
        CommandTiming { cmds: self.cmds + other.cmds, virt_ns: self.virt_ns + other.virt_ns }
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("cmds".into(), Value::Number(self.cmds as f64));
        m.insert("virt_ns".into(), Value::Number(self.virt_ns as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<CommandTiming> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("command timing missing `{key}`")))
        };
        Ok(CommandTiming { cmds: num("cmds")?, virt_ns: num("virt_ns")? })
    }
}

/// FastCaloSim serving counters (DESIGN.md S17), pool-level: folded in by
/// the pooled FCS driver after the run — one `record_fcs_event` per event
/// with that event's virtual hit count and Fig.-4-style command-class
/// split from the simulator's drained queue windows. All zero on a pool
/// that never served FastCaloSim.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FcsCounters {
    /// Events simulated through the pool.
    pub events: u64,
    /// Virtual hits across those events.
    pub hits: u64,
    /// Summed virtual ns in Generate-class commands (rng + rng:floor).
    pub gen_ns: u64,
    /// Summed virtual ns in Transform-class commands (hit deposition).
    pub transform_ns: u64,
    /// Summed virtual ns in D2H transfers (result readback).
    pub d2h_ns: u64,
}

impl FcsCounters {
    /// True when any FastCaloSim event was folded in.
    pub fn any(&self) -> bool {
        self.events != 0
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("events".into(), Value::Number(self.events as f64));
        m.insert("hits".into(), Value::Number(self.hits as f64));
        m.insert("gen_ns".into(), Value::Number(self.gen_ns as f64));
        m.insert("transform_ns".into(), Value::Number(self.transform_ns as f64));
        m.insert("d2h_ns".into(), Value::Number(self.d2h_ns as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<FcsCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("fcs counters missing `{key}`")))
        };
        Ok(FcsCounters {
            events: num("events")?,
            hits: num("hits")?,
            gen_ns: num("gen_ns")?,
            transform_ns: num("transform_ns")?,
            d2h_ns: num("d2h_ns")?,
        })
    }
}

/// Request-tracer activity (DESIGN.md S18), pool-level: the supervisor
/// publishes the tracer's running span counters every sweep tick
/// ([`TelemetryRegistry::set_trace_activity`], absolute values — the
/// tracer owns them) and counts each flight-recorder dump it takes from
/// a dead shard ([`TelemetryRegistry::record_flight_dump`], cumulative).
/// All zero on a pool that never enabled tracing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCounters {
    /// Spans recorded across all rings (coordinator ring included).
    pub spans: u64,
    /// Spans overwritten before any snapshot could read them (ring
    /// wrap-around) — nonzero is fine, it is what "overwrite oldest"
    /// means; it just bounds how far back a flight dump can see.
    pub dropped: u64,
    /// Flight-recorder dumps the supervisor took from dead shards.
    pub flight_dumps: u64,
}

impl TraceCounters {
    /// True when tracing recorded anything at all.
    pub fn any(&self) -> bool {
        self.spans != 0 || self.dropped != 0 || self.flight_dumps != 0
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("spans".into(), Value::Number(self.spans as f64));
        m.insert("dropped".into(), Value::Number(self.dropped as f64));
        m.insert("flight_dumps".into(), Value::Number(self.flight_dumps as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<TraceCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("trace counters missing `{key}`")))
        };
        Ok(TraceCounters {
            spans: num("spans")?,
            dropped: num("dropped")?,
            flight_dumps: num("flight_dumps")?,
        })
    }
}

/// Tile-executor counters for one shard (DESIGN.md S16): how many
/// nd-range tiles its flushes executed (generate + transform work items)
/// and the summed *real* wall time the tile closures took on the team
/// threads — unlike [`CommandTiming`] these are measured, not modelled,
/// which is what makes the per-tile distribution an honest occupancy
/// signal for the `tile_size`/`team_width` autotune knobs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TileCounters {
    /// Tiles executed across all flushes.
    pub tiles: u64,
    /// Summed real wall time of the tile closures, ns.
    pub wall_ns: u64,
}

impl TileCounters {
    fn merged(self, other: TileCounters) -> TileCounters {
        TileCounters { tiles: self.tiles + other.tiles, wall_ns: self.wall_ns + other.wall_ns }
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("tiles".into(), Value::Number(self.tiles as f64));
        m.insert("wall_ns".into(), Value::Number(self.wall_ns as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<TileCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("tile counters missing `{key}`")))
        };
        Ok(TileCounters { tiles: num("tiles")?, wall_ns: num("wall_ns")? })
    }
}

/// Cross-flush pipelining occupancy for one shard (DESIGN.md S16). A
/// pipelined (tiled, double-buffered) flush *overlaps* the previous one
/// when its first generate command starts on the virtual clock before the
/// previous flush's last command retires — exactly what the deferred
/// lease recycle buys. All-zero on a serial shard.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipelineCounters {
    /// Pipelined (tiled) flushes issued.
    pub flushes: u64,
    /// Flushes whose generate overlapped the previous flush.
    pub overlapped: u64,
    /// Summed virtual overlap across those flushes, ns.
    pub overlap_ns: u64,
}

impl PipelineCounters {
    /// Fraction of pipelined flushes that actually overlapped their
    /// predecessor (0 when none were issued).
    pub fn occupancy(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.overlapped as f64 / self.flushes as f64
        }
    }

    fn merged(self, other: PipelineCounters) -> PipelineCounters {
        PipelineCounters {
            flushes: self.flushes + other.flushes,
            overlapped: self.overlapped + other.overlapped,
            overlap_ns: self.overlap_ns + other.overlap_ns,
        }
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("flushes".into(), Value::Number(self.flushes as f64));
        m.insert("overlapped".into(), Value::Number(self.overlapped as f64));
        m.insert("overlap_ns".into(), Value::Number(self.overlap_ns as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<PipelineCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("pipeline counters missing `{key}`")))
        };
        Ok(PipelineCounters {
            flushes: num("flushes")?,
            overlapped: num("overlapped")?,
            overlap_ns: num("overlap_ns")?,
        })
    }
}

/// Point-in-time copy of a worker's USM-arena counters (mirror of
/// `sycl::ArenaStats`, defined here to keep the layer independent).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaCounters {
    /// Leases handed out.
    pub checkouts: u64,
    /// Checkouts served from a parked allocation.
    pub hits: u64,
    /// Checkouts that had to allocate (cold class).
    pub misses: u64,
    /// Leases returned to the free lists.
    pub recycles: u64,
    /// Leases dropped without recycling (allocation freed, pending events
    /// discarded) — nonzero means a worker is burning warm allocations.
    pub leaked: u64,
    /// Allocations parked in the free lists.
    pub pooled: u64,
    /// Bytes parked in the free lists.
    pub pooled_bytes: u64,
}

impl ArenaCounters {
    /// Fraction of checkouts served without an allocation.
    pub fn hit_rate(&self) -> f64 {
        if self.checkouts == 0 {
            0.0
        } else {
            self.hits as f64 / self.checkouts as f64
        }
    }

    fn merged(self, other: ArenaCounters) -> ArenaCounters {
        ArenaCounters {
            checkouts: self.checkouts + other.checkouts,
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            recycles: self.recycles + other.recycles,
            leaked: self.leaked + other.leaked,
            pooled: self.pooled + other.pooled,
            pooled_bytes: self.pooled_bytes + other.pooled_bytes,
        }
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("checkouts".into(), Value::Number(self.checkouts as f64));
        m.insert("hits".into(), Value::Number(self.hits as f64));
        m.insert("misses".into(), Value::Number(self.misses as f64));
        m.insert("recycles".into(), Value::Number(self.recycles as f64));
        m.insert("leaked".into(), Value::Number(self.leaked as f64));
        m.insert("pooled".into(), Value::Number(self.pooled as f64));
        m.insert("pooled_bytes".into(), Value::Number(self.pooled_bytes as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<ArenaCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("arena counters missing `{key}`")))
        };
        Ok(ArenaCounters {
            checkouts: num("checkouts")?,
            hits: num("hits")?,
            misses: num("misses")?,
            recycles: num("recycles")?,
            leaked: num("leaked")?,
            pooled: num("pooled")?,
            pooled_bytes: num("pooled_bytes")?,
        })
    }
}

/// Accumulated DAG hazard-analysis results for one shard (mirror of the
/// `sycl::hazard` report counts, defined here to keep the layer
/// independent of the substrate). Workers fold one window in per flush —
/// on a healthy pool every diagnostic counter stays zero and `windows`
/// tracks `launches`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HazardCounters {
    /// Record windows analyzed (one per drained flush).
    pub windows: u64,
    /// Commands covered across all windows.
    pub commands: u64,
    /// Dependency edges satisfied by earlier (drained) windows.
    pub external_deps: u64,
    /// Read-after-write hazards (no ordering path).
    pub raw: u64,
    /// Write-after-read hazards.
    pub war: u64,
    /// Write-after-write hazards.
    pub waw: u64,
    /// D2H readbacks not ordered after their producer.
    pub unordered_d2h: u64,
    /// Arena-lease generations reused without inheriting pending events.
    pub lease_reuse: u64,
    /// Commands holding a stale lease generation.
    pub stale_lease: u64,
    /// Dependency edges pointing at unknown commands.
    pub dangling_dep: u64,
    /// Duplicate command ids in one window.
    pub duplicate_id: u64,
}

impl HazardCounters {
    /// One analyzed window with per-kind diagnostic counts in
    /// `sycl::HazardKind::ALL` order (raw, war, waw, unordered-d2h,
    /// lease-reuse, stale-lease, dangling-dep, duplicate-id) — the layout
    /// `sycl::HazardReport::counts` produces.
    pub fn from_window(commands: u64, external_deps: u64, counts: [u64; 8]) -> HazardCounters {
        HazardCounters {
            windows: 1,
            commands,
            external_deps,
            raw: counts[0],
            war: counts[1],
            waw: counts[2],
            unordered_d2h: counts[3],
            lease_reuse: counts[4],
            stale_lease: counts[5],
            dangling_dep: counts[6],
            duplicate_id: counts[7],
        }
    }

    /// Total diagnostics of any kind.
    pub fn total(&self) -> u64 {
        self.raw
            + self.war
            + self.waw
            + self.unordered_d2h
            + self.lease_reuse
            + self.stale_lease
            + self.dangling_dep
            + self.duplicate_id
    }

    /// True when every analyzed window was race-free.
    pub fn clean(&self) -> bool {
        self.total() == 0
    }

    fn merged(self, other: HazardCounters) -> HazardCounters {
        HazardCounters {
            windows: self.windows + other.windows,
            commands: self.commands + other.commands,
            external_deps: self.external_deps + other.external_deps,
            raw: self.raw + other.raw,
            war: self.war + other.war,
            waw: self.waw + other.waw,
            unordered_d2h: self.unordered_d2h + other.unordered_d2h,
            lease_reuse: self.lease_reuse + other.lease_reuse,
            stale_lease: self.stale_lease + other.stale_lease,
            dangling_dep: self.dangling_dep + other.dangling_dep,
            duplicate_id: self.duplicate_id + other.duplicate_id,
        }
    }

    fn to_json(self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("windows".into(), Value::Number(self.windows as f64));
        m.insert("commands".into(), Value::Number(self.commands as f64));
        m.insert("external_deps".into(), Value::Number(self.external_deps as f64));
        m.insert("raw".into(), Value::Number(self.raw as f64));
        m.insert("war".into(), Value::Number(self.war as f64));
        m.insert("waw".into(), Value::Number(self.waw as f64));
        m.insert("unordered_d2h".into(), Value::Number(self.unordered_d2h as f64));
        m.insert("lease_reuse".into(), Value::Number(self.lease_reuse as f64));
        m.insert("stale_lease".into(), Value::Number(self.stale_lease as f64));
        m.insert("dangling_dep".into(), Value::Number(self.dangling_dep as f64));
        m.insert("duplicate_id".into(), Value::Number(self.duplicate_id as f64));
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<HazardCounters> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("hazard counters missing `{key}`")))
        };
        Ok(HazardCounters {
            windows: num("windows")?,
            commands: num("commands")?,
            external_deps: num("external_deps")?,
            raw: num("raw")?,
            war: num("war")?,
            waw: num("waw")?,
            unordered_d2h: num("unordered_d2h")?,
            lease_reuse: num("lease_reuse")?,
            stale_lease: num("stale_lease")?,
            dangling_dep: num("dangling_dep")?,
            duplicate_id: num("duplicate_id")?,
        })
    }
}

/// Which lane a shard serves (mirrors `coordinator::Route`, defined here
/// so the telemetry layer does not depend on the coordinator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Batched round-robin small-request lane.
    Batched,
    /// Unbatched large-request overflow lane.
    Overflow,
}

impl Lane {
    /// Stable label used in snapshots.
    pub fn token(self) -> &'static str {
        match self {
            Lane::Batched => "batched",
            Lane::Overflow => "overflow",
        }
    }

    /// Parse a snapshot label.
    pub fn parse(s: &str) -> Option<Lane> {
        match s {
            "batched" => Some(Lane::Batched),
            "overflow" => Some(Lane::Overflow),
            _ => None,
        }
    }
}

/// Lock-free per-shard counters and histograms.
#[derive(Debug)]
pub struct ShardTelemetry {
    /// Shard index in dispatch order.
    pub shard: usize,
    /// Lane this shard serves.
    pub lane: Lane,
    backend: OnceLock<String>,
    requests: AtomicU64,
    launches: AtomicU64,
    numbers: AtomicU64,
    delivered: AtomicU64,
    failures: AtomicU64,
    /// Faults the chaos plan injected into this shard so far (absolute
    /// publish from the plan's own counter, like `arena`).
    faults_injected: AtomicU64,
    /// Times the supervisor respawned this shard's worker.
    respawns: AtomicU64,
    /// Requests whose deadline budget expired before generation.
    deadline_exceeded: AtomicU64,
    launch_ns: Log2Histogram,
    batch_fill: Log2Histogram,
    request_n: Log2Histogram,
    /// Per-command-class counts/virtual-ns, indexed by `CommandKind`.
    command_cmds: [AtomicU64; 4],
    command_virt_ns: [AtomicU64; 4],
    /// Tile-executor work items and their measured wall time.
    tiles: AtomicU64,
    tile_wall_ns: AtomicU64,
    /// Cross-flush pipelining occupancy.
    pipeline_flushes: AtomicU64,
    pipeline_overlapped: AtomicU64,
    pipeline_overlap_ns: AtomicU64,
    /// Latest worker-arena counters, published whole once per flush — a
    /// mutex (not the request path: one uncontended lock per flush) so a
    /// concurrent snapshot can never observe counters torn across two
    /// flushes (hits from one, checkouts from another would make the
    /// allocation gate's deltas lie).
    arena: std::sync::Mutex<ArenaCounters>,
    /// Accumulated hazard-analysis results, folded in once per drained
    /// flush window (same one-lock-per-flush pattern as `arena`).
    hazards: std::sync::Mutex<HazardCounters>,
}

impl ShardTelemetry {
    fn new(shard: usize, lane: Lane) -> ShardTelemetry {
        ShardTelemetry {
            shard,
            lane,
            backend: OnceLock::new(),
            requests: AtomicU64::new(0),
            launches: AtomicU64::new(0),
            numbers: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            launch_ns: Log2Histogram::new(),
            batch_fill: Log2Histogram::new(),
            request_n: Log2Histogram::new(),
            command_cmds: std::array::from_fn(|_| AtomicU64::new(0)),
            command_virt_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            tiles: AtomicU64::new(0),
            tile_wall_ns: AtomicU64::new(0),
            pipeline_flushes: AtomicU64::new(0),
            pipeline_overlapped: AtomicU64::new(0),
            pipeline_overlap_ns: AtomicU64::new(0),
            arena: std::sync::Mutex::new(ArenaCounters::default()),
            hazards: std::sync::Mutex::new(HazardCounters::default()),
        }
    }

    /// Record which backend the worker built (first caller wins; workers
    /// set it once right after construction).
    pub fn set_backend(&self, name: &str) {
        let _ = self.backend.set(name.to_string());
    }

    /// One request accepted by this shard.
    pub fn record_request(&self, n: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.request_n.record(n as u64);
    }

    /// One kernel launch over a closed batch: `members` requests totalling
    /// `payload` delivered numbers in a `launch_n`-number launch (padding
    /// included), taking `wall_ns` of real time.
    pub fn record_launch(&self, members: usize, payload: u64, launch_n: u64, wall_ns: u64) {
        self.launches.fetch_add(1, Ordering::Relaxed);
        self.numbers.fetch_add(launch_n, Ordering::Relaxed);
        self.delivered.fetch_add(payload, Ordering::Relaxed);
        self.launch_ns.record(wall_ns);
        self.batch_fill.record(members as u64);
    }

    /// One request failed (backend error / degraded shard).
    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the chaos plan's cumulative injected-fault count for this
    /// shard (absolute value — the plan owns the counter; the worker and
    /// the supervisor both push it, so last-writer-wins is correct).
    pub fn set_faults_injected(&self, n: u64) {
        self.faults_injected.store(n, Ordering::Relaxed);
    }

    /// One supervisor respawn of this shard's worker.
    pub fn record_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// One request expired before this shard generated its payload.
    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one executed command's virtual duration into the per-class
    /// timings — workers call this while draining their queue's records
    /// after a flush, so autotune sees where the time actually goes
    /// (generate vs transform vs D2H).
    pub fn record_command(&self, kind: CommandKind, virt_ns: u64) {
        self.command_cmds[kind.index()].fetch_add(1, Ordering::Relaxed);
        self.command_virt_ns[kind.index()].fetch_add(virt_ns, Ordering::Relaxed);
    }

    /// Fold one flush's tile-executor work in: `tiles` nd-range tiles
    /// whose closures took `wall_ns` of summed real time on the team.
    pub fn record_tiles(&self, tiles: u64, wall_ns: u64) {
        self.tiles.fetch_add(tiles, Ordering::Relaxed);
        self.tile_wall_ns.fetch_add(wall_ns, Ordering::Relaxed);
    }

    /// One pipelined (tiled, double-buffered) flush; `overlap_ns` is how
    /// far its first generate started before the previous flush's last
    /// command retired on the virtual clock (0 = no overlap achieved).
    pub fn record_pipeline_flush(&self, overlap_ns: u64) {
        self.pipeline_flushes.fetch_add(1, Ordering::Relaxed);
        if overlap_ns > 0 {
            self.pipeline_overlapped.fetch_add(1, Ordering::Relaxed);
            self.pipeline_overlap_ns.fetch_add(overlap_ns, Ordering::Relaxed);
        }
    }

    /// Publish the worker arena's current counters (absolute values — the
    /// worker owns the arena and pushes its stats once per flush). The
    /// whole set swaps atomically, so snapshots never mix two flushes.
    pub fn set_arena(&self, c: ArenaCounters) {
        *self.arena.lock().unwrap() = c;
    }

    /// Fold one flush window's hazard-analysis results in (counts are
    /// cumulative, unlike the absolute arena publish — each drained window
    /// is analyzed exactly once).
    pub fn record_hazards(&self, window: HazardCounters) {
        let mut h = self.hazards.lock().unwrap();
        *h = h.merged(window);
    }

    /// Copy this shard's counters out.
    pub fn snapshot(&self) -> ShardSnapshot {
        let timing = |k: CommandKind| CommandTiming {
            cmds: self.command_cmds[k.index()].load(Ordering::Relaxed),
            virt_ns: self.command_virt_ns[k.index()].load(Ordering::Relaxed),
        };
        let arena = *self.arena.lock().unwrap();
        let hazards = *self.hazards.lock().unwrap();
        ShardSnapshot {
            shard: self.shard,
            lane: self.lane,
            backend: self.backend.get().cloned().unwrap_or_default(),
            requests: self.requests.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            numbers: self.numbers.load(Ordering::Relaxed),
            delivered: self.delivered.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            launch_ns: self.launch_ns.snapshot(),
            batch_fill: self.batch_fill.snapshot(),
            request_n: self.request_n.snapshot(),
            generate: timing(CommandKind::Generate),
            transform: timing(CommandKind::Transform),
            d2h: timing(CommandKind::TransferD2H),
            other: timing(CommandKind::Other),
            tiles: TileCounters {
                tiles: self.tiles.load(Ordering::Relaxed),
                wall_ns: self.tile_wall_ns.load(Ordering::Relaxed),
            },
            pipeline: PipelineCounters {
                flushes: self.pipeline_flushes.load(Ordering::Relaxed),
                overlapped: self.pipeline_overlapped.load(Ordering::Relaxed),
                overlap_ns: self.pipeline_overlap_ns.load(Ordering::Relaxed),
            },
            arena,
            hazards,
        }
    }
}

/// Pool-wide metrics registry: per-shard telemetry plus dispatcher-side
/// counters.
#[derive(Debug)]
pub struct TelemetryRegistry {
    platform: PlatformId,
    shards: Vec<Arc<ShardTelemetry>>,
    dispatched_batched: AtomicU64,
    dispatched_overflow: AtomicU64,
    retunes: AtomicU64,
    requests_retried: AtomicU64,
    requests_shed: AtomicU64,
    fcs_events: AtomicU64,
    fcs_hits: AtomicU64,
    fcs_gen_ns: AtomicU64,
    fcs_transform_ns: AtomicU64,
    fcs_d2h_ns: AtomicU64,
    trace_spans: AtomicU64,
    trace_dropped: AtomicU64,
    flight_dumps: AtomicU64,
    started: Instant,
}

impl TelemetryRegistry {
    /// Registry with one [`ShardTelemetry`] per lane entry, in dispatch
    /// order.
    pub fn new(platform: PlatformId, lanes: &[Lane]) -> Arc<TelemetryRegistry> {
        Arc::new(TelemetryRegistry {
            platform,
            shards: lanes
                .iter()
                .enumerate()
                .map(|(i, &lane)| Arc::new(ShardTelemetry::new(i, lane)))
                .collect(),
            dispatched_batched: AtomicU64::new(0),
            dispatched_overflow: AtomicU64::new(0),
            retunes: AtomicU64::new(0),
            requests_retried: AtomicU64::new(0),
            requests_shed: AtomicU64::new(0),
            fcs_events: AtomicU64::new(0),
            fcs_hits: AtomicU64::new(0),
            fcs_gen_ns: AtomicU64::new(0),
            fcs_transform_ns: AtomicU64::new(0),
            fcs_d2h_ns: AtomicU64::new(0),
            trace_spans: AtomicU64::new(0),
            trace_dropped: AtomicU64::new(0),
            flight_dumps: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// The shard-`i` telemetry handle (shared with that worker).
    pub fn shard(&self, i: usize) -> Arc<ShardTelemetry> {
        self.shards[i].clone()
    }

    /// Shard count (including the overflow lane when present).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Count one dispatcher routing decision.
    pub fn record_dispatch(&self, overflow: bool) {
        if overflow {
            self.dispatched_overflow.fetch_add(1, Ordering::Relaxed);
        } else {
            self.dispatched_batched.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one policy retune (autotuner nudge).
    pub fn record_retune(&self) {
        self.retunes.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one transient-fault retry re-dispatched by the supervisor.
    pub fn record_retry(&self) {
        self.requests_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request shed at the ingress gate (depth bound hit).
    pub fn record_shed(&self) {
        self.requests_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one FastCaloSim event served through this pool into the
    /// `fcs` block: the event's virtual hit count and its per-class
    /// virtual split (from the simulator's drained command window).
    pub fn record_fcs_event(&self, hits: u64, gen_ns: u64, transform_ns: u64, d2h_ns: u64) {
        self.fcs_events.fetch_add(1, Ordering::Relaxed);
        self.fcs_hits.fetch_add(hits, Ordering::Relaxed);
        self.fcs_gen_ns.fetch_add(gen_ns, Ordering::Relaxed);
        self.fcs_transform_ns.fetch_add(transform_ns, Ordering::Relaxed);
        self.fcs_d2h_ns.fetch_add(d2h_ns, Ordering::Relaxed);
    }

    /// Publish the request tracer's running span counters (absolute
    /// values — the tracer owns them; the supervisor and the pool's
    /// shutdown path both push, so last-writer-wins is correct).
    pub fn set_trace_activity(&self, spans: u64, dropped: u64) {
        self.trace_spans.store(spans, Ordering::Relaxed);
        self.trace_dropped.store(dropped, Ordering::Relaxed);
    }

    /// Count one flight-recorder dump taken from a dead shard's ring.
    pub fn record_flight_dump(&self) {
        self.flight_dumps.fetch_add(1, Ordering::Relaxed);
    }

    /// Copy everything into a plain snapshot.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            platform: self.platform,
            uptime_ns: self.started.elapsed().as_nanos() as u64,
            dispatched_batched: self.dispatched_batched.load(Ordering::Relaxed),
            dispatched_overflow: self.dispatched_overflow.load(Ordering::Relaxed),
            retunes: self.retunes.load(Ordering::Relaxed),
            requests_retried: self.requests_retried.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            fcs: FcsCounters {
                events: self.fcs_events.load(Ordering::Relaxed),
                hits: self.fcs_hits.load(Ordering::Relaxed),
                gen_ns: self.fcs_gen_ns.load(Ordering::Relaxed),
                transform_ns: self.fcs_transform_ns.load(Ordering::Relaxed),
                d2h_ns: self.fcs_d2h_ns.load(Ordering::Relaxed),
            },
            trace: TraceCounters {
                spans: self.trace_spans.load(Ordering::Relaxed),
                dropped: self.trace_dropped.load(Ordering::Relaxed),
                flight_dumps: self.flight_dumps.load(Ordering::Relaxed),
            },
            shards: self.shards.iter().map(|s| s.snapshot()).collect(),
        }
    }
}

/// Plain-data copy of one shard's telemetry.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSnapshot {
    /// Shard index in dispatch order.
    pub shard: usize,
    /// Lane served.
    pub lane: Lane,
    /// Backend the worker built (empty until the worker reports in).
    pub backend: String,
    /// Requests accepted.
    pub requests: u64,
    /// Kernel launches issued.
    pub launches: u64,
    /// Numbers generated (padded launch totals).
    pub numbers: u64,
    /// Numbers delivered to requesters (padding excluded).
    pub delivered: u64,
    /// Failed requests.
    pub failures: u64,
    /// Faults the chaos plan injected into this shard (0 without a plan).
    pub faults_injected: u64,
    /// Supervisor respawns of this shard's worker.
    pub respawns: u64,
    /// Requests expired before generation (deadline budget).
    pub deadline_exceeded: u64,
    /// Real wall time per launch, ns.
    pub launch_ns: HistogramSnapshot,
    /// Requests per closed batch (batch occupancy).
    pub batch_fill: HistogramSnapshot,
    /// Request sizes seen.
    pub request_n: HistogramSnapshot,
    /// Generate host tasks executed on the worker queue (virtual ns).
    pub generate: CommandTiming,
    /// Range-transform kernels executed (virtual ns).
    pub transform: CommandTiming,
    /// D2H slice copies executed (virtual ns).
    pub d2h: CommandTiming,
    /// Everything else on the worker queue (mallocs, setup; virtual ns).
    pub other: CommandTiming,
    /// Tile-executor work items and their measured wall time (all-zero on
    /// a serial shard).
    pub tiles: TileCounters,
    /// Cross-flush pipelining occupancy (all-zero on a serial shard).
    pub pipeline: PipelineCounters,
    /// Worker USM-arena counters at snapshot time.
    pub arena: ArenaCounters,
    /// Accumulated hazard-analysis results for this shard's flushes.
    pub hazards: HazardCounters,
}

impl ShardSnapshot {
    fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("shard".into(), Value::Number(self.shard as f64));
        m.insert("lane".into(), Value::String(self.lane.token().into()));
        m.insert("backend".into(), Value::String(self.backend.clone()));
        m.insert("requests".into(), Value::Number(self.requests as f64));
        m.insert("launches".into(), Value::Number(self.launches as f64));
        m.insert("numbers".into(), Value::Number(self.numbers as f64));
        m.insert("delivered".into(), Value::Number(self.delivered as f64));
        m.insert("failures".into(), Value::Number(self.failures as f64));
        m.insert("faults_injected".into(), Value::Number(self.faults_injected as f64));
        m.insert("respawns".into(), Value::Number(self.respawns as f64));
        m.insert(
            "deadline_exceeded".into(),
            Value::Number(self.deadline_exceeded as f64),
        );
        m.insert("launch_ns".into(), self.launch_ns.to_json());
        m.insert("batch_fill".into(), self.batch_fill.to_json());
        m.insert("request_n".into(), self.request_n.to_json());
        let mut commands = BTreeMap::new();
        commands.insert("generate".into(), self.generate.to_json());
        commands.insert("transform".into(), self.transform.to_json());
        commands.insert("d2h".into(), self.d2h.to_json());
        commands.insert("other".into(), self.other.to_json());
        m.insert("commands".into(), Value::Object(commands));
        m.insert("tiles".into(), self.tiles.to_json());
        m.insert("pipeline".into(), self.pipeline.to_json());
        m.insert("arena".into(), self.arena.to_json());
        m.insert("hazards".into(), self.hazards.to_json());
        Value::Object(m)
    }

    fn from_json(v: &Value) -> Result<ShardSnapshot> {
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("shard snapshot missing `{key}`")))
        };
        let hist = |key: &str| -> Result<HistogramSnapshot> {
            HistogramSnapshot::from_json(
                v.get(key)
                    .ok_or_else(|| Error::Json(format!("shard snapshot missing `{key}`")))?,
            )
        };
        let lane_str = v
            .get("lane")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Json("shard snapshot missing `lane`".into()))?;
        let commands = v
            .get("commands")
            .ok_or_else(|| Error::Json("shard snapshot missing `commands`".into()))?;
        let timing = |key: &str| -> Result<CommandTiming> {
            CommandTiming::from_json(commands.get(key).ok_or_else(|| {
                Error::Json(format!("shard snapshot missing command class `{key}`"))
            })?)
        };
        Ok(ShardSnapshot {
            shard: num("shard")? as usize,
            lane: Lane::parse(lane_str)
                .ok_or_else(|| Error::Json(format!("unknown lane `{lane_str}`")))?,
            backend: v
                .get("backend")
                .and_then(Value::as_str)
                .unwrap_or_default()
                .to_string(),
            requests: num("requests")?,
            launches: num("launches")?,
            numbers: num("numbers")?,
            delivered: num("delivered")?,
            failures: num("failures")?,
            faults_injected: num("faults_injected")?,
            respawns: num("respawns")?,
            deadline_exceeded: num("deadline_exceeded")?,
            launch_ns: hist("launch_ns")?,
            batch_fill: hist("batch_fill")?,
            request_n: hist("request_n")?,
            generate: timing("generate")?,
            transform: timing("transform")?,
            d2h: timing("d2h")?,
            other: timing("other")?,
            tiles: TileCounters::from_json(
                v.get("tiles")
                    .ok_or_else(|| Error::Json("shard snapshot missing `tiles`".into()))?,
            )?,
            pipeline: PipelineCounters::from_json(
                v.get("pipeline")
                    .ok_or_else(|| Error::Json("shard snapshot missing `pipeline`".into()))?,
            )?,
            arena: ArenaCounters::from_json(
                v.get("arena")
                    .ok_or_else(|| Error::Json("shard snapshot missing `arena`".into()))?,
            )?,
            hazards: HazardCounters::from_json(
                v.get("hazards")
                    .ok_or_else(|| Error::Json("shard snapshot missing `hazards`".into()))?,
            )?,
        })
    }
}

/// Aggregated per-class virtual timings (see
/// [`TelemetrySnapshot::command_breakdown`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommandBreakdown {
    /// Interop generate host tasks.
    pub generate: CommandTiming,
    /// Range-transform kernels.
    pub transform: CommandTiming,
    /// D2H slice copies.
    pub d2h: CommandTiming,
    /// Everything else.
    pub other: CommandTiming,
}

/// Plain-data copy of a [`TelemetryRegistry`] at one instant.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    /// Platform the pool serves.
    pub platform: PlatformId,
    /// Nanoseconds since the registry (pool) was created.
    pub uptime_ns: u64,
    /// Dispatcher decisions routed to batched shards.
    pub dispatched_batched: u64,
    /// Dispatcher decisions routed to the overflow lane.
    pub dispatched_overflow: u64,
    /// Policy retunes applied.
    pub retunes: u64,
    /// Transient-fault retries re-dispatched by the supervisor.
    pub requests_retried: u64,
    /// Requests shed at the ingress gate (depth bound hit).
    pub requests_shed: u64,
    /// FastCaloSim serving counters (all zero unless the pool served a
    /// FastCaloSim run; DESIGN.md S17).
    pub fcs: FcsCounters,
    /// Request-tracer activity (all zero unless tracing was enabled;
    /// DESIGN.md S18).
    pub trace: TraceCounters,
    /// Per-shard telemetry, dispatch order.
    pub shards: Vec<ShardSnapshot>,
}

/// Resilience-layer counters aggregated across the pool (see
/// [`TelemetrySnapshot::resilience_totals`]) — the chaos soak's gate
/// surface: all five are zero on a fault-free run and the first three are
/// nonzero under an armed plan with kills.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResilienceTotals {
    /// Faults injected across all shards (`faults.injected`).
    pub faults_injected: u64,
    /// Worker respawns across all shards (`shard.respawns`).
    pub shard_respawns: u64,
    /// Supervisor retry re-dispatches (`requests.retried`).
    pub requests_retried: u64,
    /// Ingress sheds (`requests.shed`).
    pub requests_shed: u64,
    /// Deadline expiries across all shards (`requests.deadline_exceeded`).
    pub deadline_exceeded: u64,
}

impl ResilienceTotals {
    /// True when any resilience machinery fired at all.
    pub fn any(&self) -> bool {
        self.faults_injected != 0
            || self.shard_respawns != 0
            || self.requests_retried != 0
            || self.requests_shed != 0
            || self.deadline_exceeded != 0
    }
}

impl TelemetrySnapshot {
    /// Total requests accepted across shards.
    pub fn total_requests(&self) -> u64 {
        self.shards.iter().map(|s| s.requests).sum()
    }

    /// Total numbers delivered to requesters (padding excluded).
    pub fn total_delivered(&self) -> u64 {
        self.shards.iter().map(|s| s.delivered).sum()
    }

    /// Total kernel launches issued.
    pub fn total_launches(&self) -> u64 {
        self.shards.iter().map(|s| s.launches).sum()
    }

    /// Total failed requests.
    pub fn total_failures(&self) -> u64 {
        self.shards.iter().map(|s| s.failures).sum()
    }

    /// Delivered throughput since `earlier`, in numbers per second (the
    /// autotuner's objective). Returns 0 when no time has passed.
    pub fn delivered_per_s_since(&self, earlier: &TelemetrySnapshot) -> f64 {
        let dt = self.uptime_ns.saturating_sub(earlier.uptime_ns);
        if dt == 0 {
            return 0.0;
        }
        let dn = self.total_delivered().saturating_sub(earlier.total_delivered());
        dn as f64 / dt as f64 * 1e9
    }

    /// Per-command-class virtual timings summed across shards — the
    /// Fig.-4-style gen/transform/D2H split of the serving path.
    pub fn command_breakdown(&self) -> CommandBreakdown {
        let fold = |f: fn(&ShardSnapshot) -> CommandTiming| {
            self.shards
                .iter()
                .map(f)
                .fold(CommandTiming::default(), CommandTiming::merged)
        };
        CommandBreakdown {
            generate: fold(|s| s.generate),
            transform: fold(|s| s.transform),
            d2h: fold(|s| s.d2h),
            other: fold(|s| s.other),
        }
    }

    /// Arena counters summed across shards (each worker owns its own
    /// arena; the sum is what the allocation gate checks).
    pub fn arena_totals(&self) -> ArenaCounters {
        self.shards
            .iter()
            .map(|s| s.arena)
            .fold(ArenaCounters::default(), ArenaCounters::merged)
    }

    /// Resilience counters summed across shards plus the pool-level
    /// ingress counters — all-zero on a fault-free run (itself a gate:
    /// the fault layer must be inert when no plan is configured).
    pub fn resilience_totals(&self) -> ResilienceTotals {
        ResilienceTotals {
            faults_injected: self.shards.iter().map(|s| s.faults_injected).sum(),
            shard_respawns: self.shards.iter().map(|s| s.respawns).sum(),
            requests_retried: self.requests_retried,
            requests_shed: self.requests_shed,
            deadline_exceeded: self.shards.iter().map(|s| s.deadline_exceeded).sum(),
        }
    }

    /// Tile-executor counters summed across shards — zero everywhere on
    /// a serial pool, which is itself an invariant the default-config
    /// tests lean on.
    pub fn tile_totals(&self) -> TileCounters {
        self.shards
            .iter()
            .map(|s| s.tiles)
            .fold(TileCounters::default(), TileCounters::merged)
    }

    /// Pipelining occupancy summed across shards.
    pub fn pipeline_totals(&self) -> PipelineCounters {
        self.shards
            .iter()
            .map(|s| s.pipeline)
            .fold(PipelineCounters::default(), PipelineCounters::merged)
    }

    /// Hazard-analysis results summed across shards — on a healthy pool
    /// `total()` is zero and `windows` equals [`Self::total_launches`].
    pub fn hazard_totals(&self) -> HazardCounters {
        self.shards
            .iter()
            .map(|s| s.hazards)
            .fold(HazardCounters::default(), HazardCounters::merged)
    }

    /// Serialize (schema `portarng-telemetry-v7`).
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Value::String(TELEMETRY_SCHEMA.into()));
        m.insert("platform".into(), Value::String(self.platform.token().into()));
        m.insert("uptime_ns".into(), Value::Number(self.uptime_ns as f64));
        m.insert(
            "dispatched_batched".into(),
            Value::Number(self.dispatched_batched as f64),
        );
        m.insert(
            "dispatched_overflow".into(),
            Value::Number(self.dispatched_overflow as f64),
        );
        m.insert("retunes".into(), Value::Number(self.retunes as f64));
        m.insert(
            "requests_retried".into(),
            Value::Number(self.requests_retried as f64),
        );
        m.insert("requests_shed".into(), Value::Number(self.requests_shed as f64));
        m.insert("fcs".into(), self.fcs.to_json());
        m.insert("trace".into(), self.trace.to_json());
        m.insert(
            "shards".into(),
            Value::Array(self.shards.iter().map(ShardSnapshot::to_json).collect()),
        );
        Value::Object(m)
    }

    /// Parse the [`TelemetrySnapshot::to_json`] form back.
    pub fn from_json(v: &Value) -> Result<TelemetrySnapshot> {
        match v.get("schema").and_then(Value::as_str) {
            Some(TELEMETRY_SCHEMA) => {}
            other => {
                return Err(Error::Json(format!(
                    "expected schema `{TELEMETRY_SCHEMA}`, got {other:?}"
                )))
            }
        }
        let token = v
            .get("platform")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Json("snapshot missing `platform`".into()))?;
        let platform = PlatformId::parse(token)
            .ok_or_else(|| Error::Json(format!("unknown platform `{token}`")))?;
        let num = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Value::as_f64)
                .map(|f| f as u64)
                .ok_or_else(|| Error::Json(format!("snapshot missing `{key}`")))
        };
        let shards = v
            .get("shards")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Json("snapshot missing `shards`".into()))?
            .iter()
            .map(ShardSnapshot::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(TelemetrySnapshot {
            platform,
            uptime_ns: num("uptime_ns")?,
            dispatched_batched: num("dispatched_batched")?,
            dispatched_overflow: num("dispatched_overflow")?,
            retunes: num("retunes")?,
            requests_retried: num("requests_retried")?,
            requests_shed: num("requests_shed")?,
            fcs: FcsCounters::from_json(
                v.get("fcs")
                    .ok_or_else(|| Error::Json("snapshot missing `fcs`".into()))?,
            )?,
            trace: TraceCounters::from_json(
                v.get("trace")
                    .ok_or_else(|| Error::Json("snapshot missing `trace`".into()))?,
            )?,
            shards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> Arc<TelemetryRegistry> {
        let reg =
            TelemetryRegistry::new(PlatformId::A100, &[Lane::Batched, Lane::Overflow]);
        let s0 = reg.shard(0);
        s0.set_backend("oneMKL-x86");
        s0.record_request(100);
        s0.record_request(44);
        s0.record_launch(2, 144, 144, 12_000);
        s0.record_command(CommandKind::Generate, 4_000);
        s0.record_command(CommandKind::Transform, 1_500);
        s0.record_command(CommandKind::TransferD2H, 800);
        s0.record_command(CommandKind::TransferD2H, 200);
        s0.set_arena(ArenaCounters {
            checkouts: 10,
            hits: 9,
            misses: 1,
            recycles: 10,
            leaked: 0,
            pooled: 1,
            pooled_bytes: 4096,
        });
        s0.record_hazards(HazardCounters::from_window(4, 2, [0; 8]));
        s0.record_hazards(HazardCounters::from_window(6, 3, [0, 0, 0, 1, 0, 0, 0, 0]));
        s0.record_tiles(8, 64_000);
        s0.record_tiles(4, 30_000);
        s0.record_pipeline_flush(0);
        s0.record_pipeline_flush(2_500);
        let s1 = reg.shard(1);
        s1.set_backend("cuRAND");
        s1.record_request(5000);
        s1.record_launch(1, 5000, 5000, 90_000);
        s1.record_failure();
        s1.record_command(CommandKind::Generate, 9_000);
        s1.set_faults_injected(3);
        s1.record_respawn();
        s1.record_deadline_exceeded();
        reg.record_dispatch(false);
        reg.record_dispatch(false);
        reg.record_dispatch(true);
        reg.record_retune();
        reg.record_retry();
        reg.record_retry();
        reg.record_shed();
        reg.record_fcs_event(5_100, 40_000, 12_000, 3_000);
        reg.record_fcs_event(4_900, 38_000, 11_000, 3_000);
        reg.set_trace_activity(250, 10);
        reg.record_flight_dump();
        reg
    }

    #[test]
    fn snapshot_aggregates_shards() {
        let snap = sample_registry().snapshot();
        assert_eq!(snap.total_requests(), 3);
        assert_eq!(snap.total_delivered(), 5144);
        assert_eq!(snap.total_launches(), 2);
        assert_eq!(snap.total_failures(), 1);
        assert_eq!(snap.dispatched_batched, 2);
        assert_eq!(snap.dispatched_overflow, 1);
        assert_eq!(snap.retunes, 1);
        assert_eq!(snap.shards[0].lane, Lane::Batched);
        assert_eq!(snap.shards[1].backend, "cuRAND");
        assert_eq!(snap.shards[0].batch_fill.count, 1);
        assert!(snap.shards[1].launch_ns.mean() > 0.0);
    }

    #[test]
    fn command_classes_and_arena_aggregate_across_shards() {
        let snap = sample_registry().snapshot();
        assert_eq!(snap.shards[0].generate, CommandTiming { cmds: 1, virt_ns: 4_000 });
        assert_eq!(snap.shards[0].d2h, CommandTiming { cmds: 2, virt_ns: 1_000 });
        let b = snap.command_breakdown();
        assert_eq!(b.generate, CommandTiming { cmds: 2, virt_ns: 13_000 });
        assert_eq!(b.transform, CommandTiming { cmds: 1, virt_ns: 1_500 });
        assert_eq!(b.d2h, CommandTiming { cmds: 2, virt_ns: 1_000 });
        assert_eq!(b.other, CommandTiming::default());
        let a = snap.arena_totals();
        assert_eq!(a.checkouts, 10);
        assert_eq!(a.misses, 1);
        assert!((a.hit_rate() - 0.9).abs() < 1e-12);
        // Shard 1 never published arena counters: all-zero, rate 0.
        assert_eq!(snap.shards[1].arena, ArenaCounters::default());
        assert_eq!(snap.shards[1].arena.hit_rate(), 0.0);
    }

    #[test]
    fn resilience_counters_aggregate_and_stay_zero_untouched() {
        let snap = sample_registry().snapshot();
        // Shard 0 never saw resilience traffic: all-zero (the fault-free
        // invariant every untouched shard must keep).
        assert_eq!(snap.shards[0].faults_injected, 0);
        assert_eq!(snap.shards[0].respawns, 0);
        assert_eq!(snap.shards[0].deadline_exceeded, 0);
        let r = snap.resilience_totals();
        assert_eq!(
            r,
            ResilienceTotals {
                faults_injected: 3,
                shard_respawns: 1,
                requests_retried: 2,
                requests_shed: 1,
                deadline_exceeded: 1,
            }
        );
        assert!(r.any());
        // A virgin registry reports all-zero totals.
        let clean = TelemetryRegistry::new(PlatformId::A100, &[Lane::Batched]).snapshot();
        assert!(!clean.resilience_totals().any());
        // set_faults_injected is an absolute publish, not cumulative.
        let reg = sample_registry();
        reg.shard(1).set_faults_injected(7);
        assert_eq!(reg.snapshot().resilience_totals().faults_injected, 7);
    }

    #[test]
    fn tile_and_pipeline_counters_accumulate_and_aggregate() {
        let snap = sample_registry().snapshot();
        assert_eq!(snap.shards[0].tiles, TileCounters { tiles: 12, wall_ns: 94_000 });
        let p = snap.shards[0].pipeline;
        assert_eq!(p, PipelineCounters { flushes: 2, overlapped: 1, overlap_ns: 2_500 });
        assert!((p.occupancy() - 0.5).abs() < 1e-12);
        // Shard 1 runs serial: both blocks stay all-zero.
        assert_eq!(snap.shards[1].tiles, TileCounters::default());
        assert_eq!(snap.shards[1].pipeline, PipelineCounters::default());
        assert_eq!(snap.shards[1].pipeline.occupancy(), 0.0);
        assert_eq!(snap.tile_totals(), snap.shards[0].tiles);
        assert_eq!(snap.pipeline_totals(), snap.shards[0].pipeline);
    }

    #[test]
    fn hazard_windows_accumulate_and_aggregate() {
        let snap = sample_registry().snapshot();
        let h0 = snap.shards[0].hazards;
        assert_eq!(h0.windows, 2);
        assert_eq!(h0.commands, 10);
        assert_eq!(h0.external_deps, 5);
        assert_eq!(h0.unordered_d2h, 1);
        assert_eq!(h0.total(), 1);
        assert!(!h0.clean());
        // Shard 1 analyzed nothing: zero windows, trivially clean.
        assert!(snap.shards[1].hazards.clean());
        let totals = snap.hazard_totals();
        assert_eq!(totals.windows, 2);
        assert_eq!(totals.total(), 1);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let mut snap = sample_registry().snapshot();
        snap.uptime_ns = 123_456_789; // pin the clock for exact equality
        let text = snap.to_json().to_json();
        let back =
            TelemetrySnapshot::from_json(&Value::parse(&text).unwrap()).unwrap();
        // Histograms re-pad to full width; compare through re-serialization.
        assert_eq!(back.to_json().to_json(), text);
        assert_eq!(back.platform, snap.platform);
        assert_eq!(back.total_delivered(), snap.total_delivered());
        assert_eq!(back.fcs, snap.fcs);
    }

    #[test]
    fn fcs_counters_accumulate_per_event() {
        let snap = sample_registry().snapshot();
        assert_eq!(
            snap.fcs,
            FcsCounters {
                events: 2,
                hits: 10_000,
                gen_ns: 78_000,
                transform_ns: 23_000,
                d2h_ns: 6_000,
            }
        );
        assert!(snap.fcs.any());
        // A pool that never served FastCaloSim keeps the block all-zero.
        let clean = TelemetryRegistry::new(PlatformId::A100, &[Lane::Batched]).snapshot();
        assert!(!clean.fcs.any());
    }

    #[test]
    fn trace_counters_publish_and_accumulate() {
        let snap = sample_registry().snapshot();
        assert_eq!(
            snap.trace,
            TraceCounters { spans: 250, dropped: 10, flight_dumps: 1 }
        );
        assert!(snap.trace.any());
        // set_trace_activity is an absolute publish, record_flight_dump
        // is cumulative.
        let reg = sample_registry();
        reg.set_trace_activity(400, 12);
        reg.record_flight_dump();
        let snap = reg.snapshot();
        assert_eq!(
            snap.trace,
            TraceCounters { spans: 400, dropped: 12, flight_dumps: 2 }
        );
        // A pool that never enabled tracing keeps the block all-zero.
        let clean = TelemetryRegistry::new(PlatformId::A100, &[Lane::Batched]).snapshot();
        assert!(!clean.trace.any());
    }

    #[test]
    fn from_json_rejects_wrong_schema() {
        let v = Value::parse(r#"{"schema":"nope","platform":"a100"}"#).unwrap();
        assert!(TelemetrySnapshot::from_json(&v).is_err());
    }

    #[test]
    fn windowed_throughput_uses_deltas() {
        let mut early = sample_registry().snapshot();
        let mut late = early.clone();
        early.uptime_ns = 0;
        late.uptime_ns = 1_000_000_000;
        late.shards[0].delivered += 1_000_000;
        let tput = late.delivered_per_s_since(&early);
        assert!((tput - 1_000_000.0).abs() < 1e-6, "tput={tput}");
    }
}
