//! Lock-free log₂-bucketed histogram.
//!
//! The hot path ([`Log2Histogram::record`]) is two relaxed `fetch_add`s
//! plus one on the value's bucket — no locks, no allocation — so shard
//! workers can record per-launch latencies and batch occupancies without
//! perturbing the throughput they are measuring. Reads go through
//! [`Log2Histogram::snapshot`], which copies the counters into a plain
//! [`HistogramSnapshot`] for aggregation and `jsonlite` serialization.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::jsonlite::Value;

/// Bucket count: bucket 0 holds the value 0, bucket `i >= 1` holds values
/// in `[2^(i-1), 2^i)`, and the last bucket absorbs everything above
/// `2^(BUCKETS-2)` (~7e13 — minutes of nanoseconds, terascale batch
/// sizes), so no observable value is dropped.
pub const BUCKETS: usize = 48;

/// Index of the bucket holding `value`.
fn bucket_of(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        (64 - value.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Lower bound of bucket `i` (inclusive).
fn bucket_floor(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Lock-free log₂ histogram of `u64` observations.
#[derive(Debug)]
pub struct Log2Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Log2Histogram {
    /// Empty histogram.
    pub fn new() -> Log2Histogram {
        Log2Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation (lock-free, relaxed ordering — counters are
    /// monotonic and read only through whole-histogram snapshots).
    pub fn record(&self, value: u64) {
        self.buckets[bucket_of(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Copy the counters out. Concurrent recorders may land between the
    /// individual loads; the snapshot is still a valid histogram (each
    /// counter is internally consistent and monotonic).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        HistogramSnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-data copy of a [`Log2Histogram`] at one instant.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    /// Per-bucket observation counts (see [`BUCKETS`] for the layout).
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile: the lower bound of the bucket containing the
    /// `q`-th observation (`q` in `[0, 1]`). Bucket resolution, so at most
    /// a factor-2 overestimate of the true quantile.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_floor(i);
            }
        }
        bucket_floor(self.buckets.len().saturating_sub(1))
    }

    /// Component-wise sum (cross-shard aggregation).
    pub fn merged(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let n = self.buckets.len().max(other.buckets.len());
        let mut buckets = vec![0u64; n];
        for (i, b) in buckets.iter_mut().enumerate() {
            *b = self.buckets.get(i).copied().unwrap_or(0)
                + other.buckets.get(i).copied().unwrap_or(0);
        }
        HistogramSnapshot {
            buckets,
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }

    /// Observations recorded since `earlier` (windowed rates). Saturates
    /// at zero if `earlier` is not actually earlier.
    pub fn delta_count(&self, earlier: &HistogramSnapshot) -> u64 {
        self.count.saturating_sub(earlier.count)
    }

    /// Serialize as `{"count": .., "sum": .., "buckets": [..]}` with
    /// trailing zero buckets trimmed.
    pub fn to_json(&self) -> Value {
        let trimmed = self.buckets.len()
            - self.buckets.iter().rev().take_while(|&&b| b == 0).count();
        let mut m = std::collections::BTreeMap::new();
        m.insert("count".to_string(), Value::Number(self.count as f64));
        m.insert("sum".to_string(), Value::Number(self.sum as f64));
        m.insert(
            "buckets".to_string(),
            Value::Array(
                self.buckets[..trimmed].iter().map(|&b| Value::Number(b as f64)).collect(),
            ),
        );
        Value::Object(m)
    }

    /// Parse the [`HistogramSnapshot::to_json`] form back (buckets are
    /// re-padded to [`BUCKETS`]).
    pub fn from_json(v: &Value) -> Result<HistogramSnapshot> {
        let count = v
            .get("count")
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Json("histogram missing `count`".into()))?
            as u64;
        let sum = v
            .get("sum")
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Json("histogram missing `sum`".into()))?
            as u64;
        let arr = v
            .get("buckets")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Json("histogram missing `buckets`".into()))?;
        let mut buckets = vec![0u64; BUCKETS.max(arr.len())];
        for (i, b) in arr.iter().enumerate() {
            buckets[i] = b
                .as_f64()
                .ok_or_else(|| Error::Json("non-numeric histogram bucket".into()))?
                as u64;
        }
        Ok(HistogramSnapshot { buckets, count, sum })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        for i in 1..BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_floor(i)), i, "floor of bucket {i}");
        }
    }

    #[test]
    fn record_and_snapshot() {
        let h = Log2Histogram::new();
        for v in [0u64, 1, 1, 5, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1007);
        assert_eq!(s.buckets[0], 1); // the zero
        assert_eq!(s.buckets[1], 2); // the ones
        assert!((s.mean() - 201.4).abs() < 1e-9);
    }

    #[test]
    fn quantiles_are_bucket_floors() {
        let h = Log2Histogram::new();
        for _ in 0..90 {
            h.record(10); // bucket [8, 16)
        }
        for _ in 0..10 {
            h.record(1000); // bucket [512, 1024)
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 8);
        assert_eq!(s.quantile(0.99), 512);
        assert_eq!(HistogramSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(Log2Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for v in 0..10_000u64 {
                        h.record(v);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 40_000);
        assert_eq!(s.sum, 4 * (10_000 * 9_999 / 2));
    }

    #[test]
    fn json_round_trip() {
        let h = Log2Histogram::new();
        for v in [0u64, 3, 900, 1 << 40] {
            h.record(v);
        }
        let s = h.snapshot();
        let text = s.to_json().to_json();
        let back = HistogramSnapshot::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.count, s.count);
        assert_eq!(back.sum, s.sum);
        assert_eq!(&back.buckets[..BUCKETS], &s.buckets[..]);
    }

    #[test]
    fn merge_sums_componentwise() {
        let a = Log2Histogram::new();
        a.record(5);
        let b = Log2Histogram::new();
        b.record(5);
        b.record(100);
        let m = a.snapshot().merged(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum, 110);
        assert_eq!(m.buckets[bucket_of(5)], 2);
    }
}
