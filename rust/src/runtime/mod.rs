//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the "device" of the three-layer stack: the Pallas Philox kernel,
//! fused with its range transform, lowered to HLO and run from Rust with
//! Python nowhere on the request path. Pattern follows
//! /opt/xla-example/load_hlo (HLO *text* interchange — see aot.py for why
//! serialized protos are rejected by xla_extension 0.5.1).

mod artifact;
mod client;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{PjrtRuntime, DEFAULT_ARTIFACT_DIR};
