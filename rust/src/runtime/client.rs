//! PJRT client wrapper with a compiled-executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::xla;

use super::artifact::{ArtifactSpec, Manifest};

/// Default artifact location relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// A PJRT CPU client plus the artifact registry. Compilation happens once
/// per artifact (at first use or via [`PjrtRuntime::warmup`]); execution is
/// the request-path hot call.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    /// Create from an artifact directory (reads `manifest.json`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        Ok(PjrtRuntime {
            client: xla::PjRtClient::cpu()?,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Locate the artifact dir by walking up from the current directory —
    /// lets tests/examples run from any workspace subdirectory.
    pub fn discover() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join(DEFAULT_ARTIFACT_DIR);
            if cand.join("manifest.json").exists() {
                return PjrtRuntime::new(cand);
            }
            if !dir.pop() {
                return Err(Error::Artifact(
                    "artifacts/manifest.json not found in any parent directory; \
                     run `make artifacts`"
                        .into(),
                ));
            }
        }
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact spec lookup.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Compile (and cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (or all).
    pub fn warmup(&self, names: Option<&[&str]>) -> Result<()> {
        match names {
            Some(list) => {
                for n in list {
                    self.load(n)?;
                }
            }
            None => {
                let all: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
                for n in all {
                    self.load(&n)?;
                }
            }
        }
        Ok(())
    }

    /// Execute an artifact; returns the flattened tuple leaves.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so the single
    /// result literal is decomposed into its tuple elements.
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.get(name)?;
        if args.len() != spec.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} args, got {}",
                spec.inputs.len(),
                args.len()
            )));
        }
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Convenience: run a burner artifact.
    /// `key`/`off` are the Philox seed/counter words, `p0`/`p1` the range
    /// (or mean/std) parameters. Returns the generated f32 batch.
    pub fn run_burner(
        &self,
        name: &str,
        key: [u32; 2],
        off: [u32; 2],
        p0: f32,
        p1: f32,
    ) -> Result<Vec<f32>> {
        let args = [
            xla::Literal::vec1(&key[..]),
            xla::Literal::vec1(&off[..]),
            xla::Literal::vec1(&[p0, p1][..]),
        ];
        let out = self.run(name, &args)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Convenience: run the FastCaloSim hit-deposit artifact. Returns
    /// (per-cell deposits, total energy).
    pub fn run_calosim(
        &self,
        name: &str,
        key: [u32; 2],
        off: [u32; 2],
        params: [f32; 5],
    ) -> Result<(Vec<f32>, f32)> {
        let args = [
            xla::Literal::vec1(&key[..]),
            xla::Literal::vec1(&off[..]),
            xla::Literal::vec1(&params[..]),
        ];
        let out = self.run(name, &args)?;
        let deposits = out[0].to_vec::<f32>()?;
        let total = out[1].get_first_element::<f32>()?;
        Ok((deposits, total))
    }
}
