//! Artifact manifest: the Rust mirror of `python/compile/model.ARTIFACTS`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::jsonlite::Value;

/// Dtype+shape of one parameter or result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// numpy dtype name ("float32", "uint32", ...).
    pub dtype: String,
    /// Dimensions.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let dtype = v
            .get("dtype")
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Artifact("missing dtype".into()))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Artifact("missing shape".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Artifact("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, shape })
    }
}

/// One compiled-graph artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Registry name (e.g. "burner_uniform_65536").
    pub name: String,
    /// HLO text file relative to the artifact dir.
    pub file: PathBuf,
    /// Parameter signature.
    pub inputs: Vec<TensorSpec>,
    /// Result signature (flattened tuple leaves).
    pub outputs: Vec<TensorSpec>,
    /// Content hash from the AOT step.
    pub sha256: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// name -> artifact.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let format = v.get("format").and_then(Value::as_str).unwrap_or("");
        if format != "hlo-text-v1" {
            return Err(Error::Artifact(format!("unsupported manifest format `{format}`")));
        }
        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Value::as_object)
            .ok_or_else(|| Error::Artifact("missing artifacts".into()))?;
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing file")))?;
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Value::as_array)
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing {key}")))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: PathBuf::from(file),
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                    sha256: a
                        .get("sha256")
                        .and_then(Value::as_str)
                        .unwrap_or_default()
                        .to_string(),
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    /// Look up by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact `{name}`")))
    }

    /// Names of burner-uniform artifacts sorted ascending by size — the
    /// padding ladder for arbitrary batch sizes.
    pub fn burner_sizes(&self) -> Vec<(usize, String)> {
        let mut v: Vec<(usize, String)> = self
            .artifacts
            .keys()
            .filter_map(|name| {
                let n: usize = name.strip_prefix("burner_uniform_")?.parse().ok()?;
                Some((n, name.clone()))
            })
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"format":"hlo-text-v1","artifacts":{
      "burner_uniform_4096":{"file":"burner_uniform_4096.hlo.txt",
        "inputs":[{"dtype":"uint32","shape":[2]},{"dtype":"uint32","shape":[2]},
                  {"dtype":"float32","shape":[2]}],
        "outputs":[{"dtype":"float32","shape":[4096]}],"sha256":"x"},
      "burner_uniform_65536":{"file":"burner_uniform_65536.hlo.txt",
        "inputs":[],"outputs":[],"sha256":"y"}}}"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("burner_uniform_4096").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.outputs[0].shape, vec![4096]);
        assert_eq!(a.outputs[0].elements(), 4096);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn burner_ladder_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let sizes = m.burner_sizes();
        assert_eq!(sizes.len(), 2);
        assert_eq!(sizes[0].0, 4096);
        assert_eq!(sizes[1].0, 65536);
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Manifest::parse(r#"{"format":"v2","artifacts":{}}"#).is_err());
    }
}
