//! Mini benchmark harness (substrate — criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call into this
//! module: warmup, fixed sample counts, outlier-robust statistics, and
//! throughput reporting. Results can be dumped as markdown or CSV for
//! EXPERIMENTS.md.

use std::time::Instant;

use crate::metrics::Summary;

/// One benchmark's configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 3, samples: 15 }
    }
}

/// A completed measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id ("group/name").
    pub name: String,
    /// Per-sample wall nanoseconds.
    pub samples_ns: Vec<f64>,
    /// Elements processed per iteration (for throughput), if any.
    pub items: Option<u64>,
}

impl BenchResult {
    /// Summary statistics over samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    /// Throughput in M items/s at the median, when `items` is set.
    pub fn throughput_m_per_s(&self) -> Option<f64> {
        self.items.map(|n| n as f64 / crate::metrics::median(&self.samples_ns) * 1e3)
    }

    /// One human-readable line.
    pub fn line(&self) -> String {
        let s = self.summary();
        let tp = self
            .throughput_m_per_s()
            .map(|t| format!("  {:>10.1} Mitem/s", t))
            .unwrap_or_default();
        format!(
            "{:<48} {:>12.3} ms ±{:>8.3} (median {:>12.3}){}",
            self.name,
            s.mean / 1e6,
            s.stddev / 1e6,
            s.median / 1e6,
            tp
        )
    }
}

/// A named group of benchmarks, criterion-style.
pub struct BenchGroup {
    name: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchGroup {
    /// New group.
    pub fn new(name: impl Into<String>) -> Self {
        BenchGroup { name: name.into(), config: BenchConfig::default(), results: Vec::new() }
    }

    /// Override sample counts.
    pub fn config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Measure a closure.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Measure a closure that processes `items` elements per call.
    pub fn bench_items(&mut self, name: &str, items: u64, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.config.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.name, name),
            samples_ns: samples,
            items,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// CSV dump (name, mean_ns, stddev_ns, median_ns, items).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,mean_ns,stddev_ns,median_ns,items\n");
        for r in &self.results {
            let s = r.summary();
            out.push_str(&format!(
                "{},{:.0},{:.0},{:.0},{}\n",
                r.name,
                s.mean,
                s.stddev,
                s.median,
                r.items.map(|i| i.to_string()).unwrap_or_default()
            ));
        }
        out
    }
}

/// Prevent the optimizer from discarding a value (criterion::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut g = BenchGroup::new("test").config(BenchConfig { warmup: 1, samples: 5 });
        let mut acc = 0u64;
        g.bench_items("spin", 1000, || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let r = &g.results()[0];
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.summary().mean > 0.0);
        assert!(r.throughput_m_per_s().unwrap() > 0.0);
        assert!(g.to_csv().lines().count() == 2);
    }
}
