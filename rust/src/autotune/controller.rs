//! Online tuning controller: a log₂ coordinate hill-climber.
//!
//! [`AutoTuner`] is measurement-agnostic: feed it one delivered-throughput
//! observation per window ([`AutoTuner::observe`]) and it answers with the
//! knobs to try next — threshold, flush size, and (when the tile executor
//! is enabled) tile size and team width move by factors of two, one knob
//! at a time, continuing while a direction keeps improving and
//! flipping/switching when it stops. Plateaus (flat regions around a
//! disabled-like threshold) are walked through up to a budget instead of
//! being mistaken for optima; clamped candidates count as rejections so
//! bounds never trap the walk. After both directions of both knobs
//! reject, the tuner holds the best point — and re-opens exploration if
//! the observed throughput later drifts well below it (load shift).
//!
//! [`PoolAutoTuner`] binds the controller to a live
//! [`ServicePool`](crate::coordinator::ServicePool): each
//! [`step`](PoolAutoTuner::step) turns telemetry-snapshot deltas into the
//! observation and publishes the proposal through the pool's lock-free
//! [`TuningHandle`](crate::coordinator::TuningHandle).

use crate::coordinator::{ServicePool, TuningParams};
use crate::telemetry::TelemetrySnapshot;

/// Upper bound for the threshold knob (everything realistic overflows
/// below this; `usize::MAX` positions step back into the grid from here).
pub const MAX_THRESHOLD: usize = 1 << 28;

/// Upper bound for the flush-requests knob.
pub const MAX_FLUSH: usize = 256;

/// Lower bound for the executor tile-size knob: below this the per-tile
/// submission overhead swamps the kernel itself.
pub const MIN_TILE: usize = 1024;

/// Upper bound for the executor tile-size knob.
pub const MAX_TILE: usize = 1 << 22;

/// Upper bound for the executor team-width knob.
pub const MAX_TEAM: usize = 16;

/// Consecutive rejected candidates before the tuner holds its best point
/// (covers both directions of all four knobs).
const STALL_LIMIT: u32 = 8;

/// Plateau moves tolerated before the walk is abandoned as flat.
const PLATEAU_LIMIT: u32 = 16;

/// Fractional throughput drop (at the held optimum) that re-opens
/// exploration.
const DRIFT: f64 = 0.3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Knob {
    Threshold,
    Flush,
    TileSize,
    TeamWidth,
}

impl Knob {
    fn next(self) -> Knob {
        match self {
            Knob::Threshold => Knob::Flush,
            Knob::Flush => Knob::TileSize,
            Knob::TileSize => Knob::TeamWidth,
            Knob::TeamWidth => Knob::Threshold,
        }
    }
}

fn step(p: TuningParams, knob: Knob, up: bool) -> TuningParams {
    let mut c = p;
    match knob {
        Knob::Threshold => {
            let base = p.threshold.min(MAX_THRESHOLD).max(1);
            c.threshold = if up {
                base.saturating_mul(2).min(MAX_THRESHOLD)
            } else {
                (base / 2).max(1)
            };
        }
        Knob::Flush => {
            let base = p.flush_requests.min(MAX_FLUSH).max(1);
            c.flush_requests = if up { (base * 2).min(MAX_FLUSH) } else { (base / 2).max(1) };
        }
        // The serial/tiled decision belongs to the operator (pool config,
        // profile, or PORTARNG_TILE); the tuner only refines an executor
        // that is already on. With `tile_size == 0` both executor knobs
        // are immovable, which `propose` treats as instant rejections —
        // a serial pool pays no extra observation windows for them.
        Knob::TileSize => {
            if p.tile_size > 0 {
                let base = p.tile_size.clamp(MIN_TILE, MAX_TILE);
                c.tile_size =
                    if up { (base * 2).min(MAX_TILE) } else { (base / 2).max(MIN_TILE) };
            }
        }
        Knob::TeamWidth => {
            if p.tile_size > 0 {
                let base = p.team_width.clamp(1, MAX_TEAM);
                c.team_width = if up { (base * 2).min(MAX_TEAM) } else { (base / 2).max(1) };
            }
        }
    }
    c
}

/// Log₂ coordinate hill-climber over [`TuningParams`].
#[derive(Debug, Clone)]
pub struct AutoTuner {
    /// Last proposal handed out (what the next observation measures).
    trial: TuningParams,
    /// Accepted position the next candidate steps from.
    pos: TuningParams,
    /// Throughput anchor at `pos` (0 until the first observation).
    pos_tput: f64,
    best: TuningParams,
    best_tput: f64,
    knob: Knob,
    dir_up: bool,
    stalls: u32,
    plateau_run: u32,
    /// Relative improvement threshold separating improve/plateau/worse.
    eps: f64,
}

impl AutoTuner {
    /// Tuner starting (and first measuring) at `initial`.
    pub fn new(initial: TuningParams) -> AutoTuner {
        AutoTuner {
            trial: initial,
            pos: initial,
            pos_tput: 0.0,
            best: initial,
            best_tput: 0.0,
            knob: Knob::Threshold,
            dir_up: true,
            stalls: 0,
            plateau_run: 0,
            eps: 0.001,
        }
    }

    /// Override the improve/plateau tolerance (raise it for noisy real
    /// wall-clock measurements; the default suits the virtual clock).
    pub fn with_epsilon(mut self, eps: f64) -> AutoTuner {
        self.eps = eps.max(0.0);
        self
    }

    /// The knobs the caller should be running right now.
    pub fn params(&self) -> TuningParams {
        self.trial
    }

    /// Best point seen so far and its throughput.
    pub fn best(&self) -> (TuningParams, f64) {
        (self.best, self.best_tput)
    }

    /// Whether the tuner is holding its optimum (exploration exhausted).
    pub fn converged(&self) -> bool {
        self.stalls >= STALL_LIMIT
    }

    fn register_stall(&mut self) {
        self.stalls += 1;
        self.plateau_run = 0;
        self.pos = self.best;
        self.pos_tput = self.best_tput;
        if self.dir_up {
            self.dir_up = false;
        } else {
            self.dir_up = true;
            self.knob = self.knob.next();
        }
    }

    fn propose(&mut self) -> TuningParams {
        // A clamped candidate that cannot move counts as a rejection; at
        // most all eight (knob, direction) pairs can be exhausted here.
        for _ in 0..8 {
            if self.converged() {
                break;
            }
            let cand = step(self.pos, self.knob, self.dir_up);
            if cand != self.pos {
                self.trial = cand;
                return cand;
            }
            self.register_stall();
        }
        self.trial = self.best;
        self.best
    }

    /// Digest the throughput observed while running [`params`], and
    /// return the knobs to run next. Observations of `<= 0` (idle window)
    /// leave the state untouched.
    ///
    /// [`params`]: AutoTuner::params
    pub fn observe(&mut self, throughput: f64) -> TuningParams {
        if throughput <= 0.0 {
            return self.trial;
        }
        if self.converged() {
            // Holding the optimum: re-open exploration only on a real
            // regression (load drift), re-anchoring to today's reality.
            if throughput < self.best_tput * (1.0 - DRIFT) {
                self.best_tput = throughput;
                self.pos_tput = throughput;
                self.stalls = 0;
                self.plateau_run = 0;
            } else {
                return self.trial;
            }
        }
        if self.pos_tput == 0.0 {
            // First observation: anchors the starting point.
            self.pos_tput = throughput;
            self.best_tput = throughput;
            return self.propose();
        }
        if throughput > self.pos_tput * (1.0 + self.eps) {
            // Strict improvement: accept and keep going.
            self.pos = self.trial;
            self.pos_tput = throughput;
            self.stalls = 0;
            self.plateau_run = 0;
            if throughput > self.best_tput {
                self.best_tput = throughput;
                self.best = self.trial;
            }
        } else if throughput >= self.pos_tput * (1.0 - self.eps) {
            // Plateau: walk through it (bounded), keeping the anchor.
            self.plateau_run += 1;
            if self.plateau_run > PLATEAU_LIMIT {
                self.register_stall();
            } else {
                self.pos = self.trial;
                if throughput > self.best_tput {
                    self.best_tput = throughput;
                    self.best = self.trial;
                }
            }
        } else {
            // Worse: back to the best point, try the next direction/knob.
            self.register_stall();
        }
        self.propose()
    }
}

/// Binds an [`AutoTuner`] to a live pool: snapshot deltas in, lock-free
/// retunes out.
pub struct PoolAutoTuner {
    tuner: AutoTuner,
    last: TelemetrySnapshot,
}

impl PoolAutoTuner {
    /// Controller for `pool`, starting from the pool's current knobs.
    /// Real wall-clock windows are noisy, so the improvement tolerance is
    /// widened to 5%.
    pub fn new(pool: &ServicePool) -> PoolAutoTuner {
        PoolAutoTuner {
            tuner: AutoTuner::new(pool.tuning().params()).with_epsilon(0.05),
            last: pool.telemetry().snapshot(),
        }
    }

    /// Close one observation window: read the telemetry delta, feed the
    /// tuner, publish its proposal to the pool. Returns the knobs now in
    /// effect.
    pub fn step(&mut self, pool: &ServicePool) -> TuningParams {
        let snap = pool.telemetry().snapshot();
        let tput = snap.delivered_per_s_since(&self.last);
        self.last = snap;
        let next = self.tuner.observe(tput);
        if next != pool.tuning().params() {
            pool.retune(next);
        }
        next
    }

    /// The underlying controller (for reporting).
    pub fn tuner(&self) -> &AutoTuner {
        &self.tuner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(threshold: usize, flush: usize) -> TuningParams {
        TuningParams {
            threshold,
            flush_requests: flush,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        }
    }

    /// Smooth unimodal objective peaking at threshold 2^12, flat in flush.
    fn objective(params: &TuningParams) -> f64 {
        let l2 = (params.threshold.max(1) as f64).log2();
        1e6 / (1.0 + (l2 - 12.0).abs())
    }

    #[test]
    fn climbs_to_a_unimodal_peak_from_above() {
        let mut tuner = AutoTuner::new(p(1 << 20, 16));
        let mut params = tuner.params();
        for _ in 0..60 {
            params = tuner.observe(objective(&params));
        }
        assert!(tuner.converged());
        assert_eq!(tuner.best().0.threshold, 1 << 12);
        assert_eq!(params.threshold, 1 << 12, "holds the optimum");
    }

    #[test]
    fn climbs_to_a_unimodal_peak_from_below() {
        let mut tuner = AutoTuner::new(p(4, 16));
        let mut params = tuner.params();
        for _ in 0..60 {
            params = tuner.observe(objective(&params));
        }
        assert!(tuner.converged());
        assert_eq!(tuner.best().0.threshold, 1 << 12);
    }

    #[test]
    fn disabled_start_steps_back_into_the_grid() {
        let mut tuner = AutoTuner::new(p(usize::MAX, 16));
        let mut params = tuner.params();
        for _ in 0..80 {
            params = tuner.observe(objective(&params));
        }
        assert_eq!(tuner.best().0.threshold, 1 << 12, "params={params:?}");
    }

    #[test]
    fn idle_windows_do_not_move_the_tuner() {
        let mut tuner = AutoTuner::new(p(1 << 12, 16));
        let first = tuner.observe(1000.0);
        let after_idle = tuner.observe(0.0);
        assert_eq!(first, after_idle);
    }

    #[test]
    fn drift_reopens_exploration() {
        let mut tuner = AutoTuner::new(p(1 << 12, 16));
        let mut params = tuner.params();
        for _ in 0..60 {
            params = tuner.observe(objective(&params));
        }
        assert!(tuner.converged());
        // A mild wobble at the optimum does not re-open exploration...
        params = tuner.observe(objective(&params) * 0.9);
        assert!(tuner.converged());
        // ...a real regression does.
        tuner.observe(objective(&params) * 0.5);
        assert!(!tuner.converged());
    }

    #[test]
    fn serial_pools_never_get_tiling_turned_on() {
        // tile_size == 0 means the operator chose a serial flush; the
        // tuner must refine around that, never enable the executor.
        let mut tuner = AutoTuner::new(p(1 << 20, 16));
        let mut params = tuner.params();
        for _ in 0..60 {
            params = tuner.observe(objective(&params));
            assert_eq!(params.tile_size, 0);
            assert_eq!(params.team_width, 1);
        }
        assert!(tuner.converged());
        assert_eq!(tuner.best().0.threshold, 1 << 12);
    }

    #[test]
    fn refines_executor_knobs_when_tiling_is_enabled() {
        // Objective peaking at tile 2^17 / team 8, flat in the batcher
        // knobs: the tuner should walk both executor knobs to the peak.
        let mut tuner = AutoTuner::new(p(1 << 12, 16).tiled(1 << 14, 2));
        let mut params = tuner.params();
        let obj = |q: &TuningParams| {
            let lt = (q.tile_size.max(1) as f64).log2();
            let lw = (q.team_width.max(1) as f64).log2();
            1e6 / (1.0 + (lt - 17.0).abs() + (lw - 3.0).abs())
        };
        for _ in 0..120 {
            params = tuner.observe(obj(&params));
        }
        assert!(tuner.converged(), "params={params:?}");
        assert_eq!(tuner.best().0.tile_size, 1 << 17);
        assert_eq!(tuner.best().0.team_width, 8);
        // Refinement stays within the executor envelope.
        assert!(tuner.best().0.tile_size >= MIN_TILE);
        assert!(tuner.best().0.team_width <= MAX_TEAM);
    }

    #[test]
    fn clamps_never_trap_the_walk() {
        // Objective strictly increasing in threshold: the tuner rides to
        // the MAX_THRESHOLD clamp and converges there instead of looping.
        let mut tuner = AutoTuner::new(p(1 << 26, 16));
        let mut params = tuner.params();
        for _ in 0..60 {
            params = tuner.observe((params.threshold.min(MAX_THRESHOLD)) as f64);
        }
        assert!(tuner.converged());
        assert_eq!(tuner.best().0.threshold, MAX_THRESHOLD);
    }
}
