//! Startup calibration probes over the virtual clock.
//!
//! The controller needs a throughput objective it can evaluate without
//! hardware: [`virtual_pool_throughput`] replays a request mix through a
//! faithful cost model of the pool — round-robin batched shards on the
//! paired host CPU, the unbatched overflow lane on the device — using the
//! same [`PerfModel`] constants that drive every other virtual-clock
//! figure. [`calibrate`] sweeps the threshold and flush knobs over that
//! model in short probe bursts and distills the optimum into a
//! [`CalibrationProfile`]; the same objective powers the
//! `autotune_convergence` bench gate. Like
//! [`BackendHeuristic::calibrate`](crate::coordinator::BackendHeuristic),
//! the device cost excludes the D2H readback (the paper's §8 scenario:
//! the consumer is device-resident), which is what makes a crossover
//! exist at all.

use crate::coordinator::{BackendRegistry, Route, TuningParams};
use crate::platform::{PerfModel, PlatformId, PlatformKind};
use crate::testkit::Gen;

use super::profile::CalibrationProfile;

/// Threshold sweep bounds (powers of two): below 2^2 every request
/// overflows; above 2^26 nothing realistic does.
pub const THRESHOLD_GRID: std::ops::RangeInclusive<u32> = 2..=26;

/// Flush-size sweep grid (powers of two).
pub const FLUSH_GRID: std::ops::RangeInclusive<u32> = 0..=8;

/// A deterministic serving mix used for probes: request sizes drawn
/// log-uniformly, mostly small with a heavy tail of launch-saturating
/// ones — the regime where the host-vs-device crossover matters.
#[derive(Debug, Clone)]
pub struct ProbeWorkload {
    /// Request sizes, submission order.
    pub sizes: Vec<usize>,
}

impl ProbeWorkload {
    /// Deterministic mix of `requests` sizes in `[2^4, 2^23)`,
    /// log-uniform (each octave equally likely).
    pub fn serving_mix(seed: u64, requests: usize) -> ProbeWorkload {
        let mut g = Gen::new(seed);
        let sizes = (0..requests.max(1))
            .map(|_| {
                let base = 1usize << g.usize_in(4, 22);
                base + g.usize_in(0, base - 1)
            })
            .collect();
        ProbeWorkload { sizes }
    }

    /// Total numbers requested.
    pub fn total(&self) -> u64 {
        self.sizes.iter().map(|&n| n as u64).sum()
    }
}

/// Virtual-clock delivered throughput (numbers per virtual second) of a
/// pool serving `wl` on `platform` with `shards` batched workers and the
/// given tuning knobs.
///
/// Cost model, mirroring the real pool's structure:
/// * requests at/above the threshold go to the overflow lane: one
///   unbatched device launch each (kernel + native completion callback,
///   no D2H — device-resident consumer), serialized on that lane;
/// * everything else round-robins across the batched shards; each shard
///   closes batches by the flush limits and pays one host "kernel"
///   (launch latency + items / host throughput) per batch;
/// * lanes run concurrently, so the virtual makespan is the slowest
///   lane's busy time.
pub fn virtual_pool_throughput(
    platform: PlatformId,
    shards: usize,
    params: &TuningParams,
    wl: &ProbeWorkload,
) -> f64 {
    let spec = platform.spec();
    let host_spec = BackendRegistry::host_platform(platform).spec();
    let device = PerfModel::new(spec.clone());
    let host = PerfModel::new(host_spec);
    let policy = params.policy();
    let has_device_lane = spec.kind != PlatformKind::Cpu;

    let shards = shards.max(1);
    let mut overflow_ns = 0u64;
    let mut shard_ns = vec![0u64; shards];
    // Per-shard open batch: (queued requests, queued items).
    let mut open: Vec<(usize, usize)> = vec![(0, 0); shards];
    let mut next = 0usize;

    let close = |shard_ns: &mut [u64], i: usize, open: &mut [(usize, usize)]| {
        let (reqs, items) = open[i];
        if reqs == 0 {
            return;
        }
        shard_ns[i] += host.kernel_ns(0, items as u64 * 4, items as u64, 1);
        open[i] = (0, 0);
    };

    for &n in &wl.sizes {
        if has_device_lane && policy.route(n) == Route::Overflow {
            overflow_ns +=
                device.kernel_ns(0, n as u64 * 4, n as u64, spec.native_tpb)
                    + spec.native_callback_ns;
        } else {
            let i = next;
            next = (next + 1) % shards;
            open[i].0 += 1;
            open[i].1 += n;
            if open[i].0 >= params.flush_requests || open[i].1 >= params.max_batch {
                close(&mut shard_ns, i, &mut open);
            }
        }
    }
    for i in 0..shards {
        close(&mut shard_ns, i, &mut open);
    }

    let busiest = shard_ns.iter().copied().max().unwrap_or(0).max(overflow_ns);
    if busiest == 0 {
        return 0.0;
    }
    wl.total() as f64 / busiest as f64 * 1e9
}

/// Scan the power-of-two threshold grid (plus "disabled") at fixed flush
/// knobs; returns the best threshold and its throughput — the oracle the
/// convergence gate compares the online tuner against.
///
/// The disabled policy anchors the scan, so ties keep "no overflow lane"
/// rather than the smallest grid point — on CPU platforms, where the
/// model (like the real pool's backend sets) has no device lane worth
/// routing to, every threshold scores identically and the calibrated
/// answer must be "disabled", not "overflow everything".
pub fn best_fixed_threshold(
    platform: PlatformId,
    shards: usize,
    base: &TuningParams,
    wl: &ProbeWorkload,
) -> (usize, f64) {
    let disabled = TuningParams { threshold: usize::MAX, ..*base };
    let mut best = (usize::MAX, virtual_pool_throughput(platform, shards, &disabled, wl));
    for t in THRESHOLD_GRID.map(|e| 1usize << e) {
        let params = TuningParams { threshold: t, ..*base };
        let tput = virtual_pool_throughput(platform, shards, &params, wl);
        if tput > best.1 {
            best = (t, tput);
        }
    }
    best
}

/// Startup calibration: short probe bursts over the virtual clock —
/// threshold sweep, then flush sweep at the winning threshold — distilled
/// into a persistable profile. A warm start (profile already on disk)
/// skips this entirely.
pub fn calibrate(platform: PlatformId, shards: usize) -> CalibrationProfile {
    let wl = ProbeWorkload::serving_mix(0xCA11_B007, 192);
    let base = TuningParams {
        threshold: usize::MAX,
        flush_requests: 16,
        max_batch: 1 << 20,
        tile_size: 0,
        team_width: 1,
    };
    let (threshold, _) = best_fixed_threshold(platform, shards, &base, &wl);
    let mut best = (base.flush_requests, 0.0f64);
    for f in FLUSH_GRID.map(|e| 1usize << e) {
        let params = TuningParams { threshold, flush_requests: f, ..base };
        let tput = virtual_pool_throughput(platform, shards, &params, &wl);
        if tput > best.1 {
            best = (f, tput);
        }
    }
    CalibrationProfile {
        platform,
        shards,
        params: TuningParams { threshold, flush_requests: best.0, ..base },
        mnum_per_s: best.1 / 1e6,
        source: "probe".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_log_uniform() {
        let a = ProbeWorkload::serving_mix(7, 100);
        let b = ProbeWorkload::serving_mix(7, 100);
        assert_eq!(a.sizes, b.sizes);
        assert!(a.sizes.iter().all(|&n| (16..(1 << 23)).contains(&n)));
        // Both small and launch-saturating requests are present.
        assert!(a.sizes.iter().any(|&n| n < 1024));
        assert!(a.sizes.iter().any(|&n| n > 1 << 20));
    }

    #[test]
    fn throughput_is_positive_and_threshold_sensitive() {
        let wl = ProbeWorkload::serving_mix(1, 128);
        // 2^20 splits the mix so both lanes carry real volume — the regime
        // where splitting beats either endpoint decisively.
        let base = TuningParams {
            threshold: 1 << 20,
            flush_requests: 16,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        };
        let mid = virtual_pool_throughput(PlatformId::A100, 4, &base, &wl);
        assert!(mid > 0.0);
        // All-overflow (threshold ~0) and no-overflow (disabled) are both
        // worse than a mid crossover on a discrete GPU: the valley exists.
        let all = TuningParams { threshold: 1, ..base };
        let none = TuningParams { threshold: usize::MAX, ..base };
        let t_all = virtual_pool_throughput(PlatformId::A100, 4, &all, &wl);
        let t_none = virtual_pool_throughput(PlatformId::A100, 4, &none, &wl);
        assert!(mid > t_all, "mid={mid} all={t_all}");
        assert!(mid > t_none, "mid={mid} none={t_none}");
    }

    #[test]
    fn cpu_platforms_never_use_a_device_lane() {
        let wl = ProbeWorkload::serving_mix(2, 64);
        let base = TuningParams {
            threshold: 1,
            flush_requests: 8,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        };
        // threshold=1 would overflow everything — but a CPU platform has
        // no device lane, so the policy is inert.
        let t = virtual_pool_throughput(PlatformId::Rome7742, 2, &base, &wl);
        let none = TuningParams { threshold: usize::MAX, ..base };
        let t_none = virtual_pool_throughput(PlatformId::Rome7742, 2, &none, &wl);
        assert_eq!(t, t_none);
    }

    #[test]
    fn cpu_calibration_disables_the_overflow_lane() {
        // With routing inert, every threshold ties — the calibrated
        // answer must be the disabled policy, not the smallest grid point
        // (a real pool WOULD honor threshold=4 and serialize everything
        // on one unbatched shard).
        for p in [PlatformId::Rome7742, PlatformId::XeonGold5220] {
            let profile = calibrate(p, 4);
            assert_eq!(profile.params.threshold, usize::MAX, "{p:?}");
            assert!(!profile.params.policy().is_enabled());
        }
    }

    #[test]
    fn calibration_finds_an_interior_crossover_on_gpus() {
        for p in [PlatformId::A100, PlatformId::Vega56] {
            let profile = calibrate(p, 4);
            assert!(profile.params.threshold > 4, "{p:?}: {}", profile.params.threshold);
            assert!(
                profile.params.threshold < 1 << 30,
                "{p:?}: {}",
                profile.params.threshold
            );
            assert!(profile.mnum_per_s > 0.0);
            assert_eq!(profile.source, "probe");
        }
    }

    #[test]
    fn best_fixed_threshold_beats_endpoints() {
        let wl = ProbeWorkload::serving_mix(3, 128);
        let base = TuningParams {
            threshold: usize::MAX,
            flush_requests: 16,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        };
        let (t, tput) = best_fixed_threshold(PlatformId::A100, 4, &base, &wl);
        let lo = virtual_pool_throughput(
            PlatformId::A100,
            4,
            &TuningParams { threshold: 4, ..base },
            &wl,
        );
        assert!(tput >= lo);
        assert!(t > 4);
    }
}
