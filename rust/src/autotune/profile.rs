//! Persisted calibration profiles (schema `portarng-profile-v1`).
//!
//! A [`CalibrationProfile`] is the distilled output of a probe run or an
//! autotune session for one platform: the tuning knobs plus the
//! throughput they achieved. Profiles are persisted as a single JSON
//! document keyed by platform token ([`ProfileStore`]), so a restarted
//! server warm-starts from the previous calibration instead of probing
//! again (see README "Calibration profile format" and the checked-in
//! `profiles/example_profile.json`).

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::TuningParams;
use crate::error::{Error, Result};
use crate::jsonlite::Value;
use crate::platform::PlatformId;

/// Profile document schema identifier (bump on breaking changes).
pub const PROFILE_SCHEMA: &str = "portarng-profile-v1";

/// One platform's calibrated tuning parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationProfile {
    /// Platform the profile was calibrated on.
    pub platform: PlatformId,
    /// Batched shard count the knobs were calibrated for — the optimum
    /// moves with it, so a warm start must re-probe on a mismatch.
    pub shards: usize,
    /// The calibrated knobs (dispatch threshold + batcher limits).
    pub params: TuningParams,
    /// Delivered throughput at these knobs, millions of numbers per
    /// second (virtual-clock for probe-sourced profiles).
    pub mnum_per_s: f64,
    /// Where the profile came from: `"probe"` (startup calibration) or
    /// `"autotune"` (persisted from a live tuning session).
    pub source: String,
}

impl CalibrationProfile {
    /// Serialize the per-platform body (the store adds the platform key).
    pub fn to_json(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("shards".into(), Value::Number(self.shards as f64));
        m.insert("threshold".into(), Value::Number(self.params.threshold as f64));
        m.insert(
            "flush_requests".into(),
            Value::Number(self.params.flush_requests as f64),
        );
        m.insert("max_batch".into(), Value::Number(self.params.max_batch as f64));
        m.insert("tile_size".into(), Value::Number(self.params.tile_size as f64));
        m.insert("team_width".into(), Value::Number(self.params.team_width as f64));
        m.insert("mnum_per_s".into(), Value::Number(self.mnum_per_s));
        m.insert("source".into(), Value::String(self.source.clone()));
        Value::Object(m)
    }

    /// Parse the [`CalibrationProfile::to_json`] body back.
    pub fn from_json(platform: PlatformId, v: &Value) -> Result<CalibrationProfile> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| Error::Json(format!("profile missing `{key}`")))
        };
        // Executor knobs arrived after v1 profiles were in the wild: read
        // them optionally, defaulting to the serial flush shape, so a
        // stored pre-tiling document still warm-starts.
        let opt = |key: &str, default: usize| -> usize {
            v.get(key).and_then(Value::as_f64).map(|x| x as usize).unwrap_or(default)
        };
        Ok(CalibrationProfile {
            platform,
            shards: (num("shards")? as usize).max(1),
            params: TuningParams {
                threshold: num("threshold")? as usize,
                flush_requests: (num("flush_requests")? as usize).max(1),
                max_batch: (num("max_batch")? as usize).max(1),
                tile_size: opt("tile_size", 0),
                team_width: opt("team_width", 1).max(1),
            },
            mnum_per_s: num("mnum_per_s")?,
            source: v
                .get("source")
                .and_then(Value::as_str)
                .unwrap_or("probe")
                .to_string(),
        })
    }
}

/// The on-disk profile document: one [`CalibrationProfile`] per platform.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProfileStore {
    profiles: BTreeMap<String, CalibrationProfile>,
}

impl ProfileStore {
    /// Empty store.
    pub fn new() -> ProfileStore {
        ProfileStore::default()
    }

    /// Parse a profile document.
    pub fn from_json(v: &Value) -> Result<ProfileStore> {
        match v.get("schema").and_then(Value::as_str) {
            Some(PROFILE_SCHEMA) => {}
            other => {
                return Err(Error::Json(format!(
                    "expected schema `{PROFILE_SCHEMA}`, got {other:?}"
                )))
            }
        }
        let mut profiles = BTreeMap::new();
        let body = v
            .get("profiles")
            .and_then(Value::as_object)
            .ok_or_else(|| Error::Json("profile document missing `profiles`".into()))?;
        for (token, entry) in body {
            let platform = PlatformId::parse(token)
                .ok_or_else(|| Error::Json(format!("unknown platform `{token}`")))?;
            profiles.insert(token.clone(), CalibrationProfile::from_json(platform, entry)?);
        }
        Ok(ProfileStore { profiles })
    }

    /// Serialize the full document.
    pub fn to_json(&self) -> Value {
        let mut body = BTreeMap::new();
        for (token, profile) in &self.profiles {
            body.insert(token.clone(), profile.to_json());
        }
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Value::String(PROFILE_SCHEMA.into()));
        m.insert("profiles".into(), Value::Object(body));
        Value::Object(m)
    }

    /// Load from a JSON file. A missing file is an empty store (cold
    /// start); a present-but-invalid file is an error (never silently
    /// discard someone's calibration data).
    pub fn load(path: &Path) -> Result<ProfileStore> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(ProfileStore::new())
            }
            Err(e) => return Err(Error::Io(e)),
        };
        Self::from_json(&Value::parse(&text)?)
    }

    /// Write the document to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_json()).map_err(Error::Io)
    }

    /// The stored profile for `platform`, if any (warm start).
    pub fn get(&self, platform: PlatformId) -> Option<&CalibrationProfile> {
        self.profiles.get(platform.token())
    }

    /// Insert/replace a platform's profile.
    pub fn put(&mut self, profile: CalibrationProfile) {
        self.profiles.insert(profile.platform.token().to_string(), profile);
    }

    /// Stored profile count.
    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    /// Whether the store has no profiles.
    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CalibrationProfile {
        CalibrationProfile {
            platform: PlatformId::A100,
            shards: 4,
            params: TuningParams {
                threshold: 262_144,
                flush_requests: 32,
                max_batch: 1 << 20,
                tile_size: 1 << 17,
                team_width: 4,
            },
            mnum_per_s: 1234.5,
            source: "probe".into(),
        }
    }

    #[test]
    fn store_round_trips_through_jsonlite() {
        let mut store = ProfileStore::new();
        store.put(sample());
        let mut vega = sample();
        vega.platform = PlatformId::Vega56;
        vega.params.threshold = 65_536;
        vega.source = "autotune".into();
        store.put(vega);
        let text = store.to_json().to_json();
        let back = ProfileStore::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back, store);
        assert_eq!(back.get(PlatformId::A100).unwrap().params.threshold, 262_144);
        assert_eq!(back.get(PlatformId::Vega56).unwrap().source, "autotune");
        assert!(back.get(PlatformId::Uhd630).is_none());
    }

    #[test]
    fn load_missing_file_is_cold_start() {
        let store =
            ProfileStore::load(Path::new("/nonexistent/portarng-profiles.json")).unwrap();
        assert!(store.is_empty());
    }

    #[test]
    fn save_and_load_file() {
        let dir = std::env::temp_dir().join("portarng-profile-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        let mut store = ProfileStore::new();
        store.put(sample());
        store.save(&path).unwrap();
        let back = ProfileStore::load(&path).unwrap();
        assert_eq!(back, store);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pre_tiling_documents_parse_with_serial_executor_defaults() {
        // The checked-in example profile predates the executor knobs;
        // documents without them must still warm-start (serial flush).
        let doc = format!(
            r#"{{"schema":"{PROFILE_SCHEMA}","profiles":{{"a100":{{"shards":4,"threshold":1024,"flush_requests":8,"max_batch":65536,"mnum_per_s":9.5,"source":"probe"}}}}}}"#
        );
        let store = ProfileStore::from_json(&Value::parse(&doc).unwrap()).unwrap();
        let p = store.get(PlatformId::A100).unwrap();
        assert_eq!(p.params.tile_size, 0);
        assert_eq!(p.params.team_width, 1);
        // And the knobs round-trip once written back.
        let text = store.to_json().to_json();
        let back = ProfileStore::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(&back, &store);
    }

    #[test]
    fn rejects_unknown_schema_and_platform() {
        assert!(ProfileStore::from_json(
            &Value::parse(r#"{"schema":"nope","profiles":{}}"#).unwrap()
        )
        .is_err());
        let bad = format!(
            r#"{{"schema":"{PROFILE_SCHEMA}","profiles":{{"tpu":{{"threshold":1,"flush_requests":1,"max_batch":1,"mnum_per_s":1}}}}}}"#
        );
        assert!(ProfileStore::from_json(&Value::parse(&bad).unwrap()).is_err());
    }
}
