//! Online autotuning for the dispatch heuristic (DESIGN.md S12).
//!
//! The paper's §8 future work — "a heuristic approach to select the best
//! backend for the problem size" — stops at a static threshold. This
//! module makes the heuristic measure, calibrate, and adapt itself:
//!
//! 1. **Calibrate** ([`calibrate`]): short startup probe bursts over the
//!    [`platform`](crate::platform) virtual clock sweep the threshold and
//!    flush knobs and distill the optimum into a [`CalibrationProfile`].
//! 2. **Persist** ([`ProfileStore`]): profiles are saved as JSON keyed by
//!    platform token, so a warm start loads the previous calibration and
//!    skips probing entirely.
//! 3. **Adapt** ([`AutoTuner`] / [`PoolAutoTuner`]): under live load, the
//!    controller reads [`telemetry`](crate::telemetry) snapshot deltas
//!    once per window and nudges the pool's
//!    [`DispatchPolicy`](crate::coordinator::DispatchPolicy) threshold and
//!    [`RequestBatcher`](crate::coordinator::RequestBatcher) flush size
//!    toward the observed throughput optimum, publishing retunes through
//!    the pool's lock-free
//!    [`TuningHandle`](crate::coordinator::TuningHandle) — workers pick
//!    them up without locking the hot path.
//!
//! The `autotune_convergence` bench gates the loop end to end: starting
//! from a deliberately mis-specified threshold on a virtual-clock
//! platform, the tuner must recover at least 90% of the best
//! fixed-threshold throughput.

mod controller;
mod probe;
mod profile;

pub use controller::{
    AutoTuner, PoolAutoTuner, MAX_FLUSH, MAX_TEAM, MAX_THRESHOLD, MAX_TILE, MIN_TILE,
};
pub use probe::{
    best_fixed_threshold, calibrate, virtual_pool_throughput, ProbeWorkload, FLUSH_GRID,
    THRESHOLD_GRID,
};
pub use profile::{CalibrationProfile, ProfileStore, PROFILE_SCHEMA};
