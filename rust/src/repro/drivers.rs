//! Drivers that regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §3 per-experiment index).
//!
//! Each driver returns a [`ResultTable`] with the same rows/series the
//! paper plots; `portarng repro --experiment <id>` prints/saves them and
//! EXPERIMENTS.md records the shape comparison.

use crate::burner::{run_burner_auto, BurnerApi, BurnerConfig};
use crate::coordinator::BackendHeuristic;
use crate::error::Result;
use crate::fastcalosim::{run_fastcalosim, FcsApi, Workload};
use crate::metrics::{mean, pennycook, stddev, vavs_efficiency};
use crate::platform::PlatformId;

use super::table::ResultTable;

/// The paper's batch-size grid: 1 — 10^8, decades.
pub const PAPER_BATCHES: [usize; 9] =
    [1, 10, 100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000];

/// Iterations per point (paper: 100; reduce with `quick` for CI).
fn iters(quick: bool) -> usize {
    if quick {
        10
    } else {
        100
    }
}

/// Known experiment ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentId {
    /// Platform/software inventory.
    Table1,
    /// CPU + iGPU burner, Buffer vs USM.
    Fig2,
    /// Vega/A100 burner, SYCL vs native.
    Fig3,
    /// A100 per-kernel breakdown + occupancy.
    Fig4,
    /// VAVS performance portability.
    Table2,
    /// FastCaloSim runtimes.
    Fig5,
    /// §8 heuristic backend selection (our extension).
    AblationHeuristic,
}

impl ExperimentId {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<ExperimentId> {
        match s {
            "table1" => Some(ExperimentId::Table1),
            "fig2" => Some(ExperimentId::Fig2),
            "fig3" => Some(ExperimentId::Fig3),
            "fig4" => Some(ExperimentId::Fig4),
            "table2" => Some(ExperimentId::Table2),
            "fig5" => Some(ExperimentId::Fig5),
            "ablation-heuristic" => Some(ExperimentId::AblationHeuristic),
            _ => None,
        }
    }

    /// All ids.
    pub const ALL: [ExperimentId; 7] = [
        ExperimentId::Table1,
        ExperimentId::Fig2,
        ExperimentId::Fig3,
        ExperimentId::Fig4,
        ExperimentId::Table2,
        ExperimentId::Fig5,
        ExperimentId::AblationHeuristic,
    ];

    /// Run the driver.
    pub fn run(self, quick: bool) -> Result<Vec<ResultTable>> {
        match self {
            ExperimentId::Table1 => Ok(vec![table1()]),
            ExperimentId::Fig2 => fig2(quick),
            ExperimentId::Fig3 => fig3(quick),
            ExperimentId::Fig4 => fig4(quick),
            ExperimentId::Table2 => table2(quick),
            ExperimentId::Fig5 => fig5(quick),
            ExperimentId::AblationHeuristic => ablation_heuristic(),
        }
    }
}

/// Table 1: driver and software versions per platform.
pub fn table1() -> ResultTable {
    let mut t = ResultTable::new(
        "table1",
        "Platform and software inventory (simulated fleet)",
        &["platform", "kind", "os_kernel", "compiler", "rng_library", "mem_bw_gbps", "uma"],
    );
    for p in PlatformId::ALL {
        let s = p.spec();
        t.push(vec![
            s.name.to_string(),
            format!("{:?}", s.kind),
            s.os.to_string(),
            s.compiler.to_string(),
            s.rng_library.to_string(),
            format!("{:.1}", s.mem_bw_gbps),
            s.uma.to_string(),
        ]);
    }
    t
}

/// The burner's distribution in the figures: a non-unit range so the
/// range-transformation kernel is on the path ("the pseudorandom output
/// sequence is generated and its range is transformed" — §5.1 step 4).
fn paper_distr() -> crate::rng::Distribution {
    crate::rng::Distribution::uniform(-1.0, 1.0)
}

fn burner_point(
    platform: PlatformId,
    api: BurnerApi,
    batch: usize,
    iterations: usize,
) -> Result<(f64, f64)> {
    let mut cfg = BurnerConfig::paper_default(platform, api, batch);
    cfg.distr = paper_distr();
    cfg.iterations = iterations;
    let r = run_burner_auto(&cfg)?;
    Ok((mean(&r.totals_ns) / 1e6, stddev(&r.totals_ns) / 1e6))
}

/// Fig. 2: burner on the two x86 CPUs + the iGPU, Buffer (a) vs USM (b).
pub fn fig2(quick: bool) -> Result<Vec<ResultTable>> {
    let platforms = [PlatformId::Rome7742, PlatformId::CoreI7_10875H, PlatformId::Uhd630];
    let mut t = ResultTable::new(
        "fig2",
        "RNG burner total FP32 generation time: CPUs + iGPU, Buffer vs USM",
        &["platform", "api", "batch", "mean_ms", "std_ms"],
    );
    for p in platforms {
        for api in [BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
            for batch in PAPER_BATCHES {
                let (m, s) = burner_point(p, api, batch, iters(quick))?;
                t.push(vec![
                    p.token().into(),
                    api.token().into(),
                    batch.to_string(),
                    format!("{m:.4}"),
                    format!("{s:.4}"),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Fig. 3: burner on Vega 56 (a) and A100 (b): SYCL buffer/USM vs native.
pub fn fig3(quick: bool) -> Result<Vec<ResultTable>> {
    let mut t = ResultTable::new(
        "fig3",
        "RNG burner: SYCL Buffer/USM vs native on the discrete GPUs",
        &["platform", "api", "batch", "mean_ms", "std_ms"],
    );
    for p in [PlatformId::Vega56, PlatformId::A100] {
        for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
            for batch in PAPER_BATCHES {
                let (m, s) = burner_point(p, api, batch, iters(quick))?;
                t.push(vec![
                    p.token().into(),
                    api.token().into(),
                    batch.to_string(),
                    format!("{m:.4}"),
                    format!("{s:.4}"),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Fig. 4: per-kernel duration (a) and occupancy (b) on the A100.
pub fn fig4(quick: bool) -> Result<Vec<ResultTable>> {
    let mut dur = ResultTable::new(
        "fig4a",
        "A100 per-kernel durations (seed/generate/transform)",
        &["api", "batch", "setup_ms", "generate_ms", "transform_ms", "d2h_ms"],
    );
    let mut occ = ResultTable::new(
        "fig4b",
        "A100 kernel occupancy (native tpb=256 vs SYCL tpb=1024)",
        &["api", "batch", "tpb", "generate_occupancy", "transform_occupancy"],
    );
    let batches = [100usize, 10_000, 1_000_000, 100_000_000];
    for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
        for batch in batches {
            let mut cfg = BurnerConfig::paper_default(PlatformId::A100, api, batch);
            cfg.distr = paper_distr();
            cfg.iterations = iters(quick);
            let r = run_burner_auto(&cfg)?;
            let b = r.breakdown;
            dur.push(vec![
                api.token().into(),
                batch.to_string(),
                format!("{:.4}", b.setup_ns as f64 / 1e6),
                format!("{:.4}", b.generate_ns as f64 / 1e6),
                format!("{:.4}", b.transform_ns as f64 / 1e6),
                format!("{:.4}", b.d2h_ns as f64 / 1e6),
            ]);
            occ.push(vec![
                api.token().into(),
                batch.to_string(),
                b.tpb.to_string(),
                format!("{:.4}", b.generate_occupancy),
                format!("{:.4}", b.transform_occupancy),
            ]);
        }
    }
    Ok(vec![dur, occ])
}

/// Table 2: VAVS performance portability over the Fig. 3/4 data.
pub fn table2(quick: bool) -> Result<Vec<ResultTable>> {
    // Efficiency per platform/api: harmonic-mean VAVS over the batch grid
    // (small batches weigh in exactly as the paper's kernel-level data do).
    let eff = |p: PlatformId, api: BurnerApi| -> Result<f64> {
        let mut effs = Vec::new();
        for batch in PAPER_BATCHES {
            let (native, _) = burner_point(p, BurnerApi::Native, batch, iters(quick))?;
            let (sycl, _) = burner_point(p, api, batch, iters(quick))?;
            effs.push(Some(vavs_efficiency(native, sycl)));
        }
        Ok(pennycook(&effs))
    };
    let e_vega_buf = eff(PlatformId::Vega56, BurnerApi::SyclBuffer)?;
    let e_vega_usm = eff(PlatformId::Vega56, BurnerApi::SyclUsm)?;
    let e_a100_buf = eff(PlatformId::A100, BurnerApi::SyclBuffer)?;
    let e_a100_usm = eff(PlatformId::A100, BurnerApi::SyclUsm)?;

    let mut t = ResultTable::new(
        "table2",
        "Performance portability (VAVS metric, paper eq. 1)",
        &["H", "P_buffer", "P_usm", "P_mean"],
    );
    let p_both_buf = pennycook(&[Some(e_vega_buf), Some(e_a100_buf)]);
    let p_both_usm = pennycook(&[Some(e_vega_usm), Some(e_a100_usm)]);
    let p_both_mean = pennycook(&[
        Some(e_vega_buf),
        Some(e_a100_buf),
        Some(e_vega_usm),
        Some(e_a100_usm),
    ]);
    t.push(vec![
        "{Vega 56, A100}".into(),
        format!("{p_both_buf:.3}"),
        format!("{p_both_usm:.3}"),
        format!("{p_both_mean:.3}"),
    ]);
    t.push(vec![
        "{Vega 56}".into(),
        format!("{e_vega_buf:.3}"),
        format!("{e_vega_usm:.3}"),
        format!("{:.3}", pennycook(&[Some(e_vega_buf), Some(e_vega_usm)])),
    ]);
    t.push(vec![
        "{A100}".into(),
        format!("{e_a100_buf:.3}"),
        format!("{e_a100_usm:.3}"),
        format!("{:.3}", pennycook(&[Some(e_a100_buf), Some(e_a100_usm)])),
    ]);
    Ok(vec![t])
}

/// Fig. 5: FastCaloSim run-times across platforms, native vs SYCL, for
/// single-electron (a) and t t̄ (b) samples.
pub fn fig5(quick: bool) -> Result<Vec<ResultTable>> {
    let platforms = [
        PlatformId::Rome7742,
        PlatformId::CoreI7_10875H,
        PlatformId::Vega56,
        PlatformId::A100,
    ];
    let (n_se, n_tt, runs) = if quick { (50, 10, 3) } else { (1000, 500, 10) };
    let mut t = ResultTable::new(
        "fig5",
        "FastCaloSim total run-time (s): native vs SYCL port",
        &["workload", "platform", "api", "mean_s", "std_s", "hits", "rns", "tables"],
    );
    for (workload, label) in [
        (Workload::SingleElectron { events: n_se }, "single-e"),
        (Workload::TTbar { events: n_tt }, "ttbar"),
    ] {
        for p in platforms {
            for api in [FcsApi::Native, FcsApi::Sycl] {
                // No native HIP port exists for the Radeon (paper §7).
                if api == FcsApi::Native && p == PlatformId::Vega56 {
                    continue;
                }
                let mut totals = Vec::new();
                let mut last = None;
                for run in 0..runs {
                    let r = run_fastcalosim(p, api, workload, 1000 + run as u64)?;
                    totals.push(r.total_ns as f64 / 1e9);
                    last = Some(r);
                }
                let last = last.unwrap();
                t.push(vec![
                    label.into(),
                    p.token().into(),
                    api.token().into(),
                    format!("{:.3}", mean(&totals)),
                    format!("{:.3}", stddev(&totals)),
                    last.hits.to_string(),
                    last.rns.to_string(),
                    last.tables_loaded.to_string(),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Ablation (paper §8): heuristic host/device selection vs fixed backends.
pub fn ablation_heuristic() -> Result<Vec<ResultTable>> {
    let h = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
    let mut t = ResultTable::new(
        "ablation-heuristic",
        format!("Heuristic backend selection (crossover = {} numbers)", h.crossover).as_str(),
        &["batch", "host_ms", "device_ms", "heuristic_ms", "heuristic_picks"],
    );
    for batch in PAPER_BATCHES {
        let (host_ms, _) = burner_point(PlatformId::Rome7742, BurnerApi::SyclBuffer, batch, 10)?;
        let (dev_ms, _) = burner_point(PlatformId::A100, BurnerApi::SyclBuffer, batch, 10)?;
        let pick = h.select(batch);
        let heuristic_ms = if pick == PlatformId::A100 { dev_ms } else { host_ms };
        t.push(vec![
            batch.to_string(),
            format!("{host_ms:.4}"),
            format!("{dev_ms:.4}"),
            format!("{heuristic_ms:.4}"),
            pick.token().into(),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_platforms() {
        let t = table1();
        assert_eq!(t.rows.len(), 6);
        assert!(t.to_markdown().contains("A100"));
    }

    #[test]
    fn experiment_id_parsing() {
        assert_eq!(ExperimentId::parse("fig3"), Some(ExperimentId::Fig3));
        assert_eq!(ExperimentId::parse("bogus"), None);
        assert_eq!(ExperimentId::ALL.len(), 7);
    }
}
