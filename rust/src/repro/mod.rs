//! Experiment drivers regenerating every table and figure (DESIGN.md §3).

mod drivers;
mod table;

pub use drivers::{
    ablation_heuristic, fig2, fig3, fig4, fig5, table1, table2, ExperimentId, PAPER_BATCHES,
};
pub use table::ResultTable;
