//! Result tables: the common output container for experiment drivers.

use std::fmt::Write as _;

/// A rectangular result table with typed-as-string cells.
#[derive(Debug, Clone)]
pub struct ResultTable {
    /// Experiment id ("fig3", "table2", ...).
    pub id: String,
    /// Caption.
    pub title: String,
    /// Column names.
    pub headers: Vec<String>,
    /// Rows.
    pub rows: Vec<Vec<String>>,
}

impl ResultTable {
    /// Empty table.
    pub fn new(id: &str, title: &str, headers: &[&str]) -> ResultTable {
        ResultTable {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// CSV rendering.
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {} — {}\n", self.id, self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(out, "|{}|", "---|".repeat(self.headers.len()));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write CSV to `results/<id>.csv` under `dir`.
    pub fn write_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Find a cell by (column name, row predicate on another column).
    pub fn cell(&self, where_col: &str, equals: &str, get_col: &str) -> Option<&str> {
        let wi = self.headers.iter().position(|h| h == where_col)?;
        let gi = self.headers.iter().position(|h| h == get_col)?;
        self.rows
            .iter()
            .find(|r| r[wi] == equals)
            .map(|r| r[gi].as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_lookup() {
        let mut t = ResultTable::new("figX", "demo", &["k", "v"]);
        t.push(vec!["a".into(), "1".into()]);
        t.push(vec!["b".into(), "2".into()]);
        assert!(t.to_csv().starts_with("k,v\na,1\n"));
        assert!(t.to_markdown().contains("| a | 1 |"));
        assert_eq!(t.cell("k", "b", "v"), Some("2"));
        assert_eq!(t.cell("k", "zzz", "v"), None);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = ResultTable::new("x", "y", &["a", "b"]);
        t.push(vec!["only-one".into()]);
    }
}
