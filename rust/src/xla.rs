//! In-tree stand-in for the `xla` (xla_extension) PJRT bindings.
//!
//! The real crate links a native XLA/PJRT install and needs network access
//! to build — neither is available in this offline environment, so the
//! binding surface used by [`crate::runtime`] is mirrored here as a
//! *gated* substrate: every entry point type-checks against the real
//! binding's signatures, and constructing a client reports
//! [`Error`] with a clear message instead of segfaulting or silently
//! fabricating device results. Swapping the real `xla` crate back in is a
//! one-line change in `Cargo.toml` plus deleting this module — no call
//! site changes.
//!
//! Cross-layer numerical validation of the Pallas Philox kernel still runs
//! on the Python side (`python/tests/`), where JAX executes the same HLO;
//! the Rust tests that need a live PJRT client skip themselves when
//! [`PjRtClient::cpu`] reports unavailability.

use std::fmt;

/// Binding-level error (mirrors `xla::Error`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "xla_extension PJRT bindings are not linked in this build \
         (offline substrate); the real-compute path is gated"
            .to_string(),
    )
}

/// PJRT client handle (mirrors `xla::PjRtClient`).
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU PJRT client. Always fails in the offline
    /// substrate — callers treat the error as "real compute unavailable".
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (mirrors `xla::HloModuleProto`).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO *text* file (the interchange format `aot.py` emits).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Computation wrapper (mirrors `xla::XlaComputation`).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (mirrors `xla::PjRtLoadedExecutable`).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Device buffer handle (mirrors `xla::PjRtBuffer`).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronously copy the buffer back as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Host-side literal value (mirrors `xla::Literal`).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    /// First element of the literal.
    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("substrate must gate");
        assert!(err.to_string().contains("not linked"));
    }

    #[test]
    fn literal_construction_is_total() {
        // Building argument literals must not fail (call sites construct
        // them before the executable is consulted).
        let _ = Literal::vec1(&[1u32, 2][..]);
        let _ = Literal::vec1(&[0.5f32][..]);
    }
}
