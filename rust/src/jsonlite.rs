//! Minimal JSON parser (substrate — serde_json is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to emit experiment result files.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// Any JSON number (kept as f64, like JavaScript).
    Number(f64),
    /// String.
    String(String),
    /// Array.
    Array(Vec<Value>),
    /// Object (ordered for stable output).
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Value> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a numeric payload.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array payload.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Object payload.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Serialise back to JSON text.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::String(s) => write_escaped(s, out),
            Value::Array(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, text: &str, v: Value) -> Result<Value> {
        if self.src[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(m));
                }
                _ => return Err(Error::Json(format!("bad object at byte {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(v));
                }
                _ => return Err(Error::Json(format!("bad array at byte {}", self.pos))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .src
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {:?}", other)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.src[start]);
                    let chunk = self
                        .src
                        .get(start..start + len)
                        .ok_or_else(|| Error::Json("truncated utf8".into()))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| Error::Json("invalid utf8".into()))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| Error::Json(format!("bad number `{text}`")))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"format": "hlo-text-v1", "artifacts": {
            "burner_uniform_4096": {"file": "burner_uniform_4096.hlo.txt",
              "inputs": [{"dtype": "uint32", "shape": [2]}],
              "outputs": [{"dtype": "float32", "shape": [4096]}],
              "sha256": "ab"}}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text-v1");
        let arts = v.get("artifacts").unwrap().as_object().unwrap();
        let a = &arts["burner_uniform_4096"];
        assert_eq!(
            a.get("inputs").unwrap().as_array().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_array()
                .unwrap()[0]
                .as_usize(),
            Some(2)
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null,"e":{}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn numbers() {
        assert_eq!(Value::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Value::parse("0").unwrap().as_usize(), Some(0));
    }
}
