//! Bench gate: the online autotuner recovers a mis-specified dispatch
//! threshold.
//!
//! Scenario (all on the deterministic virtual clock, so the gate is
//! noise-free): a serving mix of log-uniform request sizes on a
//! discrete-GPU platform spec, with the pool's threshold deliberately
//! mis-specified — once far too high (nothing overflows: big requests
//! grind through the host lanes) and once far too low (everything
//! overflows: the device lane serializes launch-latency-dominated small
//! requests). The [`AutoTuner`] only sees delivered-throughput
//! observations, exactly what pool telemetry would hand it.
//!
//! Gates:
//!   * from BOTH mis-specified starts, the converged knobs reach >= 90%
//!     of the best fixed-threshold throughput (power-of-two scan oracle);
//!   * telemetry's hot-path cost stays negligible: a histogram record is
//!     sub-microsecond amortized (the 4-shard >= 2x wall-clock gate lives
//!     in `pool_throughput`, which runs the full telemetry-instrumented
//!     pool).

use portarng::autotune::{
    best_fixed_threshold, virtual_pool_throughput, AutoTuner, ProbeWorkload,
};
use portarng::coordinator::TuningParams;
use portarng::platform::PlatformId;
use portarng::telemetry::Log2Histogram;

const SHARDS: usize = 4;
const WINDOWS: usize = 80;

fn converge(platform: PlatformId, wl: &ProbeWorkload, start: TuningParams) -> (TuningParams, f64) {
    let mut tuner = AutoTuner::new(start);
    let mut params = tuner.params();
    for _ in 0..WINDOWS {
        let tput = virtual_pool_throughput(platform, SHARDS, &params, wl);
        params = tuner.observe(tput);
    }
    assert!(tuner.converged(), "tuner still exploring after {WINDOWS} windows");
    let (best, _) = tuner.best();
    // Judge the held point by re-measuring it, not by trusting the
    // tuner's bookkeeping.
    (best, virtual_pool_throughput(platform, SHARDS, &best, wl))
}

fn main() {
    let platform = PlatformId::A100;
    let wl = ProbeWorkload::serving_mix(0xBE9C4, 192);
    let defaults = TuningParams {
        threshold: usize::MAX,
        flush_requests: 16,
        max_batch: 1 << 20,
        tile_size: 0,
        team_width: 1,
    };
    let (oracle_t, oracle_tput) = best_fixed_threshold(platform, SHARDS, &defaults, &wl);
    println!(
        "oracle: best fixed threshold {} -> {:.1} M numbers/s (virtual)",
        oracle_t,
        oracle_tput / 1e6
    );

    for (label, start) in [
        ("too-high (1<<26: nothing overflows)", TuningParams { threshold: 1 << 26, ..defaults }),
        ("too-low  (16: everything overflows)", TuningParams { threshold: 16, ..defaults }),
    ] {
        let start_tput = virtual_pool_throughput(platform, SHARDS, &start, &wl);
        let (best, tput) = converge(platform, &wl, start);
        let recovered = tput / oracle_tput;
        println!(
            "start {label}: {:.1} -> {:.1} M/s at threshold {}, flush {} ({:.0}% of oracle)",
            start_tput / 1e6,
            tput / 1e6,
            best.threshold,
            best.flush_requests,
            recovered * 100.0
        );
        assert!(
            recovered >= 0.9,
            "autotuner recovered only {:.0}% of the best fixed threshold from {label}",
            recovered * 100.0
        );
    }
    println!("convergence gate (>= 90% of best fixed threshold, both starts): OK");

    // Telemetry hot-path overhead smoke: one histogram record per launch
    // is the most frequent telemetry write on the request path.
    let h = Log2Histogram::new();
    let reps = 1_000_000u64;
    let t0 = std::time::Instant::now();
    for v in 0..reps {
        h.record(v);
    }
    let per_record = t0.elapsed().as_nanos() as f64 / reps as f64;
    println!("telemetry record: {per_record:.1} ns amortized");
    assert!(
        per_record < 1_000.0,
        "telemetry record costs {per_record:.0} ns — would perturb the pool hot path"
    );
    println!("telemetry overhead gate (< 1 us/record): OK");
}
