//! Bench: Fig. 4 — A100 per-kernel breakdown + occupancy, native vs SYCL.

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::burner::{run_burner_auto, BurnerApi, BurnerConfig};
use portarng::platform::PlatformId;

fn main() {
    let mut g = BenchGroup::new("fig4").config(BenchConfig { warmup: 1, samples: 8 });
    for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
        for batch in [10_000usize, 100_000_000] {
            let mut cfg = BurnerConfig::paper_default(PlatformId::A100, api, batch);
            cfg.iterations = 3;
            let name = format!("{}/{batch}", api.token());
            let mut bd = None;
            g.bench_items(&name, batch as u64, || {
                let r = run_burner_auto(black_box(&cfg)).unwrap();
                bd = Some(r.breakdown);
            });
            let b = bd.unwrap();
            println!(
                "    -> setup {:.4} | generate {:.4} (occ {:.3}, tpb {}) | transform {:.4} | d2h {:.4} ms",
                b.setup_ns as f64 / 1e6,
                b.generate_ns as f64 / 1e6,
                b.generate_occupancy,
                b.tpb,
                b.transform_ns as f64 / 1e6,
                b.d2h_ns as f64 / 1e6
            );
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_fig4.csv", g.to_csv()).unwrap();
}
