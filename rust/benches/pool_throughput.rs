//! Bench: sharded service-pool throughput scaling on the burner workload.
//!
//! Drives R requests of B numbers each through the pool at shard counts
//! {1, 2, 4, 8} and reports delivered wall-clock throughput. The 1-shard
//! row IS the legacy single-worker `RngService` (the facade wraps a
//! one-shard pool), so the scaling factor reads directly off the table.
//!
//! Acceptance gates (checked when the machine has >= 4 CPUs):
//!   * 4-shard throughput >= 2x the single-worker service;
//!   * every shard count produces bit-identical per-request streams
//!     (equal request-stream checksums).

use portarng::benchkit::{BenchConfig, BenchGroup};
use portarng::burner::{run_burner_pooled, BurnerApi, BurnerConfig, PoolBurnerReport};
use portarng::platform::PlatformId;

const BATCH: usize = 1 << 16;
const REQUESTS: usize = 192;

fn run(shards: usize) -> PoolBurnerReport {
    let cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclBuffer, BATCH);
    run_burner_pooled(&cfg, shards, REQUESTS).unwrap()
}

fn main() {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "pool throughput: {REQUESTS} requests x {BATCH} numbers ({} M total), {cpus} CPUs\n",
        REQUESTS * BATCH / 1_000_000
    );

    let shard_counts = [1usize, 2, 4, 8];
    let mut g = BenchGroup::new("pool").config(BenchConfig { warmup: 1, samples: 5 });
    let mut checksums: Vec<(usize, u64)> = Vec::new();
    for &shards in &shard_counts {
        let mut last: Option<PoolBurnerReport> = None;
        g.bench_items(&format!("{shards}-shard/{REQUESTS}x{BATCH}"), (REQUESTS * BATCH) as u64, || {
            last = Some(run(shards));
        });
        let r = last.unwrap();
        println!(
            "    -> {} launches | checksum {:016x}",
            r.stats.total().launches,
            r.checksum
        );
        checksums.push((shards, r.checksum));
    }

    // Gate 1: bit-identical per-request streams at every shard count.
    let checksum0 = checksums[0].1;
    for &(shards, checksum) in &checksums {
        assert_eq!(
            checksum, checksum0,
            "{shards}-shard pool diverged from the single-worker stream"
        );
    }
    println!("\nstreams bit-identical across shard counts: OK (checksum {checksum0:016x})");

    // Gate 2: 4-shard pool >= 2x the single-worker service, judged on the
    // benchkit *medians* over all samples (outlier-robust), not on any
    // single run.
    let median_tput: Vec<(usize, f64)> = shard_counts
        .iter()
        .copied()
        .zip(g.results().iter().map(|r| r.throughput_m_per_s().unwrap_or(0.0)))
        .collect();
    let single = median_tput[0].1;
    let four = median_tput.iter().find(|t| t.0 == 4).unwrap().1;
    let speedup = four / single;
    println!("4-shard vs single-worker speedup: {speedup:.2}x");
    if cpus >= 4 {
        assert!(
            speedup >= 2.0,
            "4-shard pool only {speedup:.2}x the single-worker service (need >= 2x)"
        );
        println!("scaling gate (>= 2x): OK");
    } else {
        println!("scaling gate skipped: {cpus} CPUs < 4 (cannot host 4 busy shards)");
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_pool_throughput.csv", g.to_csv()).unwrap();
}
