//! Bench: sharded service-pool throughput scaling on the burner workload.
//!
//! Drives R requests of B numbers each through the pool at shard counts
//! {1, 2, 4, 8} and reports delivered wall-clock throughput. The 1-shard
//! row IS the legacy single-worker `RngService` (the facade wraps a
//! one-shard pool), so the scaling factor reads directly off the table.
//!
//! Acceptance gates:
//!   * 4-shard throughput >= 2x the single-worker service (when the
//!     machine has >= 4 CPUs);
//!   * every shard count produces bit-identical per-request streams
//!     (equal request-stream checksums);
//!   * serve-through-SYCL steady state: after warmup the batched lane's
//!     generate path allocates nothing per request — every flush's
//!     launch buffer is an arena hit (zero device mallocs; per request
//!     only the reply payload and queue-record bookkeeping remain) and
//!     each flush is exactly one generate host task + one transform
//!     kernel on the worker queue.

use portarng::benchkit::{BenchConfig, BenchGroup};
use portarng::burner::{run_burner_pooled, BurnerApi, BurnerConfig, PoolBurnerReport};
use portarng::coordinator::{PoolConfig, ServicePool};
use portarng::platform::PlatformId;

const BATCH: usize = 1 << 16;
const REQUESTS: usize = 192;

fn run(shards: usize) -> PoolBurnerReport {
    let cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclBuffer, BATCH);
    run_burner_pooled(&cfg, shards, REQUESTS).unwrap()
}

fn main() {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "pool throughput: {REQUESTS} requests x {BATCH} numbers ({} M total), {cpus} CPUs\n",
        REQUESTS * BATCH / 1_000_000
    );

    let shard_counts = [1usize, 2, 4, 8];
    let mut g = BenchGroup::new("pool").config(BenchConfig { warmup: 1, samples: 5 });
    let mut checksums: Vec<(usize, u64)> = Vec::new();
    for &shards in &shard_counts {
        let mut last: Option<PoolBurnerReport> = None;
        g.bench_items(&format!("{shards}-shard/{REQUESTS}x{BATCH}"), (REQUESTS * BATCH) as u64, || {
            last = Some(run(shards));
        });
        let r = last.unwrap();
        println!(
            "    -> {} launches | checksum {:016x}",
            r.stats.total().launches,
            r.checksum
        );
        checksums.push((shards, r.checksum));
    }

    // Gate 1: bit-identical per-request streams at every shard count.
    let checksum0 = checksums[0].1;
    for &(shards, checksum) in &checksums {
        assert_eq!(
            checksum, checksum0,
            "{shards}-shard pool diverged from the single-worker stream"
        );
    }
    println!("\nstreams bit-identical across shard counts: OK (checksum {checksum0:016x})");

    // Gate 2: 4-shard pool >= 2x the single-worker service, judged on the
    // benchkit *medians* over all samples (outlier-robust), not on any
    // single run.
    let median_tput: Vec<(usize, f64)> = shard_counts
        .iter()
        .copied()
        .zip(g.results().iter().map(|r| r.throughput_m_per_s().unwrap_or(0.0)))
        .collect();
    let single = median_tput[0].1;
    let four = median_tput.iter().find(|t| t.0 == 4).unwrap().1;
    let speedup = four / single;
    println!("4-shard vs single-worker speedup: {speedup:.2}x");
    if cpus >= 4 {
        assert!(
            speedup >= 2.0,
            "4-shard pool only {speedup:.2}x the single-worker service (need >= 2x)"
        );
        println!("scaling gate (>= 2x): OK");
    } else {
        println!("scaling gate skipped: {cpus} CPUs < 4 (cannot host 4 busy shards)");
    }

    // Gate 3: steady-state allocation gate on the batched lane. Flush
    // alignment is exact by construction (requests and warmup sizes are
    // multiples of shards * max_requests), so every launch lands in one
    // arena size class and the steady window must be 100% hits.
    let shards = 4usize;
    let mut cfg = PoolConfig::new(PlatformId::A100, 0xA11, shards);
    cfg.max_requests = 4;
    cfg.max_batch = usize::MAX >> 1; // close on request count only
    let pool = ServicePool::spawn(cfg);
    let drive = |count: usize| {
        let rxs: Vec<_> = (0..count).map(|_| pool.generate(BATCH, (-1.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    };
    drive(32); // warmup: pays each shard's one cold malloc
    let t0 = pool.telemetry().snapshot();
    drive(REQUESTS); // steady state
    let t1 = pool.telemetry().snapshot();

    let (a0, a1) = (t0.arena_totals(), t1.arena_totals());
    let d_checkouts = a1.checkouts - a0.checkouts;
    let d_misses = a1.misses - a0.misses;
    assert!(d_checkouts > 0, "steady window saw no flushes");
    // Two gates, loosest first so each failure message is accurate: the
    // documented >= 95% post-warmup hit rate (cumulative rate would still
    // carry the warmup wave's unavoidable cold mallocs, so judge the
    // steady window), then the stricter zero-malloc steady-state claim.
    let steady_rate = (d_checkouts - d_misses) as f64 / d_checkouts as f64;
    assert!(
        steady_rate >= 0.95,
        "arena hit rate {steady_rate:.3} < 0.95 after warmup"
    );
    assert_eq!(
        d_misses, 0,
        "steady-state flushes performed {d_misses} device mallocs (want 0)"
    );

    let (k0, k1) = (t0.command_breakdown(), t1.command_breakdown());
    let d_launches = t1.total_launches() - t0.total_launches();
    assert_eq!(
        k1.generate.cmds - k0.generate.cmds,
        d_launches,
        "want exactly one generate host task per flush"
    );
    assert_eq!(
        k1.transform.cmds - k0.transform.cmds,
        d_launches,
        "want exactly one transform kernel per flush (non-unit range)"
    );
    assert_eq!(k1.d2h.cmds - k0.d2h.cmds, REQUESTS as u64, "one D2H slice per request");
    pool.shutdown().unwrap();
    println!(
        "allocation gate: {d_launches} steady flushes, 0 mallocs, \
         {:.1}% arena hit rate, 1 generate + 1 transform per flush: OK",
        steady_rate * 100.0
    );

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_pool_throughput.csv", g.to_csv()).unwrap();
}
