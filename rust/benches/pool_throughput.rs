//! Bench: sharded service-pool throughput scaling on the burner workload.
//!
//! Drives R requests of B numbers each through the pool at shard counts
//! {1, 2, 4, 8} and reports delivered wall-clock throughput. The 1-shard
//! row IS the legacy single-worker `RngService` (the facade wraps a
//! one-shard pool), so the scaling factor reads directly off the table.
//!
//! Acceptance gates:
//!   * 4-shard throughput >= 2x the single-worker service (when the
//!     machine has >= 4 CPUs);
//!   * every shard count produces bit-identical per-request streams
//!     (equal request-stream checksums);
//!   * serve-through-SYCL steady state: after warmup the batched lane's
//!     generate path allocates nothing per request — every flush's
//!     launch buffer is an arena hit (zero device mallocs; per request
//!     only the reply payload and queue-record bookkeeping remain) and
//!     each flush is exactly one generate host task + one transform
//!     kernel on the worker queue;
//!   * tile executor (DESIGN.md S16): the same single-shard workload
//!     through per-tile work items at team width 4 runs >= 2x faster
//!     than the serial flush path (when the machine has >= 4 CPUs),
//!     with a bit-identical payload checksum and zero hazard
//!     diagnostics across the widened per-tile DAG.

use portarng::benchkit::{BenchConfig, BenchGroup};
use portarng::burner::{
    run_burner_pooled, run_burner_pooled_opts, BurnerApi, BurnerConfig, PoolBurnerReport,
};
use portarng::coordinator::{PoolConfig, ServicePool};
use portarng::platform::PlatformId;
use portarng::trace::TraceConfig;

const BATCH: usize = 1 << 16;
const REQUESTS: usize = 192;

fn run(shards: usize) -> PoolBurnerReport {
    let cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclBuffer, BATCH);
    run_burner_pooled(&cfg, shards, REQUESTS).unwrap()
}

fn main() {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "pool throughput: {REQUESTS} requests x {BATCH} numbers ({} M total), {cpus} CPUs\n",
        REQUESTS * BATCH / 1_000_000
    );

    let shard_counts = [1usize, 2, 4, 8];
    let mut g = BenchGroup::new("pool").config(BenchConfig { warmup: 1, samples: 5 });
    let mut checksums: Vec<(usize, u64)> = Vec::new();
    for &shards in &shard_counts {
        let mut last: Option<PoolBurnerReport> = None;
        g.bench_items(&format!("{shards}-shard/{REQUESTS}x{BATCH}"), (REQUESTS * BATCH) as u64, || {
            last = Some(run(shards));
        });
        let r = last.unwrap();
        println!(
            "    -> {} launches | checksum {:016x}",
            r.stats.total().launches,
            r.checksum
        );
        checksums.push((shards, r.checksum));
    }

    // Gate 1: bit-identical per-request streams at every shard count.
    let checksum0 = checksums[0].1;
    for &(shards, checksum) in &checksums {
        assert_eq!(
            checksum, checksum0,
            "{shards}-shard pool diverged from the single-worker stream"
        );
    }
    println!("\nstreams bit-identical across shard counts: OK (checksum {checksum0:016x})");

    // Gate 2: 4-shard pool >= 2x the single-worker service, judged on the
    // benchkit *medians* over all samples (outlier-robust), not on any
    // single run.
    let median_tput: Vec<(usize, f64)> = shard_counts
        .iter()
        .copied()
        .zip(g.results().iter().map(|r| r.throughput_m_per_s().unwrap_or(0.0)))
        .collect();
    let single = median_tput[0].1;
    let four = median_tput.iter().find(|t| t.0 == 4).unwrap().1;
    let speedup = four / single;
    println!("4-shard vs single-worker speedup: {speedup:.2}x");
    if cpus >= 4 {
        assert!(
            speedup >= 2.0,
            "4-shard pool only {speedup:.2}x the single-worker service (need >= 2x)"
        );
        println!("scaling gate (>= 2x): OK");
    } else {
        println!("scaling gate skipped: {cpus} CPUs < 4 (cannot host 4 busy shards)");
    }

    // Gate 3: steady-state allocation gate on the batched lane. Flush
    // alignment is exact by construction (requests and warmup sizes are
    // multiples of shards * max_requests), so every launch lands in one
    // arena size class and the steady window must be 100% hits.
    let shards = 4usize;
    let mut cfg = PoolConfig::new(PlatformId::A100, 0xA11, shards);
    cfg.max_requests = 4;
    cfg.max_batch = usize::MAX >> 1; // close on request count only
    let pool = ServicePool::spawn(cfg);
    let drive = |count: usize| {
        let rxs: Vec<_> = (0..count).map(|_| pool.generate(BATCH, (-1.0, 1.0))).collect();
        pool.flush();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    };
    drive(32); // warmup: pays each shard's one cold malloc
    let t0 = pool.telemetry().snapshot();
    drive(REQUESTS); // steady state
    let t1 = pool.telemetry().snapshot();

    let (a0, a1) = (t0.arena_totals(), t1.arena_totals());
    let d_checkouts = a1.checkouts - a0.checkouts;
    let d_misses = a1.misses - a0.misses;
    assert!(d_checkouts > 0, "steady window saw no flushes");
    // Two gates, loosest first so each failure message is accurate: the
    // documented >= 95% post-warmup hit rate (cumulative rate would still
    // carry the warmup wave's unavoidable cold mallocs, so judge the
    // steady window), then the stricter zero-malloc steady-state claim.
    let steady_rate = (d_checkouts - d_misses) as f64 / d_checkouts as f64;
    assert!(
        steady_rate >= 0.95,
        "arena hit rate {steady_rate:.3} < 0.95 after warmup"
    );
    assert_eq!(
        d_misses, 0,
        "steady-state flushes performed {d_misses} device mallocs (want 0)"
    );

    let (k0, k1) = (t0.command_breakdown(), t1.command_breakdown());
    let d_launches = t1.total_launches() - t0.total_launches();
    assert_eq!(
        k1.generate.cmds - k0.generate.cmds,
        d_launches,
        "want exactly one generate host task per flush"
    );
    assert_eq!(
        k1.transform.cmds - k0.transform.cmds,
        d_launches,
        "want exactly one transform kernel per flush (non-unit range)"
    );
    assert_eq!(k1.d2h.cmds - k0.d2h.cmds, REQUESTS as u64, "one D2H slice per request");
    pool.shutdown().unwrap();
    println!(
        "allocation gate: {d_launches} steady flushes, 0 mallocs, \
         {:.1}% arena hit rate, 1 generate + 1 transform per flush: OK",
        steady_rate * 100.0
    );

    // Gate 4: tile executor. One shard, one request per flush, large
    // launches (16 tiles of 2^17): the tiled pool's wall time must beat
    // the serial pool's by >= 2x at team width 4, while every payload
    // bit matches (FNV checksum over the f32 bit patterns) and the
    // per-tile DAG stays provably race-free.
    const TILE: usize = 1 << 17;
    const TILED_N: usize = 1 << 21;
    const TILED_REQS: usize = 6;
    let run_once = |tiling: Option<(usize, usize)>| {
        let mut cfg = PoolConfig::new(PlatformId::A100, 0x711E, 1);
        cfg.max_requests = 1;
        cfg.max_batch = usize::MAX >> 1;
        cfg.tiling = tiling;
        let pool = ServicePool::spawn(cfg);
        // Warmup flush: pays the cold arena malloc on both paths.
        pool.generate(TILED_N, (-1.0, 1.0)).recv().unwrap().unwrap();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..TILED_REQS).map(|_| pool.generate(TILED_N, (-1.0, 1.0))).collect();
        let payloads: Vec<Vec<f32>> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        let wall = t0.elapsed().as_secs_f64();
        let checksum = payloads.iter().flatten().fold(0u64, |h, &x| {
            h.wrapping_mul(0x0100_0000_01b3).wrapping_add(x.to_bits() as u64)
        });
        let snap = pool.telemetry().snapshot();
        pool.shutdown().unwrap();
        (wall, checksum, snap)
    };
    // Best-of-3 per configuration: robust to scheduler noise without a
    // full benchkit group.
    let best = |tiling: Option<(usize, usize)>| {
        let mut runs: Vec<_> = (0..3).map(|_| run_once(tiling)).collect();
        runs.sort_by(|a, b| a.0.total_cmp(&b.0));
        runs.swap_remove(0)
    };
    let (serial_wall, serial_sum, serial_snap) = best(None);
    let (tiled_wall, tiled_sum, tiled_snap) = best(Some((TILE, 4)));

    assert_eq!(
        tiled_sum, serial_sum,
        "tiled payloads diverged from the serial flush path"
    );
    let serial_tiles = serial_snap.tile_totals();
    assert_eq!(serial_tiles.tiles, 0, "serial pool must not run the tile executor");
    let tiles = tiled_snap.tile_totals();
    // 7 flushes (warmup + measured) x 16 generate tiles + 16 transform
    // tiles (the ranged member spans every tile).
    assert_eq!(tiles.tiles, ((1 + TILED_REQS) * 2 * (TILED_N / TILE)) as u64);
    for (label, snap) in [("serial", &serial_snap), ("tiled", &tiled_snap)] {
        let h = snap.hazard_totals();
        assert!(
            h.clean(),
            "{label} pool recorded {} hazard diagnostic(s)",
            h.total()
        );
    }
    let pipe = tiled_snap.pipeline_totals();
    let exec_speedup = serial_wall / tiled_wall;
    println!(
        "\ntile executor ({} tiles x{} team): {:.1} ms serial -> {:.1} ms tiled \
         ({exec_speedup:.2}x), checksum {tiled_sum:016x}, {} tile timings, \
         pipeline occupancy {:.0}%",
        TILED_N / TILE,
        4,
        serial_wall * 1e3,
        tiled_wall * 1e3,
        tiles.tiles,
        pipe.occupancy() * 100.0
    );
    if cpus >= 4 {
        assert!(
            exec_speedup >= 2.0,
            "tiled flushes only {exec_speedup:.2}x the serial path (need >= 2x at team width 4)"
        );
        println!("tile executor gate (>= 2x, bit-identical, zero hazards): OK");
    } else {
        println!("tile executor gate skipped: {cpus} CPUs < 4 (cannot host the team)");
    }

    // Gate 5: request-tracer overhead (DESIGN.md S18). The trace layer
    // claims near-zero cost while disabled (one relaxed load per record
    // site) and <= 5% delivered-throughput cost with rings recording.
    // Interleave the two configurations sample by sample and judge
    // medians, so drift in machine load charges both sides equally.
    const TRACE_SAMPLES: usize = 5;
    let burner_cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclBuffer, BATCH);
    let trace_cfg = TraceConfig::default(); // rings on, wall clock, no flight dir
    let mut tput_off: Vec<f64> = Vec::new();
    let mut tput_on: Vec<f64> = Vec::new();
    for _ in 0..TRACE_SAMPLES {
        let off = run_burner_pooled_opts(&burner_cfg, 4, REQUESTS, None, None).unwrap();
        assert!(off.spans.is_empty(), "untraced run recorded spans");
        tput_off.push(off.throughput_m_per_s());
        let on = run_burner_pooled_opts(&burner_cfg, 4, REQUESTS, None, Some(&trace_cfg)).unwrap();
        // The traced run must have actually paid for its spans: at least
        // admit + stage + reply per request.
        assert!(
            on.spans.len() >= REQUESTS * 3,
            "traced run recorded only {} spans for {REQUESTS} requests",
            on.spans.len()
        );
        tput_on.push(on.throughput_m_per_s());
    }
    let median = |v: &mut Vec<f64>| {
        v.sort_by(|a, b| a.total_cmp(b));
        v[v.len() / 2]
    };
    let (m_off, m_on) = (median(&mut tput_off), median(&mut tput_on));
    let cost = (1.0 - m_on / m_off) * 100.0;
    println!(
        "\ntracing overhead: {m_off:.0} M/s untraced -> {m_on:.0} M/s traced ({cost:+.1}% cost)"
    );
    assert!(
        m_on >= m_off * 0.95,
        "tracing costs {cost:.1}% of delivered throughput (gate: <= 5%)"
    );
    println!("tracing overhead gate (<= 5%): OK");

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_pool_throughput.csv", g.to_csv()).unwrap();
}
