//! Bench: FastCaloSim served through the pooled SYCL stack (DESIGN.md S17).
//!
//! Drives the same single-electron workload through the standalone host
//! engine and through a 4-shard `ServicePool` (tile executor on), and
//! compares real wall-clock event throughput. The pooled path wins by
//! generating the per-event RN floor in chunked pool submissions that
//! overlap the host deposit loop and spread across shards; the standalone
//! path draws every block inline on the simulation thread.
//!
//! Acceptance gates:
//!   * pooled and standalone produce bit-identical physics checksums —
//!     every run, not just the medians;
//!   * 4-shard pooled throughput >= 1.5x standalone-sycl (when the
//!     machine has >= 4 CPUs), judged on benchkit medians.

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::fastcalosim::{run_fastcalosim, run_fastcalosim_pooled, FcsApi, Workload};
use portarng::platform::PlatformId;

const EVENTS: usize = 12;
const SHARDS: usize = 4;
const SEED: u64 = 2024;

fn main() {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let w = Workload::SingleElectron { events: EVENTS };
    println!("fcs pool: single-e x {EVENTS} events, {SHARDS} shards, {cpus} CPUs\n");

    let mut g = BenchGroup::new("fcs_pool").config(BenchConfig { warmup: 1, samples: 5 });

    let mut standalone_sum = 0u64;
    g.bench_items(&format!("standalone/{EVENTS}ev"), EVENTS as u64, || {
        let r = run_fastcalosim(black_box(PlatformId::A100), FcsApi::Sycl, w, SEED).unwrap();
        standalone_sum = r.checksum;
    });
    println!("    -> checksum {standalone_sum:016x}");

    let mut pooled_sum = 0u64;
    let mut splits = (0u64, 0u64, 0u64);
    g.bench_items(&format!("pooled/{SHARDS}-shard/{EVENTS}ev"), EVENTS as u64, || {
        let run = run_fastcalosim_pooled(
            black_box(PlatformId::A100),
            FcsApi::Sycl,
            w,
            SEED,
            SHARDS,
            Some((256, 2)),
            None,
        )
        .unwrap();
        // Every sample must match the standalone stream, not just the last.
        assert_eq!(
            run.report.checksum, standalone_sum,
            "pooled physics diverged from standalone"
        );
        pooled_sum = run.report.checksum;
        let f = run.telemetry.fcs;
        splits = (f.gen_ns, f.transform_ns, f.d2h_ns);
    });
    println!(
        "    -> checksum {pooled_sum:016x} | virtual splits gen {:.2} ms, \
         transform {:.2} ms, d2h {:.2} ms",
        splits.0 as f64 / 1e6,
        splits.1 as f64 / 1e6,
        splits.2 as f64 / 1e6
    );
    println!("\nphysics bit-identical standalone vs pooled: OK (checksum {pooled_sum:016x})");

    // Throughput gate on the benchkit medians (outlier-robust).
    let tput: Vec<f64> = g
        .results()
        .iter()
        .map(|r| r.throughput_m_per_s().unwrap_or(0.0))
        .collect();
    let speedup = tput[1] / tput[0];
    println!("pooled vs standalone event throughput: {speedup:.2}x");
    if cpus >= 4 {
        assert!(
            speedup >= 1.5,
            "pooled serving only {speedup:.2}x standalone (need >= 1.5x at {SHARDS} shards)"
        );
        println!("serving gate (>= 1.5x): OK");
    } else {
        println!("serving gate skipped: {cpus} CPUs < 4 (cannot host {SHARDS} busy shards)");
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_fcs_pool.csv", g.to_csv()).unwrap();
}
