//! Bench: Fig. 2 — burner on the x86 CPUs + iGPU, Buffer vs USM.
//! Measures real wall time of the full application path per iteration and
//! prints the virtual (paper-comparable) series.

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::burner::{run_burner_auto, BurnerApi, BurnerConfig};
use portarng::platform::PlatformId;

fn main() {
    let mut g = BenchGroup::new("fig2").config(BenchConfig { warmup: 1, samples: 10 });
    for platform in [PlatformId::Rome7742, PlatformId::CoreI7_10875H, PlatformId::Uhd630] {
        for api in [BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
            for batch in [1_000usize, 1_000_000] {
                let mut cfg = BurnerConfig::paper_default(platform, api, batch);
                cfg.iterations = 3;
                let name = format!("{}/{}/{batch}", platform.token(), api.token());
                let mut virt = 0f64;
                g.bench_items(&name, batch as u64, || {
                    let r = run_burner_auto(black_box(&cfg)).unwrap();
                    virt = r.mean_total_ns();
                });
                println!("    -> virtual {:.4} ms/iter", virt / 1e6);
            }
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_fig2.csv", g.to_csv()).unwrap();
}
