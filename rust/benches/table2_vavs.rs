//! Bench: Table 2 — the VAVS sweep end-to-end (driver wall time) and the
//! resulting P̄ values, compared against the paper's.

use portarng::benchkit::{BenchConfig, BenchGroup};
use portarng::repro::table2;

fn main() {
    let mut g = BenchGroup::new("table2").config(BenchConfig { warmup: 0, samples: 3 });
    let mut out = None;
    g.bench("vavs-driver-quick", || {
        out = Some(table2(true).unwrap());
    });
    let tables = out.unwrap();
    let t = &tables[0];
    println!("\n{}", t.to_markdown());
    println!("paper: {{Vega56,A100}} buffer 1.070 / usm 0.393; {{Vega56}} 0.974/1.076; {{A100}} 1.186/0.240");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_table2.csv", t.to_csv()).unwrap();
}
