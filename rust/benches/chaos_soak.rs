//! Bench: chaos soak — the resilience layer's acceptance gates (S15).
//!
//! Drives the pooled burner workload twice through a 4-shard pool: once
//! fault-free (control) and once under a deterministic chaos plan that
//! injects transient faults at ~5% per op across all three transient
//! sites AND kills two shard workers outright at scheduled message ops.
//! The plan's decision indices were chosen so faults are *structurally*
//! guaranteed: shard 3's first submit op always trips (a whole flush is
//! retried), and both kill points land well inside each victim's message
//! stream.
//!
//! Acceptance gates:
//!   * bit-identical recovery: the chaos run's request-stream checksum
//!     equals the fault-free control's — every retried, re-dispatched,
//!     or respawn-replayed request delivered its exact fault-free
//!     payload;
//!   * zero hung callers: replies are drained with a 60 s timeout inside
//!     `run_burner_pooled_chaos`, so a stranded caller fails the run
//!     instead of wedging it;
//!   * live counters: faults.injected, shard.respawns and
//!     requests.retried are all nonzero under chaos and all zero in the
//!     control run;
//!   * the telemetry snapshot (current schema, `TELEMETRY_SCHEMA`)
//!     round-trips through JSON with the resilience block intact;
//!   * inert-path overhead: with no plan installed, `fault::trip` costs
//!     under 200 ns per call (one thread-local read + a `None` check).

use portarng::benchkit::{BenchConfig, BenchGroup};
use portarng::burner::{run_burner_pooled_chaos, BurnerApi, BurnerConfig, PoolBurnerReport};
use portarng::fault::{self, FaultSite, FaultSpec};
use portarng::platform::PlatformId;
use portarng::telemetry::{TelemetrySnapshot, TELEMETRY_SCHEMA};

const BATCH: usize = 4096;
const REQUESTS: usize = 160;
const SHARDS: usize = 4;

/// Seed 7 at rate 0.05 was chosen against the (pure) decision function:
/// every batched shard trips at least once inside the op range this
/// workload consumes, with no back-to-back fire runs long enough to
/// exhaust the retry budget. The kills hit shard 0 at its 3rd message and
/// shard 2 at its 5th.
const CHAOS: &str = "seed=7,rate=0.05,sites=generate+submit+d2h,kill=0@3+2@5";

fn run(chaos: Option<&FaultSpec>) -> PoolBurnerReport {
    let cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclUsm, BATCH);
    run_burner_pooled_chaos(&cfg, SHARDS, REQUESTS, chaos).unwrap()
}

fn main() {
    let spec = FaultSpec::parse(CHAOS).unwrap();
    println!(
        "chaos soak: {REQUESTS} requests x {BATCH} numbers, {SHARDS} shards\n  plan: {spec}\n"
    );

    let mut g = BenchGroup::new("chaos").config(BenchConfig { warmup: 1, samples: 5 });

    // Control: the same workload with no plan installed. Every resilience
    // counter must read zero — proof the fault layer is inert when
    // unconfigured.
    let mut control: Option<PoolBurnerReport> = None;
    g.bench_items(&format!("fault-free/{REQUESTS}x{BATCH}"), (REQUESTS * BATCH) as u64, || {
        control = Some(run(None));
    });
    let control = control.unwrap();
    let res = control.telemetry.resilience_totals();
    assert!(
        !res.any(),
        "fault-free run reported nonzero resilience counters: {res:?}"
    );
    println!(
        "    -> checksum {:016x}, resilience counters all zero: OK",
        control.checksum
    );

    // Chaos: same workload under the plan. Each sample spawns a fresh
    // pool (fresh per-shard plans), so the kills fire in every sample.
    let mut soaked: Option<PoolBurnerReport> = None;
    g.bench_items(&format!("chaos-5pct/{REQUESTS}x{BATCH}"), (REQUESTS * BATCH) as u64, || {
        soaked = Some(run(Some(&spec)));
    });
    let soaked = soaked.unwrap();

    // Gate 1: bit-identical recovery. Completed replies under chaos fold
    // to the exact fault-free checksum (counter-based streams addressed
    // by global offset make the re-dispatch a pure replay).
    assert_eq!(
        soaked.checksum, control.checksum,
        "chaos run diverged from the fault-free stream"
    );
    assert_eq!(soaked.numbers, control.numbers, "chaos run dropped replies");
    println!("\nbit-identical under chaos: OK (checksum {:016x})", soaked.checksum);

    // Gate 2: the injected faults actually happened and were absorbed.
    let res = soaked.telemetry.resilience_totals();
    assert!(res.faults_injected >= 3, "plan injected only {} fault(s)", res.faults_injected);
    assert!(res.shard_respawns >= 2, "expected both scheduled kills to respawn a worker");
    assert!(res.requests_retried >= 1, "no request was retried despite transient faults");
    println!(
        "resilience counters: {} injected, {} respawns, {} retried, {} shed, \
         {} deadline-exceeded: OK",
        res.faults_injected,
        res.shard_respawns,
        res.requests_retried,
        res.requests_shed,
        res.deadline_exceeded
    );

    // Gate 3: the snapshot survives a JSON round-trip with the
    // resilience block intact. Judge against the exported schema
    // constant, not a literal — this line predates three schema bumps
    // it silently missed.
    let json = soaked.telemetry.to_json().to_json();
    assert!(json.contains(TELEMETRY_SCHEMA), "snapshot lost its schema tag");
    let back = TelemetrySnapshot::from_json(
        &portarng::jsonlite::Value::parse(&json).expect("snapshot JSON must parse"),
    )
    .expect("snapshot must round-trip");
    let back_res = back.resilience_totals();
    assert_eq!(back_res.faults_injected, res.faults_injected, "round-trip lost fault counts");
    assert_eq!(back_res.shard_respawns, res.shard_respawns, "round-trip lost respawn counts");
    println!("telemetry {TELEMETRY_SCHEMA} round-trip with resilience block: OK");

    // Gate 4: inert-path overhead. No plan is installed on this thread,
    // so trip() must reduce to a thread-local read + None check.
    const TRIPS: u32 = 1_000_000;
    let t0 = std::time::Instant::now();
    for _ in 0..TRIPS {
        std::hint::black_box(fault::trip(std::hint::black_box(FaultSite::Generate)).is_ok());
    }
    let ns_per_trip = t0.elapsed().as_nanos() as f64 / TRIPS as f64;
    assert!(
        ns_per_trip < 200.0,
        "uninstalled fault::trip costs {ns_per_trip:.1} ns/call (want < 200)"
    );
    println!("inert trip overhead: {ns_per_trip:.1} ns/call (< 200): OK");

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_chaos_soak.csv", g.to_csv()).unwrap();
}
