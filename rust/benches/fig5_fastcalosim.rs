//! Bench: Fig. 5 — FastCaloSim across platforms, native vs SYCL, both
//! workloads. Real wall time of the simulation loop + virtual run-times.

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::fastcalosim::{run_fastcalosim, FcsApi, Workload};
use portarng::platform::PlatformId;

fn main() {
    let mut g = BenchGroup::new("fig5").config(BenchConfig { warmup: 1, samples: 5 });
    for (workload, label, events) in [
        (Workload::SingleElectron { events: 25 }, "single-e", 25u64),
        (Workload::TTbar { events: 5 }, "ttbar", 5),
    ] {
        for platform in [PlatformId::Rome7742, PlatformId::A100, PlatformId::Vega56] {
            for api in [FcsApi::Native, FcsApi::Sycl] {
                if api == FcsApi::Native && platform == PlatformId::Vega56 {
                    continue;
                }
                let name = format!("{label}/{}/{}", platform.token(), api.token());
                let mut virt = 0f64;
                g.bench_items(&name, events, || {
                    let r =
                        run_fastcalosim(black_box(platform), api, workload, 1).unwrap();
                    virt = r.total_ns as f64;
                });
                println!("    -> virtual {:.3} s total", virt / 1e9);
            }
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_fig5.csv", g.to_csv()).unwrap();
}
