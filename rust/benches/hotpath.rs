//! Bench: the real hot paths (§Perf deliverable) — engine throughput,
//! distribution sampling, SYCL runtime overhead, PJRT execution, service
//! batching. These are wall-clock measurements of OUR implementation, the
//! numbers the §Perf optimization loop tracks.

use std::sync::Arc;

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::coordinator::RngService;
use portarng::platform::{CommandCost, PlatformId};
use portarng::rng::{Distribution, Engine, EngineKind, PhiloxEngine};
use portarng::runtime::PjrtRuntime;
use portarng::sycl::{AccessMode, Buffer, CommandClass, Queue, SyclRuntimeProfile};

fn main() {
    let n = 1 << 20;

    // L3 hot path 1: raw engine throughput (u32 and fused uniform).
    let mut g = BenchGroup::new("hotpath").config(BenchConfig { warmup: 2, samples: 12 });
    {
        let mut e = PhiloxEngine::new(1);
        let mut buf = vec![0u32; n];
        g.bench_items("philox/fill_u32/1M", n as u64, || {
            e.fill_u32(black_box(&mut buf));
        });
        let mut fbuf = vec![0f32; n];
        g.bench_items("philox/fill_uniform_fused/1M", n as u64, || {
            e.fill_uniform_f32(black_box(&mut fbuf));
        });
    }
    for kind in [EngineKind::Mrg32k3a, EngineKind::Xorwow, EngineKind::Mt19937] {
        let mut e = kind.create(1);
        let mut buf = vec![0u32; n];
        g.bench_items(&format!("{}/fill_u32/1M", kind.name()), n as u64, || {
            e.fill_u32(black_box(&mut buf));
        });
    }

    // Distribution layer.
    {
        let mut e = PhiloxEngine::new(2);
        let mut out = vec![0f32; n];
        for d in [
            Distribution::uniform(-1.0, 1.0),
            Distribution::gaussian(0.0, 1.0),
            Distribution::Exponential { lambda: 1.0 },
        ] {
            g.bench_items(&format!("distr/{}/1M", d.name()), n as u64, || {
                d.sample_f32(&mut e, black_box(&mut out));
            });
        }
    }

    // SYCL runtime overhead: empty command groups (per-submit cost).
    {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let buf = Buffer::<f32>::new(64);
        g.bench_items("sycl/submit/1k-cmds", 1000, || {
            for i in 0..1000 {
                let b = buf.clone();
                queue.submit(move |cgh| {
                    let acc = cgh.require(&b, AccessMode::ReadWrite);
                    cgh.host_task(
                        format!("k{i}"),
                        CommandClass::Other,
                        CommandCost::HostCompute { ns: 0 },
                        move |_| {
                            let _ = acc;
                        },
                    );
                });
            }
        });
    }

    // PJRT execution latency (the device round trip).
    if let Ok(rt) = PjrtRuntime::discover() {
        let rt = Arc::new(rt);
        rt.warmup(Some(&["burner_uniform_65536", "burner_uniform_1048576"])).unwrap();
        g.bench_items("pjrt/burner/65536", 65536, || {
            let out = rt
                .run_burner("burner_uniform_65536", [1, 2], [0, 0], 0.0, 1.0)
                .unwrap();
            black_box(out);
        });
        g.bench_items("pjrt/burner/1048576", 1 << 20, || {
            let out = rt
                .run_burner("burner_uniform_1048576", [1, 2], [0, 0], 0.0, 1.0)
                .unwrap();
            black_box(out);
        });
        g.bench_items("pjrt/calosim/16384-hits", 16384, || {
            let out = rt
                .run_calosim(
                    "calosim_hits_16384",
                    [1, 2],
                    [0, 0],
                    [0.2, 1.0, 0.004, 0.05, 0.05],
                )
                .unwrap();
            black_box(out);
        });
    }

    // Coordinator service: request round-trip + batching throughput.
    {
        g.bench_items("service/64-requests-of-4k", 64 * 4096, || {
            let svc = RngService::spawn(PlatformId::A100, 1, 1 << 20, 16);
            let rxs: Vec<_> = (0..64).map(|_| svc.generate(4096, (0.0, 1.0))).collect();
            svc.flush();
            for rx in rxs {
                black_box(rx.recv().unwrap().unwrap());
            }
            svc.shutdown().unwrap();
        });
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_hotpath.csv", g.to_csv()).unwrap();
}
