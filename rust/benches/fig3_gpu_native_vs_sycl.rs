//! Bench: Fig. 3 — burner on the discrete GPUs, native vs SYCL buffer/USM.

use portarng::benchkit::{black_box, BenchConfig, BenchGroup};
use portarng::burner::{run_burner_auto, BurnerApi, BurnerConfig};
use portarng::platform::PlatformId;

fn main() {
    let mut g = BenchGroup::new("fig3").config(BenchConfig { warmup: 1, samples: 10 });
    for platform in [PlatformId::Vega56, PlatformId::A100] {
        for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
            for batch in [1_000usize, 1_000_000, 100_000_000] {
                let mut cfg = BurnerConfig::paper_default(platform, api, batch);
                cfg.iterations = 3;
                let name = format!("{}/{}/{batch}", platform.token(), api.token());
                let mut virt = 0f64;
                g.bench_items(&name, batch as u64, || {
                    let r = run_burner_auto(black_box(&cfg)).unwrap();
                    virt = r.mean_total_ns();
                });
                println!("    -> virtual {:.4} ms/iter", virt / 1e6);
            }
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_fig3.csv", g.to_csv()).unwrap();
}
