//! THE core correctness signal (DESIGN.md §4): the three implementations of
//! the numerics contract — Rust engines, the AOT-compiled Pallas kernel
//! (via PJRT), and (transitively, via pytest) the jnp oracle — agree on the
//! Philox4x32x10 stream.
//!
//! Requires `artifacts/*.hlo.txt` AND a linked PJRT client. In offline
//! builds the in-tree `xla` substrate gates the client, so every test here
//! self-skips with a notice instead of failing — the same contract is then
//! covered by the Python-side tests, which execute the identical HLO
//! through JAX.

use portarng::rng::{Engine, PhiloxEngine};
use portarng::runtime::PjrtRuntime;

fn runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::discover() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping cross-layer test (PJRT/artifacts unavailable): {e}");
            None
        }
    }
}

fn rust_uniform(seed_lo: u32, seed_hi: u32, block_off: u64, n: usize) -> Vec<f32> {
    let seed = (seed_hi as u64) << 32 | seed_lo as u64;
    let mut e = PhiloxEngine::with_offset(seed, block_off * 4);
    let mut out = vec![0f32; n];
    e.fill_uniform_f32(&mut out);
    out
}

/// FMA contraction bound: XLA may fuse a + u*(b-a); the Rust path doesn't.
fn assert_close(got: &[f32], want: &[f32], span: f32) {
    assert_eq!(got.len(), want.len());
    let tol = span * f32::EPSILON * 2.0;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol, "idx {i}: {g} vs {w} (tol {tol})");
    }
}

#[test]
fn pallas_artifact_is_bit_exact_on_unit_range() {
    let Some(rt) = runtime() else { return };
    // [0,1): a=0, b=1 makes the transform a*1+0 -> bit-exact across layers.
    let out = rt
        .run_burner("burner_uniform_4096", [77, 88], [0, 0], 0.0, 1.0)
        .unwrap();
    let want = rust_uniform(77, 88, 0, 4096);
    assert_eq!(out, want, "u01 stream must be bit-exact");
}

#[test]
fn pallas_artifact_matches_rust_with_range() {
    let Some(rt) = runtime() else { return };
    let out = rt
        .run_burner("burner_uniform_4096", [1234, 5678], [0, 0], -2.0, 3.0)
        .unwrap();
    let want: Vec<f32> =
        rust_uniform(1234, 5678, 0, 4096).iter().map(|u| -2.0 + u * 5.0).collect();
    assert_close(&out, &want, 5.0);
}

#[test]
fn counter_offset_matches_skip_ahead() {
    let Some(rt) = runtime() else { return };
    // Offset by 1000 counter blocks == Rust skip-ahead of 4000 draws.
    let out = rt
        .run_burner("burner_uniform_4096", [9, 0], [1000, 0], 0.0, 1.0)
        .unwrap();
    let want = rust_uniform(9, 0, 1000, 4096);
    assert_eq!(out, want);
}

#[test]
fn high_offset_word_is_honoured() {
    let Some(rt) = runtime() else { return };
    // off_hi = 2 -> blocks start at 2^33.
    let out = rt
        .run_burner("burner_uniform_4096", [5, 6], [0, 2], 0.0, 1.0)
        .unwrap();
    let want = rust_uniform(5, 6, 2u64 << 32, 4096);
    assert_eq!(out, want);
}

#[test]
fn all_burner_sizes_agree() {
    let Some(rt) = runtime() else { return };
    for (n, name) in rt.manifest().burner_sizes() {
        let out = rt.run_burner(&name, [42, 0], [0, 0], 0.0, 1.0).unwrap();
        let want = rust_uniform(42, 0, 0, n);
        assert_eq!(out, want, "artifact {name}");
    }
}

#[test]
fn two_kernel_variant_matches_fused() {
    let Some(rt) = runtime() else { return };
    let fused = rt
        .run_burner("burner_uniform_65536", [3, 4], [0, 0], 10.0, 20.0)
        .unwrap();
    let twok = rt
        .run_burner("burner_uniform_2k_65536", [3, 4], [0, 0], 10.0, 20.0)
        .unwrap();
    assert_close(&twok, &fused, 20.0);
}

#[test]
fn gaussian_artifact_moments_and_reference() {
    let Some(rt) = runtime() else { return };
    let out = rt
        .run_burner("burner_gaussian_65536", [7, 7], [0, 0], 1.0, 2.0)
        .unwrap();
    let n = out.len() as f64;
    let mean: f64 = out.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var: f64 =
        out.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / n;
    assert!((mean - 1.0).abs() < 0.03, "mean={mean}");
    assert!((var.sqrt() - 2.0).abs() < 0.03, "std={}", var.sqrt());

    // Box-Muller over the same uniforms in Rust.
    let u = rust_uniform(7, 7, 0, 65536);
    let mut want = Vec::with_capacity(65536);
    for pair in u.chunks(2) {
        let (z0, z1) = portarng::rng::distributions::box_muller_pair(pair[0], pair[1]);
        want.push(1.0 + 2.0 * z0);
        want.push(1.0 + 2.0 * z1);
    }
    for (i, (g, w)) in out.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-3, "idx {i}: {g} vs {w}");
    }
}

#[test]
fn calosim_artifact_conserves_energy_and_matches_scale() {
    let Some(rt) = runtime() else { return };
    let n_hits = 16384f32;
    let e_scale = 65.0 / n_hits;
    let (deposits, total) = rt
        .run_calosim("calosim_hits_16384", [11, 13], [0, 0], [0.5, 1.0, e_scale, 0.05, 0.05])
        .unwrap();
    let dep_sum: f64 = deposits.iter().map(|&x| x as f64).sum();
    assert!((dep_sum - f64::from(total)).abs() / f64::from(total) < 1e-3);
    assert!((50.0..80.0).contains(&total), "total={total}");
    assert_eq!(deposits.len(), 190_000);
}

#[test]
fn pjrt_backend_generator_is_stream_exact() {
    use portarng::backends::{PjrtBackend, RngBackend};
    use portarng::rng::{Distribution, EngineKind};
    use std::sync::Arc;

    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(Arc::new(rt)).unwrap();
    let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 42).unwrap();
    let mut out = vec![0f32; 3000];
    gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out).unwrap();
    assert_eq!(out, rust_uniform(42, 0, 0, 3000));

    // Second call continues at the padded block offset (4096 numbers).
    let mut out2 = vec![0f32; 100];
    gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out2).unwrap();
    assert_eq!(out2, rust_uniform(42, 0, 1024, 100));
}
