//! Backend-layer integration: the generate API over every backend,
//! engine parity across vendors, and property tests on stream slicing.

use portarng::backends::{
    CurandBackend, HiprandBackend, MklCpuBackend, OneMklIntelGpuBackend, RngBackend,
};
use portarng::platform::PlatformId;
use portarng::rng::{
    generate_buffer, generate_usm, Distribution, Engine, EngineKind, GaussianMethod,
    PhiloxEngine,
};
use portarng::sycl::{Buffer, Queue, SyclRuntimeProfile};
use portarng::testkit;

fn backends() -> Vec<(Box<dyn RngBackend>, PlatformId)> {
    vec![
        (Box::new(CurandBackend::new()) as Box<dyn RngBackend>, PlatformId::A100),
        (Box::new(HiprandBackend::new()), PlatformId::Vega56),
        (Box::new(MklCpuBackend::new(PlatformId::Rome7742)), PlatformId::Rome7742),
        (Box::new(OneMklIntelGpuBackend::new()), PlatformId::Uhd630),
    ]
}

#[test]
fn generate_buffer_parity_across_all_backends() {
    let n = 2048;
    let distr = Distribution::uniform(-4.0, 4.0);
    let mut reference: Option<Vec<f32>> = None;
    for (backend, platform) in backends() {
        let queue = Queue::new(platform, SyclRuntimeProfile::for_platform(&platform.spec()));
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 99).unwrap();
        let buf = Buffer::<f32>::new(n);
        generate_buffer(&queue, &mut gen, distr, n, &buf).unwrap();
        let out = queue.host_read(&buf);
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(&out, r, "backend {}", backend.name()),
        }
    }
}

#[test]
fn prop_buffer_usm_equivalence_any_seed_any_engine() {
    testkit::forall("buffer-usm-equiv", 20, |g| {
        let seed = g.u64();
        let n = g.usize_in(4, 3000);
        let kind = *g.choose(&[
            EngineKind::Philox4x32x10,
            EngineKind::Mrg32k3a,
            EngineKind::Xorwow,
            EngineKind::Mt19937,
        ]);
        let a = g.f32_in(-100.0, 100.0);
        let b = a + g.f32_in(0.1, 100.0);
        let distr = Distribution::Uniform { a, b, method: Default::default() };

        let backend = HiprandBackend::new();
        let qb = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let mut g1 = backend.create_generator(kind, seed).unwrap();
        let buf = Buffer::<f32>::new(n);
        generate_buffer(&qb, &mut g1, distr, n, &buf).unwrap();

        let qu = Queue::new(PlatformId::Vega56, SyclRuntimeProfile::HipSycl);
        let mut g2 = backend.create_generator(kind, seed).unwrap();
        let usm = qu.malloc_device::<f32>(n);
        let ev = generate_usm(&qu, &mut g2, distr, n, &usm, &[]).unwrap();
        let out_usm = qu.usm_to_host(&usm, std::slice::from_ref(&ev));

        if qb.host_read(&buf) != out_usm {
            return Err(format!("buffer != usm for {kind:?} seed {seed}"));
        }
        Ok(())
    });
}

#[test]
fn prop_generated_values_respect_range() {
    testkit::forall("range-respected", 25, |g| {
        let a = g.f32_in(-1000.0, 1000.0);
        let b = a + g.f32_in(0.001, 1000.0);
        let n = g.usize_in(1, 4000);
        let backend = CurandBackend::new();
        let mut gen = backend
            .create_generator(EngineKind::Philox4x32x10, g.u64())
            .unwrap();
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        let buf = Buffer::<f32>::new(n);
        generate_buffer(
            &queue,
            &mut gen,
            Distribution::Uniform { a, b, method: Default::default() },
            n,
            &buf,
        )
        .unwrap();
        let out = queue.host_read(&buf);
        let tol = (b - a).abs() * f32::EPSILON * 4.0 + 1e-6;
        for &x in &out {
            if x < a - tol || x > b + tol {
                return Err(format!("{x} outside [{a}, {b})"));
            }
        }
        Ok(())
    });
}

#[test]
fn vendor_backends_reject_what_the_paper_says() {
    // §4.1/§4.3: no ICDF for pseudorandom engines, no exponential/poisson
    // native entry points on cuRAND/hipRAND.
    for backend in [
        Box::new(CurandBackend::new()) as Box<dyn RngBackend>,
        Box::new(HiprandBackend::new()),
    ] {
        let icdf = Distribution::Gaussian {
            mean: 0.0,
            stddev: 1.0,
            method: GaussianMethod::Icdf,
        };
        assert!(!backend.supports(EngineKind::Philox4x32x10, &icdf));
        assert!(!backend
            .supports(EngineKind::Philox4x32x10, &Distribution::Exponential { lambda: 1.0 }));
        // Quasirandom engines do get ICDF.
        assert!(backend.supports(EngineKind::Sobol32, &icdf));

        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 1).unwrap();
        let mut out = vec![0f32; 8];
        assert!(gen.generate_canonical(&icdf, &mut out).is_err());
    }
}

#[test]
fn onemkl_native_backends_support_everything() {
    let mkl = MklCpuBackend::new(PlatformId::CoreI7_10875H);
    for kind in EngineKind::ALL {
        for distr in [
            Distribution::uniform(0.0, 2.0),
            Distribution::Gaussian { mean: 0.0, stddev: 1.0, method: GaussianMethod::Icdf },
            Distribution::Exponential { lambda: 0.5 },
            Distribution::Bits,
        ] {
            assert!(mkl.supports(kind, &distr), "{kind:?}/{distr:?}");
        }
    }
}

#[test]
fn generator_lifecycle_state_machine() {
    testkit::forall("generator-lifecycle", 15, |g| {
        let backend = CurandBackend::new();
        let mut gen = backend
            .create_generator(EngineKind::Philox4x32x10, g.u64())
            .unwrap();
        // Random op sequence; after destroy everything must fail.
        let mut destroyed = false;
        for _ in 0..g.usize_in(1, 10) {
            let op = g.usize_in(0, 3);
            let r = match op {
                0 => gen.set_seed(g.u64()),
                1 => gen.set_offset(g.u64() % 1_000_000),
                2 => {
                    let mut out = vec![0f32; 16];
                    gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out)
                }
                _ => {
                    let r = gen.destroy();
                    if r.is_ok() {
                        destroyed = true;
                    }
                    r
                }
            };
            if destroyed && op != 3 && r.is_ok() {
                return Err("operation succeeded on destroyed handle".into());
            }
            if destroyed {
                break;
            }
        }
        Ok(())
    });
}

#[test]
fn seed_offset_reproduces_subsequences() {
    testkit::forall("offset-subsequence", 15, |g| {
        let seed = g.u64();
        let skip = g.range(0, 100_000);
        let n = g.usize_in(1, 2000);

        let backend = CurandBackend::new();
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, seed).unwrap();
        gen.set_offset(skip).unwrap();
        let mut out = vec![0f32; n];
        gen.generate_canonical(&Distribution::uniform(0.0, 1.0), &mut out).unwrap();

        let mut e = PhiloxEngine::new(seed);
        e.skip_ahead(skip);
        let mut want = vec![0f32; n];
        e.fill_uniform_f32(&mut want);
        if out != want {
            return Err(format!("subsequence mismatch at skip {skip}"));
        }
        Ok(())
    });
}
