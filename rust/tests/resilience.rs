//! Resilience integration: deterministic chaos plans against the public
//! pool API — bit-identical recovery across respawns and retries, typed
//! errors on exhaustion, and an inert fault layer when unconfigured.
//!
//! Every reply here is drained with a timeout: the resilience layer's
//! contract is "exact payload or typed error, never a hang", so a stuck
//! receiver is itself a failure, not an excuse to wait.

use std::time::Duration;

use portarng::coordinator::{DispatchPolicy, PoolConfig, ServicePool};
use portarng::error::Error;
use portarng::fault::FaultSpec;
use portarng::platform::PlatformId;
use portarng::rng::{Engine, PhiloxEngine};
use portarng::testkit;

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// Pool under a chaos plan; retry budget sized so a ~5% transient rate
/// cannot plausibly exhaust it (each retry redraws an independent
/// decision index).
fn chaos_pool(seed: u64, shards: usize, spec: &FaultSpec) -> ServicePool {
    let mut cfg = PoolConfig::new(PlatformId::A100, seed, shards);
    cfg.fault = Some(spec.clone());
    cfg.ingress.max_retries = 12;
    ServicePool::spawn(cfg)
}

#[test]
fn prop_chaos_recovery_is_bit_identical_across_shard_counts() {
    // The tentpole invariant: under transient faults AND forced worker
    // kills, every completed reply equals the fault-free stream — for
    // shard counts {1, 2, 4}, arbitrary request sizes, and arbitrary
    // plan seeds. Offsets are assigned before routing, so the dedicated
    // engine skipped to the request's global offset is the oracle.
    testkit::forall("chaos-recovery-exact", 6, |g| {
        let pool_seed = g.u64();
        let plan_seed = g.range(1, 1 << 20);
        let n_req = g.usize_in(6, 16);
        let sizes: Vec<usize> = (0..n_req).map(|_| g.usize_in(1, 600)).collect();
        for shards in [1usize, 2, 4] {
            // Kill shard 0 early in every topology; with >= 2 batched
            // shards schedule a second kill so respawn handling is
            // exercised concurrently with live shards.
            let kills =
                if shards >= 2 { "kill=0@2+1@4".to_string() } else { "kill=0@2".to_string() };
            let spec = FaultSpec::parse(&format!(
                "seed={plan_seed},rate=0.05,sites=generate+submit+d2h,{kills}"
            ))
            .map_err(|e| e.to_string())?;
            let mut cfg = PoolConfig::new(PlatformId::A100, pool_seed, shards);
            cfg.fault = Some(spec.clone());
            cfg.ingress.max_retries = 12;
            // Pin routing so every request stays on the batched lane: the
            // kill schedule targets batched shards, which must therefore
            // see real message traffic in every topology.
            cfg.policy = DispatchPolicy::fixed(800);
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx
                    .recv_timeout(RECV_TIMEOUT)
                    .map_err(|_| format!("caller hung ({shards} shards, n={n})"))?
                    .map_err(|e| format!("typed error under light chaos: {e}"))?;
                let mut engine = PhiloxEngine::new(pool_seed);
                engine.skip_ahead(offset);
                let mut want = vec![0f32; n];
                engine.fill_uniform_f32(&mut want);
                if got != want {
                    return Err(format!(
                        "reply diverged at offset {offset} ({shards} shards, n={n})"
                    ));
                }
                offset += n as u64;
            }
            let stats = pool.shutdown().map_err(|e| e.to_string())?;
            if stats.lost_shards != 0 {
                return Err(format!(
                    "{} shard(s) still dead at shutdown despite supervision",
                    stats.lost_shards
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn exhausted_retries_surface_a_typed_injected_error() {
    // rate=1.0 on the generate seam: every attempt fails, so after the
    // retry budget every caller must hold Err(Injected) — promptly, not
    // after a hang, and the pool must still shut down cleanly.
    let spec = FaultSpec::parse("seed=3,rate=1.0,sites=generate").unwrap();
    let mut cfg = PoolConfig::new(PlatformId::A100, 0xDEAD, 2);
    cfg.fault = Some(spec);
    cfg.ingress.max_retries = 2;
    let pool = ServicePool::spawn(cfg);
    let rxs: Vec<_> = (0..6).map(|i| pool.generate(64 + 8 * i, (0.0, 1.0))).collect();
    pool.flush();
    for rx in rxs {
        let reply = rx.recv_timeout(RECV_TIMEOUT).expect("caller hung on a permanent fault");
        match reply {
            Err(Error::Injected { site }) => assert_eq!(site, "generate"),
            other => panic!("want Err(Injected) after retry exhaustion, got {other:?}"),
        }
    }
    let snap = pool.telemetry().snapshot();
    let res = snap.resilience_totals();
    assert!(res.faults_injected > 0, "permanent plan injected nothing");
    assert!(res.requests_retried > 0, "exhaustion path must pass through the retry loop");
    pool.shutdown().unwrap();
}

#[test]
fn zero_rate_plan_with_no_kills_is_inert() {
    // A configured-but-empty plan must not perturb output or counters:
    // the fault layer's presence alone is free.
    let spec = FaultSpec::parse("seed=1,rate=0.0").unwrap();
    let clean = PoolConfig::new(PlatformId::A100, 0xBEEF, 2);
    let pool_clean = ServicePool::spawn(clean);
    let pool_chaos = chaos_pool(0xBEEF, 2, &spec);
    let drain = |pool: &ServicePool| -> Vec<Vec<f32>> {
        let rxs: Vec<_> = (0..8).map(|i| pool.generate(100 + 10 * i, (0.0, 1.0))).collect();
        pool.flush();
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(RECV_TIMEOUT).unwrap().unwrap())
            .collect()
    };
    let a = drain(&pool_clean);
    let b = drain(&pool_chaos);
    assert_eq!(a, b, "an all-zero plan changed the output stream");
    let res = pool_chaos.telemetry().snapshot().resilience_totals();
    assert!(!res.any(), "an all-zero plan moved resilience counters: {res:?}");
    pool_clean.shutdown().unwrap();
    pool_chaos.shutdown().unwrap();
}
