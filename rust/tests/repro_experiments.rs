//! Experiment drivers (quick mode): every table/figure regenerates and its
//! headline shape matches the paper (the quantitative check behind
//! EXPERIMENTS.md).

use portarng::repro::{fig2, fig3, fig4, fig5, table1, table2, ResultTable};

fn get_f(t: &ResultTable, filters: &[(&str, &str)], col: &str) -> f64 {
    let idx: Vec<usize> = filters
        .iter()
        .map(|(c, _)| t.headers.iter().position(|h| h == c).unwrap())
        .collect();
    let gi = t.headers.iter().position(|h| h == col).unwrap_or_else(|| panic!("col {col}"));
    let row = t
        .rows
        .iter()
        .find(|r| idx.iter().zip(filters).all(|(&i, (_, v))| r[i] == *v))
        .unwrap_or_else(|| panic!("row {filters:?}"));
    row[gi].parse().unwrap()
}

#[test]
fn table1_has_six_platforms_and_versions() {
    let t = table1();
    assert_eq!(t.rows.len(), 6);
    let md = t.to_markdown();
    for needle in ["cuRAND", "hipRAND", "oneMKL", "hipSYCL", "DPC++"] {
        assert!(md.contains(needle), "missing {needle}");
    }
}

#[test]
fn fig2_buffer_usm_parity_and_monotone_growth() {
    let tables = fig2(true).unwrap();
    let t = &tables[0];
    assert_eq!(t.rows.len(), 3 * 2 * 9);
    // Parity at every point on CPUs/iGPU.
    for p in ["rome7742", "i7-10875h", "uhd630"] {
        for batch in ["1", "10000", "100000000"] {
            let b = get_f(t, &[("platform", p), ("api", "sycl-buffer"), ("batch", batch)], "mean_ms");
            let u = get_f(t, &[("platform", p), ("api", "sycl-usm"), ("batch", batch)], "mean_ms");
            assert!((u / b - 1.0).abs() < 0.3, "{p}@{batch}: {b} vs {u}");
        }
        // Growth from 1 to 1e8.
        let small = get_f(t, &[("platform", p), ("api", "sycl-buffer"), ("batch", "1")], "mean_ms");
        let large = get_f(
            t,
            &[("platform", p), ("api", "sycl-buffer"), ("batch", "100000000")],
            "mean_ms",
        );
        assert!(large > small * 20.0, "{p}: {small} -> {large}");
    }
}

#[test]
fn fig3_native_vs_sycl_shapes() {
    let tables = fig3(true).unwrap();
    let t = &tables[0];
    // Vega: SYCL USM at/below native at small batch; converged at 1e8.
    let nat = get_f(t, &[("platform", "vega56"), ("api", "native"), ("batch", "100")], "mean_ms");
    let usm = get_f(t, &[("platform", "vega56"), ("api", "sycl-usm"), ("batch", "100")], "mean_ms");
    assert!(usm < nat * 1.02, "vega small: usm {usm} vs native {nat}");
    // A100: USM penalty at small batch.
    let nat = get_f(t, &[("platform", "a100"), ("api", "native"), ("batch", "100")], "mean_ms");
    let usm = get_f(t, &[("platform", "a100"), ("api", "sycl-usm"), ("batch", "100")], "mean_ms");
    assert!(usm > nat * 2.0, "a100 small: usm {usm} vs native {nat}");
    // Everything converges at 1e8 (within 25%).
    for p in ["vega56", "a100"] {
        let nat = get_f(t, &[("platform", p), ("api", "native"), ("batch", "100000000")], "mean_ms");
        for api in ["sycl-buffer", "sycl-usm"] {
            let s = get_f(t, &[("platform", p), ("api", api), ("batch", "100000000")], "mean_ms");
            assert!((s / nat - 1.0).abs() < 0.25, "{p}/{api}@1e8: {s} vs {nat}");
        }
    }
}

#[test]
fn fig4_durations_equal_occupancy_diverges() {
    let tables = fig4(true).unwrap();
    let (dur, occ) = (&tables[0], &tables[1]);
    // Generate-kernel duration native vs sycl-buffer statistically equal.
    for batch in ["10000", "100000000"] {
        let n = get_f(dur, &[("api", "native"), ("batch", batch)], "generate_ms");
        let s = get_f(dur, &[("api", "sycl-buffer"), ("batch", batch)], "generate_ms");
        assert!((s / n - 1.0).abs() < 0.35, "batch {batch}: {n} vs {s}");
    }
    // Occupancy: tpb 256 vs 1024 and the 10^2-10^4 divergence.
    let tn = get_f(occ, &[("api", "native"), ("batch", "10000")], "tpb");
    let ts = get_f(occ, &[("api", "sycl-buffer"), ("batch", "10000")], "tpb");
    assert_eq!(tn as u32, 256);
    assert_eq!(ts as u32, 1024);
    let on = get_f(occ, &[("api", "native"), ("batch", "10000")], "generate_occupancy");
    let os = get_f(occ, &[("api", "sycl-buffer"), ("batch", "10000")], "generate_occupancy");
    assert!(os > on, "occupancy {os} !> {on}");
    // Saturated at 1e8 for both.
    let on8 = get_f(occ, &[("api", "native"), ("batch", "100000000")], "generate_occupancy");
    assert!(on8 > 0.95);
}

#[test]
fn table2_matches_paper_within_tolerance() {
    let tables = table2(true).unwrap();
    let t = &tables[0];
    let check = |h: &str, col: &str, want: f64, tol: f64| {
        let got = get_f(t, &[("H", h)], col);
        assert!(
            (got - want).abs() <= tol,
            "{h}/{col}: got {got}, paper {want} (tol {tol})"
        );
    };
    // Paper Table 2 values with calibration tolerance.
    check("{Vega 56}", "P_buffer", 0.974, 0.05);
    check("{Vega 56}", "P_usm", 1.076, 0.08);
    check("{A100}", "P_buffer", 1.186, 0.08);
    check("{A100}", "P_usm", 0.240, 0.06);
    check("{Vega 56, A100}", "P_buffer", 1.070, 0.06);
    check("{Vega 56, A100}", "P_usm", 0.393, 0.06);
}

#[test]
fn fig5_gpu_cpu_and_workload_shapes() {
    let tables = fig5(true).unwrap();
    let t = &tables[0];
    // No native row for the Radeon.
    assert!(t
        .rows
        .iter()
        .all(|r| !(r[1] == "vega56" && r[2] == "native")));
    // single-e: ~80% reduction GPU vs CPU (sycl rows).
    let cpu = get_f(t, &[("workload", "single-e"), ("platform", "rome7742"), ("api", "sycl")], "mean_s");
    let gpu = get_f(t, &[("workload", "single-e"), ("platform", "a100"), ("api", "sycl")], "mean_s");
    let reduction = 1.0 - gpu / cpu;
    assert!((0.55..0.95).contains(&reduction), "reduction {reduction}");
    // ttbar slower per event than single-e on every platform.
    let se = get_f(t, &[("workload", "single-e"), ("platform", "a100"), ("api", "sycl")], "mean_s");
    let tt = get_f(t, &[("workload", "ttbar"), ("platform", "a100"), ("api", "sycl")], "mean_s");
    assert!(tt > se, "ttbar {tt} !> single-e {se} (different event counts still hold)");
    // SYCL ≈ native on A100 for both workloads.
    for w in ["single-e", "ttbar"] {
        let n = get_f(t, &[("workload", w), ("platform", "a100"), ("api", "native")], "mean_s");
        let s = get_f(t, &[("workload", w), ("platform", "a100"), ("api", "sycl")], "mean_s");
        assert!((s / n - 1.0).abs() < 0.3, "{w}: sycl {s} vs native {n}");
    }
}
