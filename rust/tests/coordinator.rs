//! Coordinator integration: service batching invariants, pool stream
//! equivalence, registry dispatch, heuristic selection.

use portarng::coordinator::{
    BackendHeuristic, BackendRegistry, DispatchPolicy, PoolConfig, RngService, Route,
    ServicePool, TuningParams,
};
use portarng::platform::PlatformId;
use portarng::rng::{Engine, PhiloxEngine};
use portarng::testkit;

#[test]
fn prop_batched_service_equals_dedicated_stream() {
    // The fundamental batching invariant: any sequence of requests, any
    // batching thresholds — concatenated replies equal one dedicated
    // Philox stream.
    testkit::forall("service-stream-exact", 12, |g| {
        let seed = g.u64();
        let max_batch = g.usize_in(64, 4096);
        let max_requests = g.usize_in(1, 8);
        let svc = RngService::spawn(PlatformId::A100, seed, max_batch, max_requests);
        let n_req = g.usize_in(1, 12);
        let sizes: Vec<usize> = (0..n_req).map(|_| g.usize_in(1, 700)).collect();
        // Sizes multiples of 4 keep the padded launch == payload so the
        // dedicated stream lines up exactly.
        let sizes: Vec<usize> = sizes.iter().map(|s| s.div_ceil(4) * 4).collect();
        let rxs: Vec<_> = sizes.iter().map(|&n| svc.generate(n, (0.0, 1.0))).collect();
        svc.flush();
        let mut got = Vec::new();
        for rx in rxs {
            got.extend(rx.recv().map_err(|e| e.to_string())?.map_err(|e| e.to_string())?);
        }
        let mut want = vec![0f32; got.len()];
        PhiloxEngine::new(seed).fill_uniform_f32(&mut want);
        if got != want {
            return Err(format!("stream mismatch ({} numbers)", got.len()));
        }
        svc.shutdown().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_pooled_batched_output_is_bit_identical_to_dedicated_engines() {
    // The pool-wide invariant for shard counts {1, 2, 8} and mixed request
    // sizes: every reply equals a dedicated engine skipped to the
    // request's global offset, and the in-order concatenation equals one
    // contiguous stream — independent of batching thresholds, padding and
    // the size-aware overflow lane.
    testkit::forall("pool-stream-exact", 6, |g| {
        let seed = g.u64();
        let n_req = g.usize_in(3, 14);
        // Mixed sizes: mostly small, occasionally large enough to trip the
        // overflow threshold; deliberately not multiples of 4.
        let sizes: Vec<usize> = (0..n_req)
            .map(|_| {
                if g.bool_with(0.25) {
                    g.usize_in(800, 3000)
                } else {
                    g.usize_in(1, 500)
                }
            })
            .collect();
        let max_batch = g.usize_in(64, 4096);
        let max_requests = g.usize_in(1, 6);
        // The tile executor must be invisible in the payloads: any
        // (shard count, tile size, team width) — including serial —
        // reproduces the 1-shard serial baseline bit for bit.
        let tiling = if g.bool_with(0.5) {
            Some((*g.choose(&[64usize, 333, 1024]), g.usize_in(2, 4)))
        } else {
            None
        };
        for shards in [1usize, 2, 8] {
            let mut cfg = PoolConfig::new(PlatformId::A100, seed, shards);
            cfg.max_batch = max_batch;
            cfg.max_requests = max_requests;
            cfg.policy = DispatchPolicy::fixed(800);
            cfg.tiling = tiling;
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            let mut concat = Vec::new();
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx
                    .recv()
                    .map_err(|e| e.to_string())?
                    .map_err(|e| e.to_string())?;
                let mut want = vec![0f32; n];
                PhiloxEngine::with_offset(seed, offset).fill_uniform_f32(&mut want);
                if got != want {
                    return Err(format!(
                        "shards={shards}: request at offset {offset} (n={n}) diverged"
                    ));
                }
                offset += n as u64;
                concat.extend(got);
            }
            let mut whole = vec![0f32; concat.len()];
            PhiloxEngine::new(seed).fill_uniform_f32(&mut whole);
            if concat != whole {
                return Err(format!("shards={shards}: concatenation != dedicated stream"));
            }
            let stats = pool.shutdown().map_err(|e| e.to_string())?;
            if stats.total().requests != sizes.len() as u64 {
                return Err("request count mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_retuning_mid_stream_preserves_global_offset_invariant() {
    // The retune-safety property: global offsets are assigned before
    // routing, so ANY interleaving of threshold/flush retunes with
    // submissions yields bit-identical per-request streams.
    testkit::forall("retune-stream-exact", 8, |g| {
        let seed = g.u64();
        let n_req = g.usize_in(6, 16);
        let sizes: Vec<usize> = (0..n_req)
            .map(|_| if g.bool_with(0.3) { g.usize_in(800, 3000) } else { g.usize_in(1, 500) })
            .collect();
        let mut cfg = PoolConfig::new(PlatformId::A100, seed, g.usize_in(1, 4));
        cfg.max_requests = g.usize_in(1, 6);
        cfg.adaptive = true; // overflow lane exists from the start
        let pool = ServicePool::spawn(cfg);
        let mut rxs = Vec::new();
        for &n in &sizes {
            // Retune mid-stream, randomly: flip the threshold around,
            // jiggle the flush limits, and toggle the tile executor on
            // and off between submissions — live executor retunes must
            // not move a single bit either.
            if g.bool_with(0.4) {
                pool.retune(TuningParams {
                    threshold: *g.choose(&[0usize, 100, 800, 2000, usize::MAX]),
                    flush_requests: g.usize_in(1, 8),
                    max_batch: g.usize_in(256, 1 << 16),
                    tile_size: *g.choose(&[0usize, 0, 64, 333, 1024]),
                    team_width: g.usize_in(1, 4),
                });
            }
            rxs.push(pool.generate(n, (0.0, 1.0)));
        }
        pool.flush();
        let mut offset = 0u64;
        for (rx, &n) in rxs.iter().zip(&sizes) {
            let got = rx.recv().map_err(|e| e.to_string())?.map_err(|e| e.to_string())?;
            let mut want = vec![0f32; n];
            PhiloxEngine::with_offset(seed, offset).fill_uniform_f32(&mut want);
            if got != want {
                return Err(format!("request at offset {offset} (n={n}) diverged under retune"));
            }
            offset += n as u64;
        }
        let stats = pool.shutdown().map_err(|e| e.to_string())?;
        if stats.total().requests != sizes.len() as u64 {
            return Err("request count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sycl_serving_path_is_bit_exact_across_waves_and_arena_reuse() {
    // The S13 invariant on the serve-through-SYCL path: flushes run as
    // one DAG submission into recycled arena USM, in several waves so
    // allocations are actually reused — and every reply is still the
    // bit-exact sub-stream of a dedicated engine at the request's global
    // offset, for random shard counts, flush limits, sizes, ranges and
    // overflow policies.
    testkit::forall("sycl-serve-exact", 8, |g| {
        let seed = g.u64();
        let platform =
            *g.choose(&[PlatformId::A100, PlatformId::Vega56, PlatformId::Rome7742]);
        let mut cfg = PoolConfig::new(platform, seed, g.usize_in(1, 5));
        cfg.max_requests = g.usize_in(1, 6);
        cfg.max_batch = g.usize_in(64, 8192);
        if g.bool_with(0.5) {
            cfg.policy = DispatchPolicy::fixed(g.usize_in(400, 2000));
        }
        let pool = ServicePool::spawn(cfg);
        let mut offset = 0u64;
        let waves = g.usize_in(2, 4);
        for _ in 0..waves {
            let specs: Vec<(usize, (f32, f32))> = (0..g.usize_in(2, 10))
                .map(|_| {
                    let n = if g.bool_with(0.2) {
                        g.usize_in(800, 3000)
                    } else {
                        g.usize_in(1, 400)
                    };
                    let range = *g.choose(&[(0.0f32, 1.0f32), (-1.0, 1.0), (3.0, 7.5)]);
                    (n, range)
                })
                .collect();
            let rxs: Vec<_> =
                specs.iter().map(|&(n, range)| pool.generate(n, range)).collect();
            pool.flush();
            for (rx, &(n, range)) in rxs.iter().zip(&specs) {
                let got =
                    rx.recv().map_err(|e| e.to_string())?.map_err(|e| e.to_string())?;
                let mut want = vec![0f32; n];
                PhiloxEngine::with_offset(seed, offset).fill_uniform_f32(&mut want);
                if range != (0.0, 1.0) {
                    portarng::rng::range_transform_inplace(&mut want, range.0, range.1);
                }
                if got != want {
                    return Err(format!(
                        "reply at offset {offset} (n={n}, range {range:?}) diverged"
                    ));
                }
                offset += n as u64;
            }
        }
        // Submission shape held across every wave: exactly one generate
        // host task per launch, one D2H slice per request, and the waves
        // after the first reused arena allocations.
        let snap = pool.telemetry().snapshot();
        let k = snap.command_breakdown();
        if k.generate.cmds != snap.total_launches() {
            return Err(format!(
                "{} generate tasks for {} launches",
                k.generate.cmds,
                snap.total_launches()
            ));
        }
        if k.d2h.cmds != snap.total_requests() {
            return Err(format!(
                "{} D2H slices for {} requests",
                k.d2h.cmds,
                snap.total_requests()
            ));
        }
        let a = snap.arena_totals();
        if a.checkouts != snap.total_launches() {
            return Err("every flush must go through the arena".into());
        }
        pool.shutdown().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn pool_replies_match_the_buffer_api_generate_path() {
    // Buffer-vs-USM parity at the serving layer: a pooled reply (the USM
    // batch path through arena memory) is bit-identical to the buffer-API
    // generate flow at the same engine offset and range.
    use portarng::backends::RngBackend;
    use portarng::sycl::{Buffer, Queue, SyclRuntimeProfile};

    let (seed, n) = (99u64, 1000usize);
    let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, seed, 2));
    let rx = pool.generate(n, (2.0, 4.0));
    pool.flush();
    let pooled = rx.recv().unwrap().unwrap();
    pool.shutdown().unwrap();

    let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
    let backend = portarng::backends::CurandBackend::new();
    let mut gen = backend
        .create_generator(portarng::rng::EngineKind::Philox4x32x10, seed)
        .unwrap();
    let buf = Buffer::<f32>::new(n);
    portarng::rng::generate_buffer(
        &queue,
        &mut gen,
        portarng::rng::Distribution::uniform(2.0, 4.0),
        n,
        &buf,
    )
    .unwrap();
    assert_eq!(pooled, queue.host_read(&buf));
}

#[test]
fn dispatch_policy_edge_cases_route_as_documented() {
    // n == threshold goes to the overflow lane.
    let at = DispatchPolicy::fixed(4096);
    assert_eq!(at.route(4095), Route::Batched);
    assert_eq!(at.route(4096), Route::Overflow);
    // disabled() never overflows, even at usize::MAX.
    let off = DispatchPolicy::disabled();
    for n in [0usize, 1, 4096, usize::MAX - 1, usize::MAX] {
        assert_eq!(off.route(n), Route::Batched, "n={n}");
    }
    // threshold == 0 sends everything to the overflow lane.
    let zero = DispatchPolicy::fixed(0);
    assert!(zero.is_enabled());
    for n in [0usize, 1, 17, 1 << 20] {
        assert_eq!(zero.route(n), Route::Overflow, "n={n}");
    }
}

#[test]
fn threshold_zero_pool_serves_everything_on_the_overflow_lane() {
    let mut cfg = PoolConfig::new(PlatformId::A100, 21, 2);
    cfg.policy = DispatchPolicy::fixed(0);
    let pool = ServicePool::spawn(cfg);
    let sizes = [7usize, 123, 4000];
    let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
    // No flush needed: the overflow lane is unbatched.
    let mut offset = 0u64;
    for (rx, &n) in rxs.iter().zip(&sizes) {
        let got = rx.recv().unwrap().unwrap();
        let mut want = vec![0f32; n];
        PhiloxEngine::with_offset(21, offset).fill_uniform_f32(&mut want);
        assert_eq!(got, want);
        offset += n as u64;
    }
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.shards.len(), 3); // 2 batched (idle) + overflow
    assert_eq!(stats.shards[2].requests, 3);
    assert_eq!(stats.shards[0].requests + stats.shards[1].requests, 0);
}

#[test]
fn pool_shutdown_flushes_pending_requests_on_every_shard() {
    let mut cfg = PoolConfig::new(PlatformId::Vega56, 11, 3);
    cfg.max_requests = 1000; // nothing closes a batch before shutdown
    let pool = ServicePool::spawn(cfg);
    let rxs: Vec<_> = (0..9).map(|_| pool.generate(33, (0.0, 1.0))).collect();
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.total().requests, 9);
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
}

#[test]
fn service_counts_launches_not_requests() {
    let svc = RngService::spawn(PlatformId::Vega56, 1, 1 << 20, 4);
    for _ in 0..8 {
        let _ = svc.generate(100, (0.0, 1.0));
    }
    svc.flush();
    let stats = svc.shutdown().unwrap();
    assert_eq!(stats.requests, 8);
    assert_eq!(stats.launches, 2); // 8 requests / max_requests=4
}

#[test]
fn registry_round_trip_all_platforms() {
    let reg = BackendRegistry::new();
    for p in PlatformId::ALL {
        let backend = reg.native_for(p);
        let mut gen = backend
            .create_generator(portarng::rng::EngineKind::Philox4x32x10, 3)
            .unwrap();
        let mut out = vec![0f32; 64];
        gen.generate_canonical(&portarng::rng::Distribution::uniform(0.0, 1.0), &mut out)
            .unwrap();
        assert!(out.iter().all(|&x| (0.0..1.0).contains(&x)), "{p:?}");
    }
}

#[test]
fn heuristic_crossovers_ordered_by_device_overheads() {
    let a100 = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
    let vega = BackendHeuristic::calibrate(PlatformId::Vega56, PlatformId::XeonGold5220);
    // Both GPUs need enough work to amortise launch+runtime overheads.
    for h in [&a100, &vega] {
        assert!(h.crossover > 1_000, "crossover {}", h.crossover);
        assert!(h.crossover < 100_000_000, "crossover {}", h.crossover);
    }
}

#[test]
fn heuristic_never_worse_than_worst_fixed_choice() {
    use portarng::burner::{run_burner_virtual, BurnerApi, BurnerConfig};
    let h = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
    for batch in [10usize, 10_000, 1_000_000, 100_000_000] {
        let t = |p: PlatformId| {
            let mut c = BurnerConfig::paper_default(p, BurnerApi::SyclBuffer, batch);
            c.iterations = 3;
            let r = run_burner_virtual(&c).unwrap();
            r.mean_total_ns() - r.breakdown.d2h_ns as f64
        };
        let host = t(PlatformId::Rome7742);
        let device = t(PlatformId::A100);
        let picked = t(h.select(batch));
        assert!(
            picked <= host.max(device) * 1.05,
            "batch {batch}: picked {picked} vs {host}/{device}"
        );
    }
}
